"""Inject the generated roofline tables into EXPERIMENTS.md between the
<!-- BEGIN:<mesh> --> / <!-- END:<mesh> --> markers (idempotent).

    PYTHONPATH=src python -m benchmarks.update_experiments
"""
from __future__ import annotations

import os
import re

from benchmarks.roofline_report import load, markdown_table

ROOT = os.path.join(os.path.dirname(__file__), "..")


def summarize(mesh: str) -> str:
    recs = load(mesh)
    if not recs:
        return f"_(no dry-run records for {mesh} yet)_"
    bott = {}
    for r in recs:
        b = r["roofline"]["bottleneck"]
        bott[b] = bott.get(b, 0) + 1
    head = (f"{len(recs)} combos compiled on `{mesh}`; bottleneck mix: "
            + ", ".join(f"{k}={v}" for k, v in sorted(bott.items())) + ".\n\n")
    return head + markdown_table(mesh)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    for mesh in ("pod", "multipod", "pod_opt"):
        begin, end = f"<!-- BEGIN:{mesh} -->", f"<!-- END:{mesh} -->"
        if begin in text and end in text:
            pat = re.escape(begin) + r".*?" + re.escape(end)
            text = re.sub(pat, begin + "\n" + summarize(mesh) + "\n" + end,
                          text, flags=re.S)
    with open(path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
