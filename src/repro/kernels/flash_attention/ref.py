"""Oracle for the flash kernel: the pure-jnp chunked implementation (which
tests verify against the naive quadratic reference), re-exported with the
kernel's exact signature."""
from __future__ import annotations

from repro.models.attention import chunked_attention, naive_attention


def flash_attention_ref(q, k, v, *, window=None):
    return chunked_attention(q, k, v, window=window, q_chunk=64, kv_chunk=64)


def flash_attention_naive(q, k, v, *, window=None):
    return naive_attention(q, k, v, window=window)
