"""Quickstart: the paper's algorithm in both of its homes.

1. Convex (paper-faithful): CentralVR vs SGD on l2-regularized logistic
   regression — linear convergence with a CONSTANT step size.
2. LM (framework): a tiny decoder trained with the CentralVR optimizer.

    python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))
import repro_bootstrap  # noqa: F401,E402  (adds src/ if repro isn't installed)

import jax

from repro.config import ConvexConfig, TrainConfig, get_arch
from repro.core import baselines, centralvr, convex
from repro.train import loop


def convex_demo():
    print("=== CentralVR on logistic regression (paper §6.1 toy) ===")
    cfg = ConvexConfig(problem="logistic", n=2000, d=20)
    prob = convex.make_problem(jax.random.PRNGKey(0), cfg)
    _, rels_cvr, evals = centralvr.run(prob, eta=0.2, epochs=12,
                                       key=jax.random.PRNGKey(1))
    _, rels_sgd = baselines.run_sgd(prob, eta=0.2, epochs=12,
                                    key=jax.random.PRNGKey(1))
    print(f"{'epoch':>6} {'CentralVR':>12} {'SGD':>12}")
    for e in range(0, 12, 3):
        print(f"{e:6d} {rels_cvr[e]:12.2e} {rels_sgd[e]:12.2e}")
    print(f"final: CentralVR {rels_cvr[-1]:.2e} vs SGD {rels_sgd[-1]:.2e} "
          f"(same constant step, same gradient budget)\n")


def lm_demo():
    print("=== CentralVR as the optimizer of a tiny LM ===")
    cfg = get_arch("qwen2-7b").reduced()
    tcfg = TrainConfig(seq_len=64, global_batch=4, microbatch=2,
                       optimizer="sgd", learning_rate=0.2,
                       vr="centralvr", vr_table_size=4)
    res = loop.run_training(cfg, tcfg, steps=20, log_every=5)
    print(f"eval loss after 20 steps: {res.final_eval_loss:.3f}\n")


if __name__ == "__main__":
    convex_demo()
    lm_demo()
