"""Telemetry record tooling: render or validate ``repro.obs`` JSONL runs.

    python -m repro.launch.obs report run.jsonl
    python -m repro.launch.obs report run.jsonl --json summary.json
    python -m repro.launch.obs validate run.jsonl other.jsonl ...

``report`` prints the span timeline (with the lower/compile/warm phase
split), streamed-metric summaries, and the notable events (provenance,
comms_hlo) of one run record.  ``validate`` checks every row of every
file against the v1 schema and exits nonzero on the first violation —
the CI telemetry-smoke lane gates on it.  Neither command imports jax.
"""
from __future__ import annotations

import argparse
import json


def parse_args(argv=None):
    ap = argparse.ArgumentParser(
        description="Inspect repro.obs JSONL run records.")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rp = sub.add_parser("report", help="render one record as a "
                                       "timeline/summary")
    rp.add_argument("path", help="JSONL run record")
    rp.add_argument("--json", default="",
                    help="also write the structured summary to this path")
    vp = sub.add_parser("validate", help="schema-check records, exit 1 "
                                         "on violation")
    vp.add_argument("paths", nargs="+", help="JSONL run records")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)
    from repro.obs import report, schema

    if args.cmd == "validate":
        for path in args.paths:
            try:
                n = schema.validate_file(path)
            except (OSError, schema.SchemaError, ValueError) as e:
                print(f"FAIL {path}: {e}")
                return 1
            print(f"ok   {path}: {n} rows")
        return 0

    rows = schema.load_rows(args.path)
    schema.validate_rows(rows)
    print(report.render(rows))
    if args.json:
        s = report.summarize(rows)
        with open(args.json, "w") as f:
            json.dump(s, f, indent=1)
        print(f"\nwrote summary to {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
