"""repro: a multi-pod JAX training/inference framework implementing
"Efficient Distributed SGD with Variance Reduction" (De & Goldstein, 2015)
as a first-class distributed-optimizer feature.

The solver API (DESIGN.md §Solver API) is re-exported here lazily:

    import repro
    res = repro.solve(repro.RunSpec(algo="centralvr_sync", p=4), cfg)

Laziness matters: ``import repro`` must not import jax, so scripts can
call ``repro.core.spmd.force_host_devices`` (which must precede the first
jax operation) after importing this package.
"""
__version__ = "1.1.0"

_SOLVER_EXPORTS = ("solve", "RunSpec", "RunResult", "AlgoCaps",
                   "REGISTRY", "algorithms", "runner")

__all__ = list(_SOLVER_EXPORTS) + ["__version__"]


def __getattr__(name):
    if name in _SOLVER_EXPORTS:
        from repro.core import solver
        return getattr(solver, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
