"""SPMD execution-backend pins (DESIGN.md §2, ``core/spmd.py``).

The heavy comparisons run in a SUBPROCESS with 8 forced host devices (the
main pytest process must keep the real single-device view — see conftest):
spmd trajectories must match the event-equivalent vmap driver within
float32 tolerance for p ∈ {2, 4} on both toy problems — the synchronous
drivers AND the async drivers (CentralVR-Async against the event-serial
staleness scan, D-SAGA against its ``fetch="stale"`` event-serial
reference), round-robin and heterogeneous-speed schedules alike — and
each worker's table shard must be resident on its own device.  Cheap
contract checks (backend validation, instant-fetch D-SAGA refusing spmd,
the shared host-device helper) run in-process.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

# float32 tolerance: identical arithmetic and identical (host-precomputed)
# RNG draws on both backends; only collective reduction order differs
TOL = 3e-5

SCRIPT = textwrap.dedent("""
    import sys
    sys.path.insert(0, "src")
    from repro.core import spmd
    spmd.force_host_devices(8)      # before the first jax operation
    import json
    import jax
    import numpy as np
    from repro.config import ConvexConfig
    from repro.core import baselines, centralvr, convex, distributed

    def diff(a, b):
        return float(np.abs(np.asarray(a) - np.asarray(b)).max())

    out = {"device_count": jax.device_count(), "drivers": []}
    key = jax.random.PRNGKey(4)
    for p in (2, 4):
        for kind in ("logistic", "ridge"):
            cfg = ConvexConfig(problem=kind, n=48, d=8, workers=p)
            sp = distributed.make_distributed(jax.random.PRNGKey(0), cfg)
            eta = convex.auto_eta(sp.merged(), 0.3)
            st_v, rels_v = distributed.run_sync(sp, eta=eta, rounds=4,
                                                key=key)
            st_s, rels_s = distributed.run_sync(sp, eta=eta, rounds=4,
                                                key=key, backend="spmd")
            devs = sorted({str(s.device)
                           for s in st_s.tables.addressable_shards})
            xv, rv = distributed.run_dsvrg(sp, eta=eta, rounds=4, key=key,
                                           tau=32)
            xs, rs = distributed.run_dsvrg(sp, eta=eta, rounds=4, key=key,
                                           tau=32, backend="spmd")
            out["drivers"].append({
                "p": p, "kind": kind,
                "sync_drel": diff(rels_v, rels_s),
                "sync_dx": diff(st_v.x, st_s.x),
                "sync_shard_devices": devs,
                "dsvrg_drel": diff(rv, rs), "dsvrg_dx": diff(xv, xs),
            })

    # minibatch baselines, p=4 logistic
    cfg = ConvexConfig(problem="logistic", n=48, d=8, workers=4)
    sp = distributed.make_distributed(jax.random.PRNGKey(0), cfg)
    eta = convex.auto_eta(sp.merged(), 0.3)
    out["baselines"] = {}
    for name, kw in (("dist_sgd", dict(tau=24)), ("easgd", dict(tau=8)),
                     ("ps_svrg", dict(epoch_mult=1))):
        fn = getattr(baselines, "run_" + name)
        xv, rv = fn(sp, eta=eta / 2, rounds=3, key=key, **kw)
        xs, rs = fn(sp, eta=eta / 2, rounds=3, key=key, backend="spmd",
                    **kw)
        out["baselines"][name] = {"drel": diff(rv, rs), "dx": diff(xv, xs)}

    # async drivers as concurrency waves: spmd vs the event-serial vmap
    # reference (same schedule, same RNG, same delta algebra), round-robin
    # for p in {2, 4} x {logistic, ridge} plus heterogeneous-speed
    # schedules (speeds=[1,2,3] at p=3, speeds=[1,1,2,4] at p=4)
    out["async"] = []
    for p, speeds, kinds in ((2, None, ("logistic", "ridge")),
                             (4, None, ("logistic", "ridge")),
                             (3, (1.0, 2.0, 3.0), ("logistic",)),
                             (4, (1.0, 1.0, 2.0, 4.0), ("ridge",))):
        for kind in kinds:
            cfg = ConvexConfig(problem=kind, n=48, d=8, workers=p)
            sp = distributed.make_distributed(jax.random.PRNGKey(0), cfg)
            eta = convex.auto_eta(sp.merged(), 0.3)
            st_v, rv = distributed.run_async(sp, eta=eta, rounds=4, key=key,
                                             speeds=speeds)
            st_s, rs = distributed.run_async(sp, eta=eta, rounds=4, key=key,
                                             speeds=speeds, backend="spmd")
            dv, rdv = distributed.run_dsaga(sp, eta=eta / 2, rounds=4,
                                            key=key, tau=24, fetch="stale",
                                            speeds=speeds)
            ds, rds = distributed.run_dsaga(sp, eta=eta / 2, rounds=4,
                                            key=key, tau=24, speeds=speeds,
                                            backend="spmd")
            out["async"].append({
                "p": p, "kind": kind, "heterogeneous": speeds is not None,
                "async_drel": diff(rv, rs),
                "async_dx": diff(st_v.x_c, st_s.x_c),
                "async_shard_devices": sorted(
                    {str(s.device) for s in st_s.tables.addressable_shards}),
                "dsaga_drel": diff(rdv, rds),
                "dsaga_dx": diff(dv.x_c, ds.x_c),
                "dsaga_shard_devices": sorted(
                    {str(s.device) for s in ds.tables.addressable_shards}),
            })

    # Algorithm 1: spmd == execute on the mesh's first device
    prob = convex.make_logistic_data(jax.random.PRNGKey(1), 64, 8)
    eta1 = convex.auto_eta(prob, 0.3)
    _, r1, _ = centralvr.run(prob, eta=eta1, epochs=3, key=key)
    _, r2, _ = centralvr.run(prob, eta=eta1, epochs=3, key=key,
                             backend="spmd")
    out["centralvr_drel"] = diff(r1, r2)
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def results():
    proc = subprocess.run([sys.executable, "-c", SCRIPT], cwd=ROOT,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


@pytest.mark.slow
def test_simulated_devices_present(results):
    assert results["device_count"] >= 4


@pytest.mark.slow
@pytest.mark.parametrize("p", [2, 4])
@pytest.mark.parametrize("kind", ["logistic", "ridge"])
def test_spmd_matches_vmap(results, p, kind):
    row = [r for r in results["drivers"]
           if r["p"] == p and r["kind"] == kind][0]
    assert row["sync_drel"] < TOL, row
    assert row["sync_dx"] < TOL, row
    assert row["dsvrg_drel"] < TOL, row
    assert row["dsvrg_dx"] < TOL, row


@pytest.mark.slow
@pytest.mark.parametrize("p", [2, 4])
def test_worker_shards_on_distinct_devices(results, p):
    rows = [r for r in results["drivers"] if r["p"] == p]
    for row in rows:
        assert len(row["sync_shard_devices"]) == p, row


@pytest.mark.slow
def test_baselines_match_vmap(results):
    for name, row in results["baselines"].items():
        assert row["drel"] < TOL, (name, row)
        assert row["dx"] < TOL, (name, row)


@pytest.mark.slow
def test_centralvr_spmd_is_exact(results):
    # single worker: same executable on one device — bit-identical
    assert results["centralvr_drel"] == 0.0, results["centralvr_drel"]


@pytest.mark.slow
@pytest.mark.parametrize("p,kind", [(2, "logistic"), (2, "ridge"),
                                    (4, "logistic"), (4, "ridge")])
def test_async_spmd_matches_event_serial(results, p, kind):
    """CentralVR-Async and stale-fetch D-SAGA under the wave-parallel spmd
    backend vs their event-serial vmap references, round-robin."""
    row = [r for r in results["async"]
           if r["p"] == p and r["kind"] == kind
           and not r["heterogeneous"]][0]
    assert row["async_drel"] < TOL, row
    assert row["async_dx"] < TOL, row
    assert row["dsaga_drel"] < TOL, row
    assert row["dsaga_dx"] < TOL, row


@pytest.mark.slow
@pytest.mark.parametrize("p", [3, 4])
def test_async_spmd_matches_heterogeneous_schedule(results, p):
    """Heterogeneous speeds split rounds into several waves (a worker
    firing twice in a round forces a wave boundary); trajectories must
    still match the event-serial schedule."""
    row = [r for r in results["async"]
           if r["p"] == p and r["heterogeneous"]][0]
    assert row["async_drel"] < TOL, row
    assert row["async_dx"] < TOL, row
    assert row["dsaga_drel"] < TOL, row
    assert row["dsaga_dx"] < TOL, row


@pytest.mark.slow
@pytest.mark.parametrize("p", [2, 3, 4])
def test_async_worker_state_on_distinct_devices(results, p):
    for row in [r for r in results["async"] if r["p"] == p]:
        assert len(row["async_shard_devices"]) == p, row
        assert len(row["dsaga_shard_devices"]) == p, row


# ---------------------------------------------------------------------------
# In-process contract checks (no forced devices needed)
# ---------------------------------------------------------------------------

def _sharded(p=2):
    import jax

    from repro.config import ConvexConfig
    from repro.core import distributed

    cfg = ConvexConfig(problem="logistic", n=16, d=4, workers=p)
    return distributed.make_distributed(jax.random.PRNGKey(0), cfg)


def test_instant_fetch_dsaga_refuses_spmd():
    """Instant-fetch D-SAGA is a serial dependency chain between events —
    no worker-parallel program exists, so asking for one must error rather
    than silently running the stale-fetch construction."""
    import jax

    from repro.core import distributed

    sp = _sharded()
    key = jax.random.PRNGKey(0)
    with pytest.raises(NotImplementedError, match="event-serial"):
        distributed.run_dsaga(sp, eta=0.1, rounds=1, key=key,
                              backend="spmd", fetch="instant")
    with pytest.raises(ValueError, match="unknown fetch"):
        distributed.run_dsaga(sp, eta=0.1, rounds=1, key=key,
                              fetch="bogus")


def test_async_spmd_needs_devices():
    """run_async accepts backend="spmd" now; on a single-device process it
    must fail with the actionable device-count error, not the old
    event-serial NotImplementedError."""
    import jax

    from repro.core import distributed

    jax.device_count()              # initialize the single-device backend
    sp = _sharded(p=2)
    key = jax.random.PRNGKey(0)
    with pytest.raises(RuntimeError,
                       match="xla_force_host_platform_device_count"):
        distributed.run_async(sp, eta=0.1, rounds=1, key=key,
                              backend="spmd")


def test_unknown_backend_rejected():
    import jax

    from repro.core import baselines, distributed

    sp = _sharded()
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="unknown backend"):
        distributed.run_sync(sp, eta=0.1, rounds=1, key=key,
                             backend="bogus")
    with pytest.raises(ValueError, match="unknown backend"):
        baselines.run_dist_sgd(sp, eta=0.1, rounds=1, key=key,
                               backend="pmap")


def test_worker_mesh_error_names_the_flag():
    import jax  # noqa: F401  (initializes the backend)

    from repro.core import spmd

    jax.device_count()
    with pytest.raises(RuntimeError,
                       match="xla_force_host_platform_device_count"):
        spmd.worker_mesh(4096)


def test_force_host_devices_after_init():
    import jax

    from repro.core import spmd

    n = jax.device_count()          # initializes the backend
    spmd.force_host_devices(n)      # satisfied already: no-op
    with pytest.raises(RuntimeError, match="already initialized"):
        spmd.force_host_devices(n + 4096)


def test_bench_artifact_structure():
    """BENCH_spmd.json (written by benchmarks/spmd_scaling.py) reports warm
    epochs/sec per algorithm per backend per worker count — including the
    async rows the acceptance criteria name (CentralVR-Async on both
    backends, the spmd side running the wave construction)."""
    path = os.path.join(ROOT, "BENCH_spmd.json")
    assert os.path.exists(path), "run: python -m benchmarks.spmd_scaling"
    with open(path) as f:
        payload = json.load(f)
    rows = payload["rows"]
    for algo in ("sync", "async"):
        for backend in ("vmap", "spmd"):
            for p in (1, 2, 4):
                match = [r for r in rows
                         if r.get("algo") == algo
                         and r["backend"] == backend and r["p"] == p]
                assert match, (algo, backend, p)
                assert match[0]["epochs_per_s"] > 0, match[0]
