"""Fused SSD (Mamba2) chunk-scan kernel — Pallas, TPU target.

The SSD block is mamba2's entire compute; its chunked form is a sequence
of small dense ops per chunk (cumsum, two (Q,Q)/(Q,N) matmuls, decay
masks, state update) that XLA executes as ~10 separate HBM-visiting
fusions per chunk (the dominant memory term of the mamba2 rows in
§Roofline). This kernel fuses one (batch, head) chunk STEP into a single
VMEM-resident body and carries the (P, N) recurrent state in scratch
across the sequential chunk grid dimension — the same grid idiom as the
flash kernel (TPU grids execute the last dim in order).

Per-block working set (Q=64, N=128, P=64, f32):
    x (Q,P) + B,C (Q,N) + decay (Q,Q) + state (P,N) + y (Q,P)
    ~ (4096 + 2*8192 + 4096 + 8192 + 4096) * 4 B ~ 150 KiB  << VMEM.

Oracle: repro.models.ssm._ssd_chunked (itself verified against the naive
sequential recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(la_ref, x_ref, b_ref, c_ref, y_ref, h_scr, *, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    la = la_ref[0, 0].astype(jnp.float32)           # (Q,)
    x = x_ref[0, 0].astype(jnp.float32)             # (Q, P)
    B = b_ref[0, 0].astype(jnp.float32)             # (Q, N)
    C = c_ref[0, 0].astype(jnp.float32)             # (Q, N)
    Q = la.shape[0]

    L = jnp.cumsum(la)                              # (Q,)
    # intra-chunk dual form
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.exp(jnp.minimum(L[:, None] - L[None, :], 0.0))
    w = jnp.where(jj <= ii, scores * decay, 0.0)
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: y += exp(L) * (C @ h^T)
    h = h_scr[...]                                  # (P, N)
    y = y + jnp.exp(L)[:, None] * jax.lax.dot_general(
        C, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    # state update: h' = exp(tot) h + x^T @ (B * exp(tot - L))
    tot = L[Q - 1]
    dte = jnp.exp(tot - L)                          # (Q,)
    cs = jax.lax.dot_general(x, B * dte[:, None], (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (P, N)
    h_scr[...] = h * jnp.exp(tot) + cs
    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_scan(la, x, Bc, Cc, *, chunk: int, interpret: bool = False):
    """la: (BH, S) log-decay; x: (BH, S, P) discretized input;
    Bc, Cc: (BH_kv, S, N) with BH = B*H rows mapping to BH_kv = B rows
    (B/C shared across heads). Returns y: (BH, S, P).

    S must be a multiple of chunk (ops.py pads). Heads-share mapping:
    row bh of la/x uses row bh // H of Bc/Cc, with H = BH // BH_kv.
    """
    BH, S = la.shape
    P = x.shape[-1]
    N = Bc.shape[-1]
    Hgroup = BH // Bc.shape[0]
    assert S % chunk == 0
    nc = S // chunk

    la3 = la.reshape(BH, nc, chunk)
    x3 = x.reshape(BH, nc, chunk, P)
    b3 = Bc.reshape(Bc.shape[0], nc, chunk, N)
    c3 = Cc.reshape(Cc.shape[0], nc, chunk, N)

    fn = pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1, chunk, P), lambda bh, ci: (bh, ci, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda bh, ci: (bh // Hgroup, ci, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda bh, ci: (bh // Hgroup, ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P),
                               lambda bh, ci: (bh, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nc, chunk, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )
    y = fn(la3, x3, b3, c3)
    return y.reshape(BH, S, P)
