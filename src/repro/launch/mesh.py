"""Mesh construction. IMPORTANT: functions, never module-level constants —
importing this module must not touch jax device state (the dry-run forces a
512-device host platform and must do so before any jax initialization).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.config import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment: one v5e pod = (data=16, model=16) = 256 chips;
    two pods add a leading 'pod' axis = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axis_names)


def make_worker_mesh(p: int, *, simulate_host_devices: bool = False):
    """One CentralVR worker per device, for the convex spmd backend
    (``core/spmd.py``, DESIGN.md §2).  ``simulate_host_devices=True``
    forces the CPU host platform to present p devices through the shared
    ``spmd.force_host_devices`` helper — call it before the first jax
    operation (the helper errors once the backend is initialized)."""
    from jax._src import xla_bridge

    from repro.core import spmd

    if simulate_host_devices:
        spmd.force_host_devices(p)
        # force_host_devices validates against the GLOBAL device count,
        # which in a jax.distributed world can satisfy p while THIS
        # process holds fewer — worker_mesh would then build a mesh over
        # devices it cannot address and fail much later with an opaque
        # shard_map shape error.  Catch the mismatch here, with the
        # remediation options spelled out (DESIGN.md §2).
        if (xla_bridge.backends_are_initialized()
                and jax.local_device_count() < p):
            raise RuntimeError(
                f"make_worker_mesh(p={p}, simulate_host_devices=True): jax "
                f"is already initialized and this process has only "
                f"{jax.local_device_count()} local device(s) "
                f"(global count: {jax.device_count()}).  Simulated host "
                "devices must be configured before the first jax "
                "operation.  Either start a fresh process, call "
                "spmd.force_host_devices(p) before any jax op, export "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={p}, "
                "or use backend='vmap' (DESIGN.md §2)")
    return spmd.worker_mesh(p)


def make_test_mesh(devices: Optional[int] = None,
                   model_axis: int = 2):
    """Small mesh over whatever devices exist (tests force 8 host devices
    via a subprocess; plain test runs see (1, 1))."""
    n = devices or len(jax.devices())
    model = model_axis if n % model_axis == 0 and n > 1 else 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def worker_axes(mesh, vr_workers: str) -> Tuple[str, ...]:
    """Which mesh axes carry CentralVR worker copies.

    'data' — paper-faithful: one worker per data-axis group (params
             replicated along these axes), includes 'pod' when present.
    'pod'  — hierarchical (optimized): workers across pods, FSDP inside.
    'none' — plain data-parallel (no VR worker copies).
    """
    names = mesh.axis_names
    if vr_workers == "none":
        return ()
    if vr_workers == "pod":
        return ("pod",) if "pod" in names else ()
    if vr_workers == "data":
        return tuple(a for a in ("pod", "data") if a in names)
    raise ValueError(vr_workers)


def worker_count(mesh, vr_workers: str) -> int:
    sizes = mesh_axis_sizes(mesh)
    n = 1
    for a in worker_axes(mesh, vr_workers):
        n *= sizes[a]
    return max(n, 1)
