"""Mamba2-130M [arXiv:2405.21060] — attention-free SSM with SSD blocks.

24 layers, d_model=768, expand=2 (d_inner=1536), d_state=128, head_dim=64
(=> 24 SSD heads), vocab 50280 (GPT-NeoX tokenizer, padded).
"""
from repro.config import ModelConfig, register

MAMBA2_130M = register(ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=24,            # SSD heads = d_inner / ssm_head_dim
    num_kv_heads=24,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=64,
    norm_type="rmsnorm",
    tie_embeddings=True,
))
