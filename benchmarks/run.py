"""Benchmark harness: one module per paper table/figure + the roofline
report. Prints ``name,us_per_call,derived`` CSV per row.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig1,...]
    PYTHONPATH=src python benchmarks/run.py [--quick]   # same, script form
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

# script form: `python benchmarks/run.py` puts benchmarks/ (not the repo
# root) on sys.path, so the `from benchmarks import ...` below needs the
# root added — the CI benchmark-smoke job invokes this spelling — and
# repro_bootstrap adds src/ when repro isn't pip-installed
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))
import repro_bootstrap  # noqa: F401,E402


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI-speed)")
    ap.add_argument("--only", default="",
                    help="comma list: fig1,fig2,fig3,table1,theory,tau,"
                         "variance,drivers,spmd,train,serve,roofline")
    args = ap.parse_args(argv)

    from benchmarks import (driver_throughput, fig1_single_worker,
                            fig2_distributed, fig3_large, roofline_report,
                            serve_throughput, spmd_scaling,
                            table1_accounting, tau_sweep, theory_rates,
                            train_throughput, variance)

    suites = {
        "fig1": fig1_single_worker.run,
        "fig2": fig2_distributed.run,
        "fig3": fig3_large.run,
        "table1": table1_accounting.run,
        "theory": theory_rates.run,
        "tau": tau_sweep.run,
        "variance": variance.run,
        "drivers": driver_throughput.run,
        # subprocess suites: own interpreter (forced multi-device host
        # platform, or — roofline — a fresh jax for the vr-traffic check)
        "spmd": spmd_scaling.run_isolated,
        "train": train_throughput.run_isolated,
        "serve": serve_throughput.run_isolated,
        "roofline": roofline_report.run_isolated,
    }
    only = [s for s in args.only.split(",") if s]
    failures = []
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and name not in only:
            continue
        try:
            fn(quick=args.quick)
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            traceback.print_exc()
    if failures:
        print(f"FAILED suites: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
