"""Serving throughput: the continuous-batching engine (paged KV cache +
chunked prefill, ``repro/serve``) vs the legacy static-batch per-token
host loop it replaces, on a reduced arch.

Row families (each engine gate row is TWINNED with a host-loop row at the
exact same workload, so the regression gate compares measured-vs-measured
rather than measured-vs-remembered):

  * ``host-loop-w4`` / ``engine-paged-w4`` — the decode gate pair: a
    staggered-arrival trace with varied max_new (the workload static
    batching pads to the group max on while the engine retires/admits
    between steps).  The engine row carries ``decode_speedup_vs_host``
    (gate floor: 1.0 — the new runtime must not decode slower than the
    loop it replaces, even on CPU).
  * ``engine-dense-w4`` — the pure-JAX dense-cache oracle at the same
    workload, informational (its greedy ids are bit-identical to paged;
    tests/test_serve.py enforces that, this row just shows the cost).
  * ``host-loop-prefill128`` / ``engine-prefill128`` — the prefill gate
    pair at prompt-len 128: one 128-token chunked launch vs 128 per-token
    launches.  Engine row carries ``prefill_speedup_vs_host`` (gate
    floor: 5.0).
  * ``engine-*-w{2,8}`` — width / arrival-pattern sweep, informational
    (p50/p95 latency under burst vs poisson arrivals).
  * ``engine-tp2`` — tensor-parallel decode over 2 simulated host
    devices, ``estimated: true`` (CPU-simulated TP measures the plumbing,
    not real-accelerator scaling — informational, same convention as the
    interpret-mode fused rows).

Every row records ``cold_s`` (warmup compile) vs ``warm_s`` (steady run
wall) — with ``--compile-cache`` / ``REPRO_COMPILE_CACHE`` set, cold_s
shrinks to deserialization time on the second process launch.

Writes ``BENCH_serve.json`` at the repo root plus the standard results
CSV.  Must start in a fresh process: it forces 2 simulated host devices
for the TP row before jax initializes (same rule as
``benchmarks/train_throughput.py``).

    PYTHONPATH=src python -m benchmarks.serve_throughput [--quick]
"""
from __future__ import annotations

import json
import os

try:
    import repro_bootstrap  # noqa: F401  (repo-root module/script form)
except ModuleNotFoundError:
    pass  # installed form: repro resolves without the fallback

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _row(name, path, rep, workload, cold_s, **extra):
    s = rep.summary()
    return {
        "name": f"serve_throughput/{name}",
        "path": path,
        "decode_tok_s": s["decode_tok_s"],
        "prefill_tok_s": s["prefill_tok_s"],
        "latency_p50_s": s["latency_p50_s"],
        "latency_p95_s": s["latency_p95_s"],
        "cold_s": cold_s,
        "warm_s": s["wall_s"],
        "us_per_call": s["wall_s"] * 1e6,
        **extra,
        "provenance": {
            "spec": workload,
            "steps": s["steps"],
            "decode_tokens": s["decode_tokens"],
            "prefill_tokens": s["prefill_tokens"],
            "blocks_reused": s["blocks_reused"],
        },
        "derived": f"decode={s['decode_tok_s']:.0f}tok/s,"
                   f"prefill={s['prefill_tok_s']:.0f}tok/s,"
                   f"p95={s['latency_p95_s'] * 1e3:.1f}ms,"
                   f"cold={cold_s:.2f}s",
    }


def _best(fn, repeat):
    """Best-of-N by decode tok/s (serving wall clocks are noisy on shared
    CI hosts; both twins get the same treatment)."""
    reps = [fn() for _ in range(repeat)]
    return max(reps, key=lambda r: r.decode_tok_s)


def run(quick: bool = False):
    from repro.core import spmd

    spmd.force_host_devices(2)            # for the TP row
    import jax

    from benchmarks.common import emit
    from repro.config import get_arch
    from repro.launch.compile_cache import enable_compile_cache
    from repro.launch.mesh import make_test_mesh
    from repro.models import model
    from repro.serve import ServeEngine, run_host_loop, synthetic_trace

    enable_compile_cache()                 # honors REPRO_COMPILE_CACHE
    cfg = get_arch("qwen2-7b").reduced()
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    repeat = 2
    rows = []

    # ---- decode gate pair: staggered arrivals, varied max_new ------------
    n, max_new, prompt, width = (8, 16, 32, 4) if quick else (16, 32, 32, 4)
    trace = synthetic_trace(n, pattern="uniform", prompt_len=prompt,
                            max_new=max_new, gap=2, vary_new=True, seed=0)
    wl = {"arch": cfg.name, "requests": n, "prompt_len": prompt,
          "max_new": max_new, "vary_new": True, "pattern": "uniform",
          "width": width, "quick": quick}
    host_rep = _best(lambda: run_host_loop(cfg, trace, params=params,
                                           width=width), repeat)
    rows.append(_row("host-loop-w4", "host-loop", host_rep, wl,
                     sum(host_rep.compile_s.values())))

    def engine_rep(kv_cache, w=width, tr=trace, mesh=None, buckets=(32,),
                   max_len=prompt + max_new):
        eng = ServeEngine(cfg, params, width=w, block_size=16,
                          max_seq_len=max_len, kv_cache=kv_cache,
                          chunk_buckets=buckets, mesh=mesh)
        eng.warmup()
        rep = _best(lambda: eng.run(tr), repeat)
        return rep, sum(eng.compile_s.values())

    rep, cold = engine_rep("paged")
    rows.append(_row(
        "engine-paged-w4", "engine-paged", rep, wl, cold,
        decode_speedup_vs_host=rep.decode_tok_s / host_rep.decode_tok_s))
    # the dense oracle and the sweep rows below are informational: they
    # carry no *_speedup_vs_host key, so the gate never sees them
    rep, cold = engine_rep("dense")
    rows.append(_row("engine-dense-w4", "engine-dense", rep, wl, cold))

    # ---- prefill gate pair: prompt-len 128, one chunk vs 128 steps -------
    np_, pw = (2, 2) if quick else (4, 4)
    trace128 = synthetic_trace(np_, pattern="burst", prompt_len=128,
                               max_new=2, seed=1)
    wl128 = {"arch": cfg.name, "requests": np_, "prompt_len": 128,
             "max_new": 2, "pattern": "burst", "width": pw, "quick": quick}
    host128 = _best(lambda: run_host_loop(cfg, trace128, params=params,
                                          width=pw), repeat)
    rows.append(_row("host-loop-prefill128", "host-loop", host128, wl128,
                     sum(host128.compile_s.values())))
    rep, cold = engine_rep("paged", w=pw, tr=trace128, buckets=(128,),
                           max_len=130)
    rows.append(_row(
        "engine-prefill128", "engine-paged", rep, wl128, cold,
        prefill_speedup_vs_host=rep.prefill_tok_s / host128.prefill_tok_s))

    # ---- width / arrival-pattern sweep (informational) -------------------
    for w, pattern in ((2, "poisson"), (8, "burst")):
        tr = synthetic_trace(n, pattern=pattern, prompt_len=prompt,
                             max_new=max_new, gap=2, vary_new=True, seed=2)
        rep, cold = engine_rep("paged", w=w, tr=tr)
        rows.append(_row(f"engine-{pattern}-w{w}", "engine-paged", rep,
                         {**wl, "pattern": pattern, "width": w}, cold))

    # ---- tensor-parallel decode over 2 simulated devices -----------------
    mesh = make_test_mesh(model_axis=2)
    rep, cold = engine_rep("paged", mesh=mesh)
    rows.append(_row(
        "engine-tp2", "engine-tp", rep, {**wl, "tp": 2}, cold,
        estimated=True,
        decode_speedup_vs_host=rep.decode_tok_s / host_rep.decode_tok_s))

    payload = {
        "config": {"arch": cfg.name, "quick": quick,
                   "device_count": jax.device_count(),
                   "backend_platform": jax.default_backend(),
                   "compile_cache": os.environ.get("REPRO_COMPILE_CACHE",
                                                   "")},
        "rows": rows,
    }
    with open(os.path.join(ROOT, "BENCH_serve.json"), "w") as f:
        json.dump(payload, f, indent=1)
    emit(rows, "serve_throughput")
    gate = next(r for r in rows if r["name"].endswith("engine-paged-w4"))
    pf = next(r for r in rows if r["name"].endswith("engine-prefill128"))
    print(f"decode_speedup_vs_host={gate['decode_speedup_vs_host']:.2f}x "
          f"prefill_speedup_vs_host={pf['prefill_speedup_vs_host']:.2f}x")
    return payload


def run_isolated(quick: bool = False):
    """Entry point for the ``benchmarks.run`` harness: fresh interpreter,
    because the forced host-device count must precede jax init."""
    import subprocess
    import sys

    cmd = [sys.executable, "-m", "benchmarks.serve_throughput"]
    if quick:
        cmd.append("--quick")
    proc = subprocess.run(cmd, cwd=ROOT, capture_output=True, text=True,
                          timeout=1800)
    sys.stdout.write(proc.stdout)
    if proc.returncode != 0:
        raise RuntimeError(f"serve_throughput failed:\n{proc.stderr[-3000:]}")


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
