"""Batched serving example, now a thin client of the repro.serve runtime:
attention-family architectures run on the continuous-batching engine
(paged KV cache + chunked prefill), while attention-free / hybrid stacks
(SSM, RG-LRU) fall back to the legacy static-batch host loop — showing
both the new engine and the dispatch seam in one script.

    python examples/serve_batched.py
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))
import repro_bootstrap  # noqa: F401,E402  (adds src/ if repro isn't installed)

import jax

from repro.config import get_arch
from repro.models import model
from repro.serve import ServeEngine, check_arch, run_host_loop, \
    synthetic_trace


def serve(arch: str, requests=6, prompt=32, gen=16, width=4):
    cfg = get_arch(arch).reduced()
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    trace = synthetic_trace(requests, pattern="uniform", prompt_len=prompt,
                            max_new=gen, gap=2, vary_new=True)
    try:
        check_arch(cfg)
        eng = ServeEngine(cfg, params, width=width,
                          max_seq_len=prompt + gen, chunk_buckets=(prompt,))
        eng.warmup()
        rep, path = eng.run(trace), "engine"
    except ValueError:
        rep, path = run_host_loop(cfg, trace, params=params,
                                  width=width), "legacy"
    s = rep.summary()
    print(f"{arch:22s} [{cfg.family:6s}] {path:6s} {s['requests']} reqs, "
          f"decode {s['decode_tok_s']:7.1f} tok/s, p95 "
          f"{s['latency_p95_s'] * 1e3:6.1f}ms "
          f"-> {rep.results[0].tokens[:8]}")


if __name__ == "__main__":
    for arch in ("qwen2-7b", "mamba2-130m", "recurrentgemma-2b"):
        serve(arch)
