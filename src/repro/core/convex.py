"""The paper's experimental substrate (§6): l2-regularized logistic and
ridge regression — plus the robust-regression family (Huber,
pseudo-Huber, logistic with label outliers) that the composite/prox
drivers exercise — with the GLM scalar-residual structure that makes the
SAGA/CentralVR gradient table O(n) scalars instead of O(n·d) vectors
(the storage observation in §2.3 of the paper).

Every f_i has the form  f_i(x) = l(a_i^T x; b_i) + lam * ||x||^2, so

    grad f_i(x) = s_i(x) * a_i + 2*lam*x,     s_i(x) = l'(a_i^T x; b_i).

We apply variance reduction to the data term only and treat the
regularizer's gradient 2*lam*x exactly (it is deterministic, so adding it
outside the correction keeps the estimator unbiased and strictly reduces
variance). The stored "gradient" for index i is therefore the scalar s_i.

Loss convention: the paper prints ``log(1 + exp(b a^T x))``; we use the
standard ``log(1 + exp(-b a^T x))`` (b in {-1,+1}) — the two differ only by
the sign of b, i.e. a relabeling of the classes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Problem(NamedTuple):
    """A finite-sum convex problem; a pytree safe to close over in jit."""

    A: jax.Array          # (n, d) features
    b: jax.Array          # (n,) labels (+-1 for logistic, real otherwise)
    lam: jnp.float32      # l2 coefficient
    kind: str             # "logistic" | "ridge" | "huber[@delta]" |
                          # "pseudo_huber[@delta]"  (static; the robust
                          # losses encode delta in the kind string so the
                          # pytree structure never varies)

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def d(self) -> int:
        return self.A.shape[1]


# pytree: `kind` is static metadata
jax.tree_util.register_pytree_node(
    Problem,
    lambda p: ((p.A, p.b, p.lam), p.kind),
    lambda kind, leaves: Problem(*leaves, kind=kind),
)


# ---------------------------------------------------------------------------
# Data generators (paper §6.1)
# ---------------------------------------------------------------------------

def make_logistic_data(key, n: int, d: int, lam: float = 1e-4,
                       outliers: float = 0.0) -> Problem:
    """Two unit-variance normals with means separated by one unit.

    ``outliers`` flips that fraction of labels (adversarial label noise —
    the robust-logistic setting). ``outliers=0`` leaves the RNG stream
    and the generated data bit-identical to the original generator.
    """
    k1, k2 = jax.random.split(key)
    half = n // 2
    mu = jnp.zeros((d,)).at[0].set(0.5)
    a_pos = jax.random.normal(k1, (half, d)) + mu
    a_neg = jax.random.normal(k2, (n - half, d)) - mu
    A = jnp.concatenate([a_pos, a_neg])
    b = jnp.concatenate([jnp.ones((half,)), -jnp.ones((n - half,))])
    if outliers:
        flip = jax.random.uniform(jax.random.fold_in(key, 3), (n,)) < outliers
        b = jnp.where(flip, -b, b)
    return Problem(A, b, jnp.float32(lam), "logistic")


def make_ridge_data(key, n: int, d: int, lam: float = 1e-4) -> Problem:
    """b = A x_true + eps, A and eps standard normal."""
    k1, k2, k3 = jax.random.split(key, 3)
    A = jax.random.normal(k1, (n, d))
    x_true = jax.random.normal(k2, (d,))
    b = A @ x_true + jax.random.normal(k3, (n,))
    return Problem(A, b, jnp.float32(lam), "ridge")


def make_huber_data(key, n: int, d: int, lam: float = 1e-4,
                    delta: float = 1.0, outliers: float = 0.1,
                    kind: str = "huber") -> Problem:
    """Linear regression with a corrupted label fraction (robust setting).

    ``b = A x_true + eps`` with ``outliers`` of the labels shifted by a
    10-sigma heavy tail — the regime where the Huber loss beats L2
    (EXPERIMENTS.md §Robust regression). ``kind`` may also be
    ``"pseudo_huber"``; ``delta != 1`` is encoded as ``"huber@<delta>"``.
    """
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    A = jax.random.normal(k1, (n, d))
    x_true = jax.random.normal(k2, (d,))
    b = A @ x_true + jax.random.normal(k3, (n,))
    if outliers:
        mask = jax.random.uniform(k4, (n,)) < outliers
        b = jnp.where(mask, b + 10.0 * jax.random.normal(k5, (n,)), b)
    tag = kind if delta == 1.0 else f"{kind}@{delta:g}"
    return Problem(A, b, jnp.float32(lam), tag)


def loss_params(kind: str):
    """Split a kind string into (base, delta): ``"huber@0.5"`` ->
    ``("huber", 0.5)``; kinds without a ``@`` tag get delta = 1.0."""
    base, _, tail = kind.partition("@")
    return base, (float(tail) if tail else 1.0)


def make_problem(key, cfg) -> Problem:
    """From a :class:`repro.config.ConvexConfig`."""
    outliers = getattr(cfg, "outlier_frac", 0.0)
    if cfg.problem == "logistic":
        return make_logistic_data(key, cfg.n, cfg.d, cfg.lam,
                                  outliers=outliers)
    if cfg.problem == "ridge":
        return make_ridge_data(key, cfg.n, cfg.d, cfg.lam)
    if cfg.problem in ("huber", "pseudo_huber"):
        return make_huber_data(key, cfg.n, cfg.d, cfg.lam,
                               delta=getattr(cfg, "huber_delta", 1.0),
                               outliers=outliers, kind=cfg.problem)
    raise ValueError(f"unknown problem kind {cfg.problem!r}")


# ---------------------------------------------------------------------------
# Losses / gradients
# ---------------------------------------------------------------------------

def _margins(prob: Problem, x: jax.Array) -> jax.Array:
    return prob.A @ x


def _pointwise_loss(z, bb, kind: str):
    """l(z; b) per sample, from an already-formed margin z = a^T x."""
    base, delta = loss_params(kind)
    if base == "logistic":
        return jnp.logaddexp(0.0, -bb * z)
    if base == "ridge":
        return (z - bb) ** 2
    r = z - bb
    if base == "huber":
        return jnp.where(jnp.abs(r) <= delta,
                         0.5 * r * r,
                         delta * (jnp.abs(r) - 0.5 * delta))
    if base == "pseudo_huber":
        return delta * delta * (jnp.sqrt(1.0 + (r / delta) ** 2) - 1.0)
    raise ValueError(f"unknown problem kind {kind!r}")


def _pointwise_residual(z, bb, kind: str):
    """s = l'(z; b) per sample — the scalar the VR tables store."""
    base, delta = loss_params(kind)
    if base == "logistic":
        return -bb * jax.nn.sigmoid(-bb * z)
    if base == "ridge":
        return 2.0 * (z - bb)
    r = z - bb
    if base == "huber":
        return jnp.clip(r, -delta, delta)
    if base == "pseudo_huber":
        return r / jnp.sqrt(1.0 + (r / delta) ** 2)
    raise ValueError(f"unknown problem kind {kind!r}")


def full_loss(prob: Problem, x: jax.Array) -> jax.Array:
    z = _margins(prob, x)
    data = jnp.mean(_pointwise_loss(z, prob.b, prob.kind))
    return data + prob.lam * jnp.sum(x * x)


def scalar_residual(prob: Problem, x: jax.Array, idx) -> jax.Array:
    """s_i(x) = l'(a_i^T x; b_i) for the given indices (vectorized)."""
    a = prob.A[idx]
    bb = prob.b[idx]
    return _pointwise_residual(a @ x, bb, prob.kind)


def scalar_residual_all(prob: Problem, x: jax.Array) -> jax.Array:
    return _pointwise_residual(_margins(prob, x), prob.b, prob.kind)


def sample_grad(prob: Problem, x: jax.Array, i) -> jax.Array:
    """grad f_i(x) (single index), regularizer included."""
    s = scalar_residual(prob, x, i)
    return s * prob.A[i] + 2.0 * prob.lam * x


def data_grad_from_scalars(prob: Problem, s: jax.Array) -> jax.Array:
    """(1/n) sum_j s_j a_j — the data term of the mean gradient."""
    return prob.A.T @ s / prob.n


def full_grad(prob: Problem, x: jax.Array) -> jax.Array:
    s = scalar_residual_all(prob, x)
    return data_grad_from_scalars(prob, s) + 2.0 * prob.lam * x


# ---------------------------------------------------------------------------
# Smoothness / strong-convexity constants and exact solutions (theory.py
# consumes these; tests compare measured rates against Theorem 1)
# ---------------------------------------------------------------------------

def constants(prob: Problem):
    """(mu, L) such that every f_i is mu-strongly convex, L-smooth.

    Per-loss curvature bounds sup l'': logistic 1/4, ridge 2, Huber and
    pseudo-Huber 1 (both have |l''| <= 1 for every delta).
    """
    row_sq = jnp.sum(prob.A * prob.A, axis=1)
    base, _ = loss_params(prob.kind)
    curv = {"logistic": 0.25, "ridge": 2.0,
            "huber": 1.0, "pseudo_huber": 1.0}[base]
    L = curv * jnp.max(row_sq) + 2.0 * prob.lam
    mu = 2.0 * prob.lam
    return mu, L


def auto_eta(prob: Problem, c: float = 0.3) -> float:
    """Practical step size c/L (the paper tunes per-problem constants; we
    derive them from the smoothness constant so every dataset shape gets a
    stable-but-fast step)."""
    _, L = constants(prob)
    return float(c / L)


def solve_exact(prob: Problem, iters: int = 100) -> jax.Array:
    """x*: closed form for ridge, Newton for logistic, IRLS for the
    robust losses (d is small).

    Huber/pseudo-Huber use iteratively-reweighted least squares with the
    majorization weights w = l'(r)/r (min(1, delta/|r|) for Huber) —
    each step solves the weighted normal equations exactly and
    monotonically decreases the objective, unlike raw Newton on Huber,
    whose piecewise-constant curvature can cycle between active sets.
    The fixed point satisfies A^T l'(r)/n + 2*lam*x = 0, i.e. it is the
    exact stationary point of :func:`full_loss`.
    """
    n, d = prob.A.shape
    base, delta = loss_params(prob.kind)
    if base == "ridge":
        H = 2.0 * (prob.A.T @ prob.A) / n + 2.0 * prob.lam * jnp.eye(d)
        g = 2.0 * (prob.A.T @ prob.b) / n
        return jnp.linalg.solve(H, g)

    if base in ("huber", "pseudo_huber"):
        def irls_step(x, _):
            r = prob.A @ x - prob.b
            if base == "huber":
                w = jnp.minimum(1.0, delta / jnp.maximum(jnp.abs(r), 1e-300))
            else:
                w = 1.0 / jnp.sqrt(1.0 + (r / delta) ** 2)
            Aw = prob.A * w[:, None]
            H = Aw.T @ prob.A / n + 2.0 * prob.lam * jnp.eye(d)
            g = Aw.T @ prob.b / n
            return jnp.linalg.solve(H, g), None

        x0 = jnp.zeros((d,))
        x, _ = jax.lax.scan(irls_step, x0, None, length=max(iters, 400))
        return x

    def newton_step(x, _):
        z = prob.A @ x
        p = jax.nn.sigmoid(-prob.b * z)
        g = prob.A.T @ (-prob.b * p) / n + 2.0 * prob.lam * x
        w = p * (1.0 - p)
        H = (prob.A * w[:, None]).T @ prob.A / n + 2.0 * prob.lam * jnp.eye(d)
        return x - jnp.linalg.solve(H, g), None

    x0 = jnp.zeros((d,))
    x, _ = jax.lax.scan(newton_step, x0, None, length=iters)
    return x


def rel_grad_norm(prob: Problem, x: jax.Array, g0: jax.Array | None = None,
                  *, prox=None, eta: float | None = None):
    """The paper's y-axis: ||grad f(x)|| / ||grad f(x0)||.

    For composite runs (``prox`` a ProxSpec) the numerator becomes the
    gradient-mapping residual ``||x - prox_{eta*g}(x - eta*grad f(x))||``
    — the quantity that vanishes at minimizers of f + g. The 1/eta scale
    cancels against the matching :func:`grad_norm0`, so the smooth path
    (prox=None) stays bit-identical to the original metric.
    """
    if prox is None:
        g = jnp.linalg.norm(full_grad(prob, x))
    else:
        from repro.prox import operators as proxops
        g = jnp.linalg.norm(
            proxops.grad_map(prox, x, full_grad(prob, x), eta))
    if g0 is None:
        return g
    return g / g0


def grad_norm0(prob: Problem, *, prox=None, eta: float | None = None):
    """||grad f(0)|| — the normalizer of the paper's y-axis (the
    gradient-mapping residual at 0 for composite runs).  Stays on device:
    the scan-based drivers divide by it inside the scan instead of
    fetching it to the host (DESIGN.md §3).

    Degenerate composite configs can make x0 = 0 an exact fixed point of
    the prox-gradient map (a threshold ``eta*lam1`` larger than every
    coordinate of ``eta*grad f(0)`` zeroes the whole step); dividing by
    that zero would turn every rel into NaN, so the normalizer falls back
    to 1 and the trajectory reports raw residuals instead."""
    g0 = rel_grad_norm(prob, jnp.zeros((prob.d,)), prox=prox, eta=eta)
    return jnp.where(g0 == 0.0, jnp.ones_like(g0), g0)
