"""Render a JSONL run record into a human-readable timeline/summary.

Backs the ``repro.launch.obs`` CLI (``report`` subcommand).  Pure
stdlib + the schema module; rendering never imports jax.
"""
from __future__ import annotations

from typing import List

from repro.obs import schema


def _fmt_s(v: float) -> str:
    return f"{v * 1e3:8.1f} ms" if v < 1.0 else f"{v:8.2f} s "


def summarize(rows: List[dict]) -> dict:
    """Structured summary of a run record: spans (timeline order), metric
    streams, events, and the derived compile-vs-warm split."""
    spans = sorted((r for r in rows if r["kind"] == "span"),
                   key=lambda r: r["t0"])
    metrics: dict = {}
    for r in rows:
        if r["kind"] == "metric":
            m = metrics.setdefault(r["name"], {"count": 0, "first_step": None,
                                               "last_step": None,
                                               "last_value": None})
            m["count"] += 1
            if m["first_step"] is None:
                m["first_step"] = r["step"]
            m["last_step"], m["last_value"] = r["step"], r["value"]
    events = [r for r in rows if r["kind"] == "event"]
    compile_s = sum(r["dur_s"] for r in spans
                    if r["name"].endswith("/compile"))
    warm_s = sum(r["dur_s"] for r in spans
                 if r["name"].endswith("/execute"))
    lower_s = sum(r["dur_s"] for r in spans if r["name"].endswith("/lower"))
    return {"run": rows[0]["run"] if rows else None,
            "n_rows": len(rows), "spans": spans, "metrics": metrics,
            "events": events, "lower_s": lower_s, "compile_s": compile_s,
            "warm_s": warm_s}


def render(rows: List[dict]) -> str:
    """The ``report`` CLI's output: timeline + summaries, one string."""
    s = summarize(rows)
    out = [f"run {s['run']}  ({s['n_rows']} rows)"]

    if s["spans"]:
        out.append("")
        out.append("spans (timeline):")
        for r in s["spans"]:
            flag = "  FAILED" if r.get("failed") else ""
            out.append(f"  t={r['t0']:9.3f}s  {_fmt_s(r['dur_s'])}  "
                       f"{r['name']}{flag}")
        if s["compile_s"] or s["warm_s"]:
            out.append(f"  phase split: lower {s['lower_s']:.3f}s  "
                       f"compile {s['compile_s']:.3f}s  "
                       f"warm(execute) {s['warm_s']:.3f}s")

    if s["metrics"]:
        out.append("")
        out.append("streamed metrics:")
        for name, m in sorted(s["metrics"].items()):
            out.append(f"  {name}: {m['count']} rows, steps "
                       f"{m['first_step']}..{m['last_step']}, "
                       f"last value {m['last_value']:.6g}")

    interesting = [e for e in s["events"]
                   if e["name"] not in ("run_start",)]
    if interesting:
        out.append("")
        out.append("events:")
        for e in interesting:
            body = {k: v for k, v in e.items()
                    if k not in ("v", "run", "t", "kind", "name")}
            if e["name"] == "provenance":
                stal = (body.get("staleness") or {})
                comms = (body.get("comms") or {})
                brief = {"algo": (body.get("spec") or {}).get("algo"),
                         "final_rel": body.get("final_rel"),
                         "staleness_hist": stal.get("histogram"),
                         "bytes_per_round": comms.get("bytes_per_round")}
                out.append(f"  t={e['t']:9.3f}s  {e['name']}: {brief}")
            else:
                out.append(f"  t={e['t']:9.3f}s  {e['name']}: {body}")
    return "\n".join(out)


def render_file(path: str) -> str:
    rows = schema.load_rows(path)
    schema.validate_rows(rows)
    return render(rows)
