"""Multi-process execution of the sync and wave-partitioned async
runtimes over a ``jax.distributed`` process mesh (DESIGN.md §Multi-host &
elasticity).

Topology: N processes (process 0 co-hosts the coordination service), each
owning a CONTIGUOUS block of the p workers (:func:`worker_blocks`).
Every process derives the full deterministic plan — dataset, init state,
event schedule, wave partition, per-event RNG draws — from the shared
``(spec, key)``, so the processes agree on every round's structure
without exchanging a byte of control data.  Only the wave algebra's
payloads move: per-event deltas ``(dx, dgbar)`` are published to the
coordination-service KV store and applied at the wave boundary in the
schedule's event order — the SAME sequential delta additions the
event-serial reference performs, which is why the two-process async
trajectory pins bit-exact in f64 against ``run_async`` /
``run_async_elastic`` (``tests/test_multihost.py``).

Why a KV-store data plane instead of cross-process ``shard_map``: XLA
cannot compile multi-process computations on the CPU backend (it raises
``Multiprocess computations aren't implemented on the CPU backend``), so
on this container each process runs its owned workers' epochs as LOCAL
jitted programs and the paper's central server lives in the wave-boundary
delta exchange.  On a real accelerator backend the same worker partition
maps onto a global 1-D device mesh (``spmd.process_worker_mesh``) and the
existing ``core/spmd.py`` runners execute each process's block under
``shard_map``; the KV exchange then only carries the elastic control
plane.

Elasticity (``elastic=True``): at every round boundary — every round
boundary is a wave boundary — processes heartbeat through the KV store;
process 0 (the arbiter, co-located with the coordination service) waits
``hb_timeout`` seconds for each live peer, declares missing ones dead,
admits rejoin candidates, and publishes the membership decision plus the
resync state (central pair + merged VR table, assembled from the
boundary table snapshots every process publishes BEFORE anything can
die).  Survivors re-shard per ``core/elastic.py``'s determinism
contract, so the post-dropout trajectory equals the event-serial elastic
reference replaying the observed membership plan.  Boundary deaths only:
a process that vanishes MID-round trips the data-plane deadlock guard (a
hard timeout on the delta fetch) rather than a silent hang.  Process 0's
metric trajectory and transition log are canonical — the launcher reads
results from process 0, which is never the injected-fault process.
"""
from __future__ import annotations

import dataclasses
import functools
import io
import json
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import convex, elastic, runtime
from repro.core.convex import Problem
from repro.core.distributed import (ShardedProblem, _local_centralvr_epoch,
                                    async_init, sync_init)
from repro.obs import recorder as obs_recorder

# data-plane deadlock guard: a delta/gather fetch outliving this means a
# peer vanished mid-round (outside the boundary-death contract) or the
# coordinator wedged — fail loudly instead of eating the CI job budget
DATA_TIMEOUT_S = 120.0
# how long a rejoin candidate's heartbeat peek may block the arbiter
PEEK_TIMEOUT_S = 0.05


class KVTimeout(TimeoutError):
    """A blocking KV get ran out of time."""


# ---------------------------------------------------------------------------
# Array codec + KV transports
# ---------------------------------------------------------------------------

def encode_arrays(arrays: Dict[str, np.ndarray]) -> bytes:
    buf = io.BytesIO()
    np.savez(buf, **{k: np.asarray(v) for k, v in arrays.items()})
    return buf.getvalue()


def decode_arrays(blob: bytes) -> Dict[str, np.ndarray]:
    with np.load(io.BytesIO(blob)) as data:
        return {k: data[k] for k in data.files}


class LocalKV:
    """In-process KV store: the single-process stand-in for the
    coordination service, so the engines (and their tests) run without
    spawning a world."""

    def __init__(self):
        self._d: Dict[str, bytes] = {}

    def set(self, key: str, value: bytes) -> None:
        if key in self._d:
            raise ValueError(f"KV key {key!r} already set (the protocol "
                             "never overwrites)")
        self._d[key] = bytes(value)

    def get(self, key: str, timeout_s: float) -> bytes:
        try:
            return self._d[key]
        except KeyError:
            raise KVTimeout(f"key {key!r} not present (single-process KV "
                            "never blocks)") from None


class DistributedKV:
    """The ``jax.distributed`` coordination-service KV store.  It lives in
    process 0's coordinator and survives peer death; blocking gets poll
    until the key appears or the timeout elapses."""

    def __init__(self, client):
        self._c = client

    def set(self, key: str, value: bytes) -> None:
        self._c.key_value_set_bytes(key, bytes(value))

    def get(self, key: str, timeout_s: float) -> bytes:
        try:
            return self._c.blocking_key_value_get_bytes(
                key, int(max(timeout_s, PEEK_TIMEOUT_S) * 1000))
        except Exception as e:  # jaxlib surfaces its own error types
            raise KVTimeout(f"key {key!r}: {e}") from None


@dataclasses.dataclass
class ProcComm:
    """One process's handle on the world: rank, size, KV transport, and a
    per-run key prefix so repeated runs never collide."""

    kv: object
    pid: int
    nprocs: int
    prefix: str = "run"

    def _k(self, key: str) -> str:
        return f"{self.prefix}/{key}"

    def put(self, key: str, **arrays) -> None:
        self.kv.set(self._k(key), encode_arrays(arrays))

    def get(self, key: str, timeout_s: float = DATA_TIMEOUT_S) -> dict:
        return decode_arrays(self.kv.get(self._k(key), timeout_s))

    def put_flag(self, key: str, payload: dict) -> None:
        self.kv.set(self._k(key), json.dumps(payload).encode())

    def get_flag(self, key: str, timeout_s: float) -> dict:
        return json.loads(self.kv.get(self._k(key), timeout_s).decode())

    def peek_flag(self, key: str) -> Optional[dict]:
        try:
            return self.get_flag(key, PEEK_TIMEOUT_S)
        except KVTimeout:
            return None


@dataclasses.dataclass
class Fault:
    """Deterministic fault injection for the elastic CI lane: process
    ``process`` drops at the boundary of round ``round_`` — ``exit`` mode
    terminates it (the engine raises :class:`WorkerDropped`), ``stall``
    mode takes it off the air for ``rejoin_after`` rounds and then
    rejoins through the membership protocol."""

    process: int
    round_: int
    mode: str = "exit"           # "exit" | "stall"
    rejoin_after: int = 2

    def __post_init__(self):
        if self.mode not in ("exit", "stall"):
            raise ValueError(f"Fault.mode: {self.mode!r}")
        if self.process == 0:
            raise ValueError(
                "Fault.process: process 0 co-hosts the coordination "
                "service (and the membership arbiter); killing it kills "
                "the control plane, not a worker")
        if self.round_ < 1:
            raise ValueError("Fault.round_: membership changes take effect "
                             "at wave boundaries AFTER round 0")
        if self.mode == "stall" and self.rejoin_after < 1:
            raise ValueError("Fault.rejoin_after must be >= 1: a stalled "
                             "process must miss at least one boundary "
                             "heartbeat to be declared lost")


class WorkerDropped(Exception):
    """Raised inside the engine when THIS process executes an exit-mode
    fault: the caller finalizes (flush telemetry, write partial results)
    and terminates — the dropout is the test, not a failure."""

    def __init__(self, round_: int, rels):
        super().__init__(f"process dropped at round {round_}")
        self.round_ = round_
        self.rels = rels


# ---------------------------------------------------------------------------
# Ownership + jitted local programs
# ---------------------------------------------------------------------------

def worker_blocks(p: int, nprocs: int) -> List[range]:
    """Contiguous compact-slot blocks, one per live process rank (uneven
    splits front-loaded, the usual balanced convention)."""
    if nprocs < 1 or p < nprocs:
        raise ValueError(f"cannot split p={p} workers over {nprocs} "
                         "processes (need p >= nprocs >= 1)")
    return [range(i * p // nprocs, (i + 1) * p // nprocs)
            for i in range(nprocs)]


@functools.partial(jax.jit, static_argnames=("kind",))
def _epoch_vr(A, b, lam, kind, x, table, gbar, eta, perm):
    return _local_centralvr_epoch(A, b, lam, kind, x, table, gbar, eta, perm)


@functools.partial(jax.jit, static_argnames=("kind",))
def _rel_metric(A, b, lam, kind, x, g0):
    return convex.rel_grad_norm(Problem(A, b, lam, kind), x, g0)


@jax.jit
def _mean0(xs):
    return xs.mean(0)


def _perm_rows(keys, ns: int):
    """Host-precomputed permutation draws — the same vmap the reference
    runners perform (``sync_round``, ``core/spmd.py``), so every process
    consumes identical randomness by construction."""
    keys = jnp.asarray(keys)
    keys = keys.reshape((-1,) + keys.shape[-1:])
    return np.asarray(
        jax.vmap(lambda k: jax.random.permutation(k, ns))(keys))


def _wave_layout(row: np.ndarray, p: int):
    """Greedy wave grouping of one round's event row via
    ``runtime.wave_partition``; yields ``(workers_in_event_order,
    row_offset)`` per wave."""
    active, rank, _ = runtime.wave_partition(np.asarray(row), p)
    out = []
    offset = 0
    for w in range(active.shape[1]):
        workers = np.nonzero(active[0, w])[0]
        if workers.size == 0:
            break
        ordered = workers[np.argsort(rank[0, w, workers])]
        out.append((ordered.tolist(), offset))
        offset += workers.size
    return out


def _fresh_views(x_c, gbar_c, table, p):
    """The async handover construction (``async_init`` / ``resync_state``
    on host arrays): every worker's previous contribution and fetch
    snapshot start at the central values."""
    return (np.tile(x_c, (p, 1)), np.tile(gbar_c, (p, 1)),
            np.tile(x_c, (p, 1)), np.tile(gbar_c, (p, 1)),
            np.asarray(table).reshape(p, -1))


# ---------------------------------------------------------------------------
# The engines
# ---------------------------------------------------------------------------

def run_sync_process(sp: ShardedProblem, *, eta: float, rounds: int, key,
                     comm: ProcComm):
    """CentralVR-Sync (Algorithm 2) over the process mesh.

    Init is computed LOCALLY on every process (it is a pure function of
    the shared ``(sp, eta, key)``, so replication is bit-exact and free);
    each round, owned epochs run as local jitted programs and the central
    average is assembled from the KV-exchanged worker blocks — same
    draws, same per-worker arithmetic as the single-process backend."""
    blocks = worker_blocks(sp.p, comm.nprocs)
    block = blocks[comm.pid]
    merged = sp.merged()
    g0 = convex.grad_norm0(merged)
    k_init, k_run = jax.random.split(key)
    st0 = sync_init(sp, eta, k_init)
    x_c = np.array(st0.x)
    gbar_c = np.array(st0.gbar)
    tables = np.array(st0.tables)
    round_keys = jax.random.split(k_run, rounds)
    rels = []
    for r in range(rounds):
        perms = _perm_rows(jax.random.split(round_keys[r], sp.p), sp.ns)
        own_x, own_acc = [], []
        for s in block:
            x, table, acc = _epoch_vr(
                sp.A[s], sp.b[s], sp.lam, sp.kind, jnp.asarray(x_c),
                jnp.asarray(tables[s]), jnp.asarray(gbar_c), eta,
                jnp.asarray(perms[s]))
            tables[s] = np.asarray(table)
            own_x.append(np.asarray(x))
            own_acc.append(np.asarray(acc))
        comm.put(f"s/{r}/{comm.pid}", xs=np.stack(own_x),
                 accs=np.stack(own_acc))
        xs = np.zeros((sp.p,) + x_c.shape, dtype=x_c.dtype)
        accs = np.zeros_like(xs)
        for q, qblock in enumerate(blocks):
            part = (dict(xs=np.stack(own_x), accs=np.stack(own_acc))
                    if q == comm.pid else comm.get(f"s/{r}/{q}"))
            xs[qblock.start:qblock.stop] = part["xs"]
            accs[qblock.start:qblock.stop] = part["accs"]
        x_c = np.asarray(_mean0(xs))
        gbar_c = np.asarray(_mean0(accs))
        rels.append(float(_rel_metric(merged.A, merged.b, sp.lam, sp.kind,
                                      jnp.asarray(x_c), g0)))
    state = {"x": x_c, "tables": tables, "gbar": gbar_c}
    return state, np.asarray(rels)


def run_async_process(sp: ShardedProblem, *, eta: float, rounds: int, key,
                      comm: ProcComm, speeds=None, elastic_mode: bool = False,
                      hb_timeout: float = 10.0,
                      fault: Optional[Fault] = None):
    """CentralVR-Async (Algorithm 3) over the process mesh, wave by wave.

    Per round: every process derives the round's wave layout from the
    shared segment plan, computes its owned active workers' epochs as
    local jitted programs, publishes the ``(dx, dgbar)`` deltas, and
    applies the wave's deltas IN EVENT ORDER — each worker's fresh fetch
    is the central state immediately after its own event, exactly the
    event-serial reference algebra, so the trajectory pins bit-exact in
    f64.  With ``elastic_mode`` the round boundary additionally runs the
    heartbeat/membership protocol described in the module docstring.

    Returns ``(state, rels, transitions)``; ``rels`` carries NaN for
    rounds this process sat out (stall-mode rejoin) — process 0's output
    is canonical and process 0 never sits out.
    """
    p0 = sp.p
    if fault is not None and not elastic_mode:
        raise ValueError("fault injection requires elastic_mode=True")
    if fault is not None and fault.process >= comm.nprocs:
        raise ValueError(f"Fault.process {fault.process} outside the "
                         f"{comm.nprocs}-process world")
    merged = sp.merged()
    g0 = convex.grad_norm0(merged)
    k_init, k_run = jax.random.split(key)

    live_procs: Tuple[int, ...] = tuple(range(comm.nprocs))
    live_workers: Tuple[int, ...] = tuple(range(p0))
    lost_by_proc: Dict[int, Tuple[int, ...]] = {}
    sp_cur = sp
    blocks = worker_blocks(p0, comm.nprocs)
    block = blocks[comm.pid]

    # init replicated locally — a pure function of the shared inputs
    # (np.array, not asarray: device arrays view as read-only)
    st0 = async_init(sp, eta, k_init)
    x_c = np.array(st0.x_c)
    gbar_c = np.array(st0.gbar_c)
    x_old = np.array(st0.x_old)
    gbar_old = np.array(st0.gbar_old)
    x_fetch = np.array(st0.x_fetch)
    gbar_fetch = np.array(st0.gbar_fetch)
    tables = np.array(st0.tables)

    rec = obs_recorder.active()
    rels = np.full(rounds, np.nan)
    transitions: List[dict] = []
    seg_start = 0
    sched_rows, key_rows = elastic.segment_plan(
        k_run, 0, rounds, p0, elastic.survivor_speeds(speeds, live_workers))
    perms = _perm_rows(key_rows, sp.ns)

    def replan(r, p_cur):
        rows, krows = elastic.segment_plan(
            k_run, r, rounds, p_cur,
            elastic.survivor_speeds(speeds, live_workers))
        return rows, _perm_rows(krows, sp_cur.ns)

    r = 0
    skip_boundary = False   # set after a rejoin: round r's protocol ran
    while r < rounds:
        if elastic_mode and not skip_boundary:
            # ---- wave-boundary membership protocol --------------------
            # publish the boundary table snapshot BEFORE anything can
            # die: a boundary death always leaves its tables recoverable
            comm.put(f"tab/{r}/{comm.pid}",
                     tables=tables[block.start:block.stop])
            if (fault is not None and comm.pid == fault.process
                    and r == fault.round_):
                if fault.mode == "exit":
                    raise WorkerDropped(r, rels)
                # stall: vanish (no heartbeat this boundary) and rejoin
                # through the candidate path
                rejoined = _rejoin_loop(comm, r + fault.rejoin_after,
                                        rounds, hb_timeout)
                if rejoined is None:
                    return ({"x_c": x_c, "gbar_c": gbar_c}, rels,
                            transitions)
                r, mem = rejoined
                resync = comm.get(f"resync/{r}")
                live_procs = tuple(mem["procs"])
                live_workers = tuple(mem["workers"])
                p_cur = len(live_workers)
                sp_cur = elastic.reshard_problem(sp, p_cur)
                x_c, gbar_c = resync["x_c"], resync["gbar_c"]
                x_old, gbar_old, x_fetch, gbar_fetch, tables = _fresh_views(
                    x_c, gbar_c, resync["table"], p_cur)
                blocks = worker_blocks(p_cur, len(live_procs))
                block = blocks[live_procs.index(comm.pid)]
                seg_start = r
                sched_rows, perms = replan(r, p_cur)
                fault = None
                skip_boundary = True
                continue
            comm.put_flag(f"hb/{r}/{comm.pid}", {"pid": comm.pid})
            decision = _membership_round(
                comm, r, live_procs, live_workers, blocks, tables,
                x_c, gbar_c, lost_by_proc, hb_timeout)
            if tuple(decision["procs"]) != live_procs:
                new_procs = tuple(decision["procs"])
                new_workers = tuple(decision["workers"])
                transitions.append(elastic._emit_transition(
                    rec, r, live_workers, new_workers,
                    decision["detect_s"]))
                resync = comm.get(f"resync/{r}")
                for q in live_procs:
                    if q not in new_procs:
                        lost_by_proc[q] = tuple(
                            live_workers[i]
                            for i in blocks[live_procs.index(q)])
                live_procs, live_workers = new_procs, new_workers
                p_cur = len(live_workers)
                sp_cur = elastic.reshard_problem(sp, p_cur)
                x_c, gbar_c = resync["x_c"], resync["gbar_c"]
                x_old, gbar_old, x_fetch, gbar_fetch, tables = _fresh_views(
                    x_c, gbar_c, resync["table"], p_cur)
                blocks = worker_blocks(p_cur, len(live_procs))
                block = blocks[live_procs.index(comm.pid)]
                seg_start = r
                sched_rows, perms = replan(r, p_cur)
        skip_boundary = False

        # ---- one round of waves --------------------------------------
        p_cur = len(live_workers)
        alpha = 1.0 / p_cur
        row = np.asarray(sched_rows[r - seg_start])
        base = (r - seg_start) * p_cur
        for ordered, offset in _wave_layout(row, p_cur):
            own_results: Dict[int, tuple] = {}
            for j, s in enumerate(ordered):
                if s not in block:
                    continue
                x_new, table, gtilde = _epoch_vr(
                    sp_cur.A[s], sp_cur.b[s], sp_cur.lam, sp_cur.kind,
                    jnp.asarray(x_fetch[s]), jnp.asarray(tables[s]),
                    jnp.asarray(gbar_fetch[s]), eta,
                    jnp.asarray(perms[base + offset + j]))
                own_results[s] = (np.asarray(x_new), np.asarray(table),
                                  np.asarray(gtilde))
                comm.put(f"d/{seg_start}/{r}/{offset}/{s}",
                         dx=own_results[s][0] - x_old[s],
                         dg=own_results[s][2] - gbar_old[s])
            # apply the wave's deltas in event order — the sequential
            # additions of the event-serial reference, bit for bit
            for s in ordered:
                if s in own_results:
                    x_new, table, gtilde = own_results[s]
                    dx = x_new - x_old[s]
                    dg = gtilde - gbar_old[s]
                else:
                    part = comm.get(f"d/{seg_start}/{r}/{offset}/{s}")
                    dx, dg = part["dx"], part["dg"]
                x_c = x_c + alpha * dx
                gbar_c = gbar_c + alpha * dg
                if s in own_results:
                    tables[s] = own_results[s][1]
                    x_old[s] = own_results[s][0]
                    gbar_old[s] = own_results[s][2]
                    x_fetch[s] = x_c
                    gbar_fetch[s] = gbar_c
        rels[r] = float(_rel_metric(merged.A, merged.b, sp.lam, sp.kind,
                                    jnp.asarray(x_c), g0))
        r += 1

    state = {"x_c": x_c, "gbar_c": gbar_c, "tables": tables,
             "live": np.asarray(live_workers)}
    return state, rels, transitions


def _membership_round(comm: ProcComm, r: int, live_procs, live_workers,
                      blocks, tables, x_c, gbar_c, lost_by_proc,
                      hb_timeout: float) -> dict:
    """One boundary's membership decision.  The arbiter (lowest live
    rank — process 0 by construction, co-located with the coordination
    service) waits for live peers' heartbeats, peeks for rejoin
    candidates, publishes the resync state when membership changes, then
    the decision row; everyone else blocks on the decision row."""
    if comm.pid != min(live_procs):
        return comm.get_flag(f"mem/{r}", timeout_s=3 * hb_timeout + 30)

    t0 = time.perf_counter()
    dead: List[int] = []
    with obs_recorder.span("elastic/heartbeat", round=int(r)):
        for q in live_procs:
            if q == comm.pid:
                continue
            try:
                comm.get_flag(f"hb/{r}/{q}", timeout_s=hb_timeout)
            except KVTimeout:
                dead.append(q)
    detect_s = time.perf_counter() - t0
    joiners = [q for q in range(comm.nprocs)
               if q not in live_procs and comm.peek_flag(f"hb/{r}/{q}")]
    new_procs = tuple(sorted((set(live_procs) - set(dead)) | set(joiners)))
    new_workers = tuple(live_workers)
    if new_procs != tuple(live_procs):
        gone = [w for q in dead
                for w in (live_workers[i]
                          for i in blocks[list(live_procs).index(q)])]
        back = [w for q in joiners for w in lost_by_proc.get(q, ())]
        new_workers = tuple(
            sorted((set(live_workers) - set(gone)) | set(back)))
        # assemble the merged (n,) table from the boundary snapshots (the
        # table is per-SAMPLE: the current fleet always covers all n)
        parts = []
        for rank, q in enumerate(live_procs):
            if q == comm.pid:
                part = tables[blocks[rank].start:blocks[rank].stop]
            else:
                part = comm.get(f"tab/{r}/{q}")["tables"]
            parts.append(np.asarray(part).reshape(-1))
        comm.put(f"resync/{r}", x_c=x_c, gbar_c=gbar_c,
                 table=np.concatenate(parts))
    decision = {"procs": list(new_procs), "workers": list(new_workers),
                "detect_s": detect_s if dead else 0.0}
    comm.put_flag(f"mem/{r}", decision)
    return decision


def _rejoin_loop(comm: ProcComm, target: int, rounds: int,
                 hb_timeout: float):
    """Stall-mode rejoin: from ``target`` on, heartbeat each boundary and
    wait for a membership decision that includes us.  Returns ``(round,
    decision)`` for the boundary we rejoined at, or None if the run ended
    first."""
    for r2 in range(target, rounds):
        comm.put_flag(f"hb/{r2}/{comm.pid}", {"pid": comm.pid})
        try:
            mem = comm.get_flag(f"mem/{r2}", timeout_s=3 * hb_timeout + 60)
        except KVTimeout:
            return None
        if comm.pid in mem["procs"]:
            return r2, mem
    return None


# ---------------------------------------------------------------------------
# solve() entry point (RunSpec topology="process")
# ---------------------------------------------------------------------------

def solve_process(spec, sp: ShardedProblem, eta: float, key):
    """Dispatch a ``topology='process'`` RunSpec onto this process's mesh
    context (``repro.launch.distributed`` must have initialized the
    world).  Returns ``(state, x, rels, transitions)``."""
    from repro.launch import distributed as launchd

    ctx = launchd.context()
    if ctx is None:
        raise RuntimeError(
            "RunSpec.topology='process' needs an initialized process "
            "mesh: launch through `python -m repro.launch.distributed` "
            "or call repro.launch.distributed.init_process() first "
            "(DESIGN.md §Multi-host & elasticity)")
    comm = ctx.comm
    if sp.p < comm.nprocs:
        raise ValueError(
            f"RunSpec.p: p={sp.p} workers cannot be split over the "
            f"{comm.nprocs}-process world")
    if spec.algo == "centralvr_sync":
        state, rels = run_sync_process(sp, eta=eta, rounds=spec.rounds,
                                       key=key, comm=comm)
        return state, state["x"], rels, []
    state, rels, transitions = run_async_process(
        sp, eta=eta, rounds=spec.rounds, key=key, comm=comm,
        speeds=spec.speeds, elastic_mode=spec.elastic,
        hb_timeout=ctx.hb_timeout, fault=ctx.fault)
    return state, state["x_c"], rels, transitions
