"""End-to-end dry-run machinery test on a SMALL virtual mesh (subprocess
with 8 forced host devices): reduced archs x all four shape modes must
lower + compile with the same code path as the production dry-run, and the
roofline record must be complete.
"""
import json
import subprocess
import sys
import textwrap

import pytest

# whole-module: subprocess compiles / many reduced-arch compiles — fast lane skips these (DESIGN.md §5)
pytestmark = pytest.mark.slow

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json, dataclasses
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.config import get_arch, TrainConfig, InputShape
    from repro.models import model as modellib
    from repro.sharding import specs
    from repro.train import step as tstep
    from repro.roofline import analysis, hlo_cost

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = {}
    for arch in ["qwen2-7b", "mamba2-130m", "qwen3-moe-30b-a3b",
                 "recurrentgemma-2b"]:
        cfg = get_arch(arch).reduced()
        # --- train ---
        tcfg = TrainConfig(seq_len=64, global_batch=8, optimizer="sgd",
                           vr="centralvr", vr_table_size=2)
        ts, meta = tstep.make_train_step(cfg, tcfg, mesh, "none")
        st = tstep.eval_shape_train_state(cfg, tcfg, 1)
        sh = tstep.state_shardings(st, cfg, tcfg, mesh, "none")
        toks = jax.ShapeDtypeStruct((2, 4, 64), jnp.int32)
        bsh = tstep.batch_sharding(mesh, tcfg, "none")
        c = jax.jit(ts, in_shardings=(sh, bsh["tokens"]),
                    out_shardings=(sh, None)).lower(st, toks).compile()
        hc = hlo_cost.analyze_hlo(c.as_text())
        rec = {"train_flops": hc.flops, "train_coll": hc.collective_bytes}
        # --- decode ---
        params = jax.eval_shape(
            lambda: modellib.init_params(cfg, jax.random.PRNGKey(0)))
        cache = jax.eval_shape(lambda: modellib.init_cache(cfg, 8, 64))
        psh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s),
            specs.tree_specs(params, cfg, fsdp=True, axis_sizes=sizes))
        csh = jax.tree_util.tree_map(
            lambda leaf: NamedSharding(
                mesh, P(*( [None] * (leaf.ndim - 1) + [None]))),
            cache)
        step_fn, prefill_fn = tstep.make_serve_step(cfg)
        tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        c2 = jax.jit(step_fn).lower(params, tok, cache, pos).compile()
        rec["decode_ok"] = True
        rec["mem"] = c.memory_analysis().temp_size_in_bytes
        out[arch] = rec
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def result():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_all_families_lower_and_compile(result):
    assert set(result) == {"qwen2-7b", "mamba2-130m", "qwen3-moe-30b-a3b",
                           "recurrentgemma-2b"}
    for arch, rec in result.items():
        assert rec["decode_ok"], arch
        assert rec["train_flops"] > 0, arch


def test_memory_analysis_present(result):
    for arch, rec in result.items():
        assert rec["mem"] > 0, arch
