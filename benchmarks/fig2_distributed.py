"""Figure 2 reproduction: distributed convergence + weak scaling on toy data.

Left panels: convergence of CVR-Sync / CVR-Async / D-SVRG / D-SAGA /
EASGD / PS-SVRG / dist-SGD with p workers (paper: 192 cores; here p=8
simulated workers — numerically identical semantics, see DESIGN.md §2).

Right panels (the LINEAR-SCALING headline): weak scaling — per-worker data
FIXED (|Omega_s| = const), workers swept; the hardware-independent form of
the claim is that communication ROUNDS to reach eps stay ~flat as p grows.
We report rounds-to-eps and a simulated wall-clock using the measured
per-gradient cost + a per-round communication cost model (2 x d floats,
ICI 50 GB/s + 10us latency per hop).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.config import ConvexConfig
from repro.core import baselines, convex, distributed


def _sharded(problem, p, n, d, seed=0):
    cfg = ConvexConfig(problem=problem, n=n, d=d, workers=p)
    return distributed.make_distributed(jax.random.PRNGKey(seed), cfg)


def rounds_to(rels, eps):
    r = np.asarray(rels)
    hit = np.nonzero(r < eps)[0]
    return int(hit[0]) + 1 if hit.size else float("inf")


def sim_time_per_round(n_local, d, grad_us):
    """Simulated seconds per round: n_local sequential gradient evals on
    each worker (parallel across workers) + one (x, gbar) exchange."""
    comm = 2 * d * 4 / 50e9 + 10e-6
    return n_local * grad_us * 1e-6 + comm


def run(quick: bool = False):
    rows = []
    n, d = (1000, 100) if quick else (2000, 1000)
    rounds = 10 if quick else 16

    # ---- convergence panel (logistic + ridge), p = 8 ----
    for problem in ("logistic", "ridge"):
        sp = _sharded(problem, 8, n, d)
        eta = convex.auto_eta(sp.merged(), 0.4)
        key = jax.random.PRNGKey(1)
        # warm compile, then time the steady-state scan
        jax.block_until_ready(
            distributed.run_sync(sp, eta=eta, rounds=rounds, key=key))
        t0 = time.perf_counter()
        _, r_sync = distributed.run_sync(sp, eta=eta, rounds=rounds, key=key)
        jax.block_until_ready(r_sync)
        t_sync = (time.perf_counter() - t0) / rounds
        _, r_async = distributed.run_async(sp, eta=eta, rounds=rounds,
                                           key=key)
        _, r_dsvrg = distributed.run_dsvrg(sp, eta=eta, rounds=rounds,
                                           key=key)
        _, r_dsaga = distributed.run_dsaga(sp, eta=eta / 2, rounds=rounds,
                                           key=key, tau=n // 2)
        _, r_easgd = baselines.run_easgd(sp, eta=eta, rounds=rounds, key=key)
        _, r_ps = baselines.run_ps_svrg(sp, eta=eta, rounds=rounds, key=key)
        _, r_sgd = baselines.run_dist_sgd(sp, eta=eta, rounds=rounds,
                                          key=key, decay=0.01)
        final = {
            "cvr_sync": float(r_sync[-1]), "cvr_async": float(r_async[-1]),
            "d_svrg": float(r_dsvrg[-1]), "d_saga": float(r_dsaga[-1]),
            "easgd": float(r_easgd[-1]), "ps_svrg": float(r_ps[-1]),
            "dist_sgd": float(r_sgd[-1]),
        }
        rows.append({
            "name": f"fig2/convergence-{problem}-p8",
            "us_per_call": t_sync * 1e6,
            "derived": ";".join(f"{k}={v:.2e}" for k, v in final.items()),
            "curves": {
                "cvr_sync": np.asarray(r_sync).tolist(),
                "cvr_async": np.asarray(r_async).tolist(),
                "d_svrg": np.asarray(r_dsvrg).tolist(),
                "d_saga": np.asarray(r_dsaga).tolist(),
                "easgd": np.asarray(r_easgd).tolist(),
                "ps_svrg": np.asarray(r_ps).tolist(),
                "dist_sgd": np.asarray(r_sgd).tolist(),
            },
        })

    # ---- weak scaling panel ----
    ps = (2, 4, 8) if quick else (2, 4, 8, 16)
    sc_rounds = rounds if quick else 36
    for problem in ("logistic", "ridge"):
        scaling = {}
        grad_us = None
        for p in ps:
            sp = _sharded(problem, p, n, d, seed=2)
            eta = convex.auto_eta(sp.merged(), 0.4)
            key = jax.random.PRNGKey(2)
            jax.block_until_ready(distributed.run_sync(
                sp, eta=eta, rounds=sc_rounds, key=key))
            t0 = time.perf_counter()
            _, rels = distributed.run_sync(sp, eta=eta, rounds=sc_rounds,
                                           key=key)
            jax.block_until_ready(rels)
            wall = time.perf_counter() - t0
            if grad_us is None:
                grad_us = wall / sc_rounds / n / p * 1e6 * p  # per local eval
            # per-problem tolerance: logistic's tiny strong convexity
            # (mu = 2e-4) makes its tail slow; the scaling readout only
            # needs a threshold every p reaches
            rt = rounds_to(rels, 2e-3 if problem == "logistic" else 1e-4)
            sim = (rt * sim_time_per_round(n, d, grad_us)
                   if np.isfinite(rt) else float("inf"))
            scaling[p] = {"rounds_to_eps": rt, "sim_seconds": sim,
                          "total_data": p * n}
        base_r = scaling[ps[0]]["rounds_to_eps"]
        last_r = scaling[ps[-1]]["rounds_to_eps"]
        rows.append({
            "name": f"fig2/weak-scaling-{problem}",
            "us_per_call": 0.0,
            "derived": (";".join(
                f"p{p}:rounds={scaling[p]['rounds_to_eps']}"
                for p in ps)
                + f";flat={'yes' if last_r <= base_r * 2 else 'no'}"),
            "scaling": scaling,
        })
    emit(rows, "fig2_distributed")
    return rows


if __name__ == "__main__":
    run()
