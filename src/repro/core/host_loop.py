"""Host-driven reference drivers — the pre-runtime (seed) execution model.

These reproduce the original driver layer exactly: a Python loop over
rounds, one jitted closure per call (re-traced per driver invocation), a
blocking ``float(rel)`` device->host transfer every round, and — for the
event-driven algorithms — p separately jitted per-worker closures, so
compile count grows linearly in p.

They are kept for two reasons (DESIGN.md §3):

  * ``tests/test_driver_runtime.py`` pins the scan-based drivers in
    ``centralvr`` / ``distributed`` to these trajectories — the refactor
    must be a pure execution-model change, not an algorithm change;
  * ``benchmarks/driver_throughput.py`` measures the scan runtime against
    this baseline (compile time and epochs/sec vs. worker count).

Do not add algorithms here; new work goes in the scan runtime.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import centralvr, convex, distributed, runtime
from repro.core.convex import Problem
from repro.core.distributed import ShardedProblem


def run(prob: Problem, *, eta: float, epochs: int, key: jax.Array,
        sampling: str = "permutation", x0=None):
    """Seed-model Algorithm 1 driver (host loop, per-epoch sync)."""
    k_init, k_run = jax.random.split(key)
    state = centralvr.init_state(prob, eta, k_init, x0=x0)
    g0 = jnp.linalg.norm(convex.full_grad(prob, jnp.zeros((prob.d,))))

    @jax.jit
    def one_epoch(state, k):
        if sampling == "permutation":
            order = jax.random.permutation(k, prob.n)
            new_state, _ = centralvr.epoch(prob, state, eta, order)
        else:
            new_state, _ = centralvr.epoch_uniform(prob, state, eta, k)
        rel = jnp.linalg.norm(convex.full_grad(prob, new_state.x)) / g0
        return new_state, rel

    rels = []
    grad_evals = [prob.n]  # init epoch
    keys = jax.random.split(k_run, epochs)
    for m in range(epochs):
        state, rel = one_epoch(state, keys[m])
        rels.append(float(rel))
        grad_evals.append(grad_evals[-1] + prob.n)
    return state, jnp.array(rels), jnp.array(grad_evals[1:])


def run_sync(sp: ShardedProblem, *, eta: float, rounds: int, key: jax.Array):
    """Seed-model Algorithm 2 driver."""
    merged = sp.merged()
    k_init, k_run = jax.random.split(key)
    st = distributed.sync_init(sp, eta, k_init)
    g0 = jnp.linalg.norm(convex.full_grad(merged, jnp.zeros((sp.d,))))

    @jax.jit
    def step(st, k):
        st = distributed.sync_round(sp, st, eta, k)
        rel = jnp.linalg.norm(convex.full_grad(merged, st.x)) / g0
        return st, rel

    rels = []
    for k in jax.random.split(k_run, rounds):
        st, rel = step(st, k)
        rels.append(float(rel))
    return st, jnp.array(rels)


def run_async(sp: ShardedProblem, *, eta: float, rounds: int, key: jax.Array,
              speeds=None):
    """Seed-model Algorithm 3 driver: p per-worker jitted event closures."""
    merged = sp.merged()
    k_init, k_run = jax.random.split(key)
    st = distributed.async_init(sp, eta, k_init)
    g0 = jnp.linalg.norm(convex.full_grad(merged, jnp.zeros((sp.d,))))

    event_fns = [jax.jit(lambda st, k, s=s: distributed.async_event(
        sp, st, s, eta, k)) for s in range(sp.p)]

    schedule = runtime.event_schedule(sp.p, rounds, speeds)
    rels = []
    keys = jax.random.split(k_run, len(schedule))
    for t, s in enumerate(schedule):
        st = event_fns[int(s)](st, keys[t])
        if (t + 1) % sp.p == 0:
            rel = jnp.linalg.norm(convex.full_grad(merged, st.x_c)) / g0
            rels.append(float(rel))
    return st, jnp.array(rels)


def run_dsvrg(sp: ShardedProblem, *, eta: float, rounds: int, key: jax.Array,
              tau: int = 0):
    """Seed-model Algorithm 4 driver."""
    merged = sp.merged()
    tau = tau or 2 * sp.ns
    x = jnp.zeros((sp.d,))
    g0 = jnp.linalg.norm(convex.full_grad(merged, x))

    @jax.jit
    def round_(x, k):
        xbar = x
        gbar = convex.full_grad(merged, xbar)

        def local(A, b, kk):
            prob = Problem(A, b, sp.lam, sp.kind)
            idx = jax.random.randint(kk, (tau,), 0, sp.ns)

            def body(xl, i):
                g = (convex.scalar_residual(prob, xl, i) * A[i]
                     - convex.scalar_residual(prob, xbar, i) * A[i]
                     + gbar + 2.0 * sp.lam * (xl - xbar))
                return xl - eta * g, None

            xl, _ = jax.lax.scan(body, xbar, idx)
            return xl

        xs = jax.vmap(local)(sp.A, sp.b, jax.random.split(k, sp.p))
        x = xs.mean(0)
        rel = jnp.linalg.norm(convex.full_grad(merged, x)) / g0
        return x, rel

    rels = []
    for k in jax.random.split(key, rounds):
        x, rel = round_(x, k)
        rels.append(float(rel))
    return x, jnp.array(rels)


def run_dsaga(sp: ShardedProblem, *, eta: float, rounds: int, key: jax.Array,
              tau: int = 100, literal_scaling: bool = False):
    """Seed-model Algorithm 5 driver: p per-worker jitted event closures."""
    merged = sp.merged()
    st = distributed.dsaga_init(sp)
    g0 = jnp.linalg.norm(convex.full_grad(merged, jnp.zeros((sp.d,))))

    event_fns = [jax.jit(lambda st, k, s=s: distributed.dsaga_event(
        sp, st, s, eta, tau, k, literal_scaling)) for s in range(sp.p)]
    rels = []
    n_events = rounds * sp.p
    keys = jax.random.split(key, n_events)
    for t in range(n_events):
        st = event_fns[t % sp.p](st, keys[t])
        if (t + 1) % sp.p == 0:
            rel = jnp.linalg.norm(convex.full_grad(merged, st.x_c)) / g0
            rels.append(float(rel))
    return st, jnp.array(rels)
