"""Serving launcher: batched prefill + decode with the sharded KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --batch 4 --prompt-len 32 --decode-tokens 16
"""
from __future__ import annotations

import argparse
import functools
import time


@functools.lru_cache(maxsize=None)
def compiled_decode_step(cfg):
    """ONE jitted token step per arch config, shared by prefill and decode
    and cached across launches in the same process — the seed wrapped a
    fresh unjitted lambda inside ``main`` on every launch, so each launch
    re-traced and prefill/decode could not share the compiled executable.
    ``cfg`` is a frozen dataclass (hashable) and is baked in as a static
    closure; ``pos`` stays a traced scalar so every token position hits the
    same cache entry."""
    import jax

    from repro.models import model

    @jax.jit
    def step(params, token, cache, pos):
        return model.decode_step(params, cfg, token, cache, pos)

    return step


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs", default="", metavar="PATH",
                    help="record telemetry (warmup/prefill/decode spans + "
                         "tok/s) to this JSONL file")
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    import jax
    import jax.numpy as jnp

    from repro import obs
    from repro.config import get_arch
    from repro.data import synthetic
    from repro.models import model

    if args.obs:
        obs.enable(args.obs)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = model.init_params(cfg, key)
    B, S = args.batch, args.prompt_len
    max_len = S + args.decode_tokens
    prompts = synthetic.eval_batch(cfg, args.seed, batch=B, seq=S)

    # prefill: run the prompt through the SAME compiled decode step that
    # serves decode, building the cache token by token (chunked
    # prefill-into-cache; the dry-run prefill path lowers the
    # full-sequence forward instead)
    cache = model.init_cache(cfg, B, max_len)
    step = compiled_decode_step(cfg)
    # pay the one-time compile outside both timed regions (on a throwaway
    # cache), so the prefill/decode tok/s compare throughput, not XLA
    with obs.span("serve/warmup", batch=B):
        jax.block_until_ready(
            step(params, prompts[:, :1], model.init_cache(cfg, B, max_len),
                 0))
    t0 = time.time()
    with obs.span("serve/prefill", tokens=S, batch=B):
        logits = None
        for t in range(S):
            logits, cache = step(params, prompts[:, t:t + 1], cache, t)
        jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    # decode (timer covers all n_gen tokens, including the first one
    # sampled from the prefill logits)
    t0 = time.time()
    with obs.span("serve/decode", batch=B):
        tok = jnp.argmax(logits, -1)[:, None]
        out_tokens = [tok]
        for t in range(S, max_len - 1):
            logits, cache = step(params, tok, cache, t)
            if args.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits / args.temperature)[:, None]
            else:
                tok = jnp.argmax(logits, -1)[:, None]
            out_tokens.append(tok)
        gen = jnp.concatenate(out_tokens, axis=1)
        jax.block_until_ready(gen)
    t_decode = time.time() - t0
    n_gen = gen.shape[1]
    rec = obs.active()
    if rec is not None:
        rec.event("serve_throughput", batch=B, prefill_tokens=S,
                  prefill_s=t_prefill,
                  prefill_tok_s=B * S / max(t_prefill, 1e-9),
                  decode_tokens=n_gen, decode_s=t_decode,
                  decode_tok_s=B * n_gen / max(t_decode, 1e-9))
        obs.disable()
        print(f"wrote telemetry to {args.obs}")
    print(f"prefill {S} tokens x {B} seqs: {t_prefill:.2f}s "
          f"({B * S / max(t_prefill, 1e-9):.1f} tok/s); "
          f"decode {n_gen} tokens: {t_decode:.2f}s "
          f"({B * n_gen / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
