"""RG-LRU recurrent block (RecurrentGemma / Griffin [arXiv:2402.19427]).

    r_t = sigmoid(W_a x_t)          recurrence gate (block-diagonal, per head)
    i_t = sigmoid(W_x x_t)          input gate
    a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses ``jax.lax.associative_scan`` over time (parallel prefix,
log-depth — the TPU-native replacement for the paper's linear-scan CUDA
kernel); decode is the O(1) recurrence.

The full recurrent block is Griffin's: two d->dr branches, branch one goes
conv1d(4) -> RG-LRU, branch two GeLU; elementwise product, project back.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers

CONV_K = 4
C_RGLRU = 8.0


def init_rglru(cfg: ModelConfig, key, dtype):
    d = cfg.d_model
    dr = d                                  # Griffin uses d_rec = d_model
    H = cfg.rglru_heads or cfg.num_heads
    hb = dr // H
    ks = jax.random.split(key, 5)
    # Lambda init so that a^c in [0.9, 0.999] (paper's init range)
    u = jax.random.uniform(ks[0], (dr,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / C_RGLRU))  # softplus^-1(-log u / c)
    return {
        "wx_in": layers._dense_init(ks[1], (d, dr), d, dtype),
        "wy_in": layers._dense_init(ks[2], (d, dr), d, dtype),
        "conv_w": (jax.random.normal(ks[3], (CONV_K, dr)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "wa": (jax.random.normal(ks[4], (H, hb, hb)) / jnp.sqrt(hb)
               ).astype(dtype),
        "wi": (jax.random.normal(jax.random.fold_in(ks[4], 1), (H, hb, hb))
               / jnp.sqrt(hb)).astype(dtype),
        "lambda": lam.astype(jnp.float32),
        "out": layers._dense_init(jax.random.fold_in(ks[1], 1), (dr, d), dr,
                                  dtype),
    }


def _blockdiag(w, x):
    """x: (..., dr) -> per-head block-diagonal matmul; w: (H, hb, hb)."""
    H, hb, _ = w.shape
    xh = x.reshape(*x.shape[:-1], H, hb)
    yh = jnp.einsum("...hb,hbc->...hc", xh, w)
    return yh.reshape(*x.shape)


def _gates(p, x):
    """Returns (log_a, gated_input) for the RG-LRU at inputs x (B,S,dr)."""
    r = jax.nn.sigmoid(_blockdiag(p["wa"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(_blockdiag(p["wi"], x).astype(jnp.float32))
    log_a = -C_RGLRU * jax.nn.softplus(p["lambda"]) * r
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * i * x.astype(jnp.float32)
    return log_a, gated


def rglru_scan(p, x):
    """x: (B, S, dr) -> h: (B, S, dr), h_final. Parallel prefix scan."""
    log_a, b = _gates(p, x)
    a = jnp.exp(log_a)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hh, hh[:, -1]


def rglru_step(p, x, h_prev):
    """x: (B, 1, dr); O(1) decode step."""
    log_a, b = _gates(p, x)
    h = jnp.exp(log_a[:, 0]) * h_prev + b[:, 0]
    return h[:, None, :], h


class RecCache(NamedTuple):
    conv: jax.Array   # (B, CONV_K-1, dr)
    h: jax.Array      # (B, dr)


def init_rec_cache(cfg: ModelConfig, batch: int, dtype) -> RecCache:
    dr = cfg.d_model
    return RecCache(conv=jnp.zeros((batch, CONV_K - 1, dr), dtype),
                    h=jnp.zeros((batch, dr), jnp.float32))


def apply_rec_train(p, cfg: ModelConfig, u):
    """Griffin recurrent block, full sequence. u: (B, S, d)."""
    x = u @ p["wx_in"]
    y = jax.nn.gelu(u @ p["wy_in"])
    # causal depthwise conv
    pad = jnp.pad(x, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    x = sum(pad[:, i:i + u.shape[1], :] * p["conv_w"][i]
            for i in range(CONV_K)) + p["conv_b"]
    h, _ = rglru_scan(p, x)
    return (h.astype(u.dtype) * y) @ p["out"]


def apply_rec_decode(p, cfg: ModelConfig, u, cache: RecCache):
    x_new = u @ p["wx_in"]                                 # (B, 1, dr)
    y = jax.nn.gelu(u @ p["wy_in"])
    conv_in = jnp.concatenate([cache.conv, x_new], axis=1)
    x = (sum(conv_in[:, i, :] * p["conv_w"][i] for i in range(CONV_K))
         + p["conv_b"])[:, None, :]
    h_seq, h = rglru_step(p, x, cache.h)
    out = (h_seq.astype(u.dtype) * y) @ p["out"]
    return out, RecCache(conv=conv_in[:, 1:], h=h)
