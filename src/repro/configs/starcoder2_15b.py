"""StarCoder2-15B [arXiv:2402.19173] — dense, GQA (48Q/4KV), RoPE,
LayerNorm + bias, GELU MLP (d_ff=24576), sliding-window-capable (4096)."""
from repro.config import ModelConfig, register

STARCODER2_15B = register(ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    qkv_bias=True,
    rope_theta=100_000.0,
    norm_type="layernorm",
    mlp_type="gelu",
    mlp_bias=True,
))
