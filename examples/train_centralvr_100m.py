"""End-to-end training driver: a ~100M-param dense LM trained with
CentralVR for a few hundred steps through the full stack (config system ->
data pipeline -> CentralVR train step -> checkpointing -> eval).

The default profile is sized for the 1-core CPU container (a ~20M model,
200 steps, ~10 min). ``--full`` selects the ~100M x 300-step profile the
deliverable names (identical code path; budget several hours on CPU — on
one v5e host it is minutes).

    python examples/train_centralvr_100m.py [--full]
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))
import repro_bootstrap  # noqa: F401,E402  (adds src/ if repro isn't installed)

from repro.config import ModelConfig, TrainConfig
from repro.train import loop


def model_cfg(full: bool) -> ModelConfig:
    if full:
        # ~102M params: 12L, d=640, GQA 10/2, vocab 32k
        return ModelConfig(
            name="centralvr-100m", family="dense", num_layers=12,
            d_model=640, num_heads=10, num_kv_heads=2, head_dim=64,
            d_ff=1792, vocab_size=32000, qkv_bias=True,
            norm_type="rmsnorm", mlp_type="swiglu")
    # ~21M params: the same family, container-sized
    return ModelConfig(
        name="centralvr-20m", family="dense", num_layers=8, d_model=320,
        num_heads=8, num_kv_heads=2, head_dim=40, d_ff=896,
        vocab_size=16000, qkv_bias=True, norm_type="rmsnorm",
        mlp_type="swiglu")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--checkpoint", default="results/ckpt/centralvr_lm.npz")
    args = ap.parse_args()

    cfg = model_cfg(args.full)
    # the epoch-scan loop drives whole communication epochs (M*K = 8
    # steps each), so the step budget is rounded up to epoch granularity
    steps = args.steps or (304 if args.full else 200)
    tcfg = TrainConfig(
        seq_len=256 if args.full else 128,
        global_batch=8, microbatch=2,
        learning_rate=3e-3, optimizer="adam",
        vr="centralvr", vr_table_size=8, local_epoch=1, seed=0)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}  params={n_params / 1e6:.1f}M  "
          f"steps={steps}  vr={tcfg.vr} (M={tcfg.vr_table_size})")
    res = loop.run_training(
        cfg, tcfg, steps=steps, log_every=2,
        checkpoint_path=args.checkpoint, checkpoint_every=12)
    print(f"\ndone in {res.wall_time:.0f}s — "
          f"train loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}; "
          f"held-out eval loss {res.final_eval_loss:.3f}; "
          f"checkpoint at {args.checkpoint}")
    assert res.losses[-1] < res.losses[0], "training must make progress"


if __name__ == "__main__":
    main()
