"""CI regression guard over the benchmark artifacts (DESIGN.md §7).

Gates TWO artifacts (the ``--quick`` harness run regenerates both):

  * ``BENCH_drivers.json`` (``benchmarks/driver_throughput.py``) — every
    driver's warm scan-runtime speedup over the seed host loop must stay
    at or above the floor;
  * ``BENCH_train.json`` (``benchmarks/train_throughput.py``) — every
    epoch-scan path (``scan-vmap``, ``scan-spmd``) must stay at or above
    the floor against the seed per-step host path (``speedup_vs_host``).

The device-resident runtimes losing to the host loops they replaced is a
performance regression whatever absolute wall clock the runner has.  A
missing or row-less artifact is itself a failure — a gate that silently
passes because the bench never ran guards nothing.

    python benchmarks/check_regression.py [--path BENCH_drivers.json]
                                          [--train-path BENCH_train.json]
                                          [--floor 1.0]

Exit status 1 on regression — the benchmark-smoke CI job gates on it.
"""
from __future__ import annotations

import argparse
import json
import sys


def _load_rows(path: str):
    """Rows of one artifact; missing/unreadable/empty is a hard failure."""
    try:
        with open(path) as f:
            rows = json.load(f)["rows"]
    except (OSError, KeyError, TypeError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable bench artifact ({e}); run "
              "`python benchmarks/run.py --quick` first", file=sys.stderr)
        return None
    if not rows:
        print(f"{path} has no rows", file=sys.stderr)
        return None
    return rows


def _gate(rows, speedup_key: str, floor: float, what: str):
    """Names of rows whose speedup is below the floor (prints each row)."""
    bad = []
    for r in rows:
        speedup = r[speedup_key]
        status = "ok" if speedup >= floor else "REGRESSION"
        print(f"{r['name']}: {what} {speedup:.1f}x warm [{status}]")
        if speedup < floor:
            bad.append(r["name"])
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default="BENCH_drivers.json",
                    help="driver-throughput artifact to check")
    ap.add_argument("--train-path", default="BENCH_train.json",
                    help="train-throughput artifact to check")
    ap.add_argument("--floor", type=float, default=1.0,
                    help="minimum acceptable warm speedup over the seed "
                         "host path")
    args = ap.parse_args(argv)

    failed = False

    rows = _load_rows(args.path)
    if rows is None:
        failed = True
    else:
        bad = _gate(rows, "speedup_warm", args.floor, "scan vs host loop")
        if bad:
            print(f"speedup below {args.floor:.2f}x floor for: "
                  f"{', '.join(bad)}", file=sys.stderr)
            failed = True
        else:
            print(f"all {len(rows)} drivers at or above the "
                  f"{args.floor:.2f}x floor")

    rows = _load_rows(args.train_path)
    if rows is None:
        failed = True
    else:
        scan = [r for r in rows if r["path"].startswith("scan-")]
        if not scan:
            print(f"{args.train_path} has no scan-path rows",
                  file=sys.stderr)
            failed = True
        else:
            bad = _gate(scan, "speedup_vs_host", args.floor,
                        "epoch scan vs seed host path")
            if bad:
                print(f"train speedup below {args.floor:.2f}x floor for: "
                      f"{', '.join(bad)}", file=sys.stderr)
                failed = True
            else:
                print(f"all {len(scan)} train scan paths at or above the "
                      f"{args.floor:.2f}x floor")

    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
