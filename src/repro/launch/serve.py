"""Serving launcher: batched prefill + decode with the sharded KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --batch 4 --prompt-len 32 --decode-tokens 16
"""
from __future__ import annotations

import argparse
import time


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    import jax
    import jax.numpy as jnp

    from repro.config import get_arch
    from repro.data import synthetic
    from repro.models import model

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = model.init_params(cfg, key)
    B, S = args.batch, args.prompt_len
    max_len = S + args.decode_tokens
    prompts = synthetic.eval_batch(cfg, args.seed, batch=B, seq=S)

    # prefill: run the prompt through decode steps to build the cache
    # (chunked prefill-into-cache; simple sequential here — the dry-run
    # prefill path lowers the full-sequence forward instead)
    cache = model.init_cache(cfg, B, max_len)
    step = jax.jit(lambda p, t, c, pos: model.decode_step(p, cfg, t, c, pos),
                   static_argnums=())
    t0 = time.time()
    logits = None
    for t in range(S):
        logits, cache = step(params, prompts[:, t:t + 1], cache, t)
    t_prefill = time.time() - t0

    # decode
    tok = jnp.argmax(logits, -1)[:, None]
    out_tokens = [tok]
    t0 = time.time()
    for t in range(S, max_len - 1):
        logits, cache = step(params, tok, cache, t)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits, -1)[:, None]
        out_tokens.append(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    n_gen = gen.shape[1]
    print(f"prefill {S} tokens x {B} seqs: {t_prefill:.2f}s; "
          f"decode {n_gen} tokens: {t_decode:.2f}s "
          f"({B * n_gen / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
