"""Test-session configuration.

x64 is enabled so the paper's exact algebraic invariants (telescoping,
unbiasedness, delta-replacement) can be asserted to near machine precision;
model code is dtype-explicit so the zoo still exercises its configured
float32/bfloat16 paths.

NOTE: XLA_FLAGS device-count forcing deliberately does NOT happen here —
smoke tests and benches must see the real single CPU device; only
``repro/launch/dryrun.py`` forces 512 placeholder devices (see that file).
Mesh-semantics tests spawn a subprocess with the flag instead.
"""
import jax

jax.config.update("jax_enable_x64", True)
