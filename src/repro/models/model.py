"""Top-level model API: init / train loss / prefill / decode for every
architecture in the zoo, including the VLM/audio frontend stubs.

Inputs are batch dicts:
  train/prefill: {"tokens": (B, S) int32, ["labels": (B, S)],
                  ["frontend_embeds": (B, S_f, d) — VLM/audio stub]}
  decode:        {"token": (B, 1) int32, "pos": scalar int32} + cache

For frontend models the total sequence is S_f + S_text; the loss is masked
over the embedding positions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers, transformer


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    k_embed, k_stack, k_head, k_fr = jax.random.split(key, 4)
    p = {
        "embed": layers.init_embed(cfg, k_embed, dtype),
        "layers": transformer.init_stack(cfg, k_stack, dtype),
        "final_norm": layers.init_norm(cfg, dtype),
        "head": layers.init_lm_head(cfg, k_head, dtype),
    }
    if cfg.frontend is not None:
        p["frontend_proj"] = layers._dense_init(
            k_fr, (cfg.d_model, cfg.d_model), cfg.d_model, dtype)
    return p


def _embed_inputs(p, cfg: ModelConfig, batch):
    compute = jnp.dtype(cfg.dtype)
    x = layers.embed_tokens(p["embed"], batch["tokens"]).astype(compute)
    if cfg.frontend is not None and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(compute) @ p[
            "frontend_proj"].astype(compute)
        x = jnp.concatenate([fe, x], axis=1)
    return x


def forward(p, cfg: ModelConfig, batch, *, remat: str = "block",
            window: Optional[int] = None, act_sharding=None):
    """Full-sequence forward: returns (logits[f32], aux)."""
    x = _embed_inputs(p, cfg, batch)
    x, aux = transformer.apply_stack_train(p["layers"], cfg, x, remat=remat,
                                           window=window,
                                           act_sharding=act_sharding)
    x = layers.apply_norm(p["final_norm"], x, cfg.norm_type)
    logits = layers.lm_logits(p["head"], p["embed"], x, cfg.tie_embeddings)
    return logits.astype(jnp.float32), aux


def loss_fn(p, cfg: ModelConfig, batch, *, remat: str = "block",
            window: Optional[int] = None, act_sharding=None):
    """Next-token cross-entropy (+ MoE aux). Labels default to shifted
    tokens. Frontend positions are excluded from the loss."""
    logits, aux = forward(p, cfg, batch, remat=remat, window=window,
                          act_sharding=act_sharding)
    tokens = batch["tokens"]
    labels = batch.get("labels")
    n_f = logits.shape[1] - tokens.shape[1]        # frontend positions
    if labels is None:
        labels = tokens[:, 1:]
        logits_txt = logits[:, n_f:-1] if n_f else logits[:, :-1]
    else:
        logits_txt = logits[:, n_f:] if n_f else logits
    logp = jax.nn.log_softmax(logits_txt, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean() + aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return transformer.init_stack_cache(cfg, batch, max_len,
                                        jnp.dtype(cfg.dtype))


def decode_step(p, cfg: ModelConfig, token, cache, pos):
    """token: (B, 1) -> (logits (B, vocab), new_cache)."""
    compute = jnp.dtype(cfg.dtype)
    x = layers.embed_tokens(p["embed"], token).astype(compute)
    x, cache = transformer.apply_stack_decode(p["layers"], cache, cfg, x, pos)
    x = layers.apply_norm(p["final_norm"], x, cfg.norm_type)
    logits = layers.lm_logits(p["head"], p["embed"], x, cfg.tie_embeddings)
    return logits[:, 0].astype(jnp.float32), cache


def param_count_actual(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))
