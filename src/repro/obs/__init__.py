"""repro.obs — structured run telemetry.

One JSONL record per run (spans, streamed in-scan metrics, counters,
comms/staleness accounting), OFF by default and zero-overhead when off.
The package is import-light by design: ``import repro.obs`` never imports
jax (``repro``'s force_host_devices contract), and every module here is
safe to import from ``repro.core`` without cycles.

Quickstart::

    from repro import obs, solve, RunSpec

    with obs.recording("run.jsonl"):
        res = solve(RunSpec(algo="centralvr_async", p=4, eta=0.05,
                            rounds=40, speeds=(4.0, 2.0, 1.0, 1.0)))
    # then: python -m repro.launch.obs report run.jsonl

Pieces:

  * :mod:`repro.obs.recorder` — the JSONL sink (``Recorder``), module
    recorder slot (``enable``/``disable``/``active``/``recording``) and
    the no-op-safe ``span`` helper.
  * :mod:`repro.obs.stage`    — ``staged_call``: explicit
    ``lower/compile/execute`` phase spans around the jitted runners.
  * :mod:`repro.obs.stream`   — cadence-gated ``jax.debug.callback``
    metric streaming from inside the jitted scans.
  * :mod:`repro.obs.comms`    — analytical bytes-per-collective models.
  * :mod:`repro.obs.staleness`— fetch-staleness histogram + wave stats
    from the deterministic async event schedule.
  * :mod:`repro.obs.schema`   — row schema, validators, and the golden
    provenance key sets the tests pin.
  * :mod:`repro.obs.report`   — timeline/summary rendering for the
    ``repro.launch.obs`` CLI.
"""
from __future__ import annotations

from repro.obs.comms import comms_model
from repro.obs.recorder import (Recorder, active, disable, enable,
                                recording, span)
from repro.obs.schema import SCHEMA_VERSION, SchemaError, validate_file
from repro.obs.stage import staged_call
from repro.obs.staleness import staleness_stats
from repro.obs.stream import scan_metric, stream_active

__all__ = [
    "Recorder", "active", "enable", "disable", "recording", "span",
    "staged_call", "scan_metric", "stream_active",
    "comms_model", "staleness_stats",
    "SCHEMA_VERSION", "SchemaError", "validate_file",
]
