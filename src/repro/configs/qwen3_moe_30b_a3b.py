"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — MoE: 128 experts, top-8, no shared
expert; GQA 32Q/4KV, qk_norm, head_dim=128, moe_d_ff=768."""
from repro.config import ModelConfig, register

QWEN3_MOE_30B_A3B = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,                # kept for config parity; MoE path uses moe_d_ff
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    num_experts=128,
    num_experts_per_tok=8,
    moe_d_ff=768,
    norm_type="rmsnorm",
    mlp_type="swiglu",
))
