"""The pre-engine serving path: static batching + per-token host loop.

Kept as (a) the fallback for architectures the engine does not serve
(ssm / rec caches, frontend embeds) and (b) the OLD-PATH twin in
BENCH_serve.json — every engine gate row is paired with a host-loop row
at the same workload, so the "new decode tok/s >= old" regression gate
has a measured baseline rather than a remembered one.

Semantics (unchanged from the original launch/serve.py): requests are
grouped in arrival order into static batches of ``width``; each group
prefily runs S per-token ``model.decode_step`` launches against a dense
fully-preallocated cache, then decodes in lockstep to the group's LARGEST
max_new (lanes that finish early ride along as pure padding waste — the
cost continuous batching removes).

One fix vs the original: warmup no longer allocates a second full-size
throwaway cache (``model.init_cache`` used to be built twice, doubling
peak KV memory for large configs).  The first jitted step IS the warmup —
it runs on the real, donated cache and its (compile-dominated) time is
reported as ``compile_s`` instead of being folded into throughput.
"""
from __future__ import annotations

import time
from typing import Dict, Sequence

import numpy as np

import jax

from repro import obs
from repro.config import ModelConfig
from repro.models import model
from repro.serve.engine import RequestResult, ServeReport
from repro.serve.trace import Request, prompt_tokens


def run_host_loop(cfg: ModelConfig, reqs: Sequence[Request], *, params=None,
                  width: int = 4, seed: int = 0) -> ServeReport:
    """Serve ``reqs`` with the legacy path; returns the same ServeReport
    shape as the engine so bench rows are directly comparable."""
    prompt_lens = {r.prompt_len for r in reqs}
    if len(prompt_lens) != 1:
        raise ValueError("legacy host loop batches lockstep: all requests "
                         f"must share one prompt_len, got {prompt_lens}")
    if params is None:
        params = model.init_params(cfg, jax.random.PRNGKey(seed))

    def step_fn(p, tok, cache, pos):
        return model.decode_step(p, cfg, tok, cache, pos)

    step = jax.jit(step_fn, donate_argnums=(2,))

    ordered = sorted(reqs, key=lambda r: (r.arrival, r.rid))
    results = {r.rid: RequestResult(rid=r.rid, prompt_len=r.prompt_len,
                                    max_new=r.max_new,
                                    arrival_step=r.arrival,
                                    t_seen=time.time())
               for r in reqs}
    rep = ServeReport(results=[])
    compile_s: Dict[str, float] = {}
    wall0 = time.perf_counter()
    cold = True
    with obs.span("serve/legacy_run", requests=len(reqs), width=width):
        for g0 in range(0, len(ordered), width):
            group = ordered[g0:g0 + width]
            B, S = len(group), group[0].prompt_len
            gmax = max(r.max_new for r in group)
            prompts = np.stack([prompt_tokens(r, cfg.vocab_size)
                                for r in group])
            # ONE cache per group; the first step below doubles as warmup
            cache = model.init_cache(cfg, B, S + gmax)
            t_start = 0
            if cold:
                t0 = time.perf_counter()
                logits, cache = step(params, prompts[:, :1], cache,
                                     np.int32(0))
                jax.block_until_ready(logits)
                compile_s["decode"] = time.perf_counter() - t0
                cold = False
                t_start = 1
            with obs.span("serve/legacy_prefill", batch=B, tokens=B * S):
                t0 = time.perf_counter()
                for t in range(t_start, S):
                    logits, cache = step(params, prompts[:, t:t + 1], cache,
                                         np.int32(t))
                cur = np.argmax(np.asarray(logits), axis=-1)
                rep.prefill_s += time.perf_counter() - t0
            rep.prefill_tokens += B * (S - t_start)
            rep.steps += S
            now = time.time()
            gen = np.zeros((B, gmax), np.int64)
            gen[:, 0] = cur
            for r in group:
                results[r.rid].t_first = now
            with obs.span("serve/legacy_decode", batch=B, steps=gmax - 1):
                t0 = time.perf_counter()
                for g in range(1, gmax):
                    tok = cur[:, None].astype(np.int32)
                    logits, cache = step(params, tok, cache,
                                         np.int32(S + g - 1))
                    cur = np.argmax(np.asarray(logits), axis=-1)
                    gen[:, g] = cur
                rep.decode_s += time.perf_counter() - t0
            rep.steps += gmax - 1
            rep.decode_tokens += sum(r.max_new - 1 for r in group)
            now = time.time()
            for i, r in enumerate(group):
                res = results[r.rid]
                res.tokens = [int(x) for x in gen[i, :r.max_new]]
                res.t_finish = now
                res.finish_step = rep.steps
            del cache
    rep.wall_s = time.perf_counter() - wall0
    rep.compile_s = compile_s
    rep.results = [results[r.rid] for r in sorted(reqs, key=lambda q: q.rid)]
    rec = obs.active()
    if rec:
        rec.event("serve_report", path="legacy", **rep.summary())
    return rep
