"""jit'd public wrapper: pytree-level fused VR update.

Flattens the param pytree into one contiguous stream per buffer, pads to
the kernel tile, runs the fused kernel, and unflattens — one kernel launch
per training step regardless of tree structure.

The flat layout (leaf sizes, offsets, pad) depends only on the tree
structure, so it is computed once per (treedef, shapes) and cached; the
pad is folded into the same single concatenate as the leaves instead of a
second copy of the full stream.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.vr_update import kernel


class _Layout(NamedTuple):
    sizes: Tuple[int, ...]      # flat element count per leaf
    offsets: Tuple[int, ...]    # start offset of each leaf in the stream
    n: int                      # total un-padded length
    pad: int                    # zeros appended to reach a TILE multiple


@functools.lru_cache(maxsize=256)
def _layout(treedef, shapes) -> _Layout:
    del treedef  # part of the cache key only
    sizes, offsets, o = [], [], 0
    for s in shapes:
        sz = 1
        for d in s:
            sz *= d
        sizes.append(sz)
        offsets.append(o)
        o += sz
    return _Layout(tuple(sizes), tuple(offsets), o, (-o) % kernel.TILE)


def _flatten(tree):
    """Flatten + cast to f32 + pad to the kernel tile in ONE concatenate.

    Leaves already in float32 skip the astype; the tile padding rides in
    the same concatenate as a zeros leaf instead of re-copying the stream.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    lay = _layout(treedef, tuple(l.shape for l in leaves))
    parts = [l.reshape(-1) if l.dtype == jnp.float32
             else l.reshape(-1).astype(jnp.float32) for l in leaves]
    if lay.pad:
        parts.append(jnp.zeros((lay.pad,), jnp.float32))
    flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    return flat, leaves, treedef, lay


def _unflatten(flat, leaves, treedef, lay, dtype=None):
    out = [flat[o:o + sz].reshape(l.shape).astype(dtype or l.dtype)
           for l, sz, o in zip(leaves, lay.sizes, lay.offsets)]
    return jax.tree_util.tree_unflatten(treedef, out)


def _vr_update_impl(x_tree, g_tree, gold_tree, gbar_tree, gtilde_tree, *,
                    eta: float, m: int, saga: bool = False,
                    decay: float = 0.0, interpret: bool = False):
    x, x_leaves, treedef, lay = _flatten(x_tree)
    g = _flatten(g_tree)[0]
    gold = _flatten(gold_tree)[0]
    gbar = _flatten(gbar_tree)[0]
    gtilde = _flatten(gtilde_tree)[0]
    n = lay.n
    xo, tbl, gto, gbo = kernel.vr_update_flat(
        x, g, gold, gbar, gtilde, eta=eta, m=m, saga=saga, decay=decay,
        interpret=interpret)
    return (_unflatten(xo[:n], x_leaves, treedef, lay),
            _unflatten(tbl[:n], x_leaves, treedef, lay, jnp.float32),
            _unflatten(gto[:n], x_leaves, treedef, lay, jnp.float32),
            _unflatten(gbo[:n], x_leaves, treedef, lay, jnp.float32))


vr_update = jax.jit(
    _vr_update_impl,
    static_argnames=("eta", "m", "saga", "decay", "interpret"),
    donate_argnums=(0, 1, 2, 3, 4))
vr_update.__doc__ = """Returns (x', table', gtilde', gbar') as pytrees like the inputs.

All five param-sized input pytrees are DONATED: their buffers are
reused for the outputs instead of freshly allocated each training
step, so callers must not read the arguments after the call (the
training step consumes its previous VR state anyway), and the five
arguments must be distinct buffers — passing the same array twice
raises XLA's double-donation error."""

# Non-donating variant for call sites already inside a jit (e.g. the LM
# epoch scan): traces inline, so donation is managed by the outer jit and
# XLA's buffer aliasing, not by a nested jit boundary (which would be
# silently ignored anyway).
vr_update_inline = _vr_update_impl
