"""Process-global fused-kernel context for the LM forward pass.

The model stack is pure functions of (params, cfg, batch) — there is no
per-call config object to carry a "use Pallas kernels" bit through
``model.forward`` -> blocks -> ``layers.apply_norm`` /
``attention.attend_train``. Like ``sharding/gather_ctx``, the switch is a
process-global consulted at TRACE time: the step factories
(``train/step.py``) enable it around tracing their jitted runners and the
decision is baked into the compiled executable, so nothing is looked up
per step at run time.

Trace-time means jit-cache discipline is the caller's problem: any cached
runner factory that traces under this context must key its cache on the
(fused, interpret) pair it traced with (see ``step._epoch_runner_vmap``).
"""
from __future__ import annotations

import contextlib

_STATE = {"active": False, "interpret": False}


def enable(interpret: bool = False) -> None:
    _STATE["active"] = True
    _STATE["interpret"] = bool(interpret)


def disable() -> None:
    _STATE["active"] = False
    _STATE["interpret"] = False


def active() -> bool:
    return _STATE["active"]


def interpret() -> bool:
    return _STATE["interpret"]


@contextlib.contextmanager
def scope(active: bool = True, interpret: bool = False):
    """Enable (or disable) kernel dispatch for the duration of a trace."""
    prev = dict(_STATE)
    _STATE["active"] = bool(active)
    _STATE["interpret"] = bool(interpret)
    try:
        yield
    finally:
        _STATE.update(prev)
