"""Communication-period sensitivity (the paper's §6.2 robustness study):

* D-SAGA at tau in {10, 100, 1000} — "relatively stable", degrading at
  very large tau (the paper reports slowdown at tau=10000);
* EASGD at tau in {4, 16, 64} — "nearly insensitive";
* CentralVR-Sync at local epochs K in {1, 2, 4} between exchanges — the
  paper's claim that the epoch-frozen anchor tolerates LOW communication
  frequency (this is the LM TrainConfig.local_epoch knob, exercised here
  on the convex substrate where ground truth is measurable).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.config import ConvexConfig
from repro.core import baselines, convex, distributed


def run(quick: bool = False):
    rows = []
    n, d, p = (400, 50, 4) if quick else (1500, 200, 8)
    rounds = 10 if quick else 16
    cfg = ConvexConfig(problem="logistic", n=n, d=d, workers=p)
    sp = distributed.make_distributed(jax.random.PRNGKey(0), cfg)
    eta = convex.auto_eta(sp.merged(), 0.4)
    key = jax.random.PRNGKey(1)

    # --- D-SAGA tau sweep ---
    taus = (10, 100, 1000) if not quick else (10, 100)
    finals = {}
    for tau in taus:
        # equal total local iterations across settings
        r = max((rounds * n) // tau, 2)
        _, rels = distributed.run_dsaga(sp, eta=eta / 2, rounds=r, key=key,
                                        tau=tau)
        finals[tau] = float(rels[-1])
    stable = max(finals.values()) < 1.0 and all(
        np.isfinite(v) for v in finals.values())
    rows.append({
        "name": "tau_sweep/d-saga",
        "us_per_call": 0.0,
        "derived": (";".join(f"tau{t}={v:.2e}" for t, v in finals.items())
                    + f";stable={'yes' if stable else 'no'}"),
    })

    # --- EASGD tau sweep ---
    finals = {}
    for tau in (4, 16, 64):
        _, rels = baselines.run_easgd(sp, eta=eta, rounds=rounds, key=key,
                                      tau=tau)
        finals[tau] = float(rels[-1])
    spread = max(finals.values()) / max(min(finals.values()), 1e-12)
    rows.append({
        "name": "tau_sweep/easgd",
        "us_per_call": 0.0,
        "derived": (";".join(f"tau{t}={v:.2e}" for t, v in finals.items())
                    + f";insensitive={'yes' if spread < 10 else 'no'}"),
    })

    # --- CentralVR local epochs between exchanges ---
    # K local epochs before averaging: chain rounds on detached workers,
    # averaging only when the round index hits the communication period.
    # One scan over rounds; whether a round communicates is DATA (the
    # do_avg mask), so every K reuses the same compiled driver.
    # no donation here: only the scalar metric leaves the scan, so there
    # is no output buffer for the state to alias
    @jax.jit
    def _local_epochs_scan(sp, xs, tables, gbars, eta, keys, do_avg, g0):
        def body(carry, ins):
            xs, tables, gbars = carry
            k, avg = ins
            perms = jax.vmap(lambda kk: jax.random.permutation(kk, sp.ns))(
                jax.random.split(k, sp.p))
            xs, tables, accs = jax.vmap(
                lambda A, b, table, perm, x0, gb: distributed.
                _local_centralvr_epoch(A, b, sp.lam, sp.kind, x0, table,
                                       gb, eta, perm)
            )(sp.A, sp.b, tables, perms, xs, gbars)
            # communicate (average + broadcast) only where do_avg says so
            xs = jnp.where(avg, jnp.broadcast_to(xs.mean(0), xs.shape), xs)
            gbars = jnp.where(avg,
                              jnp.broadcast_to(accs.mean(0), accs.shape),
                              accs)
            return (xs, tables, gbars), None

        (xs, tables, gbars), _ = jax.lax.scan(
            body, (xs, tables, gbars), (keys, do_avg))
        rel = convex.rel_grad_norm(sp.merged(), xs.mean(0), g0)
        return rel

    finals = {}
    merged = sp.merged()
    g0 = convex.grad_norm0(merged)
    for K in (1, 2, 4):
        st = distributed.sync_init(sp, eta, jax.random.PRNGKey(2))
        keys = jax.random.split(jax.random.PRNGKey(3), rounds)
        do_avg = (jnp.arange(1, rounds + 1) % K) == 0
        rel = float(_local_epochs_scan(
            sp, jnp.broadcast_to(st.x, (sp.p, sp.d)), st.tables,
            jnp.broadcast_to(st.gbar, (sp.p, sp.d)), eta, keys, do_avg, g0))
        finals[K] = (rel, int(do_avg.sum()))
    rows.append({
        "name": "tau_sweep/centralvr-local-epochs",
        "us_per_call": 0.0,
        "derived": ";".join(
            f"K{k}={v:.2e}(comms={c})" for k, (v, c) in finals.items()),
    })
    emit(rows, "tau_sweep")
    return rows


if __name__ == "__main__":
    run()
