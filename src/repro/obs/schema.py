"""The telemetry row schema, pinned.

Every JSONL row a :class:`~repro.obs.recorder.Recorder` writes must carry
the base fields plus its kind's required fields.  The CI telemetry-smoke
lane validates captured run records against this module, and
``tests/test_obs.py`` pins both this row schema and the
``RunResult.provenance()`` row shape (:data:`PROVENANCE_KEYS` /
:data:`PROVENANCE_SPEC_KEYS`) — BENCH artifacts embed provenance rows, so
silently dropping or renaming a field would corrupt every downstream
consumer without failing anything.  Fail loudly here instead.
"""
from __future__ import annotations

import json
from typing import Iterable

from repro.obs.recorder import SCHEMA_VERSION

# base fields every row carries
BASE_FIELDS = ("v", "run", "t", "kind", "name")

# per-kind required fields (beyond the base)
KIND_FIELDS = {
    "event": (),
    "metric": ("step", "value"),
    "span": ("t0", "dur_s"),
}

# RunResult.provenance() row shape — the golden schema for the rows every
# BENCH artifact embeds.  Adding a field means updating these tuples (and
# the pinning test) deliberately; removing/renaming one fails the suite.
PROVENANCE_KEYS = ("spec", "final_rel", "rels_tail", "rounds_recorded",
                   "wall_s", "traces", "comms", "staleness", "schema_v")
PROVENANCE_SPEC_KEYS = ("algo", "p", "eta", "rounds", "backend", "fetch",
                        "speeds", "tau", "seed", "metric_every", "sampling",
                        "decay", "fused", "topology", "elastic", "prox",
                        "snapshot")

# Elastic membership events (DESIGN.md §Multi-host & elasticity): the
# required payload of each named event, pinned so the multihost-smoke CI
# lane can validate a captured dropout run structurally.
EVENT_FIELDS = {
    "worker_lost": ("worker", "round", "detect_s"),
    "worker_joined": ("worker", "round"),
    "repartition": ("round", "p_old", "p_new", "survivors"),
}


class SchemaError(ValueError):
    """A telemetry row that does not conform to the pinned schema."""


def validate_row(row: dict) -> dict:
    """Check one decoded row; returns it (for chaining) or raises
    :class:`SchemaError` naming the violation."""
    if not isinstance(row, dict):
        raise SchemaError(f"row is not an object: {row!r}")
    missing = [k for k in BASE_FIELDS if k not in row]
    if missing:
        raise SchemaError(f"row missing base fields {missing}: {row!r}")
    if row["v"] != SCHEMA_VERSION:
        raise SchemaError(
            f"row schema version {row['v']!r} != {SCHEMA_VERSION}")
    kind = row["kind"]
    if kind not in KIND_FIELDS:
        raise SchemaError(f"unknown row kind {kind!r}: {row!r}")
    missing = [k for k in KIND_FIELDS[kind] if k not in row]
    if missing:
        raise SchemaError(
            f"{kind} row missing required fields {missing}: {row!r}")
    if kind == "span" and not isinstance(row["dur_s"], (int, float)):
        raise SchemaError(f"span dur_s is not a number: {row!r}")
    if kind == "metric" and not isinstance(row["value"], (int, float)):
        raise SchemaError(f"metric value is not a number: {row!r}")
    if kind == "event" and row["name"] in EVENT_FIELDS:
        missing = [k for k in EVENT_FIELDS[row["name"]] if k not in row]
        if missing:
            raise SchemaError(
                f"{row['name']} event missing required fields {missing}: "
                f"{row!r}")
    return row


def validate_rows(rows: Iterable[dict]) -> int:
    n = 0
    for row in rows:
        validate_row(row)
        n += 1
    if n == 0:
        raise SchemaError("run record has no rows")
    return n


def load_rows(path: str) -> list:
    """Decode a JSONL run record (no validation; see
    :func:`validate_file`)."""
    rows = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise SchemaError(f"{path}:{lineno}: not JSON ({e})") from None
    return rows


def validate_file(path: str) -> int:
    """Validate a JSONL run record end to end; returns the row count."""
    return validate_rows(load_rows(path))
