"""LM train-runtime throughput: the epoch-scan runtime vs the per-step
host-loop reference, per backend, on a reduced arch (DESIGN.md §3 "LM
epoch scan").

For each worker count W we measure warm wall clock of ONE communication
epoch (M*K steps) four ways:

  * ``host``        — the retained per-step reference exactly as the seed
    ``run_training`` executed it (``train/host_loop.py``): every
    invocation builds a fresh step closure and jits it (re-traced PER
    INVOCATION — the same semantics ``benchmarks/driver_throughput.py``
    measures for the convex host loop), then dispatches one step per
    iteration with batches built pairwise on the host;
  * ``host-steady`` — the same per-step loop with the jitted step hoisted
    out and reused: isolates the steady-state dispatch + host-feed
    overhead from the per-invocation retrace;
  * ``scan-vmap``   — ``step.make_epoch_runner`` with the W workers
    stacked on one device, batches generated on device inside the scan
    (warm calls hit the jit cache: one executable per config, ever);
  * ``scan-spmd``   — the same epoch scan under shard_map with one
    worker per (CPU-simulated) device;
  * ``scan-vmap-fused`` — the vmap epoch scan with ``fused=True``: model
    forward through the Pallas rmsnorm/flash-attention kernels and the
    VR correction + SGD update through the single-launch ``vr_update``
    kernel. Fused rows carry ``fused``/``interpret`` flags and
    ``speedup_vs_unfused`` (warm scan-vmap / warm fused) for the
    ``check_regression`` fused gate; on CPU the kernels run in interpret
    mode, so those rows are gate-exempt (and excluded from the legacy
    scan-vs-host gate, which pins the unfused runtime).

Writes ``BENCH_train.json`` at the repo root (the acceptance artifact:
warm epoch-scan steps/sec >= 3x the host-loop path at W=4) plus the
standard results CSV.  Must start in a fresh process: it forces 4
simulated host devices before the first jax operation so the spmd rows
run under a real multi-device platform (same rule as
``benchmarks/spmd_scaling.py``).

    PYTHONPATH=src python -m benchmarks.train_throughput [--quick]
"""
from __future__ import annotations

import json
import os

import numpy as np

try:
    import repro_bootstrap  # noqa: F401  (repo-root module/script form)
except ModuleNotFoundError:
    pass  # installed form: repro resolves without the fallback

ROOT = os.path.join(os.path.dirname(__file__), "..")

WORKER_COUNTS = (1, 2, 4)


def _chained(run_epoch, state0):
    """Per-call closure that threads the state through (the scan runtime
    DONATES its input state, so a fixed state cannot be replayed)."""
    box = {"state": state0}

    def call():
        state, losses = run_epoch(box["state"])
        box["state"] = state
        return losses

    return call


def _host_epoch(cfg, tcfg, W, E, jit_step, box):
    from repro.train import host_loop

    accum, mb = _geometry(cfg, tcfg, W)
    state = box["state"]
    for _ in range(E):
        toks = host_loop._epoch_batch_host(
            cfg, tcfg.seed, box["step"], workers=W, accum=accum,
            microbatch=mb, seq=tcfg.seq_len,
            table_size=tcfg.vr_table_size)
        if W == 1:
            toks = toks[0]
        state, m = jit_step(state, toks)
        box["step"] += 1
    box["state"] = state
    return m["loss"]


def _geometry(cfg, tcfg, W):
    from repro.train import step as tstep

    return tstep.batch_geometry(tcfg, W)


def _make_step(cfg, tcfg, W):
    # single-device mesh: the host path is the seed reference execution
    # model (stacked workers on one device), not an FSDP configuration
    from repro.launch import mesh as meshlib
    from repro.train import step as tstep

    train_step, _ = tstep.make_train_step(cfg, tcfg,
                                          meshlib.make_test_mesh(devices=1),
                                          "none", workers=W)
    return train_step


def _host_caller(cfg, tcfg, W, E):
    """Seed semantics: each invocation builds and jits a FRESH step
    closure, exactly like the seed ``run_training`` did — the re-trace
    is part of the execution model being replaced (the convex
    ``driver_throughput`` measures its host loop the same way)."""
    import jax

    from repro.train import step as tstep

    box = {"state": tstep.init_train_state(cfg, tcfg, jax.random.PRNGKey(0),
                                           W),
           "step": 0}

    def call():
        jit_step = jax.jit(_make_step(cfg, tcfg, W))
        return _host_epoch(cfg, tcfg, W, E, jit_step, box)

    return call


def _host_steady_caller(cfg, tcfg, W, E):
    """The same per-step loop with the jitted step hoisted and reused:
    what remains is per-step dispatch + host-built batches."""
    import jax

    from repro.train import step as tstep

    jit_step = jax.jit(_make_step(cfg, tcfg, W))
    box = {"state": tstep.init_train_state(cfg, tcfg, jax.random.PRNGKey(0),
                                           W),
           "step": 0}

    def call():
        return _host_epoch(cfg, tcfg, W, E, jit_step, box)

    return call


def run(quick: bool = False):
    from repro.core import spmd

    spmd.force_host_devices(max(WORKER_COUNTS))
    import jax

    from benchmarks.common import emit, timed_cold_warm
    from repro.config import TrainConfig, get_arch
    from repro.train import step as tstep

    cfg = get_arch("qwen2-7b").reduced()
    M = 2 if quick else 4
    tcfg = TrainConfig(seq_len=32, global_batch=8, microbatch=2,
                       optimizer="sgd", learning_rate=0.1, vr="centralvr",
                       vr_table_size=M, local_epoch=1)
    E = M * tcfg.local_epoch
    repeat = 2 if quick else 3
    rows = []
    warm_by = {}

    for W in WORKER_COUNTS:
        paths = {"host": _host_caller(cfg, tcfg, W, E),
                 "host-steady": _host_steady_caller(cfg, tcfg, W, E)}
        for backend in ("vmap", "spmd"):
            run_epoch, meta = tstep.make_epoch_runner(cfg, tcfg, W,
                                                      backend=backend)
            state = tstep.init_train_state(cfg, tcfg, jax.random.PRNGKey(0),
                                           W)
            if backend == "spmd":
                state = tstep.place_train_state(state, meta["mesh"])
            paths[f"scan-{backend}"] = _chained(run_epoch, state)
        frun, fmeta = tstep.make_epoch_runner(cfg, tcfg, W, backend="vmap",
                                              fused=True)
        fstate = tstep.init_train_state(cfg, tcfg, jax.random.PRNGKey(0), W)
        paths["scan-vmap-fused"] = _chained(frun, fstate)
        for name, fn in paths.items():
            cold, warm, losses = timed_cold_warm(fn, repeat=repeat)
            warm_by[(name, W)] = warm
            # provenance row (same role as RunResult.provenance() in the
            # solver-driven artifacts): the resolved configuration that
            # produced this measurement + the last timed epoch's loss tail
            loss_tail = np.atleast_1d(np.asarray(losses, dtype=float))
            fused = name == "scan-vmap-fused"
            rows.append({
                "name": f"train_throughput/{name}-w{W}",
                "path": name,
                "workers": W,
                **({"fused": True, "interpret": fmeta["interpret"]}
                   if fused else {}),
                "us_per_call": warm * 1e6,
                "cold_s": cold,
                "warm_s": warm,
                "compile_s": max(cold - warm, 0.0),
                "steps_per_s": E / warm,
                "provenance": {
                    "spec": {"arch": cfg.name, "seq_len": tcfg.seq_len,
                             "global_batch": tcfg.global_batch,
                             "vr": tcfg.vr, "table_size": M,
                             "steps_per_epoch": E, "path": name,
                             "workers": W, "quick": quick},
                    "loss_tail": [float(v) for v in loss_tail[-8:]],
                },
                "derived": f"cold={cold:.3f}s,warm={warm:.3f}s,"
                           f"steps/s={E / warm:.1f}",
            })

    for r in rows:
        host = warm_by[("host", r["workers"])]
        r["speedup_vs_host"] = host / r["warm_s"]
        r["derived"] += f",vs_host={r['speedup_vs_host']:.1f}x"
        if r.get("fused"):
            unfused = warm_by[("scan-vmap", r["workers"])]
            r["unfused_warm_s"] = unfused
            r["speedup_vs_unfused"] = unfused / r["warm_s"]
            r["derived"] += (f",vs_unfused={r['speedup_vs_unfused']:.2f}x,"
                             f"interpret={r['interpret']}")
    scan_3x = warm_by[("host", 4)] / warm_by[("scan-vmap", 4)] >= 3.0

    payload = {
        "config": {"arch": cfg.name, "seq_len": tcfg.seq_len,
                   "global_batch": tcfg.global_batch,
                   "vr": tcfg.vr, "table_size": M,
                   "steps_per_epoch": E, "workers": list(WORKER_COUNTS),
                   "paths": ["host", "host-steady", "scan-vmap",
                             "scan-spmd", "scan-vmap-fused"],
                   "quick": quick, "device_count": jax.device_count(),
                   "backend_platform": jax.default_backend()},
        "rows": rows,
        "scan_3x_host_at_w4": scan_3x,
    }
    with open(os.path.join(ROOT, "BENCH_train.json"), "w") as f:
        json.dump(payload, f, indent=1)
    emit(rows, "train_throughput")
    print(f"scan_3x_host_at_w4={'yes' if scan_3x else 'no'}")
    return payload


def run_isolated(quick: bool = False):
    """Entry point for the ``benchmarks.run`` harness: launch a fresh
    interpreter, because the forced host-device count must be set before
    jax initializes and every other suite must keep the real
    single-device view (see tests/conftest.py)."""
    import subprocess
    import sys

    cmd = [sys.executable, "-m", "benchmarks.train_throughput"]
    if quick:
        cmd.append("--quick")
    proc = subprocess.run(cmd, cwd=ROOT, capture_output=True, text=True,
                          timeout=1800)
    sys.stdout.write(proc.stdout)
    if proc.returncode != 0:
        raise RuntimeError(f"train_throughput failed:\n{proc.stderr[-3000:]}")


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
