"""Training loop: data pipeline + jitted step + metrics + checkpointing.

Used by ``launch/train.py`` and the examples; runs on whatever mesh the
caller provides (1-device CPU for the end-to-end examples, the production
mesh on real hardware).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax

from repro.checkpoint import checkpoint as ckpt
from repro.config import ModelConfig, TrainConfig
from repro.data import synthetic
from repro.launch import mesh as meshlib
from repro.train import step as tstep


@dataclass
class LoopResult:
    losses: List[float] = field(default_factory=list)
    steps: int = 0
    wall_time: float = 0.0
    final_eval_loss: Optional[float] = None


def run_training(cfg: ModelConfig, tcfg: TrainConfig, *, steps: int,
                 mesh=None, vr_workers: str = "none",
                 checkpoint_path: Optional[str] = None,
                 checkpoint_every: int = 0,
                 log_every: int = 10,
                 log_fn: Callable[[str], None] = print) -> LoopResult:
    mesh = mesh or meshlib.make_test_mesh()
    train_step, meta = tstep.make_train_step(cfg, tcfg, mesh, vr_workers)
    W = meta["workers"]
    accum = max(tcfg.microbatch and
                tcfg.global_batch // (W * tcfg.microbatch) or 1, 1)
    mb = tcfg.microbatch or max(tcfg.global_batch // W, 1)

    state = tstep.init_train_state(cfg, tcfg, jax.random.PRNGKey(tcfg.seed),
                                   W)
    jit_step = jax.jit(train_step)

    def batch_for(s):
        toks = synthetic.epoch_batch(cfg, tcfg.seed, s, workers=W,
                                     accum=accum, microbatch=mb,
                                     seq=tcfg.seq_len,
                                     table_size=tcfg.vr_table_size)
        if W == 1:
            toks = toks[0]
        return toks

    result = LoopResult()
    t0 = time.time()
    # keep per-step metrics on device: forcing float(loss) every step
    # would block on a device->host transfer and serialize dispatch; only
    # log points pay the sync, everything else is fetched once at the end
    device_losses = []
    for s in range(steps):
        state, metrics = jit_step(state, batch_for(s))
        device_losses.append(metrics["loss"])
        if log_every and (s % log_every == 0 or s == steps - 1):
            log_fn(f"step {s:5d}  loss {float(metrics['loss']):.4f}")
        if checkpoint_path and checkpoint_every and \
                (s + 1) % checkpoint_every == 0:
            ckpt.save(checkpoint_path, state, step=s + 1)
    result.losses = [float(l) for l in jax.device_get(device_losses)]
    result.steps = steps
    result.wall_time = time.time() - t0

    # held-out eval
    from repro.models import model as modellib
    ev = synthetic.eval_batch(cfg, tcfg.seed, batch=mb, seq=tcfg.seq_len)
    params = (jax.tree_util.tree_map(lambda p: p[0], state.params)
              if W > 1 else state.params)
    result.final_eval_loss = float(modellib.loss_fn(
        params, cfg, {"tokens": ev}, remat="none"))
    if checkpoint_path:
        ckpt.save(checkpoint_path, state, step=steps)
    return result
