# NOTE: launch.dryrun must be executed as a script/module entry point so its
# XLA_FLAGS device-count override precedes jax init; do not import it here.
# mesh (jax-backed) is re-exported lazily: `python -m repro.launch.obs`
# must stay importable without the toolchain (the telemetry CLI is
# stdlib-only), and the launchers force host devices before jax init.


def __getattr__(name):
    if name == "mesh":
        import importlib
        return importlib.import_module("repro.launch.mesh")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
