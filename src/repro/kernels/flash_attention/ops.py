"""jit'd wrapper: pads sequence to block multiples, dispatches the kernel.

On-TPU this is the drop-in replacement for
``models.attention.chunked_attention``; the container validates it with
``interpret=True`` (Pallas executes the kernel body on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel


@functools.partial(jax.jit,
                   static_argnames=("window", "q_blk", "kv_blk", "interpret"))
def flash_attention(q, k, v, *, window=None, q_blk: int = 128,
                    kv_blk: int = 128, interpret: bool = False):
    B, S, H, hd = q.shape
    q_blk = min(q_blk, S)
    kv_blk = min(kv_blk, S)
    blk = max(q_blk, kv_blk)
    pad = (-S) % blk
    if pad:
        zq = jnp.zeros((B, pad, H, hd), q.dtype)
        zk = jnp.zeros((B, pad, k.shape[2], hd), k.dtype)
        q = jnp.concatenate([q, zq], axis=1)
        k = jnp.concatenate([k, zk], axis=1)
        v = jnp.concatenate([v, zk], axis=1)
    out = kernel.flash_attention(q, k, v, window=window, q_blk=q_blk,
                                 kv_blk=kv_blk, interpret=interpret)
    return out[:, :S]
