"""GQA attention: chunked (flash-style) causal attention for train/prefill,
single-token KV-cache attention for decode.

The chunked implementation is the pure-JAX analogue of the Pallas flash
kernel in ``repro/kernels/flash_attention`` (which uses it as its oracle):
an outer scan over query chunks and an inner scan over kv chunks carrying
the online-softmax statistics, so peak memory is O(chunk^2) per (batch,
head) instead of O(S^2). Sliding-window masking folds into the same chunk
mask, which is how the dense archs run the ``long_500k`` shape.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import kernel_ctx, layers

NEG_INF = -1e30


def init_attn(cfg: ModelConfig, key, dtype, *, window: int = 0):
    d, KV, hd = cfg.d_model, cfg.num_kv_heads, cfg.head_dim
    H = cfg.padded_heads        # physical heads (>= logical num_heads)
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers._dense_init(ks[0], (d, H, hd), d, dtype),
        "wk": layers._dense_init(ks[1], (d, KV, hd), d, dtype),
        "wv": layers._dense_init(ks[2], (d, KV, hd), d, dtype),
        "wo": layers._dense_init(ks[3], (H, hd, d), H * hd, dtype),
    }
    if H != cfg.num_heads:      # zero the padded heads (kept inert by the
        mask = (jnp.arange(H) < cfg.num_heads).astype(dtype)   # output mask)
        p["wq"] = p["wq"] * mask[None, :, None]
        p["wo"] = p["wo"] * mask[:, None, None]
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(p, cfg: ModelConfig, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if "q_norm" in p:
        q = layers.rms_norm_1d(p["q_norm"], q)
        k = layers.rms_norm_1d(p["k_norm"], k)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Chunked causal attention (train / prefill)
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, *, window: Optional[int] = None,
                      q_chunk: int = 512, kv_chunk: int = 512,
                      softcap: Optional[float] = None):
    """q: (B, S, H, hd); k, v: (B, S, KV, hd); returns (B, S, H, hd).

    Causal; optional sliding window (key j visible to query i iff
    i - window < j <= i). Online softmax over kv chunks.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    nq, nk = S // q_chunk, S // kv_chunk
    assert nq * q_chunk == S and nk * kv_chunk == S, (S, q_chunk, kv_chunk)

    qs = q.reshape(B, nq, q_chunk, KV, G, hd)
    ks = k.reshape(B, nk, kv_chunk, KV, hd)
    vs = v.reshape(B, nk, kv_chunk, KV, hd)
    acc_t = jnp.promote_types(q.dtype, jnp.float32)  # f32 acc (f64 under x64)
    scale = (1.0 / jnp.sqrt(hd)).astype(acc_t)

    q_pos = jnp.arange(S).reshape(nq, q_chunk)
    k_pos = jnp.arange(S).reshape(nk, kv_chunk)

    def q_block(qi, q_blk):
        # online softmax over kv chunks
        def kv_step(carry, inputs):
            m, l, acc = carry
            k_blk, v_blk, kp = inputs
            s = jnp.einsum("bqkgh,bckh->bqkgc", q_blk, k_blk,
                           preferred_element_type=acc_t) * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            qp = q_pos[qi][:, None]                       # (q_chunk, 1)
            mask = kp[None, :] <= qp
            if window is not None:
                mask &= kp[None, :] > (qp - window)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckh->bqkgh", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=acc_t)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, q_chunk, KV, G), NEG_INF, acc_t)
        l0 = jnp.zeros((B, q_chunk, KV, G), acc_t)
        a0 = jnp.zeros((B, q_chunk, KV, G, hd), acc_t)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (ks.swapaxes(0, 1), vs.swapaxes(0, 1), k_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)

    out = jax.lax.map(lambda args: q_block(*args),
                      (jnp.arange(nq), qs.swapaxes(0, 1)))
    out = out.swapaxes(0, 1).reshape(B, S, H, hd)
    return out


def _head_mask(cfg: ModelConfig, out):
    """Zero the padded heads so they are exactly inert: their (uniform-
    softmax) outputs never reach wo and no gradient flows into their rows."""
    H = cfg.padded_heads
    if H == cfg.num_heads:
        return out
    mask = (jnp.arange(H) < cfg.num_heads).astype(out.dtype)
    return out * mask[..., :, None]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash_fused(q, k, v, window, interpret: bool):
    """Pallas flash attention with the chunked-JAX backward — same
    reasoning as ``layers._rmsnorm_fused``: forward-only kernel zoo plus
    no interpret-mode transpose rule, so the VJP recomputes through the
    oracle (which IS the kernel's pinned reference)."""
    from repro.kernels.flash_attention import ops as fa_ops
    return fa_ops.flash_attention(q, k, v, window=window,
                                  interpret=interpret)


def _flash_fused_fwd(q, k, v, window, interpret):
    return _flash_fused(q, k, v, window, interpret), (q, k, v)


def _flash_fused_bwd(window, interpret, res, ct):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: chunked_attention(q, k, v, window=window), q, k, v)
    return vjp(ct)


_flash_fused.defvjp(_flash_fused_fwd, _flash_fused_bwd)


def attend_train(p, cfg: ModelConfig, x, *, window: Optional[int] = None,
                 q_chunk: int = 512, kv_chunk: int = 512):
    """Full block for train/prefill: project, chunked attention, out-proj.

    Under ``kernel_ctx`` the score/softmax/weighted-sum pipeline runs as
    the Pallas flash kernel (one launch per layer) — except for softcapped
    archs, which the kernel does not implement."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    w = window if window is not None else cfg.sliding_window
    if kernel_ctx.active() and cfg.attn_logit_softcap is None:
        out = _flash_fused(q, k, v, w, kernel_ctx.interpret())
    else:
        out = chunked_attention(q, k, v, window=w, q_chunk=q_chunk,
                                kv_chunk=kv_chunk,
                                softcap=cfg.attn_logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", _head_mask(cfg, out), p["wo"])


# ---------------------------------------------------------------------------
# Decode (single token, KV cache)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
               *, window: Optional[int] = None):
    """Cache for one attention layer. Window (or hybrid-local) layers use a
    ring buffer of size window; full attention keeps max_len slots."""
    w = window if window is not None else cfg.sliding_window
    slots = min(max_len, w) if w else max_len
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, slots, KV, hd), dtype),
        "v": jnp.zeros((batch, slots, KV, hd), dtype),
    }


def attend_decode(p, cfg: ModelConfig, x, cache, pos, *,
                  window: Optional[int] = None):
    """x: (B, 1, d); pos: scalar current position. Returns (out, new_cache).

    The cache is a ring buffer when windowed: slot = pos % slots.
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    slots = cache["k"].shape[1]
    slot = pos % slots
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, 1)

    KV, hd = cfg.num_kv_heads, cfg.head_dim
    G = cfg.padded_heads // KV
    qh = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bckh->bkgc", qh, k_cache,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(hd)
    if cfg.attn_logit_softcap:
        s = cfg.attn_logit_softcap * jnp.tanh(s / cfg.attn_logit_softcap)
    # valid slots: ring position c holds absolute index; with sequential
    # decode, slots filled so far = min(pos+1, slots)
    c_idx = jnp.arange(slots)
    valid = c_idx < jnp.minimum(pos + 1, slots)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgc,bckh->bkgh", w, v_cache)
    out = out.reshape(B, 1, cfg.padded_heads, hd)
    y = jnp.einsum("bshk,hkd->bsd", _head_mask(cfg, out), p["wo"])
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Serving: explicit-context attention (chunked prefill / per-lane decode)
# ---------------------------------------------------------------------------
#
# The serving runtime (repro/serve) batches sequences at DIFFERENT positions
# in one program, so the lockstep ``attend_decode`` above (one scalar pos for
# the whole batch) does not apply.  ``attend_serve`` is the shared primitive:
# queries carry their own absolute positions and the key/value context is an
# explicit stream with per-entry absolute positions and a validity mask —
# which is exactly what a paged pool gather, a dense lane buffer, or a
# sliding-window ring produces.  The online-softmax accumulation over kv
# chunks is the same scheme as ``chunked_attention`` (and the Pallas flash
# kernel it oracles), so peak score memory stays O(C * kv_chunk) per head.


def ring_positions(last_pos, slots: int):
    """Absolute position held by each slot of a sequentially-written ring.

    ``last_pos``: (B,) the last absolute position written (-1 if empty).
    Slot c holds the largest written position ≡ c (mod slots); returns
    (pos (B, slots), valid (B, slots)) with unwritten slots invalid.
    """
    c = jnp.arange(slots)
    pos = last_pos[:, None] - ((last_pos[:, None] - c[None, :]) % slots)
    valid = (pos >= 0) & (last_pos >= 0)[:, None]
    return pos, valid


def attend_serve(q, q_pos, k, v, k_pos, k_valid, *, window=None,
                 softcap=None, kv_chunk: int = 128):
    """q: (B, C, H, hd); k, v: (B, T, KV, hd); k_pos/k_valid: (B, T).

    Causal against ABSOLUTE positions (key visible iff valid and
    ``k_pos <= q_pos``; window: ``k_pos > q_pos - window``).  Fully-masked
    query rows return zeros (padded prefill lanes / dead decode lanes are
    discarded by the caller).  Online softmax over kv chunks of the
    context stream.
    """
    B, C, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    T = k.shape[1]
    kv_chunk = min(kv_chunk, T)
    n = -(-T // kv_chunk)
    pad = n * kv_chunk - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        k_valid = jnp.pad(k_valid, ((0, 0), (0, pad)),
                          constant_values=False)
    acc_t = jnp.promote_types(q.dtype, jnp.float32)
    scale = (1.0 / jnp.sqrt(hd)).astype(acc_t)
    qh = q.reshape(B, C, KV, G, hd)
    ks = k.reshape(B, n, kv_chunk, KV, hd).swapaxes(0, 1)
    vs = v.reshape(B, n, kv_chunk, KV, hd).swapaxes(0, 1)
    kps = k_pos.reshape(B, n, kv_chunk).swapaxes(0, 1)
    oks = k_valid.reshape(B, n, kv_chunk).swapaxes(0, 1)

    def kv_step(carry, inp):
        m, l, acc = carry
        k_blk, v_blk, kp, ok = inp
        s = jnp.einsum("bqkgh,bckh->bqkgc", qh, k_blk,
                       preferred_element_type=acc_t) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = ok[:, None, :] & (kp[:, None, :] <= q_pos[:, :, None])
        if window is not None:
            mask &= kp[:, None, :] > (q_pos[:, :, None] - window)
        mask = mask[:, :, None, None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        # the explicit re-mask keeps fully-masked rows exactly zero (m_new
        # stays NEG_INF there, so exp(s - m_new) would be 1, not 0)
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckh->bqkgh", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=acc_t)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, C, KV, G), NEG_INF, acc_t)
    l0 = jnp.zeros((B, C, KV, G), acc_t)
    a0 = jnp.zeros((B, C, KV, G, hd), acc_t)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, kps, oks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, C, H, hd).astype(q.dtype)


def project_qkv_serve(p, cfg: ModelConfig, x, positions):
    """Public spelling of the projection for the serve runtime: per-lane
    absolute positions (B, S) drive rope, unlike the lockstep decode."""
    return _project_qkv(p, cfg, x, positions)


def output_proj_serve(p, cfg: ModelConfig, out):
    """Head-masked output projection shared with the train/decode paths."""
    return jnp.einsum("bshk,hkd->bsd", _head_mask(cfg, out), p["wo"])


# ---------------------------------------------------------------------------
# Naive reference (small shapes only; used by tests)
# ---------------------------------------------------------------------------

def naive_attention(q, k, v, *, window: Optional[int] = None,
                    softcap: Optional[float] = None):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qh = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgh,bckh->bqkgc", qh, k,
                   preferred_element_type=jnp.float32) / jnp.sqrt(hd)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = j <= i
    if window is not None:
        mask &= j > (i - window)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgc,bckh->bqkgh", w.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)
