"""Train / serve step factories.

The CentralVR worker model under SPMD (DESIGN.md §2): worker copies are a
LEADING AXIS on every state leaf, sharded over the worker mesh axes, and
the per-worker local step is vmapped — each device group computes its own
worker's step, no cross-worker traffic. The paper's epoch-boundary
server exchange is a mean over the worker axis (lowers to one all-reduce
over the worker mesh axes), executed only when step % (M*K) == M*K-1 —
this is THE communication-frequency lever the paper contributes, and it is
directly visible in the dry-run HLO as a conditional collective.

Modes (TrainConfig.vr / vr_workers):
  vr="none", W=1       — classic sync data-parallel SGD/Adam: loss is the
                         global-batch mean, GSPMD all-reduces gradients
                         EVERY step (the baseline the paper beats).
  vr=..., workers=data — paper-faithful CentralVR-Sync: full model copy
                         per data-axis group (dp_replicated).
  vr=..., workers=pod  — hierarchical (beyond-paper): FSDP inside a pod,
                         CentralVR across pods; cross-pod traffic only at
                         epoch boundaries.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, TrainConfig
from repro.data import synthetic
from repro.launch import mesh as meshlib
from repro.models import kernel_ctx, model
from repro.optim import optimizers, vr_wrapper
from repro.sharding import specs

tmap = jax.tree_util.tree_map


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    vr_state: Any       # VRState or () when vr="none"
    step: jax.Array


# LM worker mesh axis for the spmd epoch runtime — same axis name as the
# convex backend (core/spmd.py WORKER_AXIS / launch.mesh.make_worker_mesh)
LM_WORKER_AXIS = "workers"


def batch_geometry(tcfg: TrainConfig, W: int):
    """(accum, microbatch) for W workers. The seed code silently truncated
    a non-dividing accumulation factor to 1 (dropping most of the global
    batch); an uneven split is a config error and raises instead."""
    if tcfg.microbatch:
        denom = W * tcfg.microbatch
        if tcfg.global_batch % denom:
            raise ValueError(
                f"global_batch={tcfg.global_batch} is not divisible by "
                f"workers*microbatch = {W}*{tcfg.microbatch} = {denom}; "
                "every worker must process the same number of whole "
                "microbatches per step")
        return tcfg.global_batch // denom, tcfg.microbatch
    if tcfg.global_batch % W:
        raise ValueError(
            f"global_batch={tcfg.global_batch} is not divisible by "
            f"workers={W}")
    return 1, max(tcfg.global_batch // W, 1)


def worker_average(tree):
    """Algorithm 2 lines 16-18: the central server average over the
    leading worker axis, broadcast back to every worker copy (lowers to
    one all-reduce over the worker mesh axes under GSPMD)."""
    return tmap(
        lambda p: jnp.broadcast_to(p.mean(0, keepdims=True),
                                   p.shape).astype(p.dtype), tree)


def eval_params(params, W: int):
    """Params for held-out eval: between exchanges the W worker copies
    have DIVERGED, so worker 0 is not the algorithm's iterate — the
    central average is (fetched to host so eval runs on the default
    device regardless of backend placement)."""
    if W <= 1:
        return params
    return jax.device_get(tmap(lambda p: p.mean(0).astype(p.dtype), params))


def _loss(params, cfg, tcfg, tokens, fe, act_sharding=None):
    batch = {"tokens": tokens}
    if fe is not None:
        batch["frontend_embeds"] = fe
    return model.loss_fn(params, cfg, batch, remat=tcfg.remat,
                         act_sharding=act_sharding)


def _local_grads(params, cfg, tcfg, tokens, fe, act_sharding=None):
    """tokens: (A, mb, S); gradient accumulated over A microbatches.

    Gradients are taken against a COMPUTE-DTYPE (bf16) copy of the params,
    cast ONCE outside the accumulation loop: every per-microbatch FSDP
    weight all-gather then moves bf16 instead of the f32 masters, and the
    backward cotangents (incl. the deferred partial-sum all-reduces GSPMD
    emits for 2D-sharded weights) stay bf16 — measured ~2x collective cut
    on qwen1.5-110b/train_4k (EXPERIMENTS.md §Perf It.6). The f32 masters
    are touched only by the optimizer/VR update, once per step.
    """
    A = tokens.shape[0]
    compute = jnp.dtype(cfg.dtype)
    params_c = tmap(
        lambda p: p.astype(compute)
        if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
    lg = jax.value_and_grad(_loss)

    def acc(carry, xs):
        loss_acc, g_acc = carry
        t, f = xs
        loss, g = lg(params_c, cfg, tcfg, t, f, act_sharding)
        g_acc = tmap(lambda a, b: a + b.astype(jnp.float32) / A, g_acc, g)
        return (loss_acc + loss / A, g_acc), None

    g0 = tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if fe is None:
        def acc_nofe(carry, t):
            return acc(carry, (t, None))
        (loss, grads), _ = jax.lax.scan(acc_nofe, (jnp.zeros(()), g0), tokens)
    else:
        (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros(()), g0),
                                        (tokens, fe))
    return loss, grads


def _make_per_worker(cfg: ModelConfig, tcfg: TrainConfig, act_sharding=None,
                     fused: bool = False, interpret: bool = False):
    """One worker's local step (grads -> VR correction -> optimizer),
    shared by the per-step train_step, the vmap epoch scan, and the spmd
    epoch runner — the execution models differ, the math must not.

    ``fused`` (a RESOLVED bool — callers go through
    ``kernels.resolve_fused``) routes the hot paths through the Pallas
    kernels: the forward/backward traces under ``kernel_ctx`` (RMSNorm +
    flash attention), and for SGD the VR correction + update collapses
    into one ``vr_update`` launch (``vr_wrapper.apply``)."""
    M = tcfg.vr_table_size
    mode = tcfg.vr
    opt = optimizers.make(tcfg.optimizer, tcfg.learning_rate,
                          tcfg.weight_decay)
    fuse_vr = fused and mode != "none" and tcfg.optimizer == "sgd"

    def per_worker(params, vr_state, opt_state, tokens, fe, idx=None):
        # idx: scalar step % M, kept OUT of the vmapped axes so the VR
        # table switch stays unbatched (see vr_wrapper.correct)
        ctx = (kernel_ctx.scope(True, interpret) if fused
               else contextlib.nullcontext())
        with ctx:
            loss, g = _local_grads(params, cfg, tcfg, tokens, fe,
                                   act_sharding)
            g_snap = None
            if mode == "svrg":
                _, g_snap = _local_grads(vr_state.snapshot, cfg, tcfg,
                                         tokens, fe, act_sharding)
        if fuse_vr:
            params, vr_state = vr_wrapper.apply(
                mode, vr_state, g, M, lr=tcfg.learning_rate,
                g_snap=g_snap, params=params, idx=idx, interpret=interpret)
            return params, vr_state, opt_state, loss
        if mode == "svrg":
            v, vr_state = vr_wrapper.correct(mode, vr_state, g, M,
                                             g_snap=g_snap, params=params,
                                             idx=idx)
        elif mode != "none":
            v, vr_state = vr_wrapper.correct(mode, vr_state, g, M,
                                             params=params, idx=idx)
        else:
            v = g
        updates, opt_state = opt.update(v, opt_state, params)
        params = optimizers.apply_updates(params, updates)
        return params, vr_state, opt_state, loss

    return per_worker


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh,
                    vr_workers: str = "none", *,
                    workers: Optional[int] = None):
    """Returns (train_step(state, tokens, fe), meta dict).

    ``workers`` overrides the mesh-derived worker count: W stacked worker
    copies simulated under vmap on whatever devices the mesh has (the
    single-device reference configuration of the epoch-scan runtime)."""
    W = workers or (meshlib.worker_count(mesh, vr_workers)
                    if tcfg.vr != "none" else 1)
    M = tcfg.vr_table_size
    K = tcfg.local_epoch
    comm_every = M * K
    mode = tcfg.vr

    # In FSDP mode, pin the residual stream to batch-over-'data' so the
    # partitioner gathers per-layer WEIGHTS (ZeRO-3 semantics), not the
    # activations, and enable the explicit per-layer weight-gather context
    # (manual ZeRO; §Perf It.6). Only when the 'data' axis actually shards
    # the batch (W==1, or pod-level workers with data free).
    act_sharding = None
    # (never with an explicit ``workers`` simulation: stacked worker
    # copies are replicated by construction, and gather_ctx.enable is
    # process-global — engaging it here would leak into other runtimes)
    if (workers is None and not tcfg.dp_replicated
            and "data" in mesh.axis_names and mesh.devices.size > 1):
        w_axes = (meshlib.worker_axes(mesh, vr_workers)
                  if tcfg.vr != "none" else ())
        if "data" not in w_axes:
            act_sharding = NamedSharding(mesh, P("data", None, None))
            from repro.sharding import gather_ctx
            gather_ctx.enable(mesh, cfg, meshlib.mesh_axis_sizes(mesh))

    per_worker = _make_per_worker(cfg, tcfg, act_sharding)

    def train_step(state: TrainState, tokens, fe=None):
        """tokens: (W, A, mb, S) when W>1 else (A, mb, S)."""
        idx = state.step % M
        if W > 1:
            params, vr_state, opt_state, loss = jax.vmap(
                per_worker,
                in_axes=(0, 0, 0, 0, 0 if fe is not None else None, None)
            )(state.params, state.vr_state, state.opt_state, tokens, fe, idx)
            loss = loss.mean()

            def communicate(args):
                params, vr_state = args
                # average x and gbar across the worker axis;
                # tables/accumulators stay local
                params = worker_average(params)
                if mode != "none":
                    vr_state = vr_state._replace(
                        gbar=worker_average(vr_state.gbar))
                return params, vr_state

            boundary = (state.step + 1) % comm_every == 0
            params, vr_state = jax.lax.cond(
                boundary, communicate, lambda a: a, (params, vr_state))
        else:
            params, vr_state, opt_state, loss = per_worker(
                state.params, state.vr_state, state.opt_state, tokens, fe,
                idx)
        return TrainState(params, opt_state, vr_state, state.step + 1), {
            "loss": loss}

    meta = {"workers": W, "comm_every": comm_every,
            "grads_per_step": vr_wrapper.grads_per_step(mode),
            "vr_storage_mult": vr_wrapper.storage_multiplier(mode, M)}
    return train_step, meta


# ---------------------------------------------------------------------------
# Epoch-scan runtime (DESIGN.md §3, "LM epoch scan")
# ---------------------------------------------------------------------------

def make_epoch_runner(cfg: ModelConfig, tcfg: TrainConfig, W: int, *,
                      backend: str = "vmap", mesh=None, fused=False):
    """One whole communication epoch (M*K steps) as a single jitted
    ``lax.scan`` with donated TrainState: ``run_epoch(state) -> (state,
    (M*K,) losses)``, with the Algorithm-2 worker average applied at the
    scan's epoch boundary. ``state.step`` must be a multiple of M*K
    (``train/loop.py`` drives whole epochs, so it always is).

      * ``backend="vmap"`` — W stacked worker copies on one device;
        batches are generated ON DEVICE inside the scan body (the
        fold_in-keyed pipeline traces with the scan's step counter), so
        nothing crosses the host boundary during an epoch.
      * ``backend="spmd"`` — ``shard_map`` over a 1-D worker mesh
        (``launch.mesh.make_worker_mesh``), one worker per device; the
        epoch boundary is a ``lax.pmean`` collective. The epoch's token
        block is host-precomputed ONCE (it is step-independent: the
        finite sum replays indices 0..M-1 every epoch) and shipped
        sharded along the worker axis — the §2 partitioner workaround:
        in-shard ``jax.random`` miscompiles on this jax version.

    Returns (run_epoch, meta); meta carries the worker mesh for spmd so
    callers can place the state (``place_train_state``).

    ``fused``: False | True | "auto" — same axis as the convex drivers
    (``solver.RunSpec.fused``). True forces the Pallas kernels (interpret
    mode off-TPU); "auto" fuses only on a compiled Pallas backend. The
    fused VR step requires the SGD optimizer (the kernel bakes the plain
    ``x - lr*v`` update); forcing it with a stateful optimizer is an
    error, while "auto" quietly fuses just the model forward.
    """
    if backend not in ("vmap", "spmd"):
        raise ValueError(f"unknown backend {backend!r}: "
                         "expected 'vmap' or 'spmd'")
    from repro import kernels
    fuse_on, interpret = kernels.resolve_fused(fused)
    if (fused is True and tcfg.vr != "none"
            and tcfg.optimizer != "sgd"):
        raise ValueError(
            f"fused=True: the fused VR step bakes a plain SGD update, but "
            f"optimizer={tcfg.optimizer!r}; use optimizer='sgd' or "
            "fused='auto' (which fuses only the model forward)")
    E = tcfg.vr_table_size * tcfg.local_epoch
    accum, mb = batch_geometry(tcfg, W)
    meta = {"workers": W, "comm_every": E, "accum": accum,
            "microbatch": mb, "backend": backend,
            "grads_per_step": vr_wrapper.grads_per_step(tcfg.vr),
            "vr_storage_mult": vr_wrapper.storage_multiplier(
                tcfg.vr, tcfg.vr_table_size),
            "fused": fuse_on, "interpret": interpret}

    if backend == "vmap":
        return _epoch_runner_vmap(cfg, tcfg, W, fuse_on, interpret), meta

    if mesh is None:
        from repro.core import spmd
        mesh = spmd.worker_mesh(W)
    if mesh.devices.size != W:
        raise ValueError(
            f"worker mesh has {mesh.devices.size} devices but W={W}; the "
            "spmd epoch runtime places exactly one worker per device")
    meta["mesh"] = mesh
    if W == 1:
        # one worker has no axis to shard — like the convex backend
        # (core/spmd.py run_centralvr), "spmd" then means "execute on the
        # mesh device" so launchers address one API regardless of backend
        return _epoch_runner_vmap(cfg, tcfg, W, fuse_on, interpret), meta
    tokens = synthetic.epoch_tokens(
        cfg, tcfg.seed, workers=W, steps=E, accum=accum, microbatch=mb,
        seq=tcfg.seq_len, table_size=tcfg.vr_table_size)
    tokens = jax.device_put(
        tokens, NamedSharding(mesh, P(LM_WORKER_AXIS)))
    runner = _epoch_runner_spmd(cfg, tcfg, mesh, fuse_on, interpret)

    def run_epoch(state: TrainState):
        params, vr, opt, step, losses = runner(
            state.params, state.vr_state, state.opt_state, state.step,
            tokens)
        return TrainState(params, opt, vr, step), losses

    return run_epoch, meta


@functools.lru_cache(maxsize=None)
def _epoch_runner_vmap(cfg: ModelConfig, tcfg: TrainConfig, W: int,
                       fused: bool = False, interpret: bool = False):
    """One jitted runner per (cfg, tcfg, W, fused, interpret) — repeated
    run_training calls on the same config reuse the compiled epoch
    executable. The fused pair is part of the key because kernel dispatch
    is decided at trace time (models/kernel_ctx)."""
    per_worker = _make_per_worker(cfg, tcfg, fused=fused,
                                  interpret=interpret)
    E = tcfg.vr_table_size * tcfg.local_epoch
    accum, mb = batch_geometry(tcfg, W)

    def run_epoch(state: TrainState):
        def body(carry, s):
            params, vr, opt = carry
            idx = s % tcfg.vr_table_size
            toks = synthetic.epoch_batch(
                cfg, tcfg.seed, s, workers=W, accum=accum, microbatch=mb,
                seq=tcfg.seq_len, table_size=tcfg.vr_table_size)
            if W > 1:
                params, vr, opt, loss = jax.vmap(
                    per_worker, in_axes=(0, 0, 0, 0, None, None))(
                    params, vr, opt, toks, None, idx)
                loss = loss.mean()
            else:
                params, vr, opt, loss = per_worker(params, vr, opt,
                                                   toks[0], None, idx)
            return (params, vr, opt), loss

        steps = state.step + jnp.arange(E, dtype=jnp.int32)
        (params, vr, opt), losses = jax.lax.scan(
            body, (state.params, state.vr_state, state.opt_state), steps)
        if W > 1:
            params = worker_average(params)
            if tcfg.vr != "none":
                vr = vr._replace(gbar=worker_average(vr.gbar))
        return TrainState(params, opt, vr, state.step + E), losses

    return jax.jit(run_epoch, donate_argnums=0)


@functools.lru_cache(maxsize=None)
def _epoch_runner_spmd(cfg: ModelConfig, tcfg: TrainConfig, mesh,
                       fused: bool = False, interpret: bool = False):
    """One compiled executable per (cfg, tcfg, mesh, fused, interpret):
    the whole epoch scan inside a single jitted shard_map, worker state
    donated. ``check_rep=False`` for the same reason as the convex
    runners (core/spmd.py): the replication checker rejects carries that
    enter unreplicated and leave pmean-replicated."""
    from jax.experimental.shard_map import shard_map

    per_worker = _make_per_worker(cfg, tcfg, fused=fused,
                                  interpret=interpret)
    E = tcfg.vr_table_size * tcfg.local_epoch
    mode = tcfg.vr
    ax = LM_WORKER_AXIS

    def body(params, vr, opt, step, tokens):
        # worker-stacked leaves arrive as this worker's (1, ...) shard
        take0 = functools.partial(tmap, lambda x: x[0])
        p, v, o = take0(params), take0(vr), take0(opt)

        def one(carry, xs):
            s, toks = xs
            p, v, o = carry
            p, v, o, loss = per_worker(p, v, o, toks, None,
                                       s % tcfg.vr_table_size)
            return (p, v, o), loss

        steps = step + jnp.arange(E, dtype=jnp.int32)
        (p, v, o), losses = jax.lax.scan(one, (p, v, o),
                                         (steps, tokens[0]))
        # epoch boundary: the central average as a collective
        pm = functools.partial(tmap, lambda x: jax.lax.pmean(x, ax))
        p = pm(p)
        if mode != "none":
            v = v._replace(gbar=pm(v.gbar))
        losses = jax.lax.pmean(losses, ax)
        lead = functools.partial(tmap, lambda x: x[None])
        return lead(p), lead(v), lead(o), step + E, losses

    ws, rep = P(ax), P()
    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(ws, ws, ws, rep, ws),
        out_specs=(ws, ws, ws, rep, rep), check_rep=False),
        donate_argnums=(0, 1, 2))


def place_train_state(state: TrainState, mesh) -> TrainState:
    """Shard every worker-stacked leaf along the worker mesh axis (one
    worker per device) and replicate the step counter. A 1-device mesh
    (W=1: no worker axis in the state) commits everything to that
    device instead."""
    if mesh.devices.size == 1:
        return jax.device_put(state, mesh.devices.ravel()[0])
    ws = NamedSharding(mesh, P(LM_WORKER_AXIS))
    rep = NamedSharding(mesh, P())
    put = lambda t: tmap(lambda x: jax.device_put(x, ws), t)
    return TrainState(put(state.params), put(state.opt_state),
                      put(state.vr_state), jax.device_put(state.step, rep))


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key, W: int
                     ) -> TrainState:
    """Concrete init (small models / examples). Workers start identical."""
    params = model.init_params(cfg, key)
    opt = optimizers.make(tcfg.optimizer, tcfg.learning_rate,
                          tcfg.weight_decay)
    opt_state = opt.init(params)
    vr_state = (vr_wrapper.init_vr(tcfg.vr, params, tcfg.vr_table_size)
                if tcfg.vr != "none" else ())
    state = TrainState(params, opt_state, vr_state, jnp.zeros((), jnp.int32))
    if W > 1:
        def rep(x):
            return jnp.broadcast_to(x[None], (W,) + x.shape)
        state = TrainState(tmap(rep, params), tmap(rep, opt_state),
                           tmap(rep, vr_state) if vr_state != () else (),
                           state.step)
    return state


def eval_shape_train_state(cfg: ModelConfig, tcfg: TrainConfig, W: int):
    """Abstract TrainState (ShapeDtypeStructs, no allocation) — dry-run."""
    return jax.eval_shape(
        functools.partial(init_train_state, cfg, tcfg, W=W),
        jax.random.PRNGKey(0))


def state_shardings(state_shapes, cfg: ModelConfig, tcfg: TrainConfig, mesh,
                    vr_workers: str):
    w_axes = (meshlib.worker_axes(mesh, vr_workers)
              if tcfg.vr != "none" else ())
    spec_tree = specs.tree_specs(state_shapes, cfg,
                                 fsdp=not tcfg.dp_replicated,
                                 worker_axes=w_axes,
                                 axis_sizes=meshlib.mesh_axis_sizes(mesh))
    return tmap(lambda s: NamedSharding(mesh, s), spec_tree)


def batch_sharding(mesh, tcfg: TrainConfig, vr_workers: str, *, with_fe=False):
    w_axes = (meshlib.worker_axes(mesh, vr_workers)
              if tcfg.vr != "none" else ())
    data_axes = tuple(a for a in ("pod", "data")
                      if a in mesh.axis_names and a not in w_axes)
    tok = specs.batch_specs(w_axes, data_axes)
    out = {"tokens": NamedSharding(mesh, tok)}
    if with_fe:
        out["fe"] = NamedSharding(mesh, P(*(tuple(tok) + (None,))))
    return out


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig, act_sharding=None):
    def serve_step(params, token, cache, pos):
        return model.decode_step(params, cfg, token, cache, pos)

    def serve_prefill(params, tokens, fe=None):
        """Returns LAST-position logits (B, vocab) — the generation
        use-case. Materializing all (B, S, vocab) f32 logits costs 40
        GiB/device at 32k x 152k vocab (§Perf It.4); scoring workloads
        should stream positions instead."""
        batch = {"tokens": tokens}
        if fe is not None:
            batch["frontend_embeds"] = fe
        logits, _ = model.forward(params, cfg, batch, remat="none",
                                  act_sharding=act_sharding)
        return logits[:, -1]

    return serve_step, serve_prefill
