"""jit'd wrapper: reshapes (..., d) to rows, pads, dispatches the kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm import kernel


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, interpret: bool = False):
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    rows = x2.shape[0]
    # pick rows_blk: <=256, divides padded rows, tile <= ~8 MiB
    rows_blk = max(min(256, 8 * 1024 * 1024 // (4 * d)), 8)
    pad = (-rows) % rows_blk
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, d), x.dtype)])
    y = kernel.rmsnorm(x2, scale, eps=eps, rows_blk=rows_blk,
                       interpret=interpret)
    return y[:rows].reshape(shape)
