"""Checkpoint round-trip of driver/VR state (DESIGN.md §8).

Interrupting a CentralVR run at an epoch boundary, saving the VR state
through ``checkpoint/``, restoring, and continuing must reproduce the
uninterrupted trajectory — the VR table and epoch-frozen gbar are part of
the algorithm state, so any drop or dtype change in the round-trip shows
up as a diverged trajectory.
"""
import jax
import numpy as np

from repro.checkpoint import checkpoint
from repro.config import ConvexConfig
from repro.core import centralvr, convex, distributed

TOL = dict(rtol=3e-5, atol=1e-7)


def test_centralvr_roundtrip_continues_trajectory(tmp_path):
    prob = convex.make_logistic_data(jax.random.PRNGKey(0), 96, 9)
    eta = convex.auto_eta(prob, 0.3)
    g0 = convex.grad_norm0(prob)
    k_init, k_run = jax.random.split(jax.random.PRNGKey(3))
    keys = jax.random.split(k_run, 6)

    # uninterrupted reference (fresh init: _run_scan donates its state)
    st_full, rels_full = centralvr._run_scan(
        prob, centralvr.init_state(prob, eta, k_init), eta, g0, keys,
        "permutation")

    # first half, save at the epoch boundary
    st_half, rels_a = centralvr._run_scan(
        prob, centralvr.init_state(prob, eta, k_init), eta, g0, keys[:3],
        "permutation")
    path = str(tmp_path / "centralvr.npz")
    checkpoint.save(path, st_half, step=3)
    assert checkpoint.latest_step(path) == 3

    # restore into the same structure and continue with the same key tail
    restored = checkpoint.restore(path, like=st_half)
    for got, want in zip(restored, st_half):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    _, rels_b = centralvr._run_scan(prob, restored, eta, g0, keys[3:],
                                    "permutation")

    rels_joined = np.concatenate([np.asarray(rels_a), np.asarray(rels_b)])
    np.testing.assert_allclose(rels_joined, np.asarray(rels_full), **TOL)


def test_lm_epoch_scan_resume_continues_trajectory(tmp_path):
    """LM analogue of the CentralVR round-trip: save at an epoch-scan
    boundary from ``train/loop.py``, restore with ``resume=True``, and
    the continued per-step loss trajectory must match an uninterrupted
    run (the data pipeline is stateless fold_in, the VR table/anchor and
    optimizer state ride the checkpoint)."""
    from repro.config import ModelConfig, TrainConfig
    from repro.train import loop

    cfg = ModelConfig(name="tiny-resume", family="dense", num_layers=2,
                      d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
                      vocab_size=128, dtype="float32",
                      param_dtype="float32")
    tcfg = TrainConfig(seq_len=16, global_batch=4, microbatch=2,
                       optimizer="adam", learning_rate=1e-3,
                       vr="centralvr", vr_table_size=2, local_epoch=1)

    full = loop.run_training(cfg, tcfg, epochs=4, workers=2, log_every=0)
    path = str(tmp_path / "lm.npz")
    first = loop.run_training(cfg, tcfg, epochs=2, workers=2,
                              checkpoint_path=path, checkpoint_every=2,
                              log_every=0)
    assert checkpoint.latest_step(path) == 2 * 2   # epoch boundary
    resumed = loop.run_training(cfg, tcfg, epochs=4, workers=2,
                                checkpoint_path=path, resume=True,
                                log_every=0)
    assert len(resumed.losses) == len(full.losses) - len(first.losses)
    np.testing.assert_allclose(first.losses + resumed.losses, full.losses,
                               **TOL)
    np.testing.assert_allclose(resumed.final_eval_loss,
                               full.final_eval_loss, **TOL)


def test_elastic_roundtrip_across_mesh_shapes(tmp_path):
    """Elastic checkpoint portability (DESIGN.md §Multi-host &
    elasticity): a p=4 checkpoint restored at p=3 and p=2 re-shards the
    VR tables losslessly, and the continued trajectory is bit-identical
    (x64, conftest) to the elastic run that dropped to that shape at the
    same wave boundary — the checkpoint round-trip adds nothing."""
    from repro.checkpoint import elastic as eckpt
    from repro.core import elastic

    cfg = ConvexConfig(problem="logistic", n=48, d=8, seed=0, workers=4)
    sp = distributed.make_distributed(jax.random.PRNGKey(0), cfg)
    eta = convex.auto_eta(sp.merged())
    g0 = convex.grad_norm0(sp.merged())
    key = jax.random.PRNGKey(0)
    k_run = jax.random.split(key)[1]
    speeds = (1.0, 1.0, 2.0, 4.0)
    rounds = 6

    elastic.run_async_elastic(sp, eta=eta, rounds=rounds, key=key,
                              speeds=speeds, checkpoint_dir=str(tmp_path),
                              checkpoint_every=3)
    path = str(tmp_path / "elastic_00003")
    man = eckpt.load_manifest(path)
    assert man["p"] == 4 and man["round"] == 3

    for live in ((0, 2, 3), (0, 3)):
        p_new = len(live)
        st_new, _ = eckpt.restore_elastic(path, p_new)
        # cfg.n is per-worker: 4 * 48 = 192 total samples re-shard
        assert st_new.tables.shape == (p_new, 4 * 48 // p_new)
        # re-sharding permutes nothing: the merged table is invariant
        st_same, _ = eckpt.restore_elastic(path)
        np.testing.assert_array_equal(
            elastic.merge_tables(st_new.tables),
            elastic.merge_tables(st_same.tables))

        _, rels_cont = elastic.continue_async(
            elastic.reshard_problem(sp, p_new), st_new, eta=eta, g0=g0,
            start_round=3, rounds=rounds, k_run=k_run,
            speeds=elastic.survivor_speeds(speeds, live))
        res_drop = elastic.run_async_elastic(
            sp, eta=eta, rounds=rounds, key=key, speeds=speeds,
            membership=elastic.PlannedMembership(4, {3: live}))
        np.testing.assert_array_equal(np.asarray(rels_cont),
                                      res_drop.rels[3:])


def test_sync_state_roundtrip(tmp_path):
    """Distributed driver state (stacked per-worker tables) survives the
    flat-npz round-trip with structure and values intact."""
    cfg = ConvexConfig(problem="ridge", n=32, d=6, workers=3)
    sp = distributed.make_distributed(jax.random.PRNGKey(1), cfg)
    eta = convex.auto_eta(sp.merged(), 0.3)
    st, _ = distributed.run_sync(sp, eta=eta, rounds=2,
                                 key=jax.random.PRNGKey(2))
    path = str(tmp_path / "sync.npz")
    checkpoint.save(path, st, step=2)
    restored = checkpoint.restore(path, like=st)
    assert isinstance(restored, distributed.SyncState)
    for got, want in zip(restored, st):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
