"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — MoE: 60 routed experts
top-4 + 4 shared experts (shared intermediate 4x1408=5632) with a shared-
expert gate; 16 heads (kv=16 => MHA), QKV bias."""
from repro.config import ModelConfig, register

QWEN2_MOE_A2_7B = register(ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    num_experts=60,
    num_experts_per_tok=4,
    moe_d_ff=1408,
    shared_expert_d_ff=5632,   # = 4 shared experts x 1408
    shared_expert_gate=True,
    norm_type="rmsnorm",
    mlp_type="swiglu",
))
