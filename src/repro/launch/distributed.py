"""Two-tier launcher for the multi-process engines (DESIGN.md §Multi-host
& elasticity).

Launcher mode (default): pick a free coordinator port, spawn
``--nprocs`` child processes of THIS module (each with ``--process-id``
and ``--coordinator`` appended), babysit them under a hard timeout, and
optionally ``--verify`` the fleet's trajectory against the single-process
reference computed in-parent:

    python -m repro.launch.distributed --nprocs 2 --workers 4 \\
        --algo centralvr_async --rounds 6 --x64 --verify

Worker mode (``--process-id >= 0``, normally only ever launched by the
parent): initialize the ``jax.distributed`` world, install the process
context, and route the run through the regular ``solve()`` entry point
with ``topology="process"``.  Process 0 writes the canonical results JSON
(rels + elastic membership transitions); each process can write its own
telemetry record (``--obs`` base path + ``-p{i}.jsonl``).

Elastic lanes inject a deterministic fault (``--drop-process`` /
``--drop-round`` / ``--drop-mode exit|stall``); the ``--verify``
reference replays the transitions process 0 OBSERVED as a
``PlannedMembership`` through the event-serial elastic engine, so the
check is end-to-end: heartbeat detection, repartition, resync, and
post-dropout trajectory all have to agree with the reference algebra.

Workers exit via ``os._exit`` after flushing results/telemetry: the
jax.distributed shutdown path barriers on the full original world, which
would hang every survivor of an exit-mode fault.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import socket
import subprocess
import sys
import tempfile
import time
from typing import Optional

# NOTE: jax (and everything that pulls it in) is imported lazily inside
# functions — x64 and the distributed service must be configured before
# the first jax operation, and argument errors should not pay jax import.

_CTX: Optional["ProcessContext"] = None


@dataclasses.dataclass
class ProcessContext:
    """This process's slice of the world, installed by
    :func:`init_process` and consumed by ``procmesh.solve_process``."""

    comm: object                 # procmesh.ProcComm
    hb_timeout: float = 10.0
    fault: Optional[object] = None   # procmesh.Fault


def context() -> Optional[ProcessContext]:
    return _CTX


def init_process(coordinator: str, num_processes: int, process_id: int, *,
                 x64: bool = False, prefix: str = "run",
                 hb_timeout: float = 10.0,
                 fault=None) -> ProcessContext:
    """Join a ``jax.distributed`` world and install the process context.

    Must run before the first jax operation in this process.  Returns the
    installed :class:`ProcessContext` (also available via
    :func:`context`)."""
    global _CTX
    import jax

    if x64:
        jax.config.update("jax_enable_x64", True)
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)
    from jax._src import distributed as jax_distributed

    from repro.core import procmesh

    client = jax_distributed.global_state.client
    comm = procmesh.ProcComm(procmesh.DistributedKV(client), process_id,
                             num_processes, prefix)
    _CTX = ProcessContext(comm=comm, hb_timeout=hb_timeout, fault=fault)
    return _CTX


def set_local_context(nprocs: int = 1, pid: int = 0, *, prefix: str = "run",
                      hb_timeout: float = 10.0, fault=None) -> ProcessContext:
    """Install a LocalKV-backed context (single-process tests of the
    ``topology='process'`` dispatch — no jax.distributed world)."""
    global _CTX
    from repro.core import procmesh

    comm = procmesh.ProcComm(procmesh.LocalKV(), pid, nprocs, prefix)
    _CTX = ProcessContext(comm=comm, hb_timeout=hb_timeout, fault=fault)
    return _CTX


def clear_context() -> None:
    global _CTX
    _CTX = None


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.distributed",
        description="multi-process elastic launcher (DESIGN.md §Multi-host "
                    "& elasticity)")
    ap.add_argument("--nprocs", type=int, default=2,
                    help="world size (launcher mode)")
    ap.add_argument("--workers", type=int, default=4,
                    help="p: CentralVR workers, split over the processes")
    ap.add_argument("--algo", default="centralvr_async",
                    choices=("centralvr_sync", "centralvr_async"))
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--problem", default="logistic",
                    choices=("logistic", "ridge"))
    ap.add_argument("--n", type=int, default=12,
                    help="samples per worker (total = n * workers)")
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--eta", type=float, default=0.0,
                    help="step size; 0 = auto_eta on the merged problem")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--speeds", default="",
                    help="comma-separated per-worker speeds (async)")
    ap.add_argument("--x64", action="store_true",
                    help="enable f64 (the bit-exact pin mode)")
    ap.add_argument("--verify", action="store_true",
                    help="launcher: compare the fleet trajectory against "
                         "the in-parent single-process reference")
    ap.add_argument("--tol", type=float, default=-1.0,
                    help="verify tolerance; -1 = auto (0.0 for x64 async, "
                         "1e-12 x64 sync, 3e-4 f32)")
    ap.add_argument("--json", default="",
                    help="results JSON path (written by process 0)")
    ap.add_argument("--obs", default="",
                    help="telemetry base path; each process writes "
                         "<base>-p<i>.jsonl")
    ap.add_argument("--logdir", default="",
                    help="child stdout/stderr directory (default: temp)")
    ap.add_argument("--timeout", type=float, default=300.0,
                    help="launcher hard timeout in seconds")
    ap.add_argument("--elastic", action="store_true")
    ap.add_argument("--drop-process", type=int, default=-1,
                    help="inject a fault: this process drops at a wave "
                         "boundary (requires --elastic; never 0)")
    ap.add_argument("--drop-round", type=int, default=2)
    ap.add_argument("--drop-mode", default="exit", choices=("exit", "stall"))
    ap.add_argument("--rejoin-after", type=int, default=2,
                    help="stall mode: boundaries to sit out before "
                         "rejoining")
    ap.add_argument("--hb-timeout", type=float, default=10.0,
                    help="heartbeat wait per peer at each wave boundary")
    ap.add_argument("--run-prefix", default="run0",
                    help="KV key namespace for this run")
    # internal (appended by the launcher when spawning workers)
    ap.add_argument("--process-id", type=int, default=-1,
                    help=argparse.SUPPRESS)
    ap.add_argument("--coordinator", default="", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.speeds:
        args.speeds = tuple(float(s) for s in args.speeds.split(","))
    else:
        args.speeds = None
    return args


def _build_spec(args):
    from repro.core import solver

    return solver.RunSpec(
        algo=args.algo, p=args.workers, rounds=args.rounds,
        eta=args.eta or None, seed=args.seed, speeds=args.speeds,
        topology="process", elastic=args.elastic)


def _build_cfg(args):
    from repro.config import ConvexConfig

    return ConvexConfig(problem=args.problem, n=args.n, d=args.d,
                        seed=args.seed)


def _fault_from(args):
    if args.drop_process < 0:
        return None
    from repro.core import procmesh

    return procmesh.Fault(process=args.drop_process, round_=args.drop_round,
                          mode=args.drop_mode,
                          rejoin_after=args.rejoin_after)


# ---------------------------------------------------------------------------
# Worker mode
# ---------------------------------------------------------------------------

def run_worker(args) -> int:
    from repro.obs import recorder as obs_recorder

    fault = _fault_from(args)
    init_process(args.coordinator, args.nprocs, args.process_id,
                 x64=args.x64, prefix=args.run_prefix,
                 hb_timeout=args.hb_timeout, fault=fault)
    if args.obs:
        obs_recorder.enable(f"{args.obs}-p{args.process_id}.jsonl")
    from repro.core import procmesh, solver

    spec = _build_spec(args)
    cfg = _build_cfg(args)
    payload = {"process": args.process_id, "nprocs": args.nprocs,
               "spec": dataclasses.asdict(spec)}
    code = 0
    try:
        res = solver.solve(spec, cfg)
        payload.update(
            rels=[float(v) for v in res.rels],
            transitions=res.transitions or [],
            final_rel=res.final_rel, dropped=False)
    except procmesh.WorkerDropped as e:
        rec = obs_recorder.active()
        if rec is not None:
            rec.event("fault_exit", process=args.process_id,
                      round=e.round_)
        payload.update(rels=[float(v) for v in e.rels], transitions=[],
                       dropped=True, dropped_round=e.round_)
    except Exception as e:     # noqa: BLE001 — report, then hard-exit
        payload.update(error=f"{type(e).__name__}: {e}")
        import traceback
        traceback.print_exc()
        code = 1
    if args.json and args.process_id == 0:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
    obs_recorder.disable()       # flush + close the telemetry record
    sys.stdout.flush()
    sys.stderr.flush()
    # Completion handshake: process 0 hosts the coordination service, so
    # it must outlive every peer — exiting early tears the service down
    # and SIGABRTs any survivor whose client is still polling it.  Every
    # process (dropped ones included — they stay connected) publishes a
    # finish flag as its last act; process 0 drains them before exiting.
    # skip jax.distributed.shutdown: it barriers on the ORIGINAL world,
    # which hangs every survivor once an exit-mode fault has fired
    ctx = context()
    if ctx is not None:
        try:
            ctx.comm.put_flag(f"fin/{ctx.comm.pid}", {"code": code})
        except Exception:        # noqa: BLE001 — exiting anyway
            pass
        if ctx.comm.pid == 0:
            for peer in range(1, ctx.comm.nprocs):
                try:
                    ctx.comm.get_flag(f"fin/{peer}", timeout_s=60.0)
                except Exception:  # noqa: BLE001 — peer crashed hard;
                    pass           # the launcher reports its exit code
            time.sleep(0.25)     # let peers clear their final exit path
    os._exit(code)


# ---------------------------------------------------------------------------
# Launcher mode
# ---------------------------------------------------------------------------

def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _child_argv(args, pid: int, coordinator: str, json_path: str):
    argv = [sys.executable, "-m", "repro.launch.distributed",
            "--nprocs", str(args.nprocs), "--workers", str(args.workers),
            "--algo", args.algo, "--rounds", str(args.rounds),
            "--problem", args.problem, "--n", str(args.n),
            "--d", str(args.d), "--eta", str(args.eta),
            "--seed", str(args.seed), "--hb-timeout", str(args.hb_timeout),
            "--run-prefix", args.run_prefix,
            "--process-id", str(pid), "--coordinator", coordinator,
            "--json", json_path]
    if args.speeds:
        argv += ["--speeds", ",".join(str(s) for s in args.speeds)]
    if args.x64:
        argv += ["--x64"]
    if args.obs:
        argv += ["--obs", args.obs]
    if args.elastic:
        argv += ["--elastic"]
        if args.drop_process >= 0:
            argv += ["--drop-process", str(args.drop_process),
                     "--drop-round", str(args.drop_round),
                     "--drop-mode", args.drop_mode,
                     "--rejoin-after", str(args.rejoin_after)]
    return argv


def _tail(path: str, lines: int = 25) -> str:
    try:
        with open(path, errors="replace") as f:
            return "".join(f.readlines()[-lines:])
    except OSError:
        return "<no log>"


def _auto_tol(args) -> float:
    if args.tol >= 0:
        return args.tol
    if not args.x64:
        return 3e-4
    # f64: the async wave algebra pins bit-exact; the sync engine's
    # separately-jitted epochs can differ from the vmapped reference by
    # reduction-order ULPs
    return 0.0 if args.algo == "centralvr_async" else 1e-10


def _verify(args, results: dict) -> int:
    """In-parent single-process reference vs the fleet's trajectory."""
    import jax

    if args.x64:
        jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.core import elastic as elasticmod
    from repro.core import solver

    tol = _auto_tol(args)
    spec = solver.RunSpec(
        algo=args.algo, p=args.workers, rounds=args.rounds,
        eta=args.eta or None, seed=args.seed, speeds=args.speeds,
        topology="local", elastic=args.elastic)
    membership = None
    if args.elastic:
        membership = elasticmod.PlannedMembership(
            args.workers,
            {t["round"]: t["live"] for t in results["transitions"]})
    res = solver.solve(spec, _build_cfg(args), membership=membership)
    got = np.asarray(results["rels"], dtype=float)
    want = np.asarray(res.rels, dtype=float)
    if got.shape != want.shape:
        print(f"VERIFY FAIL: fleet recorded {got.shape} rels, reference "
              f"has {want.shape}")
        return 1
    diff = float(np.abs(got - want).max())
    ok = diff <= tol
    print(f"verify: max|fleet - reference| = {diff:.3e} "
          f"(tol {tol:.1e}) -> {'OK' if ok else 'FAIL'}")
    if args.elastic:
        print(f"verify: replayed membership transitions: "
              f"{results['transitions']}")
    return 0 if ok else 1


def run_launcher(args) -> int:
    if args.elastic and args.drop_process == 0:
        print("--drop-process 0 is invalid: process 0 co-hosts the "
              "coordination service", file=sys.stderr)
        return 2
    logdir = args.logdir or tempfile.mkdtemp(prefix="repro-multihost-")
    os.makedirs(logdir, exist_ok=True)
    json_path = args.json or os.path.join(logdir, "results.json")
    coordinator = f"127.0.0.1:{_free_port()}"
    print(f"launching {args.nprocs} processes (coordinator {coordinator}, "
          f"logs in {logdir})")
    procs, logs = [], []
    for pid in range(args.nprocs):
        log = open(os.path.join(logdir, f"proc{pid}.log"), "w")
        logs.append(log.name)
        procs.append(subprocess.Popen(
            _child_argv(args, pid, coordinator, json_path),
            stdout=log, stderr=subprocess.STDOUT))
    deadline = time.monotonic() + args.timeout
    codes = [None] * args.nprocs
    while any(c is None for c in codes):
        if time.monotonic() > deadline:
            for p in procs:
                p.kill()
            print(f"TIMEOUT after {args.timeout:.0f}s", file=sys.stderr)
            for pid, log in enumerate(logs):
                print(f"--- proc{pid} tail ---\n{_tail(log)}",
                      file=sys.stderr)
            return 124
        for pid, p in enumerate(procs):
            if codes[pid] is None:
                codes[pid] = p.poll()
        time.sleep(0.2)
    if any(codes):
        print(f"worker exit codes: {codes}", file=sys.stderr)
        for pid, log in enumerate(logs):
            if codes[pid]:
                print(f"--- proc{pid} tail ---\n{_tail(log)}",
                      file=sys.stderr)
        return 1
    with open(json_path) as f:
        results = json.load(f)
    if "error" in results:
        print(f"process 0 reported: {results['error']}", file=sys.stderr)
        print(_tail(logs[0]), file=sys.stderr)
        return 1
    print(f"fleet ok: final_rel={results.get('final_rel'):.3e} "
          f"transitions={results.get('transitions')}")
    if args.verify:
        return _verify(args, results)
    return 0


def main(argv=None) -> int:
    args = parse_args(argv)
    if args.process_id >= 0:
        return run_worker(args)       # never returns (os._exit)
    return run_launcher(args)


if __name__ == "__main__":
    # `python -m` executes this file as __main__, a SEPARATE module
    # instance from the `repro.launch.distributed` the engines import for
    # context() — run the canonical instance so they share _CTX
    from repro.launch import distributed as _canonical
    sys.exit(_canonical.main())
