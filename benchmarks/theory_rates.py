"""Theorem 1 empirical check: measured per-epoch Lyapunov contraction rate
vs the guaranteed alpha across a step-size grid (uniform sampling)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import centralvr, convex, theory


def run(quick: bool = False):
    prob = convex.make_ridge_data(jax.random.PRNGKey(0), 80, 6, 0.05)
    A = prob.A / jnp.linalg.norm(prob.A, axis=1, keepdims=True)
    prob = convex.Problem(A, prob.b, prob.lam, "ridge")
    mu, L = map(float, convex.constants(prob))
    eta_max = theory.max_step(mu, L)
    xstar = convex.solve_exact(prob)
    fstar = float(convex.full_loss(prob, xstar))

    rows = []
    epochs = 20 if quick else 40
    for frac in (0.25, 0.5, 0.9):
        eta = frac * eta_max
        a = theory.alpha(eta, mu, L)
        c = theory.lyapunov_c(eta, prob.n, L)
        state = centralvr.init_state(prob, eta, jax.random.PRNGKey(1))
        Vs = []
        for k in jax.random.split(jax.random.PRNGKey(2), epochs):
            state, traj = centralvr.epoch_uniform(prob, state, eta, k,
                                                  track_iterates=True)
            fbar = float(jnp.mean(jax.vmap(
                lambda x: convex.full_loss(prob, x))(traj)))
            Vs.append(max(float(jnp.sum((traj[0] - xstar) ** 2))
                          + c * (fbar - fstar), 1e-300))
        rate = float(np.exp((np.log(Vs[-1]) - np.log(Vs[0]))
                            / (len(Vs) - 1)))
        rows.append({
            "name": f"theory/eta={frac:.2f}*eta_max",
            "us_per_call": 0.0,
            "derived": (f"alpha_bound={a:.4f};measured_rate={rate:.4f};"
                        f"bound_holds={'yes' if rate <= a * 1.05 else 'no'}"),
        })
    emit(rows, "theory_rates")
    return rows


if __name__ == "__main__":
    run()
