"""Fetch-staleness and wave-utilization accounting for the async drivers.

The asynchronous runtimes (CentralVR-Async, stale-fetch D-SAGA) are
DETERMINISTIC simulations: the arrival order is the precomputed event
schedule (``runtime.event_schedule``), and each worker runs its local
block from the central state it fetched at its own previous event.  The
fetch staleness of an event is therefore exactly computable from the
schedule — the number of OTHER events applied to the central state
between the worker's fetch and this event:

    staleness(t) = t - prev_event_of_worker(t) - 1

Round-robin schedules give every post-warmup event staleness p-1 (the
natural value for a rotating server, §Distributed docstring);
heterogeneous ``speeds`` spread the histogram — fast workers see fresh
state, slow workers see arbitrarily stale state.  The first event of each
worker measures staleness against the shared t=0 fetch (the init
construction in ``distributed.async_init``), i.e. staleness = t.

Wave utilization describes the spmd-async backend's concurrency
(``runtime.wave_partition``): how many waves each metric round splits
into and what fraction of the p devices each wave occupies — the
device-idle accounting behind the paper's linear-scaling claim.
Everything here is host-side numpy over the schedule; it never touches
jax and costs O(rounds * p).
"""
from __future__ import annotations

import numpy as np


def staleness_stats(schedule, p: int) -> dict:
    """Per-event fetch-staleness histogram + wave stats (JSON-able)."""
    # runtime itself is numpy-only, but the repro.core package init pulls
    # in the jax-backed modules — keep `import repro.obs` jax-free
    from repro.core import runtime

    schedule = np.asarray(schedule, dtype=np.int64)
    total = int(schedule.size)
    if total % p:
        raise ValueError(
            f"schedule size {total} is not a multiple of p={p}")
    rounds = total // p
    prev = np.full(p, -1, dtype=np.int64)
    stal = np.empty(total, dtype=np.int64)
    for t, s in enumerate(schedule.tolist()):
        stal[t] = t - prev[s] - 1
        prev[s] = t
    values, counts = np.unique(stal, return_counts=True)

    active, _, _ = runtime.wave_partition(schedule, p)
    # waves actually used per round (the trailing waves of a round can be
    # all-inactive padding up to the global width)
    used = active.any(axis=2)                   # (rounds, W)
    waves_per_round = used.sum(axis=1)
    occupancy = active.sum(axis=(1, 2)) / np.maximum(
        waves_per_round * p, 1)                 # events / (waves * p)
    return {
        "p": int(p),
        "events": total,
        "rounds": rounds,
        "histogram": {str(int(v)): int(c) for v, c in zip(values, counts)},
        "mean": float(stal.mean()),
        "max": int(stal.max()),
        "min": int(stal.min()),
        "waves_per_round_mean": float(waves_per_round.mean()),
        "waves_per_round_max": int(waves_per_round.max()),
        "wave_occupancy_mean": float(occupancy.mean()),
    }
