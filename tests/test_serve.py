"""The serving runtime (repro/serve): paged-cache equivalence, continuous
batching, admission control, and the compile cache.

The load-bearing invariants (DESIGN.md §Serving):

  * paged decode is BIT-IDENTICAL to the dense-cache oracle — including
    after blocks retire and get reused by later requests;
  * continuous batching never changes any request's token stream: batched
    output == serving the same requests one at a time == chunk-size
    invariant;
  * windowed (ring-buffer) layers match a full-recompute greedy oracle
    even after the ring wraps;
  * admission is conservative: a tight pool defers requests instead of
    corrupting live lanes, and an impossible request fails loudly;
  * tensor-parallel decode is pinned against single-device in a forced
    multi-device subprocess (slow lane).
"""
import dataclasses
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import obs
from repro.config import get_arch
from repro.serve import (BlockAllocator, Request, ServeEngine, check_arch,
                         prompt_tokens, run_host_loop, serve_trace,
                         synthetic_trace)

pytestmark = pytest.mark.slow  # jitted serving programs — compile-heavy


@pytest.fixture(scope="module")
def setup():
    """One reduced arch + params shared by every engine in this module
    (build_programs memoizes per (cfg, geo), so same-shape engines also
    share executables)."""
    import jax
    from repro.models import model

    cfg = get_arch("qwen2-7b").reduced()
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _ids(rep):
    return {r.rid: tuple(r.tokens) for r in rep.results}


TRACE = dict(pattern="uniform", prompt_len=12, max_new=6, gap=2,
             vary_new=True, seed=3)
ENGINE = dict(width=3, block_size=4, max_seq_len=32, chunk_buckets=(4, 8))


def test_paged_matches_dense_bitwise_with_block_reuse(setup):
    """The tentpole invariant: greedy ids from the paged cache equal the
    dense oracle bit-for-bit, on a trace whose retirements force block
    reuse (LIFO allocator hands freed blocks to later requests)."""
    cfg, params = setup
    trace = synthetic_trace(6, **TRACE)
    paged = serve_trace(cfg, trace, params=params, kv_cache="paged",
                        **ENGINE)
    dense = serve_trace(cfg, trace, params=params, kv_cache="dense",
                        **ENGINE)
    assert paged.blocks_reused > 0, "trace never exercised block reuse"
    assert _ids(paged) == _ids(dense)


def test_batched_equals_sequential(setup):
    """Continuous batching is invisible per request: the same ids come out
    of a width-3 batch and of serving each request alone."""
    cfg, params = setup
    trace = synthetic_trace(6, **TRACE)
    batched = _ids(serve_trace(cfg, trace, params=params, **ENGINE))
    for r in trace:
        alone = serve_trace(cfg, [dataclasses.replace(r, arrival=0)],
                            params=params, **ENGINE)
        assert _ids(alone)[r.rid] == batched[r.rid], f"rid {r.rid}"


def test_chunk_bucket_invariance(setup):
    """Prefill chunking is a launch-shape choice, not a numeric one."""
    cfg, params = setup
    trace = synthetic_trace(3, pattern="burst", prompt_len=11, max_new=4)
    base = None
    for buckets in ((16,), (4, 8), (2,)):
        rep = serve_trace(cfg, trace, params=params,
                          **{**ENGINE, "chunk_buckets": buckets})
        if base is None:
            base = _ids(rep)
        else:
            assert _ids(rep) == base, f"buckets {buckets}"


def test_engine_matches_legacy_host_loop(setup):
    """Old path and new path serve the same tokens (same greedy ids),
    which is what makes the BENCH_serve twin rows comparable."""
    cfg, params = setup
    trace = synthetic_trace(4, pattern="burst", prompt_len=12, max_new=5)
    eng = serve_trace(cfg, trace, params=params, **ENGINE)
    legacy = run_host_loop(cfg, trace, params=params, width=2)
    assert _ids(eng) == _ids(legacy)


def test_ring_window_covers_full_context_bitwise():
    """A window >= total length makes the ring a plain cache: bit-equal
    ids to the same arch with windowing off."""
    import jax
    from repro.models import model

    base = get_arch("starcoder2-15b").reduced()
    win = dataclasses.replace(base, sliding_window=32)
    full = dataclasses.replace(base, sliding_window=None)
    params = model.init_params(full, jax.random.PRNGKey(1))
    trace = synthetic_trace(2, pattern="burst", prompt_len=10, max_new=5)
    kw = dict(width=2, block_size=4, max_seq_len=20, chunk_buckets=(4,))
    a = serve_trace(win, trace, params=params, **kw)
    b = serve_trace(full, trace, params=params, **kw)
    assert _ids(a) == _ids(b)


def test_ring_wraparound_matches_recompute_oracle():
    """After the ring wraps (len > window), decode must equal a greedy
    oracle that recomputes the full forward each step (windowed attention
    applied functionally, no ring state)."""
    import jax
    import jax.numpy as jnp
    from repro.models import model

    base = get_arch("starcoder2-15b").reduced()
    cfg = dataclasses.replace(base, sliding_window=12)
    params = model.init_params(cfg, jax.random.PRNGKey(2))
    prompt_len, max_new = 20, 8          # wraps: 20+8 > window 12
    trace = synthetic_trace(1, pattern="burst", prompt_len=prompt_len,
                            max_new=max_new, seed=5)
    rep = serve_trace(cfg, trace, params=params, width=1, block_size=4,
                      max_seq_len=32, chunk_buckets=(8,))
    got = list(_ids(rep)[0])

    toks = list(np.asarray(prompt_tokens(trace[0], cfg.vocab_size)))
    oracle = []
    fwd = jax.jit(lambda p, t: model.forward(p, cfg, {"tokens": t})[0])
    for _ in range(max_new):
        logits = fwd(params, jnp.asarray([toks], jnp.int32))
        nxt = int(np.argmax(np.asarray(logits[0, -1])))
        oracle.append(nxt)
        toks.append(nxt)
    assert got == oracle


def test_tight_pool_defers_admission(setup):
    """A pool sized for ~1.5 sequences forces the scheduler to queue: all
    requests still finish with correct ids, but admission is staggered
    even under burst arrivals."""
    cfg, params = setup
    trace = synthetic_trace(4, pattern="burst", prompt_len=12, max_new=6)
    roomy = serve_trace(cfg, trace, params=params, **ENGINE)
    # blocks_for(18) = 5 → 7 free blocks fit one sequence + change
    tight = serve_trace(cfg, trace, params=params,
                        **{**ENGINE, "num_blocks": 8})
    assert _ids(tight) == _ids(roomy)
    admits = sorted(r.admit_step for r in tight.results)
    assert admits[0] < admits[-1], "tight pool never deferred admission"


def test_impossible_request_raises(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, **{**ENGINE, "num_blocks": 3})
    with pytest.raises(RuntimeError, match="can ever free up"):
        eng.run([Request(rid=0, arrival=0, prompt_len=12, max_new=6)])
    too_long = [Request(rid=0, arrival=0, prompt_len=30, max_new=8)]
    with pytest.raises(ValueError, match="exceeds max servable"):
        ServeEngine(cfg, params, **ENGINE).run(too_long)


def test_engine_rejects_unsupported_archs(setup):
    cfg, params = setup
    ssm = get_arch("mamba2-130m").reduced()
    with pytest.raises(ValueError, match="attention-family"):
        check_arch(ssm)
    with pytest.raises(ValueError):
        ServeEngine(ssm)
    # legacy fallback also refuses ragged prompts (lockstep batching)
    ragged = [Request(rid=0, arrival=0, prompt_len=8, max_new=2),
              Request(rid=1, arrival=0, prompt_len=9, max_new=2)]
    with pytest.raises(ValueError, match="prompt_len"):
        run_host_loop(cfg, ragged, params=params)


def test_serve_run_emits_valid_telemetry(setup, tmp_path):
    """A served trace under obs.recording() produces a schema-valid
    record file containing the serve spans and admit/retire events (the
    CI serve-smoke step validates the same thing via the obs CLI)."""
    from repro.obs import schema

    cfg, params = setup
    trace = synthetic_trace(2, pattern="burst", prompt_len=8, max_new=3)
    path = str(tmp_path / "serve.jsonl")
    with obs.recording(path):
        serve_trace(cfg, trace, params=params, **ENGINE)
    assert schema.validate_file(path) > 0
    kinds = [json.loads(l) for l in open(path)]
    names = {r.get("name") for r in kinds}
    assert {"serve/run", "serve/prefill"} <= names
    events = {r["name"] for r in kinds if r["kind"] == "event"}
    assert {"serve_admit", "serve_retire", "serve_report"} <= events


# -- allocator unit tests (no jax) ----------------------------------------

def test_allocator_lifo_reuse_and_reservations():
    a = BlockAllocator(6)                  # usable ids 1..5
    a.reserve(0, 3)
    assert a.available() == 2
    got = [a.alloc(0) for _ in range(3)]
    assert got == [1, 2, 3]                # deterministic order
    assert a.in_use == 3 and a.reuse_count == 0
    a.release(0, got)
    assert a.available() == 5
    a.reserve(1, 1)
    assert a.alloc(1) == 3                 # LIFO: last freed, first out
    assert a.reuse_count == 1


def test_allocator_guards():
    a = BlockAllocator(4)
    with pytest.raises(RuntimeError, match="exceeds available"):
        a.reserve(0, 4)
    with pytest.raises(RuntimeError, match="without reservation"):
        a.alloc(0)
    a.reserve(0, 2)
    with pytest.raises(RuntimeError, match="exceeds available"):
        a.reserve(1, 2)                    # only 1 unreserved left
    with pytest.raises(ValueError, match="bad block id"):
        a.release(0, [0])                  # trash block is unreleasable


def test_compile_cache_env_and_flag(tmp_path, monkeypatch):
    from repro.launch.compile_cache import ENV_VAR, enable_compile_cache

    monkeypatch.delenv(ENV_VAR, raising=False)
    assert enable_compile_cache() is None  # no-op without opt-in
    d = tmp_path / "cc"
    assert enable_compile_cache(str(d)) == str(d)
    assert d.is_dir()
    import jax
    assert jax.config.jax_compilation_cache_dir == str(d)
    monkeypatch.setenv(ENV_VAR, str(tmp_path / "cc2"))
    assert enable_compile_cache() == str(tmp_path / "cc2")


# -- tensor-parallel decode (subprocess: forced 2 host devices) -----------

TP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import sys, json
    sys.path.insert(0, "src")
    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.config import get_arch
    from repro.launch.mesh import make_test_mesh
    from repro.models import model
    from repro.serve import serve_trace, synthetic_trace

    cfg = get_arch("qwen2-7b").reduced()
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    trace = synthetic_trace(3, pattern="uniform", prompt_len=12, max_new=5,
                            gap=2, seed=4)
    kw = dict(width=2, block_size=4, max_seq_len=20, chunk_buckets=(4, 8))
    single = serve_trace(cfg, trace, params=params, **kw)
    tp = serve_trace(cfg, trace, params=params,
                     mesh=make_test_mesh(model_axis=2), **kw)
    out = {"single": {r.rid: r.tokens for r in single.results},
           "tp": {r.rid: r.tokens for r in tp.results}}
    print("RESULT" + json.dumps(out))
""")


def test_tp_decode_matches_single_device():
    proc = subprocess.run([sys.executable, "-c", TP_SCRIPT],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    assert out["tp"] == out["single"]
    assert out["tp"], "empty results"
