"""Theorem 1 constants and step-size bounds, used by tests and benchmarks
to validate the measured convergence against the paper's guarantee.

Theorem 1: with uniform-with-replacement sampling and

    alpha = max( 1 - eta*mu,  2*L^2*eta / (mu*(1 - 2*L*eta)) ),

if 0 < alpha < 1 the Lyapunov function

    V_m = ||x_m^0 - x*||^2 + c * ( fbar(x_m) - f(x*) ),   c = 2*n*eta*(1-2*L*eta)

contracts: V_{m+1} <= alpha * V_m.  The remark gives the sufficient step
size  eta < mu / (2*L*(L+mu)).
"""
from __future__ import annotations

import jax.numpy as jnp


def alpha(eta: float, mu: float, L: float) -> float:
    """The contraction factor of Theorem 1."""
    a1 = 1.0 - eta * mu
    denom = mu * (1.0 - 2.0 * L * eta)
    a2 = jnp.inf if denom <= 0 else 2.0 * L**2 * eta / denom
    return float(max(a1, a2))


def max_step(mu: float, L: float) -> float:
    """Sufficient step-size bound from the remark after Theorem 1."""
    return float(min(1.0 / mu, 1.0 / (2.0 * L), mu / (2.0 * L * (L + mu))))


def lyapunov_c(eta: float, n: int, L: float) -> float:
    return float(2.0 * n * eta * (1.0 - 2.0 * L * eta))


def lyapunov(x0_dist_sq: float, fbar_gap: float, eta: float, n: int,
             L: float) -> float:
    """V_m = ||x_m^0 - x*||^2 + c (fbar - f*)."""
    return float(x0_dist_sq + lyapunov_c(eta, n, L) * fbar_gap)


def epochs_to_eps(eps: float, alpha_: float) -> int:
    """Epochs needed for a factor-eps contraction at rate alpha."""
    import math
    return int(math.ceil(math.log(eps) / math.log(alpha_)))
