"""Pallas TPU kernels (validated on CPU via interpret=True against the
ref.py oracles):

  vr_update/       fused CentralVR/SAGA update (the paper's hot loop)
  flash_attention/ causal GQA flash attention (online softmax, windows)
  rmsnorm/         fused RMSNorm
  ssd_scan/        fused Mamba2 SSD chunk scan (state in VMEM scratch)
"""
