"""Synthetic data pipeline.

Two jobs:

1. LM token streams with the FINITE-SUM structure the paper's technique
   needs: each (worker w, microbatch index i) pair maps to a FIXED
   minibatch — `epoch_batch(w, i)` returns the same tokens every epoch, so
   f_i = loss(microbatch_i) is a well-defined component function and the
   CentralVR/SAGA gradient tables are meaningful. Tokens are generated
   statelessly from fold_in-chained PRNG keys (no host state, shardable,
   identical across restarts — also what checkpoint resume relies on).

2. Frontend stubs for the VLM/audio archs: precomputed patch/frame
   embeddings of the right shape (the assignment's one sanctioned stub).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def _key(seed: int, *idx: int):
    k = jax.random.PRNGKey(seed)
    for i in idx:
        k = jax.random.fold_in(k, i)
    return k


def microbatch_tokens(cfg: ModelConfig, seed: int, worker: int, index: int,
                      batch: int, seq: int):
    """The i-th FIXED microbatch of worker w: same tokens every epoch.
    Markov-ish stream: low-entropy structure so training loss can fall."""
    k = _key(seed, worker, index)
    base = jax.random.randint(k, (batch, seq), 0, cfg.vocab_size)
    # overlay periodic structure (learnable signal)
    period = jax.random.randint(jax.random.fold_in(k, 1), (batch, 1), 2, 17)
    pos = jnp.arange(seq)[None, :]
    structured = (pos % period) * 37 % cfg.vocab_size
    use = jax.random.bernoulli(jax.random.fold_in(k, 2), 0.7, (batch, seq))
    return jnp.where(use, structured, base).astype(jnp.int32)


def epoch_batch(cfg: ModelConfig, seed: int, step: int, *, workers: int,
                accum: int, microbatch: int, seq: int, table_size: int):
    """Tokens for one train step: (W, A, mb, S). The microbatch INDEX
    cycles modulo table_size — step k uses component function
    i = k mod M on every worker (permutation = sequential cycling).

    Vectorized over (worker, accum) and callable INSIDE jit with a traced
    ``step`` (the fold_in key chain is stateless), so the epoch-scan
    runtime generates batches on device instead of feeding them from the
    host per step. The vmapped fold_in draws are bit-identical to the old
    per-(w, a) host loop."""
    idx = step % table_size

    def one(w, a):
        return microbatch_tokens(cfg, seed, w, idx * accum + a,
                                 microbatch, seq)

    w_ids = jnp.arange(workers, dtype=jnp.int32)
    a_ids = jnp.arange(accum, dtype=jnp.int32)
    return jax.vmap(lambda w: jax.vmap(lambda a: one(w, a))(a_ids))(w_ids)


def epoch_tokens(cfg: ModelConfig, seed: int, *, workers: int, steps: int,
                 accum: int, microbatch: int, seq: int, table_size: int):
    """All tokens of one communication epoch: (W, steps, A, mb, S).

    Because the stream is a finite sum (index = step mod table_size), the
    block for steps [0, M*K) is REUSED verbatim by every later epoch —
    the spmd LM backend precomputes it once on the host and ships it
    sharded along the worker axis (in-shard ``jax.random`` is off-limits
    under the multi-device CPU partitioner, DESIGN.md §2)."""
    per_step = jax.vmap(
        lambda s: epoch_batch(cfg, seed, s, workers=workers, accum=accum,
                              microbatch=microbatch, seq=seq,
                              table_size=table_size)
    )(jnp.arange(steps, dtype=jnp.int32))
    return jnp.swapaxes(per_step, 0, 1)


def frontend_embeds(cfg: ModelConfig, seed: int, batch: int,
                    dtype=jnp.float32):
    """STUB modality frontend: pre-computed patch/frame embeddings with the
    statistics of a trained encoder output (unit-RMS, correlated)."""
    if not (cfg.frontend and cfg.frontend_tokens):
        return None
    k = _key(seed, 999)
    base = jax.random.normal(k, (batch, cfg.frontend_tokens, cfg.d_model),
                             dtype)
    # smooth across tokens (neighbouring patches correlate)
    sm = 0.5 * base + 0.5 * jnp.roll(base, 1, axis=1)
    return sm


def eval_batch(cfg: ModelConfig, seed: int, batch: int, seq: int):
    """Held-out batch (indices offset far from the training table)."""
    return microbatch_tokens(cfg, seed, worker=10_000, index=0,
                             batch=batch, seq=seq)
