"""PartitionSpec rules for the zoo's parameter trees.

Two parameter-placement modes, mirroring the paper-vs-beyond split:

  * ``dp_replicated=True`` (paper-faithful CentralVR): every worker holds a
    full model copy — params are replicated along the data/pod axes and
    tensor-parallel along 'model'. This is the paper's memory model.
  * ``dp_replicated=False`` (optimized): additionally FSDP-shard the params'
    largest non-TP dim along 'data' (ZeRO-3); CentralVR workers then live on
    the 'pod' axis (hierarchical CentralVR — sync FSDP inside a pod, the
    paper's rare epoch-boundary exchange across pods).

Rules are path-pattern based. Dims that do not divide the axis size are
still sharded (GSPMD pads) EXCEPT tiny per-head vectors, which are
replicated. The SSM/RG-LRU mixers keep their head-structured inner dims
replicated over 'model' (heads don't align with a 16-way axis; these
models are small) — recorded in DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# rules: (substring, spec builder(leaf_ndim) -> tuple of axis names/None)
def _param_rule(path: str, shape: Tuple[int, ...], cfg: ModelConfig,
                fsdp: Optional[str], axis_sizes: Optional[dict] = None):
    """Returns the PartitionSpec dims for one unstacked param leaf.

    Head-count-aware: tensor parallelism on attention uses the HEAD axis
    only when num_heads divides the 'model' axis; otherwise attention TP is
    DROPPED for that arch (replicate over 'model', FSDP over 'data').
    Relocating 'model' onto the d_model (contracting) dim instead is a
    measured anti-optimization: GSPMD defers the partial-sum reduction into
    the attention chunk loop and all-reduces the SCORES every iteration
    (~1e14 bytes for qwen2-7b prefill_32k — see EXPERIMENTS.md §Perf #1).
    """
    tp = "model"
    tp_n = (axis_sizes or {}).get("model", 1)
    heads_ok = cfg.padded_heads % tp_n == 0

    def dims(*ds):
        return tuple(ds)

    if "embed/tok" in path:
        return dims(tp, fsdp)
    if "head/w" in path:
        return dims(fsdp, tp)
    if "frontend_proj" in path:
        return dims(fsdp, tp)

    # --- attention ---
    if "mixer/wq" in path:
        return dims(fsdp, tp if heads_ok else None, None)
    if "mixer/wk" in path or "mixer/wv" in path:
        # shard kv heads only if they cover the axis; else replicate
        # (cheap: kv_dim is small) so the kv cache stays unpadded
        return dims(fsdp, None, None)
    if "mixer/wo" in path:
        return dims(tp if heads_ok else None, None, fsdp)
    if "mixer/bq" in path:
        return dims(tp if heads_ok else None, None)
    if "mixer/bk" in path or "mixer/bv" in path:
        return dims(None, None)
    if "q_norm" in path or "k_norm" in path:
        return dims(None)

    # --- MoE --- (cfg.is_moe guard is essential: a DENSE arch's stacked
    # (L, d, ff) weight is also 3-D — without the guard it matched this
    # rule and sharded the LAYER-SCAN dim over 'model', which made XLA
    # hoist a full-stack weight all-gather out of the layer loop: 129 GB
    # materialized for qwen1.5-110b decode. EXPERIMENTS.md §Perf It.7.)
    if "ffn/router" in path:
        return dims(None, tp)
    if cfg.is_moe and ("ffn/wg" in path or "ffn/wu" in path
                       or "ffn/wd" in path) and shape and len(shape) == 3:
        return dims(tp, fsdp, None)      # expert-parallel
    if "shared/wg" in path or "shared/wu" in path:
        return dims(fsdp, tp)
    if "shared/wd" in path:
        return dims(tp, fsdp)
    if "shared_gate" in path:
        return dims(None, None)

    # --- dense MLP ---
    if "ffn/wg" in path or "ffn/wu" in path or "ffn/wi" in path:
        return dims(fsdp, tp)
    if "ffn/wd" in path or "ffn/wo" in path:
        return dims(tp, fsdp)
    if "ffn/bi" in path:
        return dims(tp)
    if "ffn/bo" in path:
        return dims(None)

    # --- SSM (mamba2): inner dims head-structured; TP not applied ---
    if "mixer/in_proj" in path or "mixer/out_proj" in path:
        return dims(fsdp, None)
    if "mixer/conv_w" in path:
        return dims(None, None)

    # --- RG-LRU ---
    if "mixer/wx_in" in path or "mixer/wy_in" in path:
        return dims(fsdp, None)
    if "mixer/out" in path:
        return dims(None, fsdp)
    if "mixer/wa" in path or "mixer/wi" in path:
        return dims(None, None, None)

    # norms, scalars, small vectors: replicated
    return tuple(None for _ in shape)


def _known_rule_len(path: str, cfg: ModelConfig) -> Optional[int]:
    """ndim of the UNSTACKED param a rule path refers to (None if the path
    matches no structural rule — then everything is replicated)."""
    probe = _param_rule(path, (), cfg, None)
    return len(probe) if probe else None


def _axis_size(axis, sizes: dict) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(axis, 1)


def _fix_divisibility(spec, shape, sizes: dict):
    """pjit in_shardings require exact divisibility: any axis that does not
    divide its dim is DROPPED (replicated). Relocation to another dim was
    tried and reverted — moving 'model' onto a contracting dim turns the
    consumer matmul into a deferred partial-sum whose all-reduce lands
    inside inner loops (EXPERIMENTS.md §Perf #1)."""
    spec = list(spec)
    for i, ax in enumerate(spec):
        if ax is None:
            continue
        if shape[i] % _axis_size(ax, sizes) != 0:
            spec[i] = None
    return tuple(spec)


def tree_specs(tree, cfg: ModelConfig, *, fsdp: bool,
               worker_axes: Tuple[str, ...] = (),
               axis_sizes: Optional[dict] = None):
    """PartitionSpec pytree for ANY state tree whose leaves are params or
    param-shaped buffers (optimizer moments, VR tables/anchors/snapshots).

    Works structurally: the substring rules give the spec of the TRAILING
    param dims; any extra LEADING dims (worker-copy axis, scan-stack axis,
    VR table axis) are padded — the first leading dim of a multi-copy
    state gets the worker axes, the rest None. With ``axis_sizes`` the
    specs are made pjit-exact (divisibility relocation/fallback).
    """
    fsdp_axis = "data" if fsdp else None
    w = None
    if worker_axes:
        w = worker_axes if len(worker_axes) > 1 else worker_axes[0]

    def spec_for(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        # find the structural rule by probing progressively shorter
        # trailing shapes until the rule length fits
        base = None
        for n_lead in range(len(shape) + 1):
            cand = _param_rule(ps, shape[n_lead:], cfg, fsdp_axis,
                               axis_sizes)
            if len(cand) == len(shape) - n_lead:
                base = cand
                n = n_lead
                break
        if base is None:                     # scalar / unknown: replicate
            return P(*(None for _ in shape))
        if axis_sizes:
            base = _fix_divisibility(base, shape[n:], axis_sizes)
        lead: list = [None] * n
        if w is not None and n > 0:
            lead[0] = w
        return P(*lead, *base)

    return jax.tree_util.tree_map_with_path(spec_for, tree)


def param_specs(params, cfg: ModelConfig, *, fsdp: bool,
                worker_axes: Tuple[str, ...] = ()):
    return tree_specs(params, cfg, fsdp=fsdp, worker_axes=worker_axes)


def cache_specs(cache, cfg: ModelConfig):
    """KV/state caches: batch dim over 'data' (+'pod' via data in specs of
    the batch), everything else replicated; scan-stacked axis leading."""

    def spec_for(path, leaf):
        ps = _path_str(path)
        n_lead = 1 if "stack" in ps else 0
        shape = leaf.shape[n_lead:]
        return P(*([None] * n_lead), "data", *([None] * (len(shape) - 1)))

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def batch_specs(worker_axes: Tuple[str, ...], data_axes: Tuple[str, ...]):
    """tokens: (W, A, mb, S) when worker axis present, else (A, mb, S)."""
    w = (worker_axes if len(worker_axes) > 1 else worker_axes[0]) \
        if worker_axes else None
    d = (data_axes if len(data_axes) > 1 else data_axes[0]) \
        if data_axes else None
    if worker_axes:
        return P(w, None, d, None)
    return P(None, d, None)
