"""Epoch-driven training loop on the device-resident runtime.

Drives whole communication epochs (M*K steps each) through
``step.make_epoch_runner``: one jitted ``lax.scan`` per epoch with donated
state, per-step losses accumulated on device, and the Algorithm-2 worker
average at the epoch boundary. The host touches the run only BETWEEN
epochs — checkpoint, eval, and logging all happen at epoch boundaries, so
per-step host overhead is zero and independent of the worker count (the
paper's linear-scaling requirement, DESIGN.md §3 "LM epoch scan").

``backend="vmap"`` simulates the W workers stacked on one device;
``backend="spmd"`` places one worker per device of a worker mesh. The
seed per-step loop is retained verbatim as ``train/host_loop.py`` (the
pinned reference path).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

import jax

from repro.checkpoint import checkpoint as ckpt
from repro.config import ModelConfig, TrainConfig
from repro.data import synthetic
from repro.obs import recorder as obs_recorder
from repro.obs import stage as obs_stage
from repro.train import step as tstep


@dataclass
class LoopResult:
    losses: List[float] = field(default_factory=list)
    steps: int = 0
    epochs: int = 0
    wall_time: float = 0.0
    final_eval_loss: Optional[float] = None
    state: Any = None


def run_training(cfg: ModelConfig, tcfg: TrainConfig, *,
                 epochs: Optional[int] = None, steps: Optional[int] = None,
                 workers: int = 1, backend: str = "vmap", mesh=None,
                 checkpoint_path: Optional[str] = None,
                 checkpoint_every: int = 0, resume: bool = False,
                 log_every: int = 1,
                 log_fn: Callable[[str], None] = print) -> LoopResult:
    """Train for whole communication epochs (cadences count EPOCHS).

    ``steps`` may be given instead of ``epochs`` but must be a multiple of
    M*K — the scan runtime has no mid-epoch host boundary to stop at (use
    ``train.host_loop`` for arbitrary step counts). ``resume=True``
    restarts from ``checkpoint_path``'s latest epoch-boundary save.
    ``result.losses`` holds the per-step losses of the epochs THIS call
    ran (after the resume point, if any).
    """
    E = tcfg.vr_table_size * tcfg.local_epoch
    if epochs is None:
        if steps is None:
            raise ValueError("pass epochs= or steps=")
        if steps % E:
            raise ValueError(
                f"steps={steps} is not a multiple of the communication "
                f"epoch M*K={E}; the epoch-scan runtime drives whole "
                "epochs (train.host_loop runs arbitrary step counts)")
        epochs = steps // E
    run_epoch, meta = tstep.make_epoch_runner(cfg, tcfg, workers,
                                              backend=backend, mesh=mesh)
    W = meta["workers"]

    state = tstep.init_train_state(cfg, tcfg, jax.random.PRNGKey(tcfg.seed),
                                   W)
    start_epoch = 0
    if resume and checkpoint_path:
        saved = ckpt.latest_step(checkpoint_path)
        if saved is not None:
            if saved % E:
                raise ValueError(
                    f"checkpoint at step {saved} is not an epoch boundary "
                    f"(M*K={E}); it was not written by the epoch-scan loop")
            state = ckpt.restore(checkpoint_path, like=state)
            start_epoch = saved // E
            if start_epoch >= epochs:
                raise ValueError(
                    f"checkpoint is already at epoch {start_epoch} "
                    f"(step {saved}); nothing left of the requested "
                    f"{epochs} epoch(s) to train — raise epochs/steps "
                    "(continuing would relabel the checkpoint with an "
                    "earlier step)")
    if backend == "spmd":
        state = tstep.place_train_state(state, meta["mesh"])

    result = LoopResult()
    rec = obs_recorder.active()
    t0 = time.time()
    device_losses = []
    for e in range(start_epoch, epochs):
        if rec is None:
            state, losses = run_epoch(state)
        elif e == start_epoch:
            # first epoch staged (lower/compile/execute spans split compile
            # from warm cost; the spmd wrapper is not AOT-stageable and
            # falls back to a plain execute span)
            state, losses = obs_stage.staged_call(run_epoch, state,
                                                  _label="train/epoch")
        else:
            # blocked on inside the span so the duration is epoch work,
            # not async dispatch — telemetry-off keeps the pipelined loop
            with rec.span("train/epoch", epoch=e):
                state, losses = jax.block_until_ready(run_epoch(state))
        device_losses.append(losses)
        if log_every and ((e - start_epoch) % log_every == 0
                          or e == epochs - 1):
            loss = float(losses[-1])
            if rec is not None:
                rec.event("train_epoch", epoch=e, step=(e + 1) * E,
                          loss=loss, workers=W)
            log_fn(f"epoch {e:4d}  step {(e + 1) * E:6d}  "
                   f"loss {loss:.4f}")
        if checkpoint_path and checkpoint_every and \
                (e + 1) % checkpoint_every == 0:
            with obs_recorder.span("train/checkpoint", epoch=e):
                ckpt.save(checkpoint_path, state, step=(e + 1) * E)
    result.losses = [float(l) for arr in jax.device_get(device_losses)
                     for l in arr]
    result.steps = epochs * E
    result.epochs = epochs
    result.wall_time = time.time() - t0
    result.state = state

    # held-out eval on the worker-averaged params (at an epoch boundary
    # the copies coincide, so the average IS every worker's iterate —
    # eval_params keeps that invariant explicit)
    from repro.models import model as modellib
    with obs_recorder.span("train/eval"):
        ev = synthetic.eval_batch(cfg, tcfg.seed, batch=meta["microbatch"],
                                  seq=tcfg.seq_len)
        params = tstep.eval_params(state.params, W)
        result.final_eval_loss = float(modellib.loss_fn(
            params, cfg, {"tokens": ev}, remat="none"))
    if rec is not None:
        rec.event("train_done", epochs=epochs, steps=epochs * E,
                  eval_loss=result.final_eval_loss,
                  wall_s=result.wall_time)
    if checkpoint_path:
        ckpt.save(checkpoint_path, state, step=epochs * E)
    return result
