"""jit'd wrapper for the fused SSD chunk-scan kernel: model-layout
adapter + sequence padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A_log, Bc, Cc, *, chunk: int = 64,
             interpret: bool = False):
    """Model-layout entry: x (B,S,H,P), dt (B,S,H), A_log (H,),
    Bc/Cc (B,S,N) -> y (B,S,H,P). Zero initial state."""
    B_, S, H, P = x.shape
    N = Bc.shape[-1]
    la = (-jnp.exp(A_log.astype(jnp.float32))[None, None, :]
          * dt.astype(jnp.float32))
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    pad = (-S) % chunk
    if pad:
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    la_f = la.transpose(0, 2, 1).reshape(B_ * H, Sp)
    x_f = xdt.transpose(0, 2, 1, 3).reshape(B_ * H, Sp, P)
    y = kernel.ssd_scan(la_f, x_f, Bc.astype(jnp.float32),
                        Cc.astype(jnp.float32), chunk=chunk,
                        interpret=interpret)
    y = y.reshape(B_, H, Sp, P).transpose(0, 2, 1, 3)
    return y[:, :S]
