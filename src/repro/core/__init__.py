"""The paper's contribution: CentralVR and its distributed variants.

Modules:
  convex       -- the paper's experimental problems (GLM scalar-residual form)
  centralvr    -- Algorithm 1 (single worker)
  distributed  -- Algorithms 2-5 (Sync/Async CentralVR, D-SVRG, D-SAGA)
  baselines    -- SGD/SVRG/SAGA (sequential) + dist-SGD/EASGD/PS-SVRG
  runtime      -- device-resident scan driver machinery (DESIGN.md §3)
  host_loop    -- seed-model host-driven reference drivers (pinning/bench)
  theory       -- Theorem 1 constants
"""
from repro.core import (baselines, centralvr, convex, distributed,  # noqa: F401
                        host_loop, runtime, theory)
