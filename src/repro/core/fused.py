"""Fused-kernel bodies for the convex VR drivers (DESIGN.md §Fused
kernels hot-path).

Every VR inner loop in ``core/`` has the same per-step structure —
correction from a stored scalar residual, parameter update, table/anchor
write — which the ``kernels/vr_update`` Pallas kernel executes as ONE
launch (5 reads / 4 writes of param-sized buffers instead of the ~9 reads
XLA materializes for the unfused algebra).  This module adapts the flat
kernel to the convex drivers:

  * the iterate/anchor vectors are padded once per epoch to the kernel
    tile (zero lanes stay exactly zero through the update: the padded
    gbar/feature columns are zero and ``0*(1-eta*decay) - eta*0 = 0``;
    a box prox with lo > 0 does move pad lanes off zero, but pad lanes
    never feed back — margins and outputs use the ``[:d]`` slice only);
  * the features are padded column-wise once so the per-step rank-1
    gradients ``s * a_i`` come out tile-shaped with a single gather;
  * the l2 term ``2*lam*x`` is folded into the kernel's static ``decay``
    instead of a separate elementwise pass.

The step size and lam are baked into the kernel as static floats, so the
fused configuration travels as a hashable tuple
``(eta, lam, interpret, prox)`` (``make_params``) that the jitted scan
runners take as a static argument — ``None`` means "unfused oracle path".
``prox`` is a :class:`repro.prox.operators.ProxSpec` (or None): the
elementwise operators (l1 / elasticnet / box) fuse as a kernel epilogue
on the updated iterate; a non-elementwise prox (group_l2) makes
``fused="auto"`` fall back to the unfused oracle here, and ``fused=True``
is refused pre-JAX by RunSpec.

Numerics: the fused step computes ``s_new*a - s_old*a`` where the oracle
computes ``(s_new - s_old)*a``, and applies the decay multiplicatively —
identical real algebra, different rounding, so trajectories agree to
float tolerance rather than bit-for-bit (pinned in
``tests/test_fused_agreement.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import kernels
from repro.core import convex
from repro.kernels.vr_update import kernel as vr_kernel
from repro.prox import operators as proxops


def make_params(flag, eta: float, lam, prox=None) -> tuple | None:
    """Resolve a driver's ``fused=`` flag into the static kernel params.

    Returns ``None`` (unfused) or ``(eta, lam, interpret, prox)`` with
    python floats and a ProxSpec-or-None — hashable, so the tuple rides
    through ``static_argnames`` of the scan runners and the spmd runner
    caches.  A non-elementwise prox disables fusion: "auto" falls back to
    the unfused oracle, and an explicit ``fused=True`` (already refused
    by RunSpec pre-JAX) raises here as a second line of defense.
    """
    on, interpret = kernels.resolve_fused(flag)
    if not on:
        return None
    if prox is not None:
        prox = proxops.parse(prox)
        if not proxops.is_elementwise(prox):
            if flag is True:
                raise ValueError(
                    f"fused=True cannot fuse the non-elementwise prox "
                    f"{prox.name!r}; use fused=False or 'auto'")
            return None
    return (float(eta), float(lam), bool(interpret), prox)


def padded_len(d: int) -> int:
    return ((d + vr_kernel.TILE - 1) // vr_kernel.TILE) * vr_kernel.TILE


def pad_vec(v, P: int):
    d = v.shape[-1]
    if d == P:
        return v
    return jnp.concatenate([v, jnp.zeros((P - d,), v.dtype)])


def pad_cols(A, P: int):
    d = A.shape[-1]
    if d == P:
        return A
    return jnp.pad(A, ((0, 0), (0, P - d)))


def _residual(z, bb, kind: str):
    """l'(z; b) — the scalar residual of convex.scalar_residual, computed
    from an already-formed margin (the fused bodies dot the unpadded
    feature row against the live iterate slice themselves)."""
    return convex._pointwise_residual(z, bb, kind)


def centralvr_epoch(A, b, kind, x, table, gbar, order, fp, *,
                    track: bool = False):
    """Fused CentralVR epoch: the arithmetic of ``centralvr.epoch`` /
    ``distributed._local_centralvr_epoch`` with the per-step update as one
    kernel launch.  Returns (x, table, acc[, traj]); ``acc`` is the
    running gtilde accumulator (data term, mean over this shard)."""
    eta, lam, interpret, prox = fp
    n, d = A.shape
    P = padded_len(d)
    Ap = pad_cols(A, P)
    xp = pad_vec(x, P)
    gbarp = pad_vec(gbar, P)

    def body(carry, i):
        xp, table, accp = carry
        ap = Ap[i]
        s_new = _residual(ap[:d] @ xp[:d], b[i], kind)
        xo, _, gto, _ = vr_kernel.vr_update_flat(
            xp, s_new * ap, table[i] * ap, gbarp, accp,
            eta=eta, m=n, saga=False, decay=2.0 * lam,
            prox=prox, interpret=interpret)
        table = table.at[i].set(s_new)
        return (xo, table, gto), (xp[:d] if track else None)

    init = (xp, table, jnp.zeros_like(xp))
    (xp, table, accp), traj = jax.lax.scan(body, init, order)
    return xp[:d], table, accp[:d], traj


def saga_steps(A, b, kind, x, table, gbar, n_global: int, idx, fp):
    """Fused SAGA inner loop: the arithmetic of ``baselines._saga_scan`` /
    ``distributed._local_saga_steps`` — VR step plus running-mean gbar
    update (global 1/n scaling) in the same launch.  Returns
    (x, table, gbar)."""
    eta, lam, interpret, prox = fp
    n, d = A.shape
    P = padded_len(d)
    Ap = pad_cols(A, P)
    xp = pad_vec(x, P)
    gbarp = pad_vec(gbar, P)
    zp = jnp.zeros_like(xp)          # dummy gtilde lane (output discarded)

    def body(carry, i):
        xp, table, gbarp = carry
        ap = Ap[i]
        s_new = _residual(ap[:d] @ xp[:d], b[i], kind)
        xo, _, _, gbo = vr_kernel.vr_update_flat(
            xp, s_new * ap, table[i] * ap, gbarp, zp,
            eta=eta, m=n_global, saga=True, decay=2.0 * lam,
            prox=prox, interpret=interpret)
        table = table.at[i].set(s_new)
        return (xo, table, gbo), None

    (xp, table, gbarp), _ = jax.lax.scan(body, (xp, table, gbarp), idx)
    return xp[:d], table, gbarp[:d]


def svrg_steps(A, b, kind, xbar, sbar, gbar, idx, fp):
    """Fused SVRG inner loop from the snapshot ``xbar``: the arithmetic of
    ``baselines._svrg_scan`` / ``distributed._dsvrg_scan``'s local body.

    ``sbar`` holds the snapshot residuals for THIS shard (one matvec per
    round instead of per-step anchor gathers); ``gbar`` is the full
    REGULARIZED gradient at the snapshot — the kernel's decay term
    supplies ``2*lam*x``, so the anchor part ``2*lam*xbar`` is subtracted
    here once:  v = s*a - sbar*a + (gbar - 2*lam*xbar) + [decay] 2*lam*x,
    exactly the oracle's  (s - sbar)*a + gbar + 2*lam*(x - xbar).
    Returns the final iterate."""
    eta, lam, interpret, prox = fp
    n, d = A.shape
    P = padded_len(d)
    Ap = pad_cols(A, P)
    xbarp = pad_vec(xbar, P)
    gbarp = pad_vec(gbar, P) - 2.0 * lam * xbarp
    zp = jnp.zeros_like(xbarp)

    def body(xp, i):
        ap = Ap[i]
        s_new = _residual(ap[:d] @ xp[:d], b[i], kind)
        xo, _, _, _ = vr_kernel.vr_update_flat(
            xp, s_new * ap, sbar[i] * ap, gbarp, zp,
            eta=eta, m=n, saga=False, decay=2.0 * lam,
            prox=prox, interpret=interpret)
        return xo, None

    xp, _ = jax.lax.scan(body, xbarp, idx)
    return xp[:d]
