"""Elastic wave execution (DESIGN.md §Multi-host & elasticity).

The elastic engine runs CentralVR-Async under a membership plan whose
changes take effect only at round (wave) boundaries.  Its determinism
contract is pinned here in x64 (conftest):

  * constant membership is bit-identical to ``distributed.run_async``;
  * a post-dropout trajectory equals a checkpoint of the SAME run
    restored at the survivor count and continued with the segment key
    stream — the elastic run is exactly "save + reshard + resume";
  * repeated runs are bit-identical (no wall-clock in the math);
  * membership transitions emit ``worker_lost`` / ``worker_joined`` /
    ``repartition`` telemetry that validates against the pinned schema.
"""
import numpy as np
import pytest

import jax

from repro.checkpoint import elastic as ckpt
from repro.config import ConvexConfig
from repro.core import convex, distributed, elastic
from repro.obs import recorder, schema


@pytest.fixture(scope="module")
def prob4():
    cfg = ConvexConfig(problem="logistic", n=48, d=8, seed=0, workers=4)
    sp = distributed.make_distributed(jax.random.PRNGKey(0), cfg)
    return sp, convex.auto_eta(sp.merged())


SPEEDS = (1.0, 1.0, 2.0, 4.0)
ROUNDS = 6
KEY = jax.random.PRNGKey(0)


def test_constant_membership_matches_run_async(prob4):
    sp, eta = prob4
    _, rels_ref = distributed.run_async(sp, eta=eta, rounds=ROUNDS,
                                        key=KEY, speeds=SPEEDS)
    res = elastic.run_async_elastic(sp, eta=eta, rounds=ROUNDS, key=KEY,
                                    speeds=SPEEDS)
    np.testing.assert_array_equal(np.asarray(rels_ref), res.rels)
    assert res.transitions == []
    assert tuple(res.live) == tuple(range(4))


def test_chunked_checkpointing_matches_whole_run(prob4, tmp_path):
    sp, eta = prob4
    res_whole = elastic.run_async_elastic(sp, eta=eta, rounds=ROUNDS,
                                          key=KEY, speeds=SPEEDS)
    res_chunk = elastic.run_async_elastic(sp, eta=eta, rounds=ROUNDS,
                                          key=KEY, speeds=SPEEDS,
                                          checkpoint_dir=str(tmp_path),
                                          checkpoint_every=2)
    np.testing.assert_array_equal(res_whole.rels, res_chunk.rels)
    latest = ckpt.latest_elastic(str(tmp_path))
    assert latest is not None
    man = ckpt.load_manifest(latest)
    # boundaries are interior: with checkpoint_every=2 the last save
    # happens at round 4, not at the run's end
    assert man["p"] == 4 and man["round"] == 4


def test_dropout_prefix_and_determinism(prob4):
    sp, eta = prob4
    _, rels_ref = distributed.run_async(sp, eta=eta, rounds=ROUNDS,
                                        key=KEY, speeds=SPEEDS)
    plan = elastic.PlannedMembership(4, {3: (0, 2, 3)})
    res = elastic.run_async_elastic(sp, eta=eta, rounds=ROUNDS, key=KEY,
                                    speeds=SPEEDS, membership=plan)
    # before the drop the trajectory is the uninterrupted one, bit-exact
    np.testing.assert_array_equal(np.asarray(rels_ref)[:3], res.rels[:3])
    assert [t["round"] for t in res.transitions] == [3]
    assert res.transitions[0]["lost"] == [1]
    assert res.transitions[0]["live"] == [0, 2, 3]
    # deterministic across repeats
    res2 = elastic.run_async_elastic(sp, eta=eta, rounds=ROUNDS, key=KEY,
                                     speeds=SPEEDS, membership=plan)
    np.testing.assert_array_equal(res.rels, res2.rels)
    assert res.transitions == res2.transitions


@pytest.mark.parametrize("live", [(0, 2, 3), (0, 3)])
def test_ckpt_resume_at_new_shape_matches_elastic_run(prob4, tmp_path, live):
    """The acceptance pin: save p=4 at the boundary, restore at the
    survivor count, continue — must equal the elastic dropout run."""
    sp, eta = prob4
    g0 = convex.grad_norm0(sp.merged())
    k_run = jax.random.split(KEY)[1]
    p_new = len(live)

    plan = elastic.PlannedMembership(4, {3: live})
    res_drop = elastic.run_async_elastic(sp, eta=eta, rounds=ROUNDS,
                                         key=KEY, speeds=SPEEDS,
                                         membership=plan)

    elastic.run_async_elastic(sp, eta=eta, rounds=ROUNDS, key=KEY,
                              speeds=SPEEDS, checkpoint_dir=str(tmp_path),
                              checkpoint_every=3)
    path = str(tmp_path / "elastic_00003")
    st_new, man = ckpt.restore_elastic(path, p_new)
    assert man["p"] == 4
    assert st_new.tables.shape[0] == p_new
    _, rels_cont = elastic.continue_async(
        elastic.reshard_problem(sp, p_new), st_new, eta=eta, g0=g0,
        start_round=3, rounds=ROUNDS, k_run=k_run,
        speeds=elastic.survivor_speeds(SPEEDS, live))
    np.testing.assert_array_equal(np.asarray(rels_cont), res_drop.rels[3:])


def test_rejoin_plan_runs_and_reports_transitions(prob4):
    sp, eta = prob4
    plan = elastic.PlannedMembership(4, {2: (0, 1, 3), 4: (0, 1, 2, 3)})
    res = elastic.run_async_elastic(sp, eta=eta, rounds=ROUNDS, key=KEY,
                                    speeds=SPEEDS, membership=plan)
    assert [t["round"] for t in res.transitions] == [2, 4]
    assert res.transitions[0]["lost"] == [2]
    assert res.transitions[1]["joined"] == [2]
    assert np.isfinite(res.rels).all()
    assert res.final_rel < 1.0


def test_transitions_emit_schema_valid_events(prob4, tmp_path):
    sp, eta = prob4
    plan = elastic.PlannedMembership(4, {2: (0, 1, 3), 4: (0, 1, 2, 3)})
    path = str(tmp_path / "elastic.jsonl")
    recorder.enable(path, run_id="test-elastic")
    try:
        elastic.run_async_elastic(sp, eta=eta, rounds=ROUNDS, key=KEY,
                                  speeds=SPEEDS, membership=plan)
    finally:
        recorder.disable()
    rows = schema.load_rows(path)
    assert schema.validate_rows(rows) == len(rows)
    names = [r["name"] for r in rows if r["kind"] == "event"]
    assert names.count("worker_lost") == 1
    assert names.count("worker_joined") == 1
    assert names.count("repartition") == 2
    lost = next(r for r in rows if r["name"] == "worker_lost")
    assert lost["worker"] == 2 and lost["round"] == 2
    repart = [r for r in rows if r["name"] == "repartition"]
    assert [(r["p_old"], r["p_new"]) for r in repart] == [(4, 3), (3, 4)]
    assert repart[0]["survivors"] == [0, 1, 3]


def test_membership_and_reshard_validation(prob4):
    sp, eta = prob4
    with pytest.raises(ValueError, match="full fleet"):
        elastic.PlannedMembership(4, {0: (0, 1)})
    with pytest.raises(ValueError, match="no live workers"):
        elastic.PlannedMembership(4, {2: ()})
    with pytest.raises(ValueError, match="duplicate"):
        elastic.PlannedMembership(4, {2: (1, 1)})
    with pytest.raises(ValueError, match="out of"):
        elastic.PlannedMembership(4, {2: (0, 7)})
    # n=44 shards over 4 and 2 but not over 3 survivors: validated
    # up front, before any jax work
    cfg = ConvexConfig(problem="ridge", n=44, d=4, seed=1, workers=4)
    sp44 = distributed.make_distributed(jax.random.PRNGKey(1), cfg)
    with pytest.raises(ValueError, match="does not divide"):
        elastic.run_async_elastic(
            sp44, eta=eta, rounds=ROUNDS, key=KEY,
            membership=elastic.PlannedMembership(4, {2: (0, 1, 2)}))
    with pytest.raises(ValueError, match="plan is for"):
        elastic.run_async_elastic(
            sp, eta=eta, rounds=ROUNDS, key=KEY,
            membership=elastic.PlannedMembership(3))
    with pytest.raises(ValueError, match="do not divide"):
        elastic.reshard_problem(sp, 5)
    with pytest.raises(ValueError, match="do not divide"):
        elastic.resync_state(np.zeros(8), np.zeros(8), np.zeros(48), 5)
