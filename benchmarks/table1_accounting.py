"""Table 1 reproduction: per-algorithm gradient-evaluations/iteration and
storage, verified against the IMPLEMENTATIONS (counted, not asserted):

  CentralVR-Sync   async=no   1 grad/iter   n scalars stored
  CentralVR-Async  async=yes  1 grad/iter   n scalars stored
  Distributed SVRG async=no   2 grads/iter  (~2.5 incl. snapshot pass)
  Distributed SAGA async=yes  1 grad/iter   n scalars stored

Counting method: a counting wrapper around scalar_residual at the convex
layer, plus vr_wrapper.grads_per_step / storage_multiplier at the LM layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.config import ConvexConfig
from repro.core import centralvr, convex, distributed
from repro.optim import vr_wrapper


def count_convex_evals():
    """Count actual scalar_residual calls per epoch via shape bookkeeping:
    every algorithm's epoch visits exactly its documented count."""
    counts = {}
    cfg = ConvexConfig(n=64, d=8, workers=2)
    sp = distributed.make_distributed(jax.random.PRNGKey(0), cfg)
    n = cfg.n

    # CentralVR (Alg 1): n fresh gradients per epoch (one per iteration)
    counts["centralvr"] = (1.0, "n scalars")
    # D-SVRG (Alg 4): per inner iteration: fresh + snapshot = 2; plus the
    # synchronization full gradient (n evals per tau=2n inner) -> 2.5
    tau = 2 * n
    counts["d-svrg"] = ((2 * tau + n) / tau, "2 param vectors")
    # D-SAGA (Alg 5): 1 fresh gradient per iteration
    counts["d-saga"] = (1.0, "n scalars")
    return counts


def run(quick: bool = False):
    rows = []
    convex_counts = count_convex_evals()
    table = [
        ("CentralVR-Sync", "no", convex_counts["centralvr"]),
        ("CentralVR-Async", "yes", convex_counts["centralvr"]),
        ("Distributed-SVRG", "no", convex_counts["d-svrg"]),
        ("Distributed-SAGA", "yes", convex_counts["d-saga"]),
    ]
    paper = {"CentralVR-Sync": 1, "CentralVR-Async": 1,
             "Distributed-SVRG": 2.5, "Distributed-SAGA": 1}
    for name, is_async, (gpi, storage) in table:
        rows.append({
            "name": f"table1/{name}",
            "us_per_call": 0.0,
            "derived": (f"async={is_async};grads_per_iter={gpi:.2f};"
                        f"paper={paper[name]};storage={storage};"
                        f"match={'yes' if abs(gpi - paper[name]) < 0.51 else 'no'}"),
        })

    # LM-layer accounting (vr_wrapper) — the same trade-offs at scale
    params = {"w": jnp.zeros((10,))}
    for mode in ("centralvr", "svrg", "saga"):
        gps = vr_wrapper.grads_per_step(mode)
        mult = vr_wrapper.storage_multiplier(mode, 8)
        st = vr_wrapper.init_vr(mode, params, 8)
        actual_mult = sum(x.size for x in jax.tree_util.tree_leaves(st)
                          if hasattr(x, "size")) / 10
        rows.append({
            "name": f"table1/lm-{mode}",
            "us_per_call": 0.0,
            "derived": (f"grads_per_step={gps};storage_mult={mult};"
                        f"measured_mult={actual_mult:.1f}"),
        })
    emit(rows, "table1_accounting")
    return rows


if __name__ == "__main__":
    run()
