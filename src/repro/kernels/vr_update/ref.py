"""Pure-jnp oracle for the fused VR update."""
from __future__ import annotations

import jax.numpy as jnp


def vr_update_ref(x, g, g_old, gbar, gtilde, *, eta: float, m: int,
                  saga: bool = False, decay: float = 0.0):
    v = g - g_old + gbar
    xf = x.astype(jnp.promote_types(x.dtype, jnp.float32))
    if decay:
        xf = xf * (1.0 - eta * decay)
    x_new = (xf - eta * v).astype(x.dtype)
    table_new = g
    gtilde_new = gtilde + g / m
    gbar_new = gbar + (g - g_old) / m if saga else gbar
    return x_new, table_new, gtilde_new, gbar_new
