"""The paper's distributed experiment (§6.2), end to end: CentralVR-Sync /
-Async vs D-SVRG / D-SAGA / EASGD on weak-scaled toy data, with the
rounds-to-tolerance linear-scaling readout.

    python examples/convex_distributed.py [--workers 8]

Every row is one declarative ``repro.solve(RunSpec(...))`` call
(DESIGN.md §Solver API).  ``--backend spmd`` runs every driver with one
worker per simulated host device (DESIGN.md §2) — the async rows execute
their event schedule as concurrency waves (D-SAGA under the stale-fetch
discipline the waves require).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))
import repro_bootstrap  # noqa: F401,E402  (adds src/ if repro isn't installed)


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--n-per-worker", type=int, default=1000)
    ap.add_argument("--d", type=int, default=200)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--backend", choices=("vmap", "spmd"), default="vmap")
    return ap.parse_args()


def main():
    args = parse_args()
    if args.backend == "spmd":
        # must precede the first jax operation (shared helper, DESIGN §2);
        # the weak-scaling sweep below also runs p in (2, 4), so force at
        # least 4 devices regardless of --workers
        from repro.core import spmd
        spmd.force_host_devices(max(args.workers, 4))

    import jax
    import numpy as np

    from repro import RunSpec, solve
    from repro.config import ConvexConfig
    from repro.core import convex, distributed

    cfg = ConvexConfig(problem="logistic", n=args.n_per_worker, d=args.d,
                       workers=args.workers)
    sp = distributed.make_distributed(jax.random.PRNGKey(0), cfg)
    eta = convex.auto_eta(sp.merged(), 0.4)

    p, be, rounds = args.workers, args.backend, args.rounds
    print(f"p={p} workers, |Omega_s|={args.n_per_worker}, "
          f"d={args.d}, {rounds} communication rounds, "
          f"backend={be}\n")
    common = dict(p=p, eta=eta, rounds=rounds, backend=be, seed=1)
    specs = {
        "CentralVR-Sync": RunSpec(algo="centralvr_sync", **common),
        "CentralVR-Async": RunSpec(algo="centralvr_async", **common),
        "CentralVR-Async (4x speed spread)": RunSpec(
            algo="centralvr_async",
            speeds=tuple(1 + 3 * i / max(p - 1, 1) for i in range(p)),
            **common),
        "Distributed-SVRG": RunSpec(algo="dsvrg", **common),
        # spmd implies the stale-fetch discipline (DESIGN.md §2)
        "Distributed-SAGA": RunSpec(algo="dsaga",
                                    tau=args.n_per_worker // 2,
                                    **{**common, "eta": eta / 2}),
        "EASGD": RunSpec(algo="easgd", **common),
        "dist-SGD": RunSpec(algo="dist_sgd", **common),
    }
    for name, spec in specs.items():
        res = solve(spec, sp)
        print(f"{name:35s} final rel-grad-norm {res.final_rel:.2e} "
              f"[{res.wall_s:.2f}s]")

    # weak scaling: rounds to 1e-5 as p grows (the linear-scaling claim)
    print("\nweak scaling (rounds to rel-grad-norm < 1e-3):")
    for pw in (2, 4, p):
        cfg_p = ConvexConfig(problem="logistic", n=args.n_per_worker,
                             d=args.d, workers=pw)
        sp_p = distributed.make_distributed(jax.random.PRNGKey(0), cfg_p)
        res = solve(RunSpec(algo="centralvr_sync", p=pw,
                            eta=convex.auto_eta(sp_p.merged(), 0.4),
                            rounds=rounds, backend=be, seed=1), sp_p)
        hit = np.nonzero(res.rels < 1e-3)[0]
        r = int(hit[0]) + 1 if hit.size else f">{rounds}"
        print(f"  p={pw:3d} (total data {pw * args.n_per_worker}): "
              f"{r} rounds")


if __name__ == "__main__":
    main()
