"""Figure 3 reproduction: large-dataset distributed runs (SUSY-like /
MILLIONSONG-like shape-matched synthetics, scaled for the 1-core container;
see DESIGN.md §9) + worker-count sweep.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.config import ConvexConfig
from repro.core import convex, distributed


def run(quick: bool = False):
    rows = []
    cases = [
        ("susy-like", "logistic", 2000 if quick else 6250, 18),
        ("millionsong-like", "ridge", 2000 if quick else 5800, 90),
    ]
    rounds = 8 if quick else 12
    for name, problem, n_per, d in cases:
        for p in ((4,) if quick else (4, 16)):
            cfg = ConvexConfig(problem=problem, n=n_per, d=d, workers=p)
            sp = distributed.make_distributed(jax.random.PRNGKey(3), cfg)
            key = jax.random.PRNGKey(4)
            eta = convex.auto_eta(sp.merged(), 0.4)
            # warm compile, then time the steady-state scan (the driver
            # returns un-fetched device arrays, so block to include
            # execution in the measurement)
            jax.block_until_ready(distributed.run_sync(
                sp, eta=eta, rounds=rounds, key=key))
            t0 = time.perf_counter()
            _, r_sync = distributed.run_sync(sp, eta=eta, rounds=rounds,
                                             key=key)
            jax.block_until_ready(r_sync)
            wall = time.perf_counter() - t0
            _, r_async = distributed.run_async(sp, eta=eta, rounds=rounds,
                                               key=key)
            rows.append({
                "name": f"fig3/{name}-p{p}",
                "us_per_call": wall / rounds * 1e6,
                "derived": (f"n_total={p * n_per};"
                            f"sync_final={float(r_sync[-1]):.2e};"
                            f"async_final={float(r_async[-1]):.2e}"),
                "curves": {"sync": np.asarray(r_sync).tolist(),
                           "async": np.asarray(r_async).tolist()},
            })
    emit(rows, "fig3_large")
    return rows


if __name__ == "__main__":
    run()
