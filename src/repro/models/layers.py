"""Shared layer primitives: norms, MLPs, rotary embeddings, initializers.

Pure-functional style: params are plain pytrees (nested dicts of arrays);
``init_*`` builds them, ``apply_*`` consumes them. No framework dependency —
this keeps pjit/shard_map sharding rules a simple path-pattern match
(see repro/sharding/specs.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import kernel_ctx


def _dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / jnp.sqrt(in_axis_size)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dtype):
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def _rmsnorm_ref(x, scale, eps: float):
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rmsnorm_fused(x, scale, eps: float, interpret: bool):
    """Pallas RMSNorm with the pure-JAX backward: interpret-mode Pallas
    has no transpose rule, and the kernel zoo ships forward kernels only —
    the reference VJP recomputes from (x, scale), same math either way."""
    from repro.kernels.rmsnorm import ops as rms_ops
    return rms_ops.rmsnorm(x, scale, eps=eps, interpret=interpret)


def _rmsnorm_fused_fwd(x, scale, eps, interpret):
    return _rmsnorm_fused(x, scale, eps, interpret), (x, scale)


def _rmsnorm_fused_bwd(eps, interpret, res, ct):
    x, scale = res
    _, vjp = jax.vjp(lambda x, s: _rmsnorm_ref(x, s, eps), x, scale)
    return vjp(ct)


_rmsnorm_fused.defvjp(_rmsnorm_fused_fwd, _rmsnorm_fused_bwd)


def apply_norm(p, x, norm_type: str, eps: float = 1e-6):
    if norm_type == "layernorm":
        xf = x.astype(jnp.float32)
        mean = xf.mean(-1, keepdims=True)
        var = ((xf - mean) ** 2).mean(-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
        return y.astype(x.dtype)
    if kernel_ctx.active():
        return _rmsnorm_fused(x, p["scale"], eps, kernel_ctx.interpret())
    return _rmsnorm_ref(x, p["scale"], eps)


def rms_norm_1d(scale, x, eps: float = 1e-6):
    """RMSNorm over the last axis with a free-standing scale (qk_norm etc.)."""
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, dtype, d_ff: int = 0):
    d, ff = cfg.d_model, (d_ff or cfg.d_ff)
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "wg": _dense_init(ks[0], (d, ff), d, dtype),
            "wu": _dense_init(ks[1], (d, ff), d, dtype),
            "wd": _dense_init(ks[2], (ff, d), ff, dtype),
        }
    p = {
        "wi": _dense_init(ks[0], (d, ff), d, dtype),
        "wo": _dense_init(ks[1], (ff, d), ff, dtype),
    }
    if cfg.mlp_bias:
        p["bi"] = jnp.zeros((ff,), dtype)
        p["bo"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_mlp(p, x, mlp_type: str):
    if mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
        return h @ p["wd"]
    h = x @ p["wi"]
    if "bi" in p:
        h = h + p["bi"]
    h = jax.nn.gelu(h)
    h = h @ p["wo"]
    if "bo" in p:
        h = h + p["bo"]
    return h


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs        # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                              # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def init_embed(cfg: ModelConfig, key, dtype):
    p = {"tok": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model)) * 0.02
                 ).astype(dtype)}
    return p


def embed_tokens(p, tokens):
    return p["tok"][tokens]


def init_lm_head(cfg: ModelConfig, key, dtype):
    if cfg.tie_embeddings:
        return {}
    return {"w": _dense_init(key, (cfg.d_model, cfg.vocab_size), cfg.d_model,
                             dtype)}


def lm_logits(head_p, embed_p, x, tie: bool):
    if tie:
        return x @ embed_p["tok"].T.astype(x.dtype)
    return x @ head_p["w"]
