"""Proximal operators for composite objectives (DESIGN.md §Composite
objectives).

Each operator evaluates, in closed form,

    prox_{eta*g}(w) = argmin_z  0.5*||z - w||^2 + eta*g(z)

as a pure jittable map ``(w, eta) -> w``. A configured operator travels
as a :class:`ProxSpec` — a flat ``(name, params)`` tuple of hashables —
so it rides through ``jit(static_argnames=...)`` and the spmd runner
``lru_cache`` keys exactly like the fused-kernel parameter tuple.

Spec strings (``RunSpec.prox`` / ``--prox``) are ``name[:p1[:p2]]``:

    "l1:0.01"                g(w) = 0.01*||w||_1
    "elasticnet:0.01:0.001"  g(w) = 0.01*||w||_1 + 0.001*||w||_2^2
    "box:-1:1"               g = indicator of [-1, 1]^d
    "group_l2:0.01:4"        g(w) = 0.01 * sum_groups ||w_g||_2, |g| = 4

Omitted params take registry defaults. ``l1``/``elasticnet``/``box`` are
elementwise (fusable into the vr_update kernel epilogue); ``group_l2``
couples coordinates within each group and therefore refuses
``fused=True`` (RunSpec rejects the combination pre-JAX).

The closed forms are standard (Parikh & Boyd, *Proximal Algorithms*):
soft-threshold for L1, scaled soft-threshold for elastic net, clipping
for box indicators, block soft-threshold for group-L2. ``numeric_prox``
re-derives them by scipy-free golden-section search — the oracle the
property tests pin the closed forms against.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax.numpy as jnp


class ProxSpec(NamedTuple):
    """A parsed, hashable prox configuration (safe as a jit static arg)."""

    name: str              # registry key
    params: tuple          # floats (ints for group size), fully resolved


class _Op(NamedTuple):
    defaults: tuple                      # default params (also fixes arity)
    elementwise: bool                    # fusable into the kernel epilogue
    apply: Callable                      # (w, eta, params) -> w
    penalty: Callable                    # (w, params) -> g(w)
    signature: str                       # human spelling for error messages


def _soft(w, t):
    """Soft-threshold S_t(w) = sign(w) * max(|w| - t, 0)."""
    return jnp.sign(w) * jnp.maximum(jnp.abs(w) - t, 0.0)


# -- l1: g(w) = lam1 * ||w||_1 ----------------------------------------------

def _l1_apply(w, eta, params):
    (lam1,) = params
    return _soft(w, eta * lam1)


def _l1_penalty(w, params):
    (lam1,) = params
    return lam1 * jnp.sum(jnp.abs(w))


# -- elasticnet: g(w) = lam1 * ||w||_1 + lam2 * ||w||_2^2 -------------------
# prox = S_{eta*lam1}(w) / (1 + 2*eta*lam2): the quadratic term rescales
# after thresholding (complete the square in the scalar subproblem).

def _en_apply(w, eta, params):
    lam1, lam2 = params
    return _soft(w, eta * lam1) / (1.0 + 2.0 * eta * lam2)


def _en_penalty(w, params):
    lam1, lam2 = params
    return lam1 * jnp.sum(jnp.abs(w)) + lam2 * jnp.sum(w * w)


# -- box: g = indicator of [lo, hi]^d ---------------------------------------

def _box_apply(w, eta, params):
    lo, hi = params
    del eta  # projection: prox of an indicator ignores the step size
    return jnp.clip(w, lo, hi)


def _box_penalty(w, params):
    lo, hi = params
    feasible = jnp.all((w >= lo) & (w <= hi))
    return jnp.where(feasible, 0.0, jnp.inf)


# -- group_l2: g(w) = lam1 * sum_g ||w_g||_2, contiguous groups of `size` --
# Block soft-threshold: w_g * max(1 - eta*lam1/||w_g||, 0). NOT
# elementwise — coordinates inside a group couple through ||w_g||.

def _gl2_apply(w, eta, params):
    lam1, size = params
    size = int(size)
    if w.shape[-1] % size:
        raise ValueError(
            f"prox 'group_l2': d={w.shape[-1]} is not divisible by the "
            f"group size {size}")
    groups = w.reshape(w.shape[:-1] + (-1, size))
    norms = jnp.linalg.norm(groups, axis=-1, keepdims=True)
    scale = jnp.maximum(1.0 - eta * lam1 / jnp.maximum(norms, 1e-300), 0.0)
    return (groups * scale).reshape(w.shape)


def _gl2_penalty(w, params):
    lam1, size = params
    groups = w.reshape(w.shape[:-1] + (-1, int(size)))
    return lam1 * jnp.sum(jnp.linalg.norm(groups, axis=-1))


_REGISTRY = {
    "l1": _Op((1e-3,), True, _l1_apply, _l1_penalty, "l1:lam1"),
    "elasticnet": _Op((1e-3, 1e-4), True, _en_apply, _en_penalty,
                      "elasticnet:lam1:lam2"),
    "box": _Op((-1.0, 1.0), True, _box_apply, _box_penalty, "box:lo:hi"),
    "group_l2": _Op((1e-3, 4.0), False, _gl2_apply, _gl2_penalty,
                    "group_l2:lam1:group_size"),
}


def names() -> tuple:
    """Registered operator names (for --list / error messages)."""
    return tuple(sorted(_REGISTRY))


def _signatures() -> str:
    return ", ".join(_REGISTRY[k].signature for k in sorted(_REGISTRY))


def parse(spec: str | ProxSpec) -> ProxSpec:
    """``"name[:p1[:p2]]"`` -> :class:`ProxSpec` (idempotent on ProxSpec).

    Raises ``ValueError`` naming the unknown operator or malformed param,
    so RunSpec validation surfaces the problem before any JAX tracing.
    """
    if isinstance(spec, ProxSpec):
        return spec
    parts = str(spec).split(":")
    name, raw = parts[0], parts[1:]
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown prox operator {name!r}; registered: {_signatures()}")
    op = _REGISTRY[name]
    if len(raw) > len(op.defaults):
        raise ValueError(
            f"prox {name!r} takes at most {len(op.defaults)} params "
            f"({op.signature}); got {spec!r}")
    params = []
    for i, dflt in enumerate(op.defaults):
        if i < len(raw):
            try:
                params.append(float(raw[i]))
            except ValueError:
                raise ValueError(
                    f"prox {name!r}: param {i + 1} must be a number "
                    f"({op.signature}); got {raw[i]!r}") from None
        else:
            params.append(float(dflt))
    if name == "box" and params[0] > params[1]:
        raise ValueError(
            f"prox 'box': lo={params[0]} > hi={params[1]} is an empty box")
    if name == "group_l2":
        if params[1] < 1 or params[1] != int(params[1]):
            raise ValueError(
                f"prox 'group_l2': group size must be a positive integer; "
                f"got {params[1]}")
    if name in ("l1", "elasticnet", "group_l2") and params[0] < 0:
        raise ValueError(
            f"prox {name!r}: lam1 must be >= 0; got {params[0]}")
    if name == "elasticnet" and params[1] < 0:
        raise ValueError(
            f"prox 'elasticnet': lam2 must be >= 0; got {params[1]}")
    return ProxSpec(name, tuple(params))


def canonical(spec: str | ProxSpec | None) -> str | None:
    """The normalized string spelling of a spec — what RunSpec stores so
    ``dataclasses.asdict`` round-trips exactly (params fully resolved)."""
    if spec is None:
        return None
    ps = parse(spec)
    return ":".join([ps.name] + [f"{p:g}" for p in ps.params])


def is_elementwise(spec: str | ProxSpec | None) -> bool:
    """True when the operator decouples across coordinates (kernel-fusable)."""
    if spec is None:
        return True
    return _REGISTRY[parse(spec).name].elementwise


def apply(spec: str | ProxSpec, w, eta):
    """prox_{eta*g}(w) for the configured g. Pure, jittable; ``spec`` must
    be static (it selects the traced branch)."""
    ps = parse(spec)
    return _REGISTRY[ps.name].apply(w, eta, ps.params)


def apply_prox(spec: str | ProxSpec | None, w, eta):
    """None-safe :func:`apply` — identity when no prox is configured.

    The single spelling every scan body uses, so "no prox" compiles to
    exactly the pre-prox program.
    """
    if spec is None:
        return w
    return apply(spec, w, eta)


def penalty(spec: str | ProxSpec | None, w):
    """g(w) — the nonsmooth term's value (0 when no prox is configured)."""
    if spec is None:
        return jnp.zeros(())
    ps = parse(spec)
    return _REGISTRY[ps.name].penalty(w, ps.params)


def grad_map(spec: str | ProxSpec | None, x, grad, eta):
    """Composite gradient-mapping residual  x - prox_{eta*g}(x - eta*grad).

    Vanishes exactly at minimizers of f + g; reduces to ``eta*grad`` when
    ``spec`` is None. Drivers report ``||grad_map||/||grad_map(x0)||`` —
    the 1/eta scale cancels in the ratio, so the smooth case reproduces
    the paper's ``||grad f(x)||/||grad f(x0)||`` y-axis bit-for-bit.
    """
    if spec is None:
        return eta * grad
    return x - apply(spec, x - eta * grad, eta)


# ---------------------------------------------------------------------------
# Numeric oracle (tests only): scipy-free golden-section search
# ---------------------------------------------------------------------------

_GOLD = 0.6180339887498949  # 1/phi


def _golden_min(f, lo, hi, iters: int):
    """Vectorized golden-section minimization of a per-coordinate convex f
    over the bracket [lo, hi]; interval shrinks by phi^-1 per iteration."""
    a, b = lo, hi
    for _ in range(iters):
        span = b - a
        x1 = b - _GOLD * span
        x2 = a + _GOLD * span
        take_left = f(x1) <= f(x2)
        a = jnp.where(take_left, a, x1)
        b = jnp.where(take_left, x2, b)
    return 0.5 * (a + b)


def numeric_prox(spec: str | ProxSpec, w, eta, iters: int = 120):
    """Solve the prox subproblem numerically, without the closed form.

    Elementwise operators reduce to independent scalar problems
    ``min_z 0.5*(z - w_i)^2 + eta*g_i(z)`` (golden-section over a bracket
    that provably contains the minimizer, since these proxes shrink
    toward the feasible set); ``group_l2`` reduces to a 1-D search over
    each group's radius. 120 golden iterations shrink the bracket by
    ~1e-25x, but comparisons go flat once (z - z*)^2 underflows against
    f(z*), so the achievable accuracy is ~sqrt(eps)*scale ≈ 1e-8 — the
    property tests pin the closed forms at 1e-6.
    """
    ps = parse(spec)
    w = jnp.asarray(w)
    if ps.name == "box":
        lo, hi = ps.params
        a = jnp.clip(jnp.minimum(w, lo), lo, hi) * jnp.ones_like(w)
        b = jnp.clip(jnp.maximum(w, hi), lo, hi) * jnp.ones_like(w)
        return _golden_min(lambda z: 0.5 * (z - w) ** 2, a, b, iters)
    if ps.name in ("l1", "elasticnet"):
        if ps.name == "l1":
            lam1, lam2 = ps.params[0], 0.0
        else:
            lam1, lam2 = ps.params

        def f(z):
            return (0.5 * (z - w) ** 2 + eta * lam1 * jnp.abs(z)
                    + eta * lam2 * z * z)

        bound = jnp.abs(w) + 1.0      # |prox| <= |w| for these operators
        return _golden_min(f, -bound, bound, iters)
    # group_l2: optimal point lies on the ray through w_g; search radius
    lam1, size = ps.params
    groups = w.reshape(w.shape[:-1] + (-1, int(size)))
    norms = jnp.linalg.norm(groups, axis=-1)

    def f(t):
        return 0.5 * (t - norms) ** 2 + eta * lam1 * t

    t_star = _golden_min(f, jnp.zeros_like(norms), norms + 1.0, iters)
    unit = groups / jnp.maximum(norms, 1e-300)[..., None]
    return (unit * t_star[..., None]).reshape(w.shape)
