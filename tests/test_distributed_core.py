"""Distributed-algorithm semantics (Algorithms 2-5) on simulated workers.

Key exact invariant: the async delta algebra keeps the central iterate
equal to the mean of the workers' latest contributions at every event —
the paper's "replace the previous contribution" property.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ConvexConfig
from repro.core import baselines, convex, distributed


def _sharded(seed=0, p=4, n=120, d=12, kind="logistic"):
    cfg = ConvexConfig(problem=kind, n=n, d=d, workers=p)
    return distributed.make_distributed(jax.random.PRNGKey(seed), cfg)


@pytest.mark.slow
def test_sync_converges_to_global_optimum():
    sp = _sharded(p=4)
    merged = sp.merged()
    xstar = convex.solve_exact(merged)
    st, rels = distributed.run_sync(sp, eta=0.05, rounds=40,
                                    key=jax.random.PRNGKey(1))
    assert rels[-1] < 1e-8, rels[-5:]
    np.testing.assert_allclose(np.asarray(st.x), np.asarray(xstar),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.slow
def test_async_delta_replacement_invariant():
    """x_c == mean_s(x_old_s) after every event (exact algebra)."""
    sp = _sharded(seed=2, p=3, n=60, d=6)
    st = distributed.async_init(sp, 0.05, jax.random.PRNGKey(0))
    keys = jax.random.split(jax.random.PRNGKey(1), 9)
    for t in range(9):
        st = distributed.async_event(sp, st, t % sp.p, 0.05, keys[t])
        np.testing.assert_allclose(np.asarray(st.x_c),
                                   np.asarray(st.x_old.mean(0)),
                                   rtol=1e-10, atol=1e-12)
        np.testing.assert_allclose(np.asarray(st.gbar_c),
                                   np.asarray(st.gbar_old.mean(0)),
                                   rtol=1e-10, atol=1e-12)


@pytest.mark.slow
def test_async_converges_round_robin_and_heterogeneous():
    sp = _sharded(seed=3, p=4)
    _, rels = distributed.run_async(sp, eta=0.05, rounds=40,
                                    key=jax.random.PRNGKey(2))
    assert rels[-1] < 1e-7, rels[-5:]
    # heterogeneous speeds: 4x spread — the delta form keeps it stable
    _, rels_h = distributed.run_async(sp, eta=0.05, rounds=40,
                                      key=jax.random.PRNGKey(2),
                                      speeds=[1.0, 1.0, 2.0, 4.0])
    assert rels_h[-1] < 1e-5, rels_h[-5:]


def test_dsvrg_converges():
    sp = _sharded(seed=4, p=4)
    _, rels = distributed.run_dsvrg(sp, eta=0.05, rounds=25,
                                    key=jax.random.PRNGKey(3))
    assert rels[-1] < 1e-8, rels[-5:]


@pytest.mark.parametrize("tau", [25, 120])
def test_dsaga_stable_across_tau(tau):
    """§5.2: stable for a range of communication periods."""
    sp = _sharded(seed=5, p=4)
    _, rels = distributed.run_dsaga(sp, eta=0.03, rounds=30,
                                    key=jax.random.PRNGKey(4), tau=tau)
    assert rels[-1] < 1e-2, rels[-5:]
    assert np.isfinite(np.asarray(rels)).all()


def test_dsaga_literal_scaling_is_worse():
    """The printed alpha-on-gbar line lags the table mean; our consistent
    default must converge at least as fast (documents the deviation)."""
    sp = _sharded(seed=6, p=4)
    _, r_default = distributed.run_dsaga(sp, eta=0.03, rounds=25,
                                         key=jax.random.PRNGKey(5), tau=60)
    _, r_literal = distributed.run_dsaga(sp, eta=0.03, rounds=25,
                                         key=jax.random.PRNGKey(5), tau=60,
                                         literal_scaling=True)
    assert r_default[-1] <= r_literal[-1] * 1.5


@pytest.mark.slow
def test_vr_methods_beat_sgd_baselines_distributed():
    """Fig. 2 qualitative claim: at equal local-gradient budget the VR
    methods reach much lower gradient norm than dist-SGD/EASGD."""
    sp = _sharded(seed=7, p=4)
    rounds = 20
    _, r_cvr = distributed.run_sync(sp, eta=0.05, rounds=rounds,
                                    key=jax.random.PRNGKey(6))
    best_base = np.inf
    for eta in (0.1, 0.05):
        _, r_sgd = baselines.run_dist_sgd(sp, eta=eta, rounds=rounds,
                                          key=jax.random.PRNGKey(6))
        _, r_ea = baselines.run_easgd(sp, eta=eta, rounds=rounds,
                                      key=jax.random.PRNGKey(6))
        best_base = min(best_base, float(r_sgd[-1]), float(r_ea[-1]))
    assert float(r_cvr[-1]) < best_base * 1e-2


@pytest.mark.slow
def test_weak_scaling_epochs_to_tolerance():
    """The linear-scaling claim, in its hardware-independent form: with
    per-worker data fixed, the number of communication rounds to reach a
    fixed tolerance does not grow with p (here: p=2 vs p=8)."""
    def rounds_to(sp, eps, key):
        _, rels = distributed.run_sync(sp, eta=0.05, rounds=30, key=key)
        hit = np.nonzero(np.asarray(rels) < eps)[0]
        return int(hit[0]) + 1 if hit.size else 10_000

    eps = 1e-6
    r2 = rounds_to(_sharded(seed=8, p=2, n=100, d=10), eps, jax.random.PRNGKey(7))
    r8 = rounds_to(_sharded(seed=8, p=8, n=100, d=10), eps, jax.random.PRNGKey(7))
    assert r8 <= r2 * 2, (r2, r8)
