"""Pallas kernel validation (interpret=True executes kernel bodies on CPU):
shape/dtype sweeps + hypothesis, assert_allclose against the ref.py
pure-jnp oracles.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# property tests skip (per-test) without the hypothesis dev extra;
# plain tests in this module always run
from hypothesis_compat import given, settings, st  # noqa: E402

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.rmsnorm import ops as rn_ops
from repro.kernels.rmsnorm import ref as rn_ref
from repro.kernels.vr_update import kernel as vr_kernel
from repro.kernels.vr_update import ops as vr_ops
from repro.kernels.vr_update import ref as vr_ref

jtu = jax.tree_util


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 64, 2, 2, 16),     # MHA
    (2, 64, 4, 2, 32),     # GQA group 2
    (1, 128, 8, 1, 16),    # MQA
    (1, 40, 4, 4, 16),     # ragged S (padding path)
])
@pytest.mark.slow
def test_flash_attention_sweep(B, S, H, KV, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32).astype(dtype)
    out = fa_ops.flash_attention(q, k, v, q_blk=32, kv_blk=32,
                                 interpret=True)
    ref = fa_ref.flash_attention_naive(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [8, 32])
def test_flash_attention_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 96, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 96, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 96, 2, 16), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, window=window, q_blk=32,
                                 kv_blk=32, interpret=True)
    ref = fa_ref.flash_attention_naive(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       qb=st.sampled_from([16, 32, 64]), kb=st.sampled_from([16, 32]))
def test_flash_attention_block_invariance(seed, qb, kb):
    """Property: output independent of block decomposition."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, q_blk=qb, kv_blk=kb,
                                 interpret=True)
    ref = fa_ref.flash_attention_naive(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 8, 64), (3, 128), (1, 1, 256),
                                   (7, 33)])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape,
                          jnp.float32).astype(dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],), jnp.float32)
    y = rn_ops.rmsnorm(x, s, interpret=True)
    ref = rn_ref.rmsnorm_ref(x.reshape(-1, shape[-1]), s).reshape(shape)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(1, 40), d=st.sampled_from([32, 64, 128]),
       seed=st.integers(0, 100))
def test_rmsnorm_property(rows, d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, d), jnp.float32)
    s = jnp.ones((d,))
    y = rn_ops.rmsnorm(x, s, interpret=True)
    # unit-RMS property
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


# ---------------------------------------------------------------------------
# vr_update
# ---------------------------------------------------------------------------

def _trees(seed, sizes=((100,), (7, 13), (3, 4, 5))):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    mk = lambda k: {"leaves": [jax.random.normal(jax.random.fold_in(k, i),
                                                 s, jnp.float32)
                               for i, s in enumerate(sizes)]}
    return [mk(k) for k in ks]


@pytest.mark.parametrize("saga", [False, True])
@pytest.mark.parametrize("m", [1, 4, 16])
def test_vr_update_matches_ref(saga, m):
    x, g, gold, gbar, gtilde = _trees(0)
    # references FIRST, materialized to numpy: vr_update donates its
    # inputs, and some reference outputs are pass-throughs of them
    refs = [tuple(np.asarray(o) for o in
                  vr_ref.vr_update_ref(*leaves, eta=0.05, m=m, saga=saga))
            for leaves in zip(*(jtu.tree_leaves(t)
                                for t in (x, g, gold, gbar, gtilde)))]
    out = vr_ops.vr_update(x, g, gold, gbar, gtilde, eta=0.05, m=m,
                           saga=saga, interpret=True)
    for i in range(4):
        got = jtu.tree_leaves(out[i])
        exp = [r[i] for r in refs]
        for a, b in zip(got, exp):
            np.testing.assert_allclose(np.asarray(a), b,
                                       rtol=1e-6, atol=1e-7)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 3 * vr_kernel.TILE))
def test_vr_update_any_length(seed, n):
    """Property: padding path correct for arbitrary flat lengths."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x, g, gold, gbar, gtilde = (jax.random.normal(k, (n,), jnp.float32)
                                for k in ks)
    # reference first — vr_update donates its inputs
    ex, etbl, egto, egbo = vr_ref.vr_update_ref(x, g, gold, gbar, gtilde,
                                                eta=0.1, m=4)
    ex, etbl, egto, egbo = map(np.asarray, (ex, etbl, egto, egbo))
    xo, tbl, gto, gbo = vr_ops.vr_update(
        x, g, gold, gbar, gtilde, eta=0.1, m=4, interpret=True)
    kw = dict(rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(xo), np.asarray(ex), **kw)
    np.testing.assert_allclose(np.asarray(tbl), np.asarray(etbl), **kw)
    np.testing.assert_allclose(np.asarray(gto), np.asarray(egto), **kw)
    np.testing.assert_allclose(np.asarray(gbo), np.asarray(egbo), **kw)


def test_vr_update_semantics_vs_wrapper():
    """The fused kernel implements exactly one vr_wrapper CentralVR step
    (mid-epoch; the epoch-boundary anchor swap happens outside)."""
    from repro.optim import vr_wrapper
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (50,),
                                     jnp.float32)}
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (50,), jnp.float32)}
    M = 4
    st_ = vr_wrapper.init_vr("centralvr", params, M)
    # put something in table slot 0 and the anchor
    table0 = {"w": jax.random.normal(jax.random.PRNGKey(2), (50,),
                                     jnp.float32)}
    st_ = st_._replace(
        table={"w": st_.table["w"].at[0].set(table0["w"])},
        gbar={"w": jax.random.normal(jax.random.PRNGKey(3), (50,),
                                     jnp.float32)})
    v, st2 = vr_wrapper.correct("centralvr", st_, g, M)
    # expected iterate BEFORE the kernel call: vr_update donates params
    expected_x = np.asarray(params["w"] - 0.05 * v["w"])
    xo, tbl, gto, _ = vr_ops.vr_update(
        params, g, table0, st_.gbar, st_.gtilde, eta=0.05, m=M,
        interpret=True)
    np.testing.assert_allclose(np.asarray(xo["w"]), expected_x, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(tbl["w"]),
                               np.asarray(st2.table["w"][0]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gto["w"]),
                               np.asarray(st2.gtilde["w"]), rtol=1e-6)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

from repro.kernels.ssd_scan import ops as ssd_ops  # noqa: E402
from repro.models import ssm as ssm_mod  # noqa: E402


@pytest.mark.slow
@pytest.mark.parametrize("chunk", [4, 8, 16])
@pytest.mark.parametrize("B,S,H,P,N", [(2, 32, 3, 8, 16), (1, 24, 2, 4, 8)])
def test_ssd_scan_kernel_matches_naive(chunk, B, S, H, P, N):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A_log = jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32))
    Bc = jax.random.normal(ks[2], (B, S, N), jnp.float32)
    Cc = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    y = ssd_ops.ssd_scan(x, dt, A_log, Bc, Cc, chunk=chunk, interpret=True)
    y_ref, _ = ssm_mod.ssd_naive(x, dt, A_log, Bc, Cc,
                                 jnp.zeros((B, H, P, N)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000), s_len=st.integers(9, 40))
def test_ssd_scan_kernel_ragged_lengths(seed, s_len):
    """Property: padding path exact for arbitrary sequence lengths."""
    B, H, P, N = 1, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (B, s_len, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s_len, H)))
    A_log = jnp.zeros((H,))
    Bc = jax.random.normal(ks[2], (B, s_len, N), jnp.float32)
    Cc = jax.random.normal(ks[3], (B, s_len, N), jnp.float32)
    y = ssd_ops.ssd_scan(x, dt, A_log, Bc, Cc, chunk=8, interpret=True)
    y_ref, _ = ssm_mod.ssd_naive(x, dt, A_log, Bc, Cc,
                                 jnp.zeros((B, H, P, N)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
