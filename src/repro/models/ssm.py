"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD algorithm: the sequence is split into chunks of length Q;
within a chunk the recurrence is computed in its "dual" quadratic
attention-like form (MXU-friendly), and a lax.scan over chunks carries the
(B, H, P, N) recurrent state between chunks — O(S·Q) work, O(S/Q) scan
steps, exactly the blocked structure the paper uses on GPUs, re-tiled here
for TPU (chunk dim sized for the MXU, state carried in registers/VMEM).

Decode is the O(1) recurrence h <- a h + dt B x, y = C h + D x.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers

CONV_K = 4  # depthwise conv kernel size (Mamba default)


def dims(cfg: ModelConfig):
    di = cfg.ssm_expand * cfg.d_model
    H = di // cfg.ssm_head_dim
    return di, H, cfg.ssm_head_dim, cfg.ssm_state


def init_ssm(cfg: ModelConfig, key, dtype):
    d = cfg.d_model
    di, H, P, N = dims(cfg)
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * N + H           # z, x, B, C, dt
    conv_dim = di + 2 * N                     # conv over (x, B, C)
    return {
        "in_proj": layers._dense_init(ks[0], (d, d_in_proj), d, dtype),
        "conv_w": (jax.random.normal(ks[1], (CONV_K, conv_dim)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": layers._dense_init(ks[2], (di, d), di, dtype),
    }


def _split_proj(cfg, proj):
    di, H, P, N = dims(cfg)
    z, xs, Bc, Cc, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    return z, xs, Bc, Cc, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv, kernel CONV_K. xBC: (B, S, C)."""
    pad = jnp.pad(xBC, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i] for i in range(CONV_K))
    return jax.nn.silu(out + b)


def _ssd_chunked(x, dt, A_log, Bc, Cc, h0, chunk: int):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H); Bc, Cc: (B, S, N); h0: (B, H, P, N).
    Returns (y: (B, S, H, P), h_final).
    """
    B_, S, H, P = x.shape
    N = Bc.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:                      # pad to a chunk multiple (zero input,
        pad = Q - S % Q            # zero log-decay: padding is inert)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    dt = dt.astype(jnp.float32)
    h0 = h0.astype(jnp.float32)
    a = -jnp.exp(A_log.astype(jnp.float32))               # (H,) negative
    la = a[None, None, :] * dt                            # (B, S, H) log-decay
    xdt = (x.astype(jnp.float32) * dt[..., None])         # discretized input

    def re(t, shape):
        return t.reshape(shape)

    la_c = re(la, (B_, nc, Q, H))
    x_c = re(xdt, (B_, nc, Q, H, P))
    B_c = re(Bc, (B_, nc, Q, N)).astype(jnp.float32)
    C_c = re(Cc, (B_, nc, Q, N)).astype(jnp.float32)
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    # Everything per-chunk INSIDE the scan: the (Q, Q, H) decay tensors
    # exist for one chunk at a time (peak O(B·Q²·H) instead of
    # O(B·S·Q·H) — materializing all chunks at once cost 16 GiB/layer at
    # S=32k and 392 GiB peak for mamba2 prefill; EXPERIMENTS.md §Perf It.9)
    def step(h, inp):
        la_i, x_i, B_i, C_i = inp       # (B,Q,H), (B,Q,H,P), (B,Q,N) x2
        L = jnp.cumsum(la_i, axis=1)                      # (B, Q, H)
        # intra-chunk dual quadratic form
        scores = jnp.einsum("bqn,bkn->bqk", C_i, B_i)
        decay = jnp.exp(jnp.minimum(L[:, :, None, :] - L[:, None, :, :],
                                    0.0))                 # (B,Q,Q,H)
        w = scores[..., None] * decay * causal[None, :, :, None]
        y = jnp.einsum("bqkh,bkhp->bqhp", w, x_i)
        # inter-chunk: contribution of the carried state
        y = y + jnp.einsum("bqn,bhpn,bqh->bqhp", C_i, h, jnp.exp(L))
        # state update
        tot = L[:, -1, :]                                 # (B, H)
        decay_to_end = jnp.exp(tot[:, None, :] - L)       # (B, Q, H)
        cs = jnp.einsum("bqn,bqhp,bqh->bhpn", B_i, x_i, decay_to_end)
        h = h * jnp.exp(tot)[:, :, None, None] + cs
        return h, y

    h_final, ys = jax.lax.scan(
        step, h0, (la_c.swapaxes(0, 1), x_c.swapaxes(0, 1),
                   B_c.swapaxes(0, 1), C_c.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(B_, S, H, P)
    return y[:, :S_orig], h_final


def ssd_naive(x, dt, A_log, Bc, Cc, h0):
    """Sequential reference recurrence (tests compare against this)."""
    dt = dt.astype(jnp.float32)
    h0 = h0.astype(jnp.float32)
    a = -jnp.exp(A_log.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(a * dtt)                          # (B, H)
        upd = jnp.einsum("bhp,bn->bhpn", (xt * dtt[..., None]), bt)
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs = (x.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1), Bc.swapaxes(0, 1).astype(jnp.float32),
          Cc.swapaxes(0, 1).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), h


class SSMCache(NamedTuple):
    conv: jax.Array   # (B, CONV_K-1, di + 2N) last conv inputs
    h: jax.Array      # (B, H, P, N) recurrent state


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    di, H, P, N = dims(cfg)
    return SSMCache(conv=jnp.zeros((batch, CONV_K - 1, di + 2 * N), dtype),
                    h=jnp.zeros((batch, H, P, N), jnp.float32))


def apply_ssm_train(p, cfg: ModelConfig, u):
    """u: (B, S, d) -> (B, S, d). Full block: proj, conv, SSD, gate, norm."""
    di, H, P, N = dims(cfg)
    proj = u @ p["in_proj"]
    z, xs, Bc, Cc, dt = _split_proj(cfg, proj)
    xBC = _causal_conv(jnp.concatenate([xs, Bc, Cc], -1),
                       p["conv_w"], p["conv_b"])
    xs, Bc, Cc = jnp.split(xBC, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    x_h = xs.reshape(*xs.shape[:2], H, P)
    h0 = jnp.zeros((u.shape[0], H, P, N), jnp.float32)
    y, _ = _ssd_chunked(x_h, dt, p["A_log"], Bc, Cc, h0, cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None] * x_h.astype(jnp.float32)
    y = y.reshape(*xs.shape[:2], di).astype(u.dtype)
    y = layers.rms_norm_1d(p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"]


def apply_ssm_decode(p, cfg: ModelConfig, u, cache: SSMCache):
    """u: (B, 1, d); O(1) per token."""
    di, H, P, N = dims(cfg)
    proj = u @ p["in_proj"]
    z, xs, Bc, Cc, dt = _split_proj(cfg, proj)
    xBC_new = jnp.concatenate([xs, Bc, Cc], -1)            # (B, 1, C)
    conv_in = jnp.concatenate([cache.conv, xBC_new], axis=1)
    out = sum(conv_in[:, i, :] * p["conv_w"][i] for i in range(CONV_K))
    xBC = jax.nn.silu(out + p["conv_b"])[:, None, :]
    xs, Bc, Cc = jnp.split(xBC, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,1,H)

    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(a * dt[:, 0])                          # (B, H)
    x_h = xs[:, 0].reshape(-1, H, P).astype(jnp.float32)
    upd = jnp.einsum("bhp,bn->bhpn", x_h * dt[:, 0, :, None],
                     Bc[:, 0].astype(jnp.float32))
    h = cache.h * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h, Cc[:, 0].astype(jnp.float32))
    y = y + p["D"][None, :, None] * x_h
    y = y.reshape(-1, 1, di).astype(u.dtype)
    y = layers.rms_norm_1d(p["norm"], y * jax.nn.silu(z))
    out = y @ p["out_proj"]
    return out, SSMCache(conv=conv_in[:, 1:], h=h)
