"""Mesh construction. IMPORTANT: functions, never module-level constants —
importing this module must not touch jax device state (the dry-run forces a
512-device host platform and must do so before any jax initialization).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.config import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment: one v5e pod = (data=16, model=16) = 256 chips;
    two pods add a leading 'pod' axis = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axis_names)


def make_worker_mesh(p: int, *, simulate_host_devices: bool = False):
    """One CentralVR worker per device, for the convex spmd backend
    (``core/spmd.py``, DESIGN.md §2).  ``simulate_host_devices=True``
    forces the CPU host platform to present p devices through the shared
    ``spmd.force_host_devices`` helper — call it before the first jax
    operation (the helper errors once the backend is initialized)."""
    from repro.core import spmd

    if simulate_host_devices:
        spmd.force_host_devices(p)
    return spmd.worker_mesh(p)


def make_test_mesh(devices: Optional[int] = None,
                   model_axis: int = 2):
    """Small mesh over whatever devices exist (tests force 8 host devices
    via a subprocess; plain test runs see (1, 1))."""
    n = devices or len(jax.devices())
    model = model_axis if n % model_axis == 0 and n > 1 else 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def worker_axes(mesh, vr_workers: str) -> Tuple[str, ...]:
    """Which mesh axes carry CentralVR worker copies.

    'data' — paper-faithful: one worker per data-axis group (params
             replicated along these axes), includes 'pod' when present.
    'pod'  — hierarchical (optimized): workers across pods, FSDP inside.
    'none' — plain data-parallel (no VR worker copies).
    """
    names = mesh.axis_names
    if vr_workers == "none":
        return ()
    if vr_workers == "pod":
        return ("pod",) if "pod" in names else ()
    if vr_workers == "data":
        return tuple(a for a in ("pod", "data") if a in names)
    raise ValueError(vr_workers)


def worker_count(mesh, vr_workers: str) -> int:
    sizes = mesh_axis_sizes(mesh)
    n = 1
    for a in worker_axes(mesh, vr_workers):
        n *= sizes[a]
    return max(n, 1)
