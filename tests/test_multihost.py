"""Multi-process execution: KV data plane, process engines, launcher lanes
(DESIGN.md §Multi-host & elasticity).

Fast tests exercise the KV codec/semantics, the worker-block split, the
nprocs=1 degenerate process engines (which must be bit-identical to the
event-serial references in x64 — the engines are the same algebra re-run
over a KV exchange), and the ``topology="process"`` solver surface.

Slow tests launch the real two-local-process ``jax.distributed`` fleet
through ``python -m repro.launch.distributed --verify`` — the same lanes
the multihost-smoke CI job runs — and assert the in-process verdict
(worker trajectories vs the single-process reference) via the exit code.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.config import ConvexConfig
from repro.core import convex, distributed, procmesh, solver
from repro.launch import distributed as launchd

SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


# ---------------------------------------------------------------- fast --

def test_worker_blocks_split():
    assert [list(b) for b in procmesh.worker_blocks(4, 2)] == [[0, 1], [2, 3]]
    assert [list(b) for b in procmesh.worker_blocks(5, 2)] == [[0, 1], [2, 3, 4]]
    assert [list(b) for b in procmesh.worker_blocks(3, 3)] == [[0], [1], [2]]
    with pytest.raises(ValueError):
        procmesh.worker_blocks(2, 3)


def test_array_codec_roundtrip():
    arrays = {"a": np.arange(6.0).reshape(2, 3),
              "b": np.array([1], dtype=np.int64)}
    out = procmesh.decode_arrays(procmesh.encode_arrays(arrays))
    assert set(out) == {"a", "b"}
    for k in arrays:
        assert out[k].dtype == arrays[k].dtype
        np.testing.assert_array_equal(out[k], arrays[k])


def test_local_kv_semantics():
    kv = procmesh.LocalKV()
    kv.set("k", b"v")
    assert kv.get("k", 1.0) == b"v"
    # the membership protocol never overwrites a key; the KV enforces it
    with pytest.raises(ValueError, match="already set"):
        kv.set("k", b"w")
    with pytest.raises(procmesh.KVTimeout):
        kv.get("missing", 1.0)


def test_fault_validation():
    procmesh.Fault(process=1, round_=2)
    with pytest.raises(ValueError, match="mode"):
        procmesh.Fault(process=1, round_=2, mode="explode")
    with pytest.raises(ValueError):
        procmesh.Fault(process=0, round_=2)
    with pytest.raises(ValueError, match="round"):
        procmesh.Fault(process=1, round_=0)
    with pytest.raises(ValueError, match="rejoin"):
        procmesh.Fault(process=1, round_=2, mode="stall", rejoin_after=0)


@pytest.fixture(scope="module")
def prob4():
    cfg = ConvexConfig(problem="logistic", n=48, d=8, seed=0, workers=4)
    sp = distributed.make_distributed(jax.random.PRNGKey(0), cfg)
    return sp, convex.auto_eta(sp.merged())


def _comm():
    return procmesh.ProcComm(procmesh.LocalKV(), 0, 1, prefix="t")


def test_single_process_async_engine_is_bit_exact(prob4):
    """nprocs=1 degenerate fleet: the KV engine runs the identical wave
    algebra, so the trajectory must match ``run_async`` bit for bit."""
    sp, eta = prob4
    key = jax.random.PRNGKey(0)
    for speeds in (None, (1.0, 1.0, 2.0, 4.0)):
        _, rels_ref = distributed.run_async(sp, eta=eta, rounds=5, key=key,
                                            speeds=speeds)
        state, rels, transitions = procmesh.run_async_process(
            sp, eta=eta, rounds=5, key=key, comm=_comm(), speeds=speeds)
        np.testing.assert_array_equal(np.asarray(rels_ref), rels)
        assert transitions == []


def test_single_process_sync_engine_matches(prob4):
    sp, eta = prob4
    key = jax.random.PRNGKey(0)
    _, rels_ref = distributed.run_sync(sp, eta=eta, rounds=5, key=key)
    state, rels = procmesh.run_sync_process(sp, eta=eta, rounds=5, key=key,
                                            comm=_comm())
    # separately-jitted per-worker epochs vs one vmapped program: same
    # math, one-ULP reassociation headroom
    np.testing.assert_allclose(np.asarray(rels_ref), rels,
                               rtol=1e-10, atol=1e-12)


def test_solve_process_topology_matches_local(prob4):
    cfg = ConvexConfig(problem="logistic", n=12, d=8, seed=0)
    kw = dict(algo="centralvr_async", p=4, rounds=6, seed=0,
              speeds=(1.0, 1.0, 2.0, 4.0))
    ref = solver.solve(solver.RunSpec(**kw), cfg)
    launchd.set_local_context(1, 0, prefix="solve-t")
    try:
        res = solver.solve(solver.RunSpec(topology="process", **kw), cfg)
    finally:
        launchd.clear_context()
    np.testing.assert_array_equal(np.asarray(ref.rels), np.asarray(res.rels))
    prov = res.provenance()["spec"]
    assert prov["topology"] == "process" and prov["elastic"] is False


def test_solve_process_requires_context(prob4):
    sp, eta = prob4
    launchd.clear_context()
    spec = solver.RunSpec(algo="centralvr_async", p=4, rounds=2,
                          topology="process")
    with pytest.raises(RuntimeError, match="process mesh"):
        procmesh.solve_process(spec, sp, eta, jax.random.PRNGKey(0))


def test_runspec_topology_validation():
    ok = solver.RunSpec(algo="centralvr_async", p=4, topology="process")
    assert ok.elastic is False
    with pytest.raises(ValueError, match="topology"):
        solver.RunSpec(algo="centralvr_async", p=4, topology="bogus")
    with pytest.raises(ValueError):
        solver.RunSpec(algo="dsaga", p=4, topology="process")
    with pytest.raises(ValueError):
        solver.RunSpec(algo="centralvr_async", p=4, topology="process",
                       backend="spmd")
    with pytest.raises(ValueError):
        solver.RunSpec(algo="centralvr_async", p=4, topology="process",
                       fused=True)
    with pytest.raises(ValueError, match="elastic"):
        solver.RunSpec(algo="centralvr_sync", p=4, elastic=True)


def test_solve_membership_requires_elastic_local():
    cfg = ConvexConfig(problem="logistic", n=12, d=8, seed=0)
    from repro.core import elastic
    plan = elastic.PlannedMembership(4, {2: (0, 1)})
    spec = solver.RunSpec(algo="centralvr_async", p=4, rounds=4)
    with pytest.raises(ValueError, match="elastic"):
        solver.solve(spec, cfg, membership=plan)


def test_worker_mesh_simulation_guard(monkeypatch):
    """Satellite bugfix: ``simulate_host_devices=True`` after jax already
    initialized must fail fast when THIS process holds fewer devices than
    p, even though the global count satisfies the force_host_devices
    check (the jax.distributed world shape)."""
    from repro.launch import mesh

    jax.devices()   # ensure the backend is initialized
    monkeypatch.setattr(jax, "device_count", lambda: 4)
    monkeypatch.setattr(jax, "local_device_count", lambda: 2)
    with pytest.raises(RuntimeError, match="DESIGN"):
        mesh.make_worker_mesh(4, simulate_host_devices=True)


def test_process_worker_mesh_validates_world():
    from repro.core import spmd
    m = spmd.process_worker_mesh(1)
    assert m.devices.shape == (1,)
    with pytest.raises(RuntimeError, match="devices across the world"):
        spmd.process_worker_mesh(1024)


# ---------------------------------------------------- slow (subprocess) --

def _launch(tmp_path, *extra):
    """Run the two-process launcher; --verify makes the parent re-solve
    the spec locally and exit nonzero on trajectory mismatch."""
    argv = [sys.executable, "-m", "repro.launch.distributed",
            "--nprocs", "2", "--workers", "4", "--rounds", "5",
            "--n", "12", "--d", "8", "--timeout", "200",
            "--logdir", str(tmp_path / "logs"),
            "--json", str(tmp_path / "results.json"),
            "--verify", *extra]
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    return subprocess.run(argv, env=env, capture_output=True, text=True,
                          timeout=280)


@pytest.mark.slow
def test_two_process_async_lane(tmp_path):
    r = _launch(tmp_path, "--algo", "centralvr_async",
                "--speeds", "1,1,2,4", "--x64")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "fleet ok" in r.stdout
    results = json.loads((tmp_path / "results.json").read_text())
    assert results["dropped"] is False and results["transitions"] == []


@pytest.mark.slow
def test_two_process_sync_lane(tmp_path):
    r = _launch(tmp_path, "--algo", "centralvr_sync", "--x64")
    assert r.returncode == 0, r.stdout + r.stderr


@pytest.mark.slow
def test_two_process_elastic_dropout_lane(tmp_path):
    """Process 1 exits at the round-2 boundary; the survivor repartitions
    deterministically, emits schema-valid worker_lost/repartition events,
    and the post-drop trajectory matches the planned-membership reference
    (exact in x64)."""
    from repro.launch import obs as launch_obs

    obs_base = str(tmp_path / "obs")
    r = _launch(tmp_path, "--algo", "centralvr_async",
                "--speeds", "1,1,2,4", "--x64", "--elastic",
                "--drop-process", "1", "--drop-round", "2",
                "--drop-mode", "exit", "--hb-timeout", "5",
                "--obs", obs_base)
    assert r.returncode == 0, r.stdout + r.stderr
    results = json.loads((tmp_path / "results.json").read_text())
    assert [t["round"] for t in results["transitions"]] == [2]
    assert results["transitions"][0]["live"] == [0, 1]

    from repro.obs import schema
    rows = schema.load_rows(obs_base + "-p0.jsonl")
    assert schema.validate_rows(rows) == len(rows)
    names = [row["name"] for row in rows if row["kind"] == "event"]
    assert names.count("worker_lost") == 2       # workers 2 and 3
    assert names.count("repartition") == 1
    lost = [row for row in rows if row["name"] == "worker_lost"]
    assert all(row["detect_s"] > 0 for row in lost)
    assert launch_obs  # imported above: launch.obs stays importable


@pytest.mark.slow
def test_two_process_elastic_rejoin_lane(tmp_path):
    r = _launch(tmp_path, "--algo", "centralvr_async", "--rounds", "7",
                "--speeds", "1,1,2,4", "--x64", "--elastic",
                "--drop-process", "1", "--drop-round", "2",
                "--drop-mode", "stall", "--rejoin-after", "2",
                "--hb-timeout", "5")
    assert r.returncode == 0, r.stdout + r.stderr
    results = json.loads((tmp_path / "results.json").read_text())
    rounds = [t["round"] for t in results["transitions"]]
    assert rounds == [2, 4]
    assert results["transitions"][1]["joined"] == [2, 3]
