"""The paper's own experimental settings (§6): l2-regularized logistic
regression and ridge regression, toy + shape-matched real-world stand-ins.

These are :class:`repro.config.ConvexConfig` presets, not ModelConfigs —
the convex problems are the paper-faithful reproduction substrate.
"""
from repro.config import ConvexConfig

# §6.1 toy: n=5000, d=20, lambda=1e-4
TOY_LOGISTIC = ConvexConfig(problem="logistic", n=5000, d=20, lam=1e-4)
TOY_RIDGE = ConvexConfig(problem="ridge", n=5000, d=20, lam=1e-4)

# real-world stand-ins, shape-matched (offline container; see DESIGN.md §9)
IJCNN1_LIKE = ConvexConfig(problem="logistic", n=35000, d=22, lam=1e-4)
MILLIONSONG_LIKE = ConvexConfig(problem="ridge", n=46371, d=90, lam=1e-4)  # 1/10 scale
SUSY_LIKE = ConvexConfig(problem="logistic", n=100000, d=18, lam=1e-4)     # 1/50 scale

# §6.2 distributed toy: d=1000, |Omega_s|=5000 per worker
DIST_TOY_LOGISTIC = ConvexConfig(problem="logistic", n=5000, d=1000, lam=1e-4, workers=8)
DIST_TOY_RIDGE = ConvexConfig(problem="ridge", n=5000, d=1000, lam=1e-4, workers=8)

PRESETS = {
    "toy-logistic": TOY_LOGISTIC,
    "toy-ridge": TOY_RIDGE,
    "ijcnn1": IJCNN1_LIKE,
    "millionsong": MILLIONSONG_LIKE,
    "susy": SUSY_LIKE,
    "dist-toy-logistic": DIST_TOY_LOGISTIC,
    "dist-toy-ridge": DIST_TOY_RIDGE,
}
