"""Multi-device semantics, run in a SUBPROCESS with 8 forced host devices
(the main pytest process must keep the real single-device view — see
conftest). Checks:

  * CentralVR-Sync worker copies diverge between and coincide at epoch
    boundaries (Algorithm 2 under SPMD),
  * the sharded W>1 run is numerically identical to an unsharded vmap run,
  * spec trees resolve for every arch without error.
"""
import json
import subprocess
import sys
import textwrap

import pytest

# whole-module: subprocess compiles / many reduced-arch compiles — fast lane skips these (DESIGN.md §5)
pytestmark = pytest.mark.slow

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.config import get_arch, TrainConfig
    from repro.train import step as tstep
    from repro.data import synthetic

    cfg = get_arch("qwen2-7b").reduced()
    tcfg = TrainConfig(optimizer="sgd", learning_rate=0.1, vr="centralvr",
                       vr_table_size=3, local_epoch=1, dp_replicated=True)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    train_step, meta = tstep.make_train_step(cfg, tcfg, mesh, "data")
    W = meta["workers"]
    assert W == 4, W
    state = tstep.init_train_state(cfg, tcfg, jax.random.PRNGKey(0), W)
    sh = tstep.state_shardings(jax.eval_shape(lambda s: s, state), cfg,
                               tcfg, mesh, "data")
    bsh = tstep.batch_sharding(mesh, tcfg, "data")
    state_sharded = jax.device_put(state, sh)
    js = jax.jit(train_step, in_shardings=(sh, bsh["tokens"]),
                 out_shardings=(sh, None))
    js_plain = jax.jit(train_step)
    state_plain = state

    spreads = []
    agree = []
    for s in range(6):
        toks = synthetic.epoch_batch(cfg, 0, s, workers=W, accum=1,
                                     microbatch=2, seq=32, table_size=3)
        state_sharded, m1 = js(state_sharded,
                               jax.device_put(toks, bsh["tokens"]))
        state_plain, m2 = js_plain(state_plain, toks)
        p = state_sharded.params["embed"]["tok"]
        spreads.append(float(jnp.abs(p - p.mean(0, keepdims=True)).max()))
        agree.append(abs(float(m1["loss"]) - float(m2["loss"])))
    out = {"spreads": spreads, "agree": agree}
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def results():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_workers_diverge_then_sync(results):
    spreads = results["spreads"]
    # local steps (0,1) diverge; boundary at step 3 (M=3): spread == 0
    assert spreads[0] > 0.0
    assert spreads[2] == 0.0, spreads   # step index 2 = 3rd step = boundary
    assert spreads[5] == 0.0, spreads


def test_sharded_matches_unsharded(results):
    # same math on 8 devices vs 1 device (bf16 params -> loose tol)
    assert max(results["agree"]) < 5e-2, results["agree"]


def test_spec_trees_resolve_for_all_archs():
    import jax

    from repro.config import TrainConfig, get_arch
    from repro.configs import ASSIGNED_ARCHS
    from repro.sharding import specs
    from repro.train import step as tstep

    for arch in ASSIGNED_ARCHS:
        cfg = get_arch(arch).reduced()
        tcfg = TrainConfig(vr="centralvr", vr_table_size=2)
        shapes = tstep.eval_shape_train_state(cfg, tcfg, W=2)
        tree = specs.tree_specs(shapes, cfg, fsdp=True,
                                worker_axes=("pod",))
        for path_spec, leaf in zip(jax.tree_util.tree_leaves(tree),
                                   jax.tree_util.tree_leaves(shapes)):
            assert len(path_spec) <= leaf.ndim, (arch, path_spec, leaf)
