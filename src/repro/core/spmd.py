"""SPMD multi-device execution backend for the convex driver runtime.

The default backend simulates the p workers with a stacked leading axis
under ``jax.vmap`` — numerically identical to p processes, but every shard
lives on ONE device.  This module is the second backend (DESIGN.md §2):
the same local-epoch primitives run under ``jax.shard_map`` over a real
``jax.sharding.Mesh`` with one worker per device, so each worker's
``(ns, d)`` shard, VR table, and gradient accumulator are resident on its
own device and the paper's central server becomes collective communication
(``jax.lax.pmean`` over the worker axis) instead of a ``mean(axis=0)``.

On this container the mesh is CPU-simulated: ``force_host_devices(n)``
(shared by ``launch/mesh.py`` and the tests) forces the host platform to
present n devices via XLA_FLAGS — it must run before the jax backend
initializes, but after ``import jax`` is fine (device state is lazy).

Sampling is data, not code (the async event schedule's rule, DESIGN.md §3,
extended to RNG): every permutation/index draw is precomputed on the host
with EXACTLY the key splits the vmap drivers perform, then shipped to the
mesh sharded along the worker axis.  This is deliberate — on this jax
version, XLA's multi-device CPU partitioner miscompiles in-shard
``jax.random.permutation``/``randint`` in larger programs (every device
silently receives device 0's draw; the spmd/vmap disagreement that exposed
it is pinned by ``tests/test_spmd_backend.py``), and shipping the draws
also guarantees both backends consume identical randomness by
construction, so the only numerical divergence left is collective
reduction order.  (``check_rep=False`` on every runner for a related
reason: this jax version's replication checker rejects scan carries that
enter unreplicated and leave pmean-replicated, which is the shape of
every round loop here; correctness is pinned by the vmap-agreement tests
instead.)

Backend contract (pinned by ``tests/test_spmd_backend.py``):

  * trajectories agree with the vmap backend within float32 tolerance;
  * worker state is genuinely placed: each shard of the ``(p, ns)`` tables
    maps to a distinct device;
  * the event-serial drivers (CentralVR-Async, D-SAGA) have no
    worker-parallel program — one worker updates the central state at a
    time — and their ``backend="spmd"`` raises ``NotImplementedError``
    from ``distributed.py`` rather than silently falling back.
"""
from __future__ import annotations

import functools
import os
import re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import convex
from repro.core.convex import Problem

WORKER_AXIS = "workers"

_COUNT_FLAG = "--xla_force_host_platform_device_count"


# ---------------------------------------------------------------------------
# Host-device simulation + mesh construction
# ---------------------------------------------------------------------------

def force_host_devices(n: int) -> None:
    """Make the CPU host platform present ``n`` devices (XLA_FLAGS).

    Safe to call after ``import jax`` but only before the backend
    initializes (first ``jax.devices()`` / first op); afterwards it is a
    no-op if enough devices already exist and an error otherwise.  Both
    ``launch/mesh.py`` and the spmd tests go through here so the flag is
    spelled in exactly one place.
    """
    from jax._src import xla_bridge

    if xla_bridge.backends_are_initialized():
        if jax.device_count() >= n:
            return
        raise RuntimeError(
            f"jax already initialized with {jax.device_count()} device(s); "
            f"force_host_devices({n}) must run before the first jax "
            "operation (importing jax is fine — touching devices is not)")
    flags = os.environ.get("XLA_FLAGS", "")
    existing = re.search(rf"{_COUNT_FLAG}=(\d+)", flags)
    if existing:
        # at-least-n semantics, same as the post-init branch: never lower
        # a count someone already forced (e.g. a user-exported XLA_FLAGS)
        if int(existing.group(1)) < n:
            flags = re.sub(rf"{_COUNT_FLAG}=\d+", f"{_COUNT_FLAG}={n}",
                           flags)
    else:
        flags = (flags + f" {_COUNT_FLAG}={n}").strip()
    os.environ["XLA_FLAGS"] = flags


def worker_mesh(p: int) -> Mesh:
    """A 1-D mesh of p devices, one CentralVR worker per device."""
    devs = jax.devices()
    if len(devs) < p:
        raise RuntimeError(
            f"spmd backend needs {p} devices, found {len(devs)}; on CPU "
            f"call repro.core.spmd.force_host_devices({p}) before the "
            f"first jax operation (or set "
            f'XLA_FLAGS="{_COUNT_FLAG}={p}")')
    return Mesh(np.asarray(devs[:p]), (WORKER_AXIS,))


def _check_mesh(mesh: Optional[Mesh], p: int) -> Mesh:
    mesh = mesh if mesh is not None else worker_mesh(p)
    if mesh.devices.size != p:
        raise ValueError(
            f"mesh has {mesh.devices.size} devices but the problem has "
            f"{p} workers; the spmd backend places exactly one worker "
            "per mesh device")
    return mesh


def _put(mesh: Mesh, sharded_tree, replicated_tree, worker_dim=0):
    """Place worker-stacked leaves sharded along ``worker_dim`` and
    everything else replicated, so the jitted runners see consistent input
    shardings (mixing mesh-sharded and single-device-committed args is an
    error)."""
    spec = P(*([None] * worker_dim + [WORKER_AXIS]))
    shard = NamedSharding(mesh, spec)
    repl = NamedSharding(mesh, P())
    return (jax.device_put(sharded_tree, shard),
            jax.device_put(replicated_tree, repl))


# ---------------------------------------------------------------------------
# Host-side RNG precompute — bit-identical to the vmap drivers' draws
# ---------------------------------------------------------------------------

def _round_perms(keys: jax.Array, p: int, ns: int) -> jax.Array:
    """(rounds, p, ns) permutations: per round, split the round key into p
    and draw each worker's epoch permutation — exactly ``sync_round``."""
    return jax.vmap(lambda k: jax.vmap(
        lambda kk: jax.random.permutation(kk, ns))(jax.random.split(k, p))
    )(keys)


def _round_indices(keys: jax.Array, p: int, ns: int, tau: int) -> jax.Array:
    """(rounds, p, tau) uniform index draws — exactly the vmapped
    ``jax.random.randint(kk, (tau,), 0, ns)`` of the local-loop drivers."""
    return jax.vmap(lambda k: jax.vmap(
        lambda kk: jax.random.randint(kk, (tau,), 0, ns))(
        jax.random.split(k, p)))(keys)


# ---------------------------------------------------------------------------
# In-shard metric helpers
# ---------------------------------------------------------------------------

def _rel_grad_norm(local: Problem, x: jax.Array, g0: jax.Array) -> jax.Array:
    """The paper's y-axis on the GLOBAL objective, from inside a shard:
    per-shard data-term means are equal-weighted (every worker holds ns
    samples), so their pmean is the merged problem's data gradient."""
    s = convex.scalar_residual_all(local, x)
    data = jax.lax.pmean(convex.data_grad_from_scalars(local, s), WORKER_AXIS)
    return jnp.linalg.norm(data + 2.0 * local.lam * x) / g0


def _full_grad(local: Problem, x: jax.Array) -> jax.Array:
    """Global full gradient via collective: pmean of per-shard full
    gradients (the replicated 2·lam·x term averages to itself)."""
    return jax.lax.pmean(convex.full_grad(local, x), WORKER_AXIS)


# ---------------------------------------------------------------------------
# CentralVR-Sync (Algorithm 2) under shard_map
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _sync_runner(mesh: Mesh, kind: str):
    """One compiled executable per (mesh, problem kind): init epoch + the
    whole round scan inside a single jitted shard_map.  Cached so warm
    calls skip shard_map re-construction and hit the jit cache."""
    from repro.core.distributed import _local_centralvr_epoch, _local_sgd_epoch

    def body(A, b, lam, eta, g0, perm0, perms):
        A, b, perm0 = A[0], b[0], perm0[0]    # this worker's shard
        local = Problem(A, b, lam, kind)

        # --- init: one plain-SGD epoch per worker, then average (line 2)
        x0 = jnp.zeros((A.shape[1],), dtype=A.dtype)
        x_w, table, acc = _local_sgd_epoch(A, b, lam, kind, x0, eta, perm0)
        x = jax.lax.pmean(x_w, WORKER_AXIS)
        gbar = jax.lax.pmean(acc, WORKER_AXIS)

        # --- communication rounds (lines 4-18): local epoch, then the
        # central average of (x, gbar) as a collective pmean
        def one_round(carry, perm):
            x, table, gbar = carry
            x_w, table, acc = _local_centralvr_epoch(
                A, b, lam, kind, x, table, gbar, eta, perm[0])
            x = jax.lax.pmean(x_w, WORKER_AXIS)
            gbar = jax.lax.pmean(acc, WORKER_AXIS)
            rel = _rel_grad_norm(local, x, g0)
            return (x, table, gbar), rel

        (x, table, gbar), rels = jax.lax.scan(one_round, (x, table, gbar),
                                              perms)
        return x, table[None], gbar, rels

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(WORKER_AXIS), P(WORKER_AXIS), P(), P(), P(),
                  P(WORKER_AXIS), P(None, WORKER_AXIS)),
        out_specs=(P(), P(WORKER_AXIS), P(), P()), check_rep=False))


def run_sync(sp, *, eta: float, rounds: int, key: jax.Array,
             mesh: Optional[Mesh] = None):
    """Algorithm 2 with one worker per device (DESIGN.md §2, spmd backend).
    Same RNG draws as the vmap driver (precomputed on host), so the
    trajectories agree within reduction-order float noise."""
    from repro.core.distributed import SyncState

    mesh = _check_mesh(mesh, sp.p)
    k_init, k_run = jax.random.split(key)
    g0 = convex.grad_norm0(sp.merged())
    perm0 = jax.vmap(lambda kk: jax.random.permutation(kk, sp.ns))(
        jax.random.split(k_init, sp.p))
    perms = _round_perms(jax.random.split(k_run, rounds), sp.p, sp.ns)
    (A, b, perm0), (lam, eta, g0) = _put(
        mesh, (sp.A, sp.b, perm0), (sp.lam, jnp.asarray(eta), g0))
    (perms,), () = _put(mesh, (perms,), (), worker_dim=1)
    x, tables, gbar, rels = _sync_runner(mesh, sp.kind)(
        A, b, lam, eta, g0, perm0, perms)
    return SyncState(x=x, tables=tables, gbar=gbar), rels


# ---------------------------------------------------------------------------
# Distributed SVRG (Algorithm 4) under shard_map
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _dsvrg_runner(mesh: Mesh, kind: str):
    def body(A, b, lam, eta, g0, idx):
        A, b = A[0], b[0]
        local = Problem(A, b, lam, kind)
        x0 = jnp.zeros((A.shape[1],), dtype=A.dtype)

        def round_(x, idx_r):
            xbar = x
            gbar = _full_grad(local, xbar)   # sync step (line 5)

            def step(xl, i):
                g = (convex.scalar_residual(local, xl, i) * A[i]
                     - convex.scalar_residual(local, xbar, i) * A[i]
                     + gbar + 2.0 * lam * (xl - xbar))
                return xl - eta * g, None

            xl, _ = jax.lax.scan(step, xbar, idx_r[0])
            x = jax.lax.pmean(xl, WORKER_AXIS)
            rel = _rel_grad_norm(local, x, g0)
            return x, rel

        return jax.lax.scan(round_, x0, idx)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(WORKER_AXIS), P(WORKER_AXIS), P(), P(), P(),
                  P(None, WORKER_AXIS)),
        out_specs=(P(), P()), check_rep=False))


def run_dsvrg(sp, *, eta: float, rounds: int, key: jax.Array, tau: int = 0,
              mesh: Optional[Mesh] = None):
    tau = tau or 2 * sp.ns
    mesh = _check_mesh(mesh, sp.p)
    g0 = convex.grad_norm0(sp.merged())
    idx = _round_indices(jax.random.split(key, rounds), sp.p, sp.ns, tau)
    (A, b), (lam, eta, g0) = _put(
        mesh, (sp.A, sp.b), (sp.lam, jnp.asarray(eta), g0))
    (idx,), () = _put(mesh, (idx,), (), worker_dim=1)
    return _dsvrg_runner(mesh, sp.kind)(A, b, lam, eta, g0, idx)


# ---------------------------------------------------------------------------
# Minibatch baselines under shard_map
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _dist_sgd_runner(mesh: Mesh, kind: str):
    def body(A, b, lam, g0, idx, etas):
        A, b = A[0], b[0]
        local = Problem(A, b, lam, kind)
        x0 = jnp.zeros((A.shape[1],), dtype=A.dtype)

        def round_(x, ins):
            idx_r, eta_l = ins

            def step(xl, i):
                g = (convex.scalar_residual(local, xl, i) * A[i]
                     + 2.0 * lam * xl)
                return xl - eta_l * g, None

            xl, _ = jax.lax.scan(step, x, idx_r[0])
            x_new = jax.lax.pmean(xl, WORKER_AXIS)
            return x_new, _rel_grad_norm(local, x_new, g0)

        return jax.lax.scan(round_, x0, (idx, etas))

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(WORKER_AXIS), P(WORKER_AXIS), P(), P(),
                  P(None, WORKER_AXIS), P()),
        out_specs=(P(), P()), check_rep=False))


def run_dist_sgd(sp, *, eta: float, rounds: int, key: jax.Array,
                 tau: int = 0, decay: float = 0.0,
                 mesh: Optional[Mesh] = None):
    tau = tau or sp.ns
    mesh = _check_mesh(mesh, sp.p)
    g0 = convex.grad_norm0(sp.merged())
    idx = _round_indices(jax.random.split(key, rounds), sp.p, sp.ns, tau)
    etas = eta / (1.0 + decay * jnp.arange(rounds) * tau) ** 0.5
    (A, b), (lam, g0, etas) = _put(
        mesh, (sp.A, sp.b), (sp.lam, g0, etas))
    (idx,), () = _put(mesh, (idx,), (), worker_dim=1)
    return _dist_sgd_runner(mesh, sp.kind)(A, b, lam, g0, idx, etas)


@functools.lru_cache(maxsize=None)
def _easgd_runner(mesh: Mesh, kind: str):
    def body(A, b, lam, alpha, g0, idx, etas):
        A, b = A[0], b[0]
        local = Problem(A, b, lam, kind)
        d = A.shape[1]
        xc0 = jnp.zeros((d,), dtype=A.dtype)
        xl0 = jnp.zeros((d,), dtype=A.dtype)

        def round_(carry, ins):
            xc, xl = carry
            idx_r, eta_l = ins

            def comm_block(carry, idx_tau):
                xl, xc_view = carry

                def step(x, i):
                    g = (convex.scalar_residual(local, x, i) * A[i]
                         + 2.0 * lam * x)
                    return x - eta_l * g, None

                xl, _ = jax.lax.scan(step, xl, idx_tau)
                diff = xl - xc_view
                return (xl - alpha * diff, xc_view + alpha * diff), diff

            (xl, _), diffs = jax.lax.scan(comm_block, (xl, xc), idx_r[0])
            # center update: sum of worker contributions / p == pmean
            xc = xc + alpha * jax.lax.pmean(diffs.sum(0), WORKER_AXIS)
            rel = _rel_grad_norm(local, xc, g0)
            return (xc, xl), rel

        (xc, xl), rels = jax.lax.scan(round_, (xc0, xl0), (idx, etas))
        return xc, xl[None], rels

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(WORKER_AXIS), P(WORKER_AXIS), P(), P(), P(),
                  P(None, WORKER_AXIS), P()),
        out_specs=(P(), P(WORKER_AXIS), P()), check_rep=False))


def run_easgd(sp, *, eta: float, rounds: int, key: jax.Array, tau: int = 16,
              rho: float = 1.0, decay: float = 0.0,
              mesh: Optional[Mesh] = None):
    mesh = _check_mesh(mesh, sp.p)
    alpha = min(0.9 / sp.p, eta * rho * tau)
    steps_per_round = max(sp.ns // tau, 1)
    g0 = convex.grad_norm0(sp.merged())
    idx = _round_indices(jax.random.split(key, rounds), sp.p, sp.ns,
                         steps_per_round * tau)
    idx = idx.reshape(rounds, sp.p, steps_per_round, tau)
    etas = eta / (1.0 + decay * jnp.arange(rounds) * sp.ns) ** 0.5
    (A, b), (lam, alpha, g0, etas) = _put(
        mesh, (sp.A, sp.b), (sp.lam, jnp.asarray(alpha), g0, etas))
    (idx,), () = _put(mesh, (idx,), (), worker_dim=1)
    xc, _, rels = _easgd_runner(mesh, sp.kind)(A, b, lam, alpha, g0, idx,
                                               etas)
    return xc, rels


@functools.lru_cache(maxsize=None)
def _ps_svrg_runner(mesh: Mesh, kind: str):
    def body(A, b, lam, eta, g0, idx):
        A, b = A[0], b[0]
        local = Problem(A, b, lam, kind)
        x0 = jnp.zeros((A.shape[1],), dtype=A.dtype)

        def round_(x, idx_r):
            xbar = x
            gbar = _full_grad(local, xbar)

            def step(x, ii):
                # this worker's index of the server step's (p,) draw
                i = ii[0]
                g_w = ((convex.scalar_residual(local, x, i)
                        - convex.scalar_residual(local, xbar, i)) * A[i]
                       + gbar + 2.0 * lam * (x - xbar))
                g = jax.lax.pmean(g_w, WORKER_AXIS)
                return x - eta * g, None

            x, _ = jax.lax.scan(step, x, idx_r)
            return x, _rel_grad_norm(local, x, g0)

        return jax.lax.scan(round_, x0, idx)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(WORKER_AXIS), P(WORKER_AXIS), P(), P(), P(),
                  P(None, None, WORKER_AXIS)),
        out_specs=(P(), P()), check_rep=False))


def run_ps_svrg(sp, *, eta: float, rounds: int, key: jax.Array,
                epoch_mult: int = 2, mesh: Optional[Mesh] = None):
    mesh = _check_mesh(mesh, sp.p)
    g0 = convex.grad_norm0(sp.merged())
    inner = epoch_mult * sp.ns
    # (rounds, inner, p): per server step, one index per worker — exactly
    # the vmap driver's randint(ks, (p,)) stream
    idx = jax.vmap(lambda k: jax.vmap(
        lambda ks: jax.random.randint(ks, (sp.p,), 0, sp.ns))(
        jax.random.split(k, inner)))(jax.random.split(key, rounds))
    (A, b), (lam, eta, g0) = _put(
        mesh, (sp.A, sp.b), (sp.lam, jnp.asarray(eta), g0))
    (idx,), () = _put(mesh, (idx,), (), worker_dim=2)
    return _ps_svrg_runner(mesh, sp.kind)(A, b, lam, eta, g0, idx)


# ---------------------------------------------------------------------------
# Algorithm 1 (single worker) on a mesh device
# ---------------------------------------------------------------------------

def run_centralvr(prob: Problem, *, eta: float, epochs: int, key: jax.Array,
                  sampling: str = "permutation", x0=None,
                  mesh: Optional[Mesh] = None):
    """Algorithm 1 has no worker axis to shard — ``backend="spmd"`` means
    "execute on the mesh": the problem is placed on the mesh's first
    device and the standard device-resident scan runs there, so a launcher
    can address one API regardless of backend."""
    from repro.core import centralvr

    mesh = mesh if mesh is not None else worker_mesh(1)
    dev = mesh.devices.ravel()[0]
    prob = jax.device_put(prob, dev)
    key = jax.device_put(key, dev)
    if x0 is not None:
        x0 = jax.device_put(x0, dev)
    return centralvr.run(prob, eta=eta, epochs=epochs, key=key,
                         sampling=sampling, x0=x0)
