"""Execution-weighted HLO cost model.

XLA's ``compiled.cost_analysis()`` counts each while-loop BODY ONCE — we
verified this empirically (a scan of 10 matmuls reports the flops of one;
see EXPERIMENTS.md §Dry-run). A scanned-layers transformer with gradient
accumulation therefore under-reports flops/bytes by the product of trip
counts (e.g. 80 layers x 16 microbatches = 1280x). This module parses the
scheduled HLO text instead and weights every op by its execution count:

  * while ops carry ``backend_config={"known_trip_count":{"n":...}}`` —
    body multiplier x= n, condition x= n+1;
  * fusion/call ops propagate the caller's multiplier into the called
    computation (flops of dots INSIDE fusions count; HBM bytes of ops
    inside fusion computations do NOT — they are register/VMEM resident);
  * conditional branches are counted at the caller's multiplier (an upper
    bound; the CentralVR epoch-boundary branch actually fires once per
    comm_every steps — the dry-run records its collectives separately so
    the report can amortize them);
  * dot flops = 2 * prod(result dims) * prod(lhs contracting dims);
  * HBM bytes = result + operand bytes of top-level (non-fused) compute
    ops — the classic operand-read + result-write accounting;
  * collective bytes = result bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (+ async -start
    forms; -done skipped to avoid double counting).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "token": 0,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

_COMP_HDR = re.compile(r"^(ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_NAME = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
# first lowercase-word-followed-by-( after the result type is the opcode
# (result types contain no parens; /*index=N*/ comments contain no parens)
_OPCODE = re.compile(r"([a-z][\w\-]*)\(")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TFT = re.compile(r"(?:true|false)_computation=%?([\w.\-]+)")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_B = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_OPERAND = re.compile(r"%([\w.\-]+)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_dims(text: str) -> Optional[List[int]]:
    m = _SHAPE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    result: str
    opcode: str
    rest: str           # operands + attrs (single line)
    is_root: bool = False

    @property
    def result_bytes(self) -> int:
        return _shape_bytes(self.result)

    def operands(self) -> List[str]:
        return _OPERAND.findall(self.rest.split(")")[0])


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # op name -> result


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _OP_NAME.match(line)
        if m:
            rest = m.group(2)
            mo = _OPCODE.search(rest)
            if not mo:
                continue
            op = Op(m.group(1), rest[:mo.start()].strip(), mo.group(1),
                    rest[mo.end():], is_root="ROOT" in line[:12])
            cur.ops.append(op)
            cur.shapes[op.name] = op.result
    return comps, entry


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    dot_flops_by_mult: Dict[float, float] = field(
        default_factory=lambda: defaultdict(float))

    def as_dict(self):
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "collectives": dict(self.collective_breakdown),
            "collective_counts": dict(self.collective_counts),
        }


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    dims = _shape_dims(op.result)
    if dims is None:
        return 0.0
    out = 1
    for d in dims:
        out *= d
    mc = _LHS_C.search(op.rest)
    contracting = 1
    if mc:
        idxs = [int(i) for i in mc.group(1).split(",") if i]
        operands = _OPERAND.findall(op.rest)
        if operands:
            lhs_shape = _shape_dims(shapes.get(operands[0], "")) or []
            for i in idxs:
                if i < len(lhs_shape):
                    contracting *= lhs_shape[i]
    return 2.0 * out * contracting


_BYTE_OPS = {
    "fusion", "dot", "copy", "convolution", "dynamic-slice",
    "dynamic-update-slice", "gather", "scatter", "sort", "reduce",
    "transpose", "concatenate", "pad", "broadcast", "iota", "rng",
    "select-and-scatter", "reduce-window", "custom-call", "slice",
    "reverse", "reshape", "convert", "cholesky", "triangular-solve",
    "tanh", "exponential", "add", "multiply",
} | set(COLLECTIVE_KINDS) | {k + "-start" for k in COLLECTIVE_KINDS}


def _fusion_operand_bytes(comp: Computation, called: Computation) -> dict:
    """Per-parameter-index HBM charge for one fusion: a parameter consumed
    ONLY through dynamic-slice / gather / slice ops inside the fusion is
    charged the sliced size, not the full buffer (scan bodies slice their
    stacked inputs; charging the stack would overcount by the trip count).
    Returns {param_index: bytes}."""
    charge: dict = {}
    param_name = {}
    for op in called.ops:
        if op.opcode == "parameter":
            mi = re.match(r"\s*(\d+)", op.rest)
            if mi:
                param_name[op.name] = int(mi.group(1))
                charge[int(mi.group(1))] = 0
    for op in called.ops:
        for o in op.operands():
            if o in param_name:
                idx = param_name[o]
                if op.opcode in ("dynamic-slice", "slice", "gather"):
                    charge[idx] = charge.get(idx, 0) + op.result_bytes
                elif op.opcode == "dynamic-update-slice":
                    ops_ = op.operands()
                    upd = (_shape_bytes(called.shapes.get(ops_[1], ""))
                           if len(ops_) > 1 else op.result_bytes)
                    charge[idx] = charge.get(idx, 0) + upd
                elif op.opcode in ("get-tuple-element", "bitcast", "tuple"):
                    pass
                else:
                    charge[idx] = None       # full access
    return charge


def _fusion_result_bytes(called: Computation) -> Optional[int]:
    """If the fusion root is a dynamic-update-slice, only the update slice
    is written (the buffer aliases in place)."""
    for op in called.ops:
        if op.is_root and op.opcode == "dynamic-update-slice":
            ops_ = op.operands()
            if len(ops_) > 1:
                return _shape_bytes(called.shapes.get(ops_[1], ""))
    return None


def analyze_hlo(text: str) -> HloCost:
    comps, entry = parse_hlo(text)

    # computations reached via fusion `calls=` hold register-resident ops
    fused: set = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                m = _CALLS.search(op.rest)
                if m:
                    fused.add(m.group(1))

    cost = HloCost()
    visited_stack = []

    def walk(comp_name: str, mult: float):
        comp = comps.get(comp_name)
        if comp is None or mult <= 0:
            return
        if comp_name in visited_stack:       # defensive: no recursion
            return
        visited_stack.append(comp_name)
        in_fused = comp_name in fused
        for op in comp.ops:
            oc = op.opcode
            if oc == "dot":
                f = _dot_flops(op, comp.shapes) * mult
                cost.flops += f
                cost.dot_flops_by_mult[mult] += f
            kind = oc[:-6] if oc.endswith("-start") else oc
            if kind in COLLECTIVE_KINDS and not oc.endswith("-done"):
                b = op.result_bytes * mult
                cost.collective_bytes += b
                cost.collective_breakdown[kind] += b
                cost.collective_counts[kind] += mult
            if not in_fused and oc in _BYTE_OPS:
                result_b = op.result_bytes
                opnds = op.operands()
                if oc == "fusion":
                    m = _CALLS.search(op.rest)
                    called = comps.get(m.group(1)) if m else None
                    if called is not None:
                        per_param = _fusion_operand_bytes(comp, called)
                        operand_bytes = 0
                        for idx, o in enumerate(opnds):
                            full = _shape_bytes(comp.shapes.get(o, ""))
                            c = per_param.get(idx, None)
                            operand_bytes += full if c is None else min(c, full)
                        rb = _fusion_result_bytes(called)
                        if rb is not None:
                            result_b = rb
                    else:
                        operand_bytes = sum(
                            _shape_bytes(comp.shapes.get(o, ""))
                            for o in opnds)
                elif oc == "dynamic-slice":
                    operand_bytes = result_b       # reads only the slice
                elif oc == "dynamic-update-slice":
                    upd = (_shape_bytes(comp.shapes.get(opnds[1], ""))
                           if len(opnds) > 1 else result_b)
                    result_b = upd                 # in-place slice write
                    operand_bytes = upd
                elif oc in ("broadcast", "iota", "slice", "gather"):
                    operand_bytes = 0 if oc in ("broadcast", "iota") else result_b
                else:
                    operand_bytes = sum(
                        _shape_bytes(comp.shapes.get(o, ""))
                        for o in opnds)
                cost.bytes_accessed += (result_b + operand_bytes) * mult
            # recurse
            if oc == "while":
                n = 1.0
                mt = _TRIP.search(op.rest)
                if mt:
                    n = float(mt.group(1))
                mb = _BODY.search(op.rest)
                mc = _COND.search(op.rest)
                if mb:
                    walk(mb.group(1), mult * n)
                if mc:
                    walk(mc.group(1), mult * (n + 1.0))
            elif oc in ("fusion", "call", "map", "reduce", "sort",
                        "reduce-window", "select-and-scatter", "scatter",
                        "all-reduce", "all-reduce-start"):
                m = _CALLS.search(op.rest) or re.search(
                    r"to_apply=%?([\w.\-]+)", op.rest)
                if m and m.group(1) in comps:
                    walk(m.group(1), mult)
            elif oc == "conditional":
                names = _BRANCHES.search(op.rest)
                if names:
                    for nm in _OPERAND.findall(names.group(1)):
                        walk(nm, mult)
                else:
                    for m in _TFT.finditer(op.rest):
                        walk(m.group(1), mult)
        visited_stack.pop()

    walk(entry, 1.0)
    return cost


def attribute(text: str, top: int = 15):
    """Perf-debugging view: the top collective and byte contributors with
    (computation, opcode, result shape, mult, total). This is the 'profile'
    of the dry-run workflow — no wall-clock exists on CPU, so the
    execution-weighted HLO is what we optimize against."""
    comps, entry = parse_hlo(text)
    fused = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                m = _CALLS.search(op.rest)
                if m:
                    fused.add(m.group(1))
    colls: list = []
    bytes_rows: dict = {}
    stack: list = []

    def walk(cn, mult):
        comp = comps.get(cn)
        if comp is None or cn in stack:
            return
        stack.append(cn)
        in_fused = cn in fused
        for op in comp.ops:
            oc = op.opcode
            kind = oc[:-6] if oc.endswith("-start") else oc
            if kind in COLLECTIVE_KINDS and not oc.endswith("-done"):
                colls.append((op.result_bytes * mult, kind, op.result[:48],
                              mult, cn[:48]))
            if not in_fused and oc in _BYTE_OPS:
                key = (cn[:48], oc)
                bytes_rows[key] = bytes_rows.get(key, 0.0) + \
                    op.result_bytes * mult
            if oc == "while":
                n = 1.0
                mt = _TRIP.search(op.rest)
                if mt:
                    n = float(mt.group(1))
                mb = _BODY.search(op.rest)
                mc = _COND.search(op.rest)
                if mb:
                    walk(mb.group(1), mult * n)
                if mc:
                    walk(mc.group(1), mult * (n + 1.0))
            elif oc in ("fusion", "call"):
                m = _CALLS.search(op.rest)
                if m and m.group(1) in comps:
                    walk(m.group(1), mult)
            elif oc == "conditional":
                names = _BRANCHES.search(op.rest)
                if names:
                    for nm in _OPERAND.findall(names.group(1)):
                        walk(nm, mult)
                else:
                    for m in _TFT.finditer(op.rest):
                        walk(m.group(1), mult)
        stack.pop()

    walk(entry, 1.0)
    colls.sort(reverse=True)
    byte_top = sorted(bytes_rows.items(), key=lambda kv: -kv[1])[:top]
    return colls[:top], byte_top
