"""Batched serving example: prefill a batch of prompts, then decode with
the sharded KV cache — across three architecture families (dense GQA,
attention-free SSM, hybrid RG-LRU) to show the cache abstraction.

    python examples/serve_batched.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))
import repro_bootstrap  # noqa: F401,E402  (adds src/ if repro isn't installed)

import jax
import jax.numpy as jnp

from repro.config import get_arch
from repro.data import synthetic
from repro.models import model


def serve(arch: str, batch=4, prompt=32, gen=16):
    cfg = get_arch(arch).reduced()
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompts = synthetic.eval_batch(cfg, 0, batch=batch, seq=prompt)
    cache = model.init_cache(cfg, batch, prompt + gen)
    step = jax.jit(
        lambda p, t, c, pos: model.decode_step(p, cfg, t, c, pos))

    t0 = time.time()
    logits = None
    for t in range(prompt):                      # prefill via decode steps
        logits, cache = step(params, prompts[:, t:t + 1], cache, t)
    tok = jnp.argmax(logits, -1)[:, None]
    toks = [tok]
    for t in range(prompt, prompt + gen - 1):    # decode
        logits, cache = step(params, tok, cache, t)
        tok = jnp.argmax(logits, -1)[:, None]
        toks.append(tok)
    dt = time.time() - t0
    out = jnp.concatenate(toks, 1)
    print(f"{arch:22s} [{cfg.family:6s}] {batch} seqs x {gen} new tokens "
          f"in {dt:.2f}s -> {out[0, :8].tolist()}")


if __name__ == "__main__":
    for arch in ("qwen2-7b", "mamba2-130m", "recurrentgemma-2b"):
        serve(arch)
