"""Unified solver API: declarative ``RunSpec`` -> ``solve`` -> ``RunResult``.

The paper's experiments (§6) are head-to-head sweeps of one algorithm
family — CentralVR-Sync/Async vs D-SVRG, D-SAGA, EASGD and SGD baselines —
parameterized by a few axes (table form, fetch discipline, topology,
speeds).  This module exposes that family *as data* instead of 11 drifting
``run_*`` keyword surfaces:

  * :class:`RunSpec` — a frozen, validated description of one run (algo,
    p, eta, rounds, backend, fetch, speeds, tau, seed, metric cadence).
    Every backend/fetch/speeds combination check lives in spec
    construction, so an invalid combination fails *before* any JAX work,
    with an error naming the offending spec field.
  * the algorithm **registry** — name -> driver + :class:`AlgoCaps`
    capability record (distributed? spmd program? async? accepts
    fetch/speeds/tau?).  New workloads are one registry entry, not a new
    bespoke driver signature.
  * :class:`RunResult` — the uniform return: rels trajectory, final
    iterate + full driver state, wall clock, trace-count stats, and the
    resolved spec for provenance (``RunResult.provenance()`` is what the
    benchmark artifacts embed).
  * :func:`solve` — runs a spec against a problem/config: acquires
    simulated host devices before the first jax op when
    ``backend="spmd"``, shards or merges the data to match the algorithm's
    topology, derives the RNG key from ``spec.seed``, and normalizes every
    driver's return tuple.

The ``run_*`` drivers keep their exact signatures and trajectories; they
now build a spec internally for validation (DESIGN.md §Solver API), so
existing call sites — and all vmap/spmd/host-loop trajectory pins — are
untouched.
"""
from __future__ import annotations

import dataclasses
import importlib
import time
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import numpy as np

from repro.core import runtime

__all__ = ["RunSpec", "RunResult", "AlgoCaps", "Algorithm", "REGISTRY",
           "algorithms", "runner", "solve"]


# ---------------------------------------------------------------------------
# Capability records + registry
# ---------------------------------------------------------------------------

class AlgoCaps(NamedTuple):
    """What a registry algorithm supports — the validation contract
    :class:`RunSpec` enforces at construction, pinned against observed
    driver behavior by ``tests/test_solver_api.py``."""

    distributed: bool          # runs on a ShardedProblem (p workers)?
    spmd_ok: bool              # has a backend="spmd" program?
    is_async: bool             # event-scheduled (vs bulk-synchronous)?
    accepts_fetch: bool = False   # fetch="instant"|"stale" discipline?
    accepts_speeds: bool = False  # heterogeneous-speed event schedule?
    accepts_tau: bool = False     # local-step count (inner loop length)?
    accepts_fused: bool = False   # fused vr_update kernel hot path?
    accepts_prox: bool = False    # composite objectives (prox= axis)?
    snapshots: Tuple[str, ...] = ()   # supported snapshot= anchors; the
                                  # table-based VR algorithms pin their
                                  # anchor to the running table ("last"
                                  # only), the SVRG family re-anchors per
                                  # round ("last" | "avg" | "rand")


class Algorithm(NamedTuple):
    name: str
    module: str                # dotted module of the public run_* driver
    func: str                  # driver attribute within ``module``
    caps: AlgoCaps
    call: Callable             # (spec, problem, eta, key, mesh) ->
                               #   (state, x, rels, grad_evals | None)
    doc: str


REGISTRY: dict[str, Algorithm] = {}


def register(name: str, module: str, func: str, caps: AlgoCaps,
             call: Callable, doc: str) -> None:
    if name in REGISTRY:
        raise ValueError(f"algorithm {name!r} already registered")
    REGISTRY[name] = Algorithm(name, module, func, caps, call, doc)


def algorithms() -> Tuple[str, ...]:
    """Registered algorithm names, in registration (paper) order."""
    return tuple(REGISTRY)


def runner(name: str) -> Callable:
    """Resolve a registry entry to its public ``run_*`` driver."""
    entry = REGISTRY[name]
    return getattr(importlib.import_module(entry.module), entry.func)


# ---------------------------------------------------------------------------
# RunSpec — declarative, frozen, validated at construction
# ---------------------------------------------------------------------------

_SAMPLINGS = ("permutation", "uniform", "sparse")
_DECAY_ALGOS = ("sgd", "dist_sgd", "easgd")
_SNAPSHOTS = ("last", "avg", "rand")


@dataclass(frozen=True)
class RunSpec:
    """One solver run, as data.

    Fields:
      algo          registry name (see :func:`algorithms`)
      p             worker count (must be 1 for single-worker algorithms)
      eta           step size; None -> ``convex.auto_eta`` on the (merged)
                    problem at solve time
      rounds        communication rounds (epochs for the single-worker
                    algorithms; ``spec.epochs`` is an alias)
      backend       "vmap" (stacked single-device simulation, default) or
                    "spmd" (one worker per mesh device, DESIGN.md §2)
      fetch         D-SAGA fetch discipline "instant"|"stale"; None
                    resolves to the driver default ("stale" under spmd,
                    else "instant")
      speeds        per-worker relative speeds for the async event
                    schedule (len p); None -> round-robin
      tau           local steps per event/round where the algorithm takes
                    them (D-SVRG/D-SAGA/dist-SGD/EASGD; SVRG's inner loop);
                    None -> the driver's documented default
      seed          PRNGKey seed used by :func:`solve` when no explicit
                    key is passed
      metric_every  metric cadence: keep every k-th round's rel-grad-norm
                    (plus the final round) in ``RunResult.rels``.  The
                    drivers still compute the metric on device each round
                    inside their jitted scan; this controls what the
                    result records.
      sampling      CentralVR sampling mode ("permutation"|"uniform",
                    Algorithm 1 only); "sparse" routes Algorithm 1
                    through the lazy CSR driver (``prox/lazy.py``) —
                    per-coordinate just-in-time catch-up of the skipped
                    ``eta*gbar`` (+ L1 prox) updates
      prox          composite objective: apply ``prox_{eta*g}`` at every
                    update site (``"l1:0.01"``, ``"elasticnet:a:b"``,
                    ``"box:lo:hi"``, ``"group_l2:lam:size"`` — see
                    ``repro.prox.operators``).  VR family only; stored
                    normalized (params resolved) so asdict round-trips.
                    ``fused=True`` + a non-elementwise prox (group_l2)
                    is refused here, pre-JAX; ``fused="auto"`` falls
                    back to the unfused oracle path instead
      snapshot      VR anchor strategy: "last" (default — the anchor the
                    table algorithms maintain implicitly), "avg"/"rand"
                    re-anchor the SVRG family's round snapshot at the
                    inner-iterate average / a uniformly drawn inner
                    iterate (svrg, dsvrg only; refused with fused=True,
                    whose kernel path anchors at the last iterate)
      decay         step-size decay for the SGD-family baselines
      fused         route the VR inner loop through the Pallas
                    ``vr_update`` kernel (DESIGN.md §Fused kernels
                    hot-path): False (unfused oracle, default), True
                    (force; interpret mode off-TPU), or "auto" (fused
                    iff a compiled Pallas backend is present)
      topology      "local" (one process, default) or "process" (a
                    ``jax.distributed`` process mesh — the run must be
                    launched through ``repro.launch.distributed``;
                    CentralVR-Sync/Async only, DESIGN.md §Multi-host &
                    elasticity)
      elastic       tolerate worker dropout/rejoin at wave boundaries
                    (CentralVR-Async only): under topology="process" the
                    heartbeat/membership protocol runs at every round
                    boundary; under topology="local" the run replays a
                    deterministic ``membership=`` plan passed to
                    :func:`solve`

    All cross-field validation happens here: asking for an impossible
    combination (spmd on a serial algorithm, speeds on a synchronous one,
    fetch="instant" under spmd, elastic on a synchronous algorithm, ...)
    raises at construction with the offending field named, before any JAX
    work.
    """

    algo: str
    p: int = 1
    eta: Optional[float] = None
    rounds: int = 10
    backend: str = "vmap"
    fetch: Optional[str] = None
    speeds: Optional[Tuple[float, ...]] = None
    tau: Optional[int] = None
    seed: int = 0
    metric_every: int = 1
    sampling: str = "permutation"
    decay: float = 0.0
    fused: Any = False
    topology: str = "local"
    elastic: bool = False
    prox: Optional[str] = None
    snapshot: Optional[str] = None

    def __post_init__(self):
        if self.algo not in REGISTRY:
            raise ValueError(
                f"RunSpec.algo: unknown algorithm {self.algo!r}; registry "
                f"has {', '.join(REGISTRY)}")
        caps = REGISTRY[self.algo].caps
        _set = lambda k, v: object.__setattr__(self, k, v)  # noqa: E731

        # normalize scalar fields so asdict() round-trips exactly
        _set("p", int(self.p))
        _set("rounds", int(self.rounds))
        _set("seed", int(self.seed))
        _set("metric_every", int(self.metric_every))
        if self.eta is not None:
            _set("eta", float(self.eta))
        if self.tau is not None:
            _set("tau", int(self.tau))
        _set("decay", float(self.decay))

        if self.p < 1:
            raise ValueError(f"RunSpec.p: need at least 1 worker, got "
                             f"{self.p}")
        if not caps.distributed and self.p != 1:
            raise ValueError(
                f"RunSpec.p: algorithm {self.algo!r} is single-worker; "
                f"got p={self.p} (use the distributed variants for p>1)")
        if self.rounds < 1:
            raise ValueError(f"RunSpec.rounds: need >= 1, got {self.rounds}")
        if self.metric_every < 1:
            raise ValueError(
                f"RunSpec.metric_every: need >= 1, got {self.metric_every}")
        if self.eta is not None and not self.eta > 0.0:
            raise ValueError(f"RunSpec.eta: need > 0, got {self.eta}")
        if self.tau is not None and self.tau < 1:
            raise ValueError(f"RunSpec.tau: need >= 1, got {self.tau}")

        # fetch discipline (resolved BEFORE the backend check: whether an
        # spmd program exists for D-SAGA depends on the discipline)
        if self.fetch is not None and not caps.accepts_fetch:
            raise ValueError(
                f"RunSpec.fetch: algorithm {self.algo!r} has a single "
                "fetch discipline; only D-SAGA exposes fetch=")
        if caps.accepts_fetch:
            if self.fetch is None:
                _set("fetch",
                     "stale" if self.backend == "spmd" else "instant")
            if self.fetch not in ("instant", "stale"):
                raise ValueError(
                    f"RunSpec.fetch: unknown fetch {self.fetch!r}: "
                    "expected 'instant' or 'stale'")

        # backend — reuse check_backend so the error contracts ("unknown
        # backend", "event-serial") stay the single spelling everywhere
        from repro.core.distributed import check_backend
        try:
            check_backend(self.backend)
        except ValueError as e:
            raise ValueError(f"RunSpec.backend: {e}") from None
        if self.backend == "spmd":
            if not caps.spmd_ok:
                raise NotImplementedError(
                    f"RunSpec.backend: algorithm {self.algo!r} has no SPMD "
                    "program (single-device driver); use backend='vmap'")
            if caps.accepts_fetch and self.fetch == "instant":
                try:
                    check_backend(
                        "spmd", spmd_ok=False,
                        algo=f"{self.algo} with fetch='instant'")
                except NotImplementedError as e:
                    raise NotImplementedError(
                        f"RunSpec.backend: {e}") from None

        # speeds — async event schedules only
        if self.speeds is not None:
            if not caps.accepts_speeds:
                raise ValueError(
                    f"RunSpec.speeds: algorithm {self.algo!r} is "
                    "synchronous — per-worker speeds only weight the "
                    "asynchronous event schedules (centralvr_async, dsaga)")
            speeds = tuple(float(s) for s in self.speeds)
            if len(speeds) != self.p:
                raise ValueError(
                    f"RunSpec.speeds: need one entry per worker "
                    f"(p={self.p}), got {len(speeds)}")
            if any(s <= 0.0 for s in speeds):
                raise ValueError("RunSpec.speeds: speeds must be > 0, got "
                                 f"{speeds}")
            _set("speeds", speeds)

        if self.tau is not None and not caps.accepts_tau:
            raise ValueError(
                f"RunSpec.tau: algorithm {self.algo!r} has no local-step "
                "count (its inner loop is a full epoch)")
        if self.sampling not in _SAMPLINGS:
            raise ValueError(
                f"RunSpec.sampling: unknown sampling {self.sampling!r}: "
                f"expected one of {_SAMPLINGS}")
        if self.sampling != "permutation" and self.algo != "centralvr":
            raise ValueError(
                "RunSpec.sampling: only 'centralvr' (Algorithm 1) exposes "
                "the sampling mode")

        # composite objective (prox=) — parse eagerly so a bad operator
        # string fails here, pre-JAX, naming the field
        if self.prox is not None:
            from repro.prox import operators as proxops
            if not caps.accepts_prox:
                raise ValueError(
                    f"RunSpec.prox: algorithm {self.algo!r} has no VR "
                    "update site to compose a prox into; only the VR "
                    "family (centralvr, centralvr_sync, centralvr_async, "
                    "dsvrg, dsaga, svrg, saga) exposes prox=")
            try:
                _set("prox", proxops.canonical(self.prox))
            except ValueError as e:
                raise ValueError(f"RunSpec.prox: {e}") from None
            if self.fused is True and not proxops.is_elementwise(self.prox):
                raise ValueError(
                    f"RunSpec.fused: prox "
                    f"{proxops.parse(self.prox).name!r} couples "
                    "coordinates, but the fused vr_update epilogue is "
                    "elementwise; use fused=False (or 'auto', which falls "
                    "back to the unfused oracle)")

        # snapshot anchor strategy — capability-gated per algorithm
        if self.snapshot is not None:
            if self.snapshot not in _SNAPSHOTS:
                raise ValueError(
                    f"RunSpec.snapshot: unknown snapshot "
                    f"{self.snapshot!r}: expected one of {_SNAPSHOTS}")
            if not caps.snapshots:
                raise ValueError(
                    f"RunSpec.snapshot: algorithm {self.algo!r} has no VR "
                    "anchor to re-snapshot; only the VR family exposes "
                    "snapshot=")
            if self.snapshot not in caps.snapshots:
                raise ValueError(
                    f"RunSpec.snapshot: algorithm {self.algo!r} supports "
                    f"snapshot in {caps.snapshots}, got {self.snapshot!r} "
                    "(the table-based algorithms maintain their anchor "
                    "incrementally — 'last' only)")
            if self.fused and self.snapshot != "last":
                raise ValueError(
                    "RunSpec.fused: the fused SVRG kernel path anchors at "
                    f"the last iterate; snapshot={self.snapshot!r} "
                    "requires fused=False")

        # sparse lazy driver (Algorithm 1 only; sampling rule above)
        if self.sampling == "sparse":
            if self.backend != "vmap":
                raise ValueError(
                    "RunSpec.backend: sampling='sparse' is the lazy "
                    "host-CSR driver (prox/lazy.py); it has no spmd "
                    "program — use backend='vmap'")
            if self.fused:
                raise ValueError(
                    "RunSpec.fused: sampling='sparse' already skips the "
                    "dense update (lazy catch-up); fused= does not apply")
            if self.prox is not None:
                from repro.prox import operators as proxops
                if proxops.parse(self.prox).name != "l1":
                    raise ValueError(
                        "RunSpec.prox: the lazy sparse driver composes "
                        "skipped steps in closed form only for the "
                        "separable soft-threshold; sampling='sparse' "
                        f"supports prox='l1:...', got {self.prox!r}")
        if self.decay != 0.0 and self.algo not in _DECAY_ALGOS:
            raise ValueError(
                f"RunSpec.decay: step-size decay only applies to "
                f"{_DECAY_ALGOS}, not {self.algo!r}")
        if self.fused is None:
            _set("fused", False)
        if self.fused not in (True, False, "auto"):
            raise ValueError(
                f"RunSpec.fused: expected True, False or 'auto', got "
                f"{self.fused!r}")
        if self.fused and not caps.accepts_fused:
            raise ValueError(
                f"RunSpec.fused: algorithm {self.algo!r} has no VR inner "
                "loop to fuse; only the VR family (centralvr, "
                "centralvr_sync, centralvr_async, dsvrg, dsaga, svrg, "
                "saga) exposes fused=")

        # multi-host topology + elasticity (DESIGN.md §Multi-host &
        # elasticity) — validated before any JAX work, like everything
        # else here, so a bad launch fails in the parent, not the fleet
        if self.topology not in ("local", "process"):
            raise ValueError(
                f"RunSpec.topology: unknown topology {self.topology!r}: "
                "expected 'local' or 'process'")
        _set("elastic", bool(self.elastic))
        if self.topology == "process":
            if self.algo not in ("centralvr_sync", "centralvr_async"):
                raise ValueError(
                    f"RunSpec.topology: algorithm {self.algo!r} has no "
                    "process-mesh program; topology='process' supports "
                    "centralvr_sync and centralvr_async")
            if self.backend != "vmap":
                raise ValueError(
                    "RunSpec.backend: topology='process' runs each "
                    "process's workers as local jitted programs; set "
                    "backend='vmap' (the per-process spmd tier is the "
                    "accelerator path, DESIGN.md §Multi-host & elasticity)")
            if self.fused:
                raise ValueError(
                    "RunSpec.fused: the process-mesh engines pin "
                    "bit-exactness against the unfused event-serial "
                    "reference; fused= is not supported under "
                    "topology='process'")
            if self.prox is not None:
                raise ValueError(
                    "RunSpec.prox: the process-mesh engines run the "
                    "smooth objective only; prox= is not supported under "
                    "topology='process'")
        if self.elastic and self.algo != "centralvr_async":
            raise ValueError(
                f"RunSpec.elastic: only centralvr_async has wave "
                f"boundaries to repartition at; got algo={self.algo!r}")
        if self.elastic and self.prox is not None:
            raise ValueError(
                "RunSpec.prox: the elastic event-serial reference runs "
                "the smooth objective only; prox= is not supported with "
                "elastic=True")

    @property
    def epochs(self) -> int:
        """Alias: the single-worker algorithms call rounds 'epochs'."""
        return self.rounds

    @property
    def caps(self) -> AlgoCaps:
        return REGISTRY[self.algo].caps


# ---------------------------------------------------------------------------
# RunResult — the uniform return
# ---------------------------------------------------------------------------

@dataclass
class RunResult:
    """What every algorithm returns through :func:`solve`.

    ``spec`` is the *resolved* spec (eta filled in, fetch defaulted) — the
    exact configuration that produced the run, suitable for artifact
    provenance.  ``wall_s`` is the blocking wall clock of the driver call
    (the first call of a fresh process includes jit compilation);
    ``traces`` is the delta of ``runtime.TRACES`` over the call — 0 on a
    jit cache hit, the exact retrace/compile probe of DESIGN.md §3.
    ``comms`` is the analytical bytes-per-collective model of the run
    (``obs/comms.py``, roofline result-shape convention) and
    ``staleness`` the fetch-staleness/wave-utilization record of the
    deterministic event schedule (``obs/staleness.py``; None for the
    bulk-synchronous algorithms) — both derived host-side from the spec
    and shapes, so every provenance row carries them whatever backend ran.
    """

    spec: RunSpec
    rels: np.ndarray           # recorded rel-grad-norm trajectory
    x: np.ndarray              # final iterate (d,)
    state: Any                 # the driver's full final state pytree
    wall_s: float
    traces: dict
    grad_evals: Optional[np.ndarray] = None
    comms: Optional[dict] = None
    staleness: Optional[dict] = None
    transitions: Optional[list] = None   # elastic membership changes

    @property
    def final_rel(self) -> float:
        return float(self.rels[-1])

    def provenance(self, tail: int = 8) -> dict:
        """JSON-able record of exactly what configuration produced this
        result — embedded alongside each benchmark-artifact row.  The row
        shape is golden (``obs/schema.py: PROVENANCE_KEYS``); extend both
        together."""
        from repro.obs.recorder import SCHEMA_VERSION

        rels = np.asarray(self.rels, dtype=float)
        return {
            "spec": dataclasses.asdict(self.spec),
            "final_rel": float(rels[-1]) if rels.size else None,
            "rels_tail": [float(v) for v in rels[-tail:]],
            "rounds_recorded": int(rels.size),
            "wall_s": float(self.wall_s),
            "traces": dict(self.traces),
            "comms": dict(self.comms) if self.comms else None,
            "staleness": dict(self.staleness) if self.staleness else None,
            "schema_v": SCHEMA_VERSION,
        }


# ---------------------------------------------------------------------------
# solve
# ---------------------------------------------------------------------------

def _coerce_problem(spec: RunSpec, problem):
    """Match the data topology to the algorithm: shard a flat Problem for
    the distributed algorithms, merge a ShardedProblem for the
    single-worker ones, or build either from a ConvexConfig (dataset keyed
    by ``cfg.seed``, so the same config always yields the same data)."""
    import jax

    from repro.config import ConvexConfig
    from repro.core import convex, distributed

    caps = REGISTRY[spec.algo].caps
    if isinstance(problem, ConvexConfig):
        if caps.distributed:
            # cfg.workers left at its default (1) means "let the spec
            # decide"; an explicit conflicting value is an error, same as
            # the ShardedProblem mismatch below
            if problem.workers not in (1, spec.p):
                raise ValueError(
                    f"RunSpec.p: spec says p={spec.p} but the ConvexConfig "
                    f"sets workers={problem.workers}; make them agree (or "
                    "leave cfg.workers at its default)")
            cfg = dataclasses.replace(problem, workers=spec.p)
            return distributed.make_distributed(
                jax.random.PRNGKey(cfg.seed), cfg)
        if problem.workers > 1:
            # single-worker algorithm on a multi-worker config: run on the
            # merged total dataset — the same data the distributed
            # algorithms see, so baseline comparisons stay exact
            return distributed.make_distributed(
                jax.random.PRNGKey(problem.seed), problem).merged()
        return convex.make_problem(jax.random.PRNGKey(problem.seed), problem)
    if isinstance(problem, distributed.ShardedProblem):
        if not caps.distributed:
            return problem.merged()
        if problem.p != spec.p:
            raise ValueError(
                f"RunSpec.p: spec says p={spec.p} but the ShardedProblem "
                f"has p={problem.p}")
        return problem
    if isinstance(problem, convex.Problem):
        if caps.distributed:
            return distributed.shard_problem(problem, spec.p)
        return problem
    raise TypeError(
        f"solve() takes a ConvexConfig, Problem, or ShardedProblem; got "
        f"{type(problem).__name__}")


def solve(spec: RunSpec, problem, *, key=None, mesh=None,
          membership=None) -> RunResult:
    """Run ``spec`` against ``problem`` (a ``ConvexConfig``, ``Problem``,
    or ``ShardedProblem``) and return the uniform :class:`RunResult`.

    Uniform handling across every registry algorithm:

      * ``backend="spmd"``: simulated host devices are forced *before*
        the first jax operation (``spmd.force_host_devices``; a fresh
        process acquires them, an already-initialized one validates the
        count) and the driver gets one worker per device of ``mesh``
        (default: the first p devices);
      * data sharding/merging per the algorithm's topology
        (:func:`_coerce_problem`);
      * ``eta=None`` resolves to ``convex.auto_eta`` on the merged
        problem;
      * the RNG key derives from ``spec.seed`` unless ``key`` overrides
        it; all drivers precompute their draws on the host (DESIGN.md §2);
      * the driver's return tuple is normalized to
        (state, final iterate, rels, grad_evals).

    ``topology="process"`` routes to the process-mesh engines
    (``core/procmesh.py``; requires a ``repro.launch.distributed`` world).
    ``elastic=True`` under topology="local" replays the deterministic
    ``membership=`` plan (a ``core.elastic.PlannedMembership``) through
    ``run_async_elastic`` — the event-serial elastic reference.
    """
    entry = REGISTRY[spec.algo]
    if membership is not None and not (spec.elastic
                                       and spec.topology == "local"):
        raise ValueError(
            "solve(membership=...) is the deterministic dropout plan of a "
            "LOCAL elastic run; it needs spec.elastic=True and "
            "spec.topology='local' (process topology discovers membership "
            "through heartbeats)")
    if spec.backend == "spmd":
        from repro.core import spmd
        spmd.force_host_devices(max(spec.p, 1))

    import jax

    from repro.core import convex, distributed
    from repro.obs import recorder as obs_recorder

    with obs_recorder.span("solve/build", algo=spec.algo,
                           backend=spec.backend):
        problem = _coerce_problem(spec, problem)
        eta = spec.eta
        if eta is None:
            merged = (problem.merged()
                      if isinstance(problem, distributed.ShardedProblem)
                      else problem)
            eta = convex.auto_eta(merged)
        if key is None:
            key = jax.random.PRNGKey(spec.seed)

    with runtime.traces_delta() as traces:
        t0 = time.perf_counter()
        if spec.topology == "process":
            from repro.core import procmesh
            state, x, rels, transitions = procmesh.solve_process(
                spec, problem, eta, key)
            grad_evals = None
        elif spec.elastic:
            from repro.core import elastic as elasticmod
            eres = elasticmod.run_async_elastic(
                problem, eta=eta, rounds=spec.rounds, key=key,
                membership=membership, speeds=spec.speeds)
            state, x, rels = eres.state, eres.state.x_c, eres.rels
            transitions, grad_evals = eres.transitions, None
        else:
            state, x, rels, grad_evals = entry.call(spec, problem, eta, key,
                                                    mesh)
            transitions = None
        rels = jax.block_until_ready(rels)
        wall = time.perf_counter() - t0

    rels = np.asarray(rels)
    if grad_evals is not None:
        grad_evals = np.asarray(grad_evals)
    if spec.metric_every > 1 and rels.size:
        idx = np.arange(spec.metric_every - 1, rels.size, spec.metric_every)
        idx = np.unique(np.append(idx, rels.size - 1))
        rels = rels[idx]
        if grad_evals is not None:
            # keep the two trajectories aligned (rels[i] <-> grad_evals[i])
            grad_evals = grad_evals[idx]
    resolved = dataclasses.replace(spec, eta=float(eta))
    x = np.asarray(x)

    # comms/staleness accounting: host-side, derived from spec + shapes,
    # so it is cheap enough to compute for EVERY run (bench provenance
    # rows carry it with telemetry off)
    from repro.obs import comms as obs_comms
    from repro.obs import staleness as obs_staleness

    comms = obs_comms.comms_model(spec.algo, p=spec.p, d=int(x.shape[-1]),
                                  rounds=spec.rounds)
    staleness = None
    if entry.caps.is_async:
        staleness = obs_staleness.staleness_stats(
            runtime.event_schedule(spec.p, spec.rounds, spec.speeds), spec.p)

    res = RunResult(spec=resolved, rels=rels, x=x, state=state,
                    wall_s=wall, traces=traces, grad_evals=grad_evals,
                    comms=comms, staleness=staleness,
                    transitions=transitions)
    rec = obs_recorder.active()
    if rec is not None:
        rec.event("traces", **traces)
        rec.event("provenance", **res.provenance())
    return res


# ---------------------------------------------------------------------------
# Registry entries — the paper's algorithm family as data
# ---------------------------------------------------------------------------
# Each ``call`` adapter maps the uniform spec onto one driver's native
# keyword surface and normalizes its return tuple.  Driver modules are
# imported lazily: they import this module (inside their run_* bodies) for
# spec validation, and the registry must be importable first.

def _call_centralvr(spec, prob, eta, key, mesh):
    from repro.core import centralvr
    st, rels, evals = centralvr.run(prob, eta=eta, epochs=spec.rounds,
                                    key=key, sampling=spec.sampling,
                                    backend=spec.backend, mesh=mesh,
                                    fused=spec.fused, prox=spec.prox)
    return st, st.x, rels, evals


def _call_sync(spec, sp, eta, key, mesh):
    from repro.core import distributed
    st, rels = distributed.run_sync(sp, eta=eta, rounds=spec.rounds,
                                    key=key, backend=spec.backend, mesh=mesh,
                                    fused=spec.fused, prox=spec.prox)
    return st, st.x, rels, None


def _call_async(spec, sp, eta, key, mesh):
    from repro.core import distributed
    st, rels = distributed.run_async(sp, eta=eta, rounds=spec.rounds,
                                     key=key, speeds=spec.speeds,
                                     backend=spec.backend, mesh=mesh,
                                     fused=spec.fused, prox=spec.prox)
    return st, st.x_c, rels, None


def _call_dsvrg(spec, sp, eta, key, mesh):
    from repro.core import distributed
    x, rels = distributed.run_dsvrg(sp, eta=eta, rounds=spec.rounds,
                                    key=key, tau=spec.tau or 0,
                                    backend=spec.backend, mesh=mesh,
                                    fused=spec.fused, prox=spec.prox,
                                    snapshot=spec.snapshot or "last")
    return x, x, rels, None


def _call_dsaga(spec, sp, eta, key, mesh):
    from repro.core import distributed
    st, rels = distributed.run_dsaga(sp, eta=eta, rounds=spec.rounds,
                                     key=key, tau=spec.tau or 100,
                                     fetch=spec.fetch, speeds=spec.speeds,
                                     backend=spec.backend, mesh=mesh,
                                     fused=spec.fused, prox=spec.prox)
    return st, st.x_c, rels, None


def _call_sgd(spec, prob, eta, key, mesh):
    from repro.core import baselines
    x, rels = baselines.run_sgd(prob, eta=eta, epochs=spec.rounds, key=key,
                                decay=spec.decay)
    return x, x, rels, None


def _call_svrg(spec, prob, eta, key, mesh):
    from repro.core import baselines
    x, rels = baselines.run_svrg(prob, eta=eta, epochs=spec.rounds, key=key,
                                 inner=spec.tau or 0, fused=spec.fused,
                                 prox=spec.prox,
                                 snapshot=spec.snapshot or "last")
    return x, x, rels, None


def _call_saga(spec, prob, eta, key, mesh):
    from repro.core import baselines
    x, rels = baselines.run_saga(prob, eta=eta, epochs=spec.rounds, key=key,
                                 fused=spec.fused, prox=spec.prox)
    return x, x, rels, None


def _call_dist_sgd(spec, sp, eta, key, mesh):
    from repro.core import baselines
    x, rels = baselines.run_dist_sgd(sp, eta=eta, rounds=spec.rounds,
                                     key=key, tau=spec.tau or 0,
                                     decay=spec.decay,
                                     backend=spec.backend, mesh=mesh)
    return x, x, rels, None


def _call_easgd(spec, sp, eta, key, mesh):
    from repro.core import baselines
    xc, rels = baselines.run_easgd(sp, eta=eta, rounds=spec.rounds, key=key,
                                   tau=spec.tau or 16, decay=spec.decay,
                                   backend=spec.backend, mesh=mesh)
    return xc, xc, rels, None


def _call_ps_svrg(spec, sp, eta, key, mesh):
    from repro.core import baselines
    x, rels = baselines.run_ps_svrg(sp, eta=eta, rounds=spec.rounds,
                                    key=key, backend=spec.backend, mesh=mesh)
    return x, x, rels, None


register("centralvr", "repro.core.centralvr", "run",
         AlgoCaps(distributed=False, spmd_ok=True, is_async=False,
                  accepts_fused=True, accepts_prox=True,
                  snapshots=("last",)),
         _call_centralvr,
         "CentralVR, single worker (Algorithm 1); spmd = run on the mesh")
register("centralvr_sync", "repro.core.distributed", "run_sync",
         AlgoCaps(distributed=True, spmd_ok=True, is_async=False,
                  accepts_fused=True, accepts_prox=True,
                  snapshots=("last",)),
         _call_sync, "CentralVR-Sync (Algorithm 2)")
register("centralvr_async", "repro.core.distributed", "run_async",
         AlgoCaps(distributed=True, spmd_ok=True, is_async=True,
                  accepts_speeds=True, accepts_fused=True,
                  accepts_prox=True, snapshots=("last",)),
         _call_async,
         "CentralVR-Async (Algorithm 3), deterministic event schedule")
register("dsvrg", "repro.core.distributed", "run_dsvrg",
         AlgoCaps(distributed=True, spmd_ok=True, is_async=False,
                  accepts_tau=True, accepts_fused=True, accepts_prox=True,
                  snapshots=("last", "avg", "rand")),
         _call_dsvrg, "Distributed SVRG (Algorithm 4)")
register("dsaga", "repro.core.distributed", "run_dsaga",
         AlgoCaps(distributed=True, spmd_ok=True, is_async=True,
                  accepts_fetch=True, accepts_speeds=True,
                  accepts_tau=True, accepts_fused=True, accepts_prox=True,
                  snapshots=("last",)),
         _call_dsaga,
         "Distributed SAGA (Algorithm 5); spmd requires fetch='stale'")
register("sgd", "repro.core.baselines", "run_sgd",
         AlgoCaps(distributed=False, spmd_ok=False, is_async=False),
         _call_sgd, "plain SGD, permutation sampling (Fig. 1 baseline)")
register("svrg", "repro.core.baselines", "run_svrg",
         AlgoCaps(distributed=False, spmd_ok=False, is_async=False,
                  accepts_tau=True, accepts_fused=True, accepts_prox=True,
                  snapshots=("last", "avg", "rand")),
         _call_svrg, "SVRG [17]; tau = inner-loop length (default n)")
register("saga", "repro.core.baselines", "run_saga",
         AlgoCaps(distributed=False, spmd_ok=False, is_async=False,
                  accepts_fused=True, accepts_prox=True,
                  snapshots=("last",)),
         _call_saga, "SAGA [12] (Fig. 1 baseline)")
register("dist_sgd", "repro.core.baselines", "run_dist_sgd",
         AlgoCaps(distributed=True, spmd_ok=True, is_async=False,
                  accepts_tau=True),
         _call_dist_sgd, "distributed SGD with periodic averaging")
register("easgd", "repro.core.baselines", "run_easgd",
         AlgoCaps(distributed=True, spmd_ok=True, is_async=False,
                  accepts_tau=True),
         _call_easgd, "elastic averaging SGD [36]")
register("ps_svrg", "repro.core.baselines", "run_ps_svrg",
         AlgoCaps(distributed=True, spmd_ok=True, is_async=False),
         _call_ps_svrg, "parameter-server SVRG [29]")
