"""Continuous-batching serving engine.

The engine runs a fixed-width decode batch and re-schedules BETWEEN
decode steps: finished sequences retire (their blocks return to the
pool), waiting requests admit into freed lanes via chunked prefill, and
the decode program then advances every live lane one token.  Dead lanes
ride along as masked padding — their compute is wasted but their KV
writes are provably invisible (trash block / dropped), so each request's
token stream is bit-identical to serving it alone.

Scheduling is clocked by the decode-step counter (see serve/trace.py).
One iteration:

  1. ``clock`` advances to the next arrival if the batch is empty.
  2. Arrived requests join the FIFO ready queue.
  3. Admission (FIFO, no skipping — keeps latency fair and tests simple):
     a request admits iff a lane is free AND the allocator can RESERVE
     its worst-case block count ``ceil((prompt+max_new-1)/block_size)``.
     Reservation-on-admit + lazy allocation means pool memory tracks live
     tokens while a running sequence can never starve mid-decode.
  4. Admitted prompts prefill in bucketed chunks (one jitted launch per
     chunk, C tokens per launch); the final chunk's logits yield the
     first generated token.
  5. Lanes whose block for the NEXT write position is unallocated grab
     one (lazy allocation), then one decode step runs for all lanes.
  6. Lanes reaching ``max_new`` retire; their blocks are freed and their
     table rows zeroed (back to the trash marker).

Everything host-side is numpy; device work is the two donated programs
from serve/runtime.py.  Greedy (argmax) decoding only.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax

from repro import obs
from repro.config import ModelConfig
from repro.models import model
from repro.serve import runtime
from repro.serve.cache import BlockAllocator, Geometry
from repro.serve.trace import Request, prompt_tokens

DEFAULT_CHUNK_BUCKETS = (16, 64, 128)


@dataclass
class RequestResult:
    rid: int
    prompt_len: int
    max_new: int
    tokens: List[int] = field(default_factory=list)
    arrival_step: int = 0
    admit_step: int = -1
    finish_step: int = -1
    t_seen: float = 0.0
    t_first: float = 0.0
    t_finish: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_finish - self.t_seen

    @property
    def ttft_s(self) -> float:
        return self.t_first - self.t_seen


@dataclass
class ServeReport:
    results: List[RequestResult]
    steps: int = 0
    prefill_tokens: int = 0
    prefill_s: float = 0.0
    decode_tokens: int = 0
    decode_s: float = 0.0
    wall_s: float = 0.0
    blocks_reused: int = 0
    compile_s: Dict[str, float] = field(default_factory=dict)

    @property
    def prefill_tok_s(self) -> float:
        return self.prefill_tokens / max(self.prefill_s, 1e-12)

    @property
    def decode_tok_s(self) -> float:
        return self.decode_tokens / max(self.decode_s, 1e-12)

    def latency_percentiles(self):
        lats = [r.latency_s for r in self.results]
        return (float(np.percentile(lats, 50)),
                float(np.percentile(lats, 95))) if lats else (0.0, 0.0)

    def summary(self) -> dict:
        p50, p95 = self.latency_percentiles()
        return {"requests": len(self.results), "steps": self.steps,
                "prefill_tokens": self.prefill_tokens,
                "prefill_tok_s": self.prefill_tok_s,
                "decode_tokens": self.decode_tokens,
                "decode_tok_s": self.decode_tok_s,
                "latency_p50_s": p50, "latency_p95_s": p95,
                "wall_s": self.wall_s, "blocks_reused": self.blocks_reused}


@dataclass
class _Lane:
    req: Request
    result: RequestResult
    blocks: List[int]
    generated: int = 1          # first token comes from the prefill logits


class ServeEngine:
    """Continuous-batching engine over a paged (or dense-oracle) cache.

    ``width``: decode lanes; ``max_seq_len`` rounds up to a whole number
    of blocks and bounds prompt+max_new; ``num_blocks``: pool size incl.
    trash (default: enough for every lane at full length — the
    interesting schedules use less); ``mesh``: optional ("data","model")
    mesh for tensor-parallel decode (params sharded by sharding/specs.py
    TP rules, cache + token streams replicated).
    """

    def __init__(self, cfg: ModelConfig, params=None, *, width: int = 4,
                 block_size: int = 16, max_seq_len: int = 256,
                 num_blocks: int = 0,
                 chunk_buckets: Sequence[int] = DEFAULT_CHUNK_BUCKETS,
                 kv_cache: str = "paged", mesh=None, seed: int = 0):
        runtime.check_arch(cfg)
        self.cfg = cfg
        blocks_per_seq = -(-max_seq_len // block_size)
        if num_blocks <= 0:
            num_blocks = 1 + width * blocks_per_seq
        self.geo = Geometry(width=width, block_size=block_size,
                            blocks_per_seq=blocks_per_seq,
                            num_blocks=num_blocks, kv_cache=kv_cache)
        self.buckets = tuple(sorted(set(int(b) for b in chunk_buckets)))
        if not self.buckets:
            raise ValueError("chunk_buckets must be non-empty")
        if params is None:
            params = model.init_params(cfg, jax.random.PRNGKey(seed))
        self.mesh = mesh
        if mesh is not None:
            params, place = self._place_tp(params, mesh)
        self.params = params
        self.cache = runtime.init_cache(cfg, self.geo)
        if mesh is not None:
            self.cache = place(self.cache)
        self.allocator = BlockAllocator(self.geo.num_blocks)
        self._decode, self._prefill = runtime.build_programs(cfg, self.geo)
        # host-side lane state
        w = self.geo.width
        self.lanes: List[Optional[_Lane]] = [None] * w
        self.tokens = np.zeros(w, np.int32)       # next decode input
        self.lens = np.zeros(w, np.int32)         # next write position
        self.alive = np.zeros(w, bool)
        self.tables = np.zeros((w, self.geo.blocks_per_seq), np.int32)
        self.compile_s: Dict[str, float] = {}
        self._last_prefill_s = 0.0

    def _place_tp(self, params, mesh):
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.launch.mesh import mesh_axis_sizes
        from repro.sharding import specs as shspecs
        sizes = mesh_axis_sizes(mesh)
        pspecs = shspecs.tree_specs(params, self.cfg, fsdp=False,
                                    axis_sizes=sizes)
        params = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            params, pspecs)
        rep = NamedSharding(mesh, PartitionSpec())

        def place(tree):
            return jax.tree_util.tree_map(
                lambda x: jax.device_put(x, rep), tree)
        return params, place

    # -- warmup ------------------------------------------------------------

    def warmup(self) -> Dict[str, float]:
        """Compile every program on the REAL (donated) cache with all-dead
        lanes / zero-valid chunks — no throwaway cache allocation.  Returns
        per-program compile+run seconds (cold)."""
        zero_row = np.zeros(self.geo.blocks_per_seq, np.int32)
        for c in self.buckets:
            t0 = time.perf_counter()
            logits, self.cache = self._prefill(
                self.params, self.cache, np.zeros(c, np.int32), 0, 0, 0,
                zero_row)
            jax.block_until_ready(logits)
            self.compile_s[f"prefill_c{c}"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        logits, self.cache = self._decode(
            self.params, self.cache, self.tokens, self.lens, self.alive,
            self.tables)
        jax.block_until_ready(logits)
        self.compile_s["decode"] = time.perf_counter() - t0
        return dict(self.compile_s)

    # -- scheduling --------------------------------------------------------

    def _pick_bucket(self, remaining: int) -> int:
        for b in self.buckets:
            if b >= remaining:
                return b
        return self.buckets[-1]

    def _admit(self, req: Request, lane: int, clock: int,
               result: RequestResult) -> None:
        geo, alloc = self.geo, self.allocator
        need = geo.blocks_for(req.total_len)
        alloc.reserve(lane, need)
        rec = obs.active()
        if rec:
            rec.event("serve_admit", rid=req.rid, lane=lane, step=clock,
                      prompt_len=req.prompt_len, max_new=req.max_new,
                      blocks_reserved=need)
        toks = prompt_tokens(req, self.cfg.vocab_size)
        row = np.zeros(geo.blocks_per_seq, np.int32)
        n_prompt_blocks = (req.prompt_len - 1) // geo.block_size + 1
        blocks = [alloc.alloc(lane) for _ in range(n_prompt_blocks)]
        row[:n_prompt_blocks] = blocks
        pos = 0
        t0 = time.perf_counter()
        with obs.span("serve/prefill", rid=req.rid, tokens=req.prompt_len):
            while pos < req.prompt_len:
                rem = req.prompt_len - pos
                c = self._pick_bucket(rem)
                n_valid = min(c, rem)
                chunk = np.zeros(c, np.int32)
                chunk[:n_valid] = toks[pos:pos + n_valid]
                logits, self.cache = self._prefill(
                    self.params, self.cache, chunk, pos, n_valid, lane, row)
                pos += n_valid
            first = int(np.asarray(logits).argmax())
        self._last_prefill_s = time.perf_counter() - t0
        self.lanes[lane] = _Lane(req=req, result=result, blocks=blocks)
        self.tokens[lane] = first
        self.lens[lane] = req.prompt_len
        self.alive[lane] = True
        self.tables[lane] = row
        result.admit_step = clock
        result.t_first = time.time()
        result.tokens.append(first)

    def _retire(self, lane: int, clock: int) -> None:
        ln = self.lanes[lane]
        self.allocator.release(lane, ln.blocks)
        rec = obs.active()
        if rec:
            rec.event("serve_retire", rid=ln.req.rid, lane=lane, step=clock,
                      generated=ln.generated)
        ln.result.finish_step = clock
        ln.result.t_finish = time.time()
        self.lanes[lane] = None
        self.alive[lane] = False
        self.lens[lane] = 0
        self.tokens[lane] = 0
        self.tables[lane] = 0

    def _ensure_blocks(self) -> None:
        geo = self.geo
        for lane, ln in enumerate(self.lanes):
            if ln is None:
                continue
            blk_idx = int(self.lens[lane]) // geo.block_size
            if self.tables[lane, blk_idx] == 0:
                blk = self.allocator.alloc(lane)
                ln.blocks.append(blk)
                self.tables[lane, blk_idx] = blk

    # -- main loop ---------------------------------------------------------

    def run(self, reqs: Sequence[Request]) -> ServeReport:
        geo = self.geo
        for r in reqs:
            if r.total_len > geo.context:
                raise ValueError(
                    f"request {r.rid}: prompt+max_new={r.total_len} exceeds "
                    f"max servable length {geo.context}")
        if len({r.rid for r in reqs}) != len(reqs):
            raise ValueError("request ids must be unique")
        waiting = deque(sorted(reqs, key=lambda r: (r.arrival, r.rid)))
        ready: deque = deque()
        results = {r.rid: RequestResult(rid=r.rid, prompt_len=r.prompt_len,
                                        max_new=r.max_new,
                                        arrival_step=r.arrival)
                   for r in reqs}
        rep = ServeReport(results=[])
        clock = 0
        wall0 = time.perf_counter()
        rec = obs.active()
        with obs.span("serve/run", requests=len(reqs), width=geo.width,
                      kv_cache=geo.kv_cache):
            while True:
                while waiting and waiting[0].arrival <= clock:
                    r = waiting.popleft()
                    results[r.rid].t_seen = time.time()
                    ready.append(r)
                # FIFO admission into free lanes
                while ready:
                    free = [i for i, ln in enumerate(self.lanes)
                            if ln is None]
                    r = ready[0]
                    if not free or (self.allocator.available()
                                    < geo.blocks_for(r.total_len)):
                        break
                    ready.popleft()
                    self._admit(r, free[0], clock, results[r.rid])
                    rep.prefill_tokens += r.prompt_len
                    rep.prefill_s += self._last_prefill_s
                    if r.max_new == 1:          # done at prefill already
                        self.lanes[free[0]].generated = 1
                        self._retire(free[0], clock)
                if not self.alive.any():
                    if ready:
                        r = ready[0]
                        raise RuntimeError(
                            f"request {r.rid} needs "
                            f"{geo.blocks_for(r.total_len)} blocks but only "
                            f"{self.allocator.available()} can ever free up")
                    if waiting:
                        clock = waiting[0].arrival
                        continue
                    break
                self._ensure_blocks()
                t0 = time.perf_counter()
                logits, self.cache = self._decode(
                    self.params, self.cache, self.tokens, self.lens,
                    self.alive, self.tables)
                # host-side argmax: a device argmax would cost an extra
                # dispatch round-trip per step (~0.7ms on CPU, measured)
                nxt = np.argmax(np.asarray(logits), axis=-1)
                step_s = time.perf_counter() - t0
                rep.decode_s += step_s
                clock += 1
                rep.steps += 1
                n_live = int(self.alive.sum())
                rep.decode_tokens += n_live
                if rec:
                    rec.metric("serve/decode_live_lanes", step=clock,
                               value=float(n_live))
                for lane, ln in enumerate(self.lanes):
                    if ln is None:
                        continue
                    tok = int(nxt[lane])
                    ln.result.tokens.append(tok)
                    ln.generated += 1
                    self.tokens[lane] = tok
                    self.lens[lane] += 1
                    if ln.generated >= ln.req.max_new:
                        self._retire(lane, clock)
        rep.results = [results[r.rid] for r in
                       sorted(reqs, key=lambda q: q.rid)]
        rep.wall_s = time.perf_counter() - wall0
        rep.blocks_reused = self.allocator.reuse_count
        rep.compile_s = dict(self.compile_s)
        if rec:
            rec.event("serve_report", **{k: v for k, v in
                                         rep.summary().items()})
        return rep


def serve_trace(cfg: ModelConfig, reqs: Sequence[Request], *, params=None,
                warmup: bool = True, **kw) -> ServeReport:
    """One-call convenience: build an engine, warm it up, run the trace."""
    eng = ServeEngine(cfg, params, **kw)
    if warmup:
        eng.warmup()
    return eng.run(reqs)
