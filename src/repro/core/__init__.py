"""The paper's contribution: CentralVR and its distributed variants.

Modules:
  convex       -- the paper's experimental problems (GLM scalar-residual form)
  centralvr    -- Algorithm 1 (single worker)
  distributed  -- Algorithms 2-5 (Sync/Async CentralVR, D-SVRG, D-SAGA)
  baselines    -- SGD/SVRG/SAGA (sequential) + dist-SGD/EASGD/PS-SVRG
  theory       -- Theorem 1 constants
"""
from repro.core import baselines, centralvr, convex, distributed, theory  # noqa: F401
