"""Unified solver API contracts (DESIGN.md §Solver API).

Three layers of pins:

  * registry completeness — every public ``run_*`` driver in the three
    driver modules is reachable through exactly one registry entry, and
    the capability records match observed behavior (impossible
    combinations fail at ``RunSpec`` construction, before any JAX work,
    with the offending field named);
  * ``RunSpec`` round-trips through ``dataclasses.asdict`` -> rebuild;
  * ``solve(RunSpec(...))`` reproduces every driver's direct-call
    trajectory exactly — the unified entry point is pure dispatch, so all
    existing vmap/spmd/host-loop pins transfer to it unchanged.
"""
import dataclasses
import inspect

import jax
import numpy as np
import pytest

import repro
from repro import REGISTRY, RunSpec, algorithms, runner, solve
from repro.config import ConvexConfig
from repro.core import baselines, centralvr, convex, distributed, solver


def _sharded(p=2, n=32, d=6, kind="logistic"):
    cfg = ConvexConfig(problem=kind, n=n, d=d, workers=p)
    return distributed.make_distributed(jax.random.PRNGKey(0), cfg)


def _prob(n=32, d=6):
    return convex.make_logistic_data(jax.random.PRNGKey(0), n, d)


# ---------------------------------------------------------------------------
# Registry completeness
# ---------------------------------------------------------------------------

def test_registry_covers_every_public_driver():
    """Every public run_* entry point of the driver modules is some
    registry entry's resolved runner — adding a driver without a registry
    entry (or retiring one without cleaning up) fails here."""
    public = set()
    for mod in (centralvr, distributed, baselines):
        for name, fn in inspect.getmembers(mod, inspect.isfunction):
            if (name == "run" or name.startswith("run_")) \
                    and fn.__module__ == mod.__name__:
                public.add(fn)
    registered = {runner(name) for name in algorithms()}
    assert registered == public, (
        "registry out of sync with the public run_* surface: "
        f"unregistered={[f.__qualname__ for f in public - registered]}, "
        f"stale={[f.__qualname__ for f in registered - public]}")
    assert len(algorithms()) == 11


def test_registry_names_are_the_papers_family():
    assert set(algorithms()) == {
        "centralvr", "centralvr_sync", "centralvr_async", "dsvrg", "dsaga",
        "sgd", "svrg", "saga", "dist_sgd", "easgd", "ps_svrg"}


# ---------------------------------------------------------------------------
# Spec validation matches the capability records (fails pre-JAX,
# naming the offending field)
# ---------------------------------------------------------------------------

def test_unknown_algo_names_field_and_registry():
    with pytest.raises(ValueError, match=r"RunSpec\.algo.*centralvr_sync"):
        RunSpec(algo="centralvr2")


def test_spmd_on_non_spmd_algo_raises_at_spec_build():
    for algo in algorithms():
        caps = REGISTRY[algo].caps
        if caps.spmd_ok:
            continue
        with pytest.raises(NotImplementedError, match=r"RunSpec\.backend"):
            RunSpec(algo=algo, backend="spmd")


def test_unknown_backend_keeps_error_contract():
    with pytest.raises(ValueError, match="unknown backend"):
        RunSpec(algo="centralvr_sync", p=2, backend="pmap")


def test_instant_fetch_plus_spmd_raises_at_spec_build():
    with pytest.raises(NotImplementedError, match="event-serial"):
        RunSpec(algo="dsaga", p=2, backend="spmd", fetch="instant")
    with pytest.raises(ValueError, match="unknown fetch"):
        RunSpec(algo="dsaga", p=2, fetch="bogus")


def test_fetch_default_resolution():
    assert RunSpec(algo="dsaga", p=2).fetch == "instant"
    assert RunSpec(algo="dsaga", p=2, backend="spmd").fetch == "stale"
    # only D-SAGA exposes the discipline
    with pytest.raises(ValueError, match=r"RunSpec\.fetch"):
        RunSpec(algo="centralvr_async", p=2, fetch="stale")


def test_speeds_rejected_for_sync_algos():
    for algo in algorithms():
        caps = REGISTRY[algo].caps
        if caps.accepts_speeds:
            continue
        with pytest.raises(ValueError, match=r"RunSpec\.speeds"):
            RunSpec(algo=algo, p=2 if caps.distributed else 1,
                    speeds=(1.0, 2.0))


def test_speeds_shape_and_sign_validated():
    with pytest.raises(ValueError, match=r"RunSpec\.speeds.*p=3"):
        RunSpec(algo="centralvr_async", p=3, speeds=(1.0, 2.0))
    with pytest.raises(ValueError, match=r"RunSpec\.speeds"):
        RunSpec(algo="centralvr_async", p=2, speeds=(1.0, -2.0))


def test_tau_rejected_where_meaningless():
    for algo in algorithms():
        caps = REGISTRY[algo].caps
        if caps.accepts_tau:
            continue
        with pytest.raises(ValueError, match=r"RunSpec\.tau"):
            RunSpec(algo=algo, p=2 if caps.distributed else 1, tau=7)


def test_single_worker_algos_reject_p():
    for algo in algorithms():
        if REGISTRY[algo].caps.distributed:
            continue
        with pytest.raises(ValueError, match=r"RunSpec\.p"):
            RunSpec(algo=algo, p=2)


def test_scalar_field_validation():
    with pytest.raises(ValueError, match=r"RunSpec\.rounds"):
        RunSpec(algo="sgd", rounds=0)
    with pytest.raises(ValueError, match=r"RunSpec\.eta"):
        RunSpec(algo="sgd", eta=-0.1)
    with pytest.raises(ValueError, match=r"RunSpec\.metric_every"):
        RunSpec(algo="sgd", metric_every=0)
    with pytest.raises(ValueError, match=r"RunSpec\.sampling"):
        RunSpec(algo="centralvr", sampling="bogus")
    with pytest.raises(ValueError, match=r"RunSpec\.sampling"):
        RunSpec(algo="sgd", sampling="uniform")
    with pytest.raises(ValueError, match=r"RunSpec\.decay"):
        RunSpec(algo="svrg", decay=0.5)


def test_thin_wrappers_validate_via_spec():
    """The run_* signatures stay, but their validation is a spec build:
    the same invalid combinations fail identically both ways."""
    sp = _sharded()
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="unknown backend"):
        distributed.run_sync(sp, eta=0.1, rounds=1, key=key,
                             backend="bogus")
    with pytest.raises(ValueError, match=r"RunSpec\.speeds"):
        distributed.run_async(sp, eta=0.1, rounds=1, key=key,
                              speeds=[1.0, 2.0, 3.0])
    with pytest.raises(ValueError, match=r"RunSpec\.rounds"):
        baselines.run_sgd(_prob(), eta=0.1, epochs=0,
                          key=key)
    # eta is part of the shared contract too: both surfaces reject it
    with pytest.raises(ValueError, match=r"RunSpec\.eta"):
        distributed.run_sync(sp, eta=-0.1, rounds=1, key=key)
    with pytest.raises(ValueError, match=r"RunSpec\.eta"):
        baselines.run_saga(_prob(), eta=0.0, epochs=1, key=key)


def test_runspec_roundtrips_through_asdict():
    for spec in (
        RunSpec(algo="centralvr_async", p=3, eta=0.05, rounds=7,
                speeds=(1, 2, 3), seed=4, metric_every=2),
        RunSpec(algo="dsaga", p=2, tau=50, fetch="stale"),
        RunSpec(algo="centralvr", sampling="uniform"),
        RunSpec(algo="easgd", p=4, tau=8, decay=0.1),
    ):
        rebuilt = RunSpec(**dataclasses.asdict(spec))
        assert rebuilt == spec
        assert isinstance(rebuilt.speeds, (tuple, type(None)))


def test_lazy_package_export():
    assert repro.solve is solver.solve
    assert repro.RunSpec is solver.RunSpec
    with pytest.raises(AttributeError):
        repro.nonexistent_symbol


# ---------------------------------------------------------------------------
# solve() == the direct drivers, for every registry algorithm
# ---------------------------------------------------------------------------

def _direct(algo, problem, eta, rounds, key, tau):
    """The pre-API call for each driver, normalized to (x, rels)."""
    if algo == "centralvr":
        st, rels, _ = centralvr.run(problem, eta=eta, epochs=rounds, key=key)
        return st.x, rels
    if algo == "centralvr_sync":
        st, rels = distributed.run_sync(problem, eta=eta, rounds=rounds,
                                        key=key)
        return st.x, rels
    if algo == "centralvr_async":
        st, rels = distributed.run_async(problem, eta=eta, rounds=rounds,
                                         key=key)
        return st.x_c, rels
    if algo == "dsvrg":
        return distributed.run_dsvrg(problem, eta=eta, rounds=rounds,
                                     key=key, tau=tau)
    if algo == "dsaga":
        st, rels = distributed.run_dsaga(problem, eta=eta, rounds=rounds,
                                         key=key, tau=tau)
        return st.x_c, rels
    if algo == "sgd":
        return baselines.run_sgd(problem, eta=eta, epochs=rounds, key=key)
    if algo == "svrg":
        return baselines.run_svrg(problem, eta=eta, epochs=rounds, key=key,
                                  inner=tau)
    if algo == "saga":
        return baselines.run_saga(problem, eta=eta, epochs=rounds, key=key)
    if algo == "dist_sgd":
        return baselines.run_dist_sgd(problem, eta=eta, rounds=rounds,
                                      key=key, tau=tau)
    if algo == "easgd":
        return baselines.run_easgd(problem, eta=eta, rounds=rounds, key=key,
                                   tau=tau)
    if algo == "ps_svrg":
        return baselines.run_ps_svrg(problem, eta=eta, rounds=rounds,
                                     key=key)
    raise AssertionError(algo)


@pytest.mark.parametrize("algo", sorted(
    {"centralvr", "centralvr_sync", "centralvr_async", "dsvrg", "dsaga",
     "sgd", "svrg", "saga", "dist_sgd", "easgd", "ps_svrg"}))
def test_solve_matches_direct_driver(algo):
    """solve(RunSpec(...)) is pure dispatch: bit-identical trajectory and
    final iterate to calling the run_* driver directly with the same
    problem, eta, and key — so every existing trajectory pin transfers."""
    caps = REGISTRY[algo].caps
    p = 2 if caps.distributed else 1
    problem = _sharded(p=p) if caps.distributed else _prob()
    merged = problem.merged() if caps.distributed else problem
    eta, rounds, tau = convex.auto_eta(merged, 0.3), 2, 8
    key = jax.random.PRNGKey(5)

    spec = RunSpec(algo=algo, p=p, eta=eta, rounds=rounds, seed=5,
                   **({"tau": tau} if caps.accepts_tau else {}))
    res = solve(spec, problem)
    x, rels = _direct(algo, problem, eta, rounds, key, tau)

    np.testing.assert_array_equal(res.rels, np.asarray(rels))
    np.testing.assert_array_equal(res.x, np.asarray(x))
    assert res.spec.eta == eta
    assert res.wall_s > 0.0
    assert res.final_rel == float(np.asarray(rels)[-1])


def test_solve_from_config_is_deterministic():
    """A ConvexConfig input builds the dataset from cfg.seed: the same
    spec + config always produces the same trajectory."""
    cfg = ConvexConfig(problem="ridge", n=24, d=4)
    spec = RunSpec(algo="centralvr_sync", p=2, rounds=2)
    a = solve(spec, cfg)
    b = solve(spec, cfg)
    np.testing.assert_array_equal(a.rels, b.rels)
    assert a.spec == b.spec
    assert a.spec.eta is not None and a.spec.eta > 0


def test_solve_topology_coercion():
    """Flat Problem -> sharded for distributed algos; ShardedProblem ->
    merged for single-worker algos; p mismatch is a spec error."""
    prob = _prob(n=32)
    res = solve(RunSpec(algo="centralvr_sync", p=2, rounds=1), prob)
    assert res.rels.shape == (1,)
    sp = _sharded(p=2)
    res = solve(RunSpec(algo="sgd", rounds=1), sp)
    assert res.rels.shape == (1,)
    with pytest.raises(ValueError, match=r"RunSpec\.p"):
        solve(RunSpec(algo="centralvr_sync", p=4, rounds=1), sp)
    with pytest.raises(TypeError, match="ConvexConfig"):
        solve(RunSpec(algo="sgd"), object())
    # an explicitly conflicting cfg.workers is an error, not a silent
    # override (cfg.workers=1, the default, defers to the spec)
    with pytest.raises(ValueError, match=r"RunSpec\.p"):
        solve(RunSpec(algo="centralvr_sync", p=2, rounds=1),
              ConvexConfig(n=16, d=4, workers=8))
    # single-worker algo + multi-worker cfg runs on the merged total data
    res = solve(RunSpec(algo="sgd", rounds=1),
                ConvexConfig(n=16, d=4, workers=2))
    assert res.rels.shape == (1,)


def test_metric_cadence_subsamples_with_final_round():
    sp = _sharded(p=2)
    eta = convex.auto_eta(sp.merged(), 0.3)
    full = solve(RunSpec(algo="centralvr_sync", p=2, eta=eta, rounds=5), sp)
    thin = solve(RunSpec(algo="centralvr_sync", p=2, eta=eta, rounds=5,
                         metric_every=2), sp)
    # rounds 2, 4 (cadence) + round 5 (final)
    np.testing.assert_array_equal(thin.rels, full.rels[[1, 3, 4]])
    assert thin.final_rel == full.final_rel
    # grad_evals stays aligned with rels (rels[i] <-> grad_evals[i])
    prob = _prob()
    full = solve(RunSpec(algo="centralvr", rounds=5), prob)
    thin = solve(RunSpec(algo="centralvr", rounds=5, metric_every=2), prob)
    assert thin.grad_evals.shape == thin.rels.shape
    np.testing.assert_array_equal(thin.grad_evals, full.grad_evals[[1, 3, 4]])


def test_runresult_provenance_is_jsonable():
    import json

    res = solve(RunSpec(algo="saga", rounds=2),
                ConvexConfig(problem="logistic", n=16, d=4))
    row = res.provenance(tail=4)
    encoded = json.dumps(row)
    assert "saga" in encoded
    assert row["spec"]["eta"] == res.spec.eta
    assert row["rels_tail"][-1] == res.final_rel
    assert row["rounds_recorded"] == 2
    # traces reports the TRACES delta of THIS call (0 on a jit cache hit)
    again = solve(RunSpec(algo="saga", rounds=2),
                  ConvexConfig(problem="logistic", n=16, d=4))
    assert again.traces == {}
