"""Direct measurement of the paper's MECHANISM: the variance of the
corrected stochastic gradient vs plain SGD's, along the same trajectory.

The paper's premise (§1, §2): VR's error-correction term shrinks gradient
variance as iterates approach the optimum, allowing constant step sizes.
We measure E||g_est - grad f(x)||^2 over the component-function
distribution at checkpoints along a CentralVR run: for SGD the variance
plateaus (noise floor), for CentralVR it decays with the suboptimality.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.config import ConvexConfig
from repro.core import centralvr, convex


def _variances_dev(prob, state, x):
    """Device-resident (var_sgd, var_cvr) at iterate x given the table."""
    full = convex.full_grad(prob, x)
    s_fresh = convex.scalar_residual_all(prob, x)
    # per-index plain SGD gradient: s_i a_i + 2 lam x
    g_sgd = s_fresh[:, None] * prob.A + 2.0 * prob.lam * x
    var_sgd = jnp.mean(jnp.sum((g_sgd - full) ** 2, axis=1))
    # per-index corrected gradient: (s_i - table_i) a_i + gbar + 2 lam x
    g_cvr = ((s_fresh - state.table)[:, None] * prob.A
             + state.gbar + 2.0 * prob.lam * x)
    var_cvr = jnp.mean(jnp.sum((g_cvr - full) ** 2, axis=1))
    return var_sgd, var_cvr


def gradient_variances(prob, state, x):
    """(var_sgd, var_cvr) at iterate x given the CentralVR table state."""
    var_sgd, var_cvr = _variances_dev(prob, state, x)
    return float(var_sgd), float(var_cvr)


@functools.partial(jax.jit, donate_argnames=("state",))
def _trajectory_scan(prob, state, eta, keys):
    """Measure (grad gap, var_sgd, var_cvr) at each epoch checkpoint, then
    advance one CentralVR epoch — all inside one scan, one transfer out."""

    def body(state, k):
        v_sgd, v_cvr = _variances_dev(prob, state, state.x)
        gap = jnp.linalg.norm(convex.full_grad(prob, state.x))
        perm = jax.random.permutation(k, prob.n)
        state, _ = centralvr.epoch(prob, state, eta, perm)
        return state, (gap, v_sgd, v_cvr)

    return jax.lax.scan(body, state, keys)


def run(quick: bool = False):
    cfg = ConvexConfig(problem="logistic", n=500 if quick else 2000, d=30)
    prob = convex.make_problem(jax.random.PRNGKey(0), cfg)
    eta = convex.auto_eta(prob, 0.5)
    epochs = 8 if quick else 24

    key = jax.random.PRNGKey(1)
    state = centralvr.init_state(prob, eta, key)
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(2), epochs)
    _, (gaps, vs_sgd, vs_cvr) = _trajectory_scan(prob, state, eta, ks)
    track = [(m, float(gaps[m]), float(vs_sgd[m]), float(vs_cvr[m]))
             for m in range(epochs)]

    first, last = track[1], track[-1]
    ratio_first = first[2] / max(first[3], 1e-30)
    ratio_last = last[2] / max(last[3], 1e-30)
    rows.append({
        "name": "variance/centralvr-vs-sgd",
        "us_per_call": 0.0,
        "derived": (f"epoch{first[0]}:var_sgd={first[2]:.2e},"
                    f"var_cvr={first[3]:.2e},ratio={ratio_first:.1f}x;"
                    f"epoch{last[0]}:var_sgd={last[2]:.2e},"
                    f"var_cvr={last[3]:.2e},ratio={ratio_last:.1f}x;"
                    f"vr_variance_decays={'yes' if last[3] < first[3] * 1e-2 else 'no'};"
                    f"sgd_variance_plateaus={'yes' if last[2] > first[2] * 1e-2 else 'no'}"),
        "trajectory": [{"epoch": m, "grad_norm": g, "var_sgd": vs,
                        "var_cvr": vc} for m, g, vs, vc in track],
    })
    emit(rows, "variance")
    return rows


if __name__ == "__main__":
    run()
