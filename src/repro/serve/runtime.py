"""Jitted serving programs: chunked prefill and fixed-width batched decode
over a paged (or dense-oracle) KV cache.

Two programs per (config, geometry), built by :func:`build_programs`:

``decode_step(params, cache, tokens, lens, alive, tables)``
    One token for every lane of a fixed decode batch of ``width`` lanes,
    each lane at its OWN position ``lens[lane]`` (unlike the lockstep
    ``model.decode_step``).  Dead lanes (``alive=False``) run padded
    compute whose KV writes land in the trash block / are dropped, so a
    lane's output stream is bitwise independent of what the other lanes
    are doing — the property the batched-vs-sequential equivalence test
    pins.  Cache is donated.

``prefill_chunk(params, cache, tokens, len0, n_valid, lane, table_row)``
    Writes one chunk of ``C = tokens.shape[0]`` prompt tokens (``n_valid``
    real, rest padding) into lane ``lane``'s cache starting at absolute
    position ``len0``, and returns the logits at the LAST valid position
    (the first generated token when the final chunk lands).  One jit
    executable per chunk bucket C; the engine pads to its bucket list so
    the executable count stays bounded.  Cache is donated.

Per-layer cache modes (decided by layer kind + geometry):
  * windowed layers ("local" always; "attn" with cfg.sliding_window) keep
    per-lane RING buffers of ``min(context, window)`` slots — already
    bounded, nothing to page;
  * full-attention layers use the paged POOL ``(num_blocks, block_size,
    KV, hd)`` + shared block tables, or per-lane dense buffers of the
    same padded context width when ``geometry.kv_cache == "dense"``.

Both full-attention modes feed :func:`attention.attend_serve` a context
of identical width T = context with identical validity masks, and masked
entries contribute an exact 0.0 to the online softmax — so paged and
dense greedy decode are bit-identical, which is what makes the dense
path a usable oracle.

Ring prefill subtlety: a chunk may overwrite ring slots that EARLIER
queries of the same chunk still need, so the ring path attends over the
concatenated stream ``[old ring, chunk]`` and only afterwards folds the
chunk into the ring via a deterministic gather (slot c takes the newest
chunk position ≡ c mod slots) — write-then-attend would be wrong there.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention, layers, moe, transformer
from repro.serve.cache import Geometry

SERVE_KINDS = ("attn", "local")


def check_arch(cfg: ModelConfig) -> None:
    """The engine serves attention-family stacks; recurrent caches (ssm /
    rec) and frontend embeds keep the legacy per-token path."""
    bad = sorted(set(cfg.layer_kinds()) - set(SERVE_KINDS))
    if bad:
        raise ValueError(
            f"{cfg.name}: serve runtime handles attention-family layers "
            f"only, found {bad}; use the legacy host-loop path "
            f"(serve/legacy.py)")
    if cfg.frontend is not None:
        raise ValueError(f"{cfg.name}: frontend embeds are not servable by "
                         "the engine; use the legacy host-loop path")


def init_cache(cfg: ModelConfig, geo: Geometry):
    """Serve cache pytree: per-layer paged pools / dense lane buffers /
    rings, in the stack/tail structure every stack walker expects."""
    dtype = jnp.dtype(cfg.dtype)
    KV, hd = cfg.num_kv_heads, cfg.head_dim

    def make(kind, window):
        if window:
            slots = min(geo.context, window)
            shape = (geo.width, slots, KV, hd)
        elif geo.kv_cache == "paged":
            shape = (geo.num_blocks, geo.block_size, KV, hd)
        else:
            shape = (geo.width, geo.context, KV, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    return transformer.init_stack_serve_cache(cfg, make)


# ---------------------------------------------------------------------------
# Per-layer attention: decode
# ---------------------------------------------------------------------------

def _decode_attend(p, cfg, geo, x, kv, window, lens, alive, tables):
    D = geo.width
    positions = lens[:, None]                       # (D, 1) per-lane rope
    q, k_new, v_new = attention.project_qkv_serve(p, cfg, x, positions)
    lane = jnp.arange(D)

    if window:
        slots = kv["k"].shape[1]
        slot = lens % slots
        keep = ~alive[:, None, None]
        k_c = kv["k"].at[lane, slot].set(
            jnp.where(keep, kv["k"][lane, slot], k_new[:, 0]))
        v_c = kv["v"].at[lane, slot].set(
            jnp.where(keep, kv["v"][lane, slot], v_new[:, 0]))
        k_pos, k_valid = attention.ring_positions(lens, slots)
        out = attention.attend_serve(q, positions, k_c, v_c, k_pos, k_valid,
                                     window=window,
                                     softcap=cfg.attn_logit_softcap)
        return attention.output_proj_serve(p, cfg, out), {"k": k_c, "v": v_c}

    t = jnp.arange(geo.context)
    k_pos = jnp.broadcast_to(t[None, :], (D, geo.context))
    k_valid = t[None, :] <= lens[:, None]

    if geo.kv_cache == "paged":
        # dead lanes write the trash block 0 (never table-reachable)
        phys = jnp.where(alive, tables[lane, lens // geo.block_size], 0)
        off = lens % geo.block_size
        k_pool = kv["k"].at[phys, off].set(k_new[:, 0])
        v_pool = kv["v"].at[phys, off].set(v_new[:, 0])
        k_c = k_pool[tables].reshape(D, geo.context, *k_pool.shape[2:])
        v_c = v_pool[tables].reshape(D, geo.context, *v_pool.shape[2:])
        new_kv = {"k": k_pool, "v": v_pool}
    else:
        # dense oracle: dead-lane writes dropped via OOB slot
        slot = jnp.where(alive, lens, geo.context)
        k_c = kv["k"].at[lane, slot].set(k_new[:, 0], mode="drop")
        v_c = kv["v"].at[lane, slot].set(v_new[:, 0], mode="drop")
        new_kv = {"k": k_c, "v": v_c}

    out = attention.attend_serve(q, positions, k_c, v_c, k_pos, k_valid,
                                 window=None, softcap=cfg.attn_logit_softcap)
    return attention.output_proj_serve(p, cfg, out), new_kv


# ---------------------------------------------------------------------------
# Per-layer attention: prefill (single lane, one chunk)
# ---------------------------------------------------------------------------

def _prefill_attend(p, cfg, geo, x, kv, window, len0, n_valid, lane,
                    table_row):
    C = x.shape[1]
    i = jnp.arange(C)
    pos_i = len0 + i                                # (C,) absolute positions
    positions = pos_i[None, :]
    q, k_new, v_new = attention.project_qkv_serve(p, cfg, x, positions)
    chunk_valid = i < n_valid

    if window:
        slots = kv["k"].shape[1]
        ring_k, ring_v = kv["k"][lane], kv["v"][lane]
        r_pos, r_valid = attention.ring_positions(
            jnp.reshape(len0 - 1, (1,)), slots)
        k_s = jnp.concatenate([ring_k[None], k_new], axis=1)
        v_s = jnp.concatenate([ring_v[None], v_new], axis=1)
        k_pos = jnp.concatenate([r_pos, positions], axis=1)
        k_valid = jnp.concatenate([r_valid, chunk_valid[None]], axis=1)
        out = attention.attend_serve(q, positions, k_s, v_s, k_pos, k_valid,
                                     window=window,
                                     softcap=cfg.attn_logit_softcap)
        # fold the chunk into the ring: slot c takes the newest valid chunk
        # position ≡ c (mod slots), else keeps its old entry
        last = len0 + n_valid - 1
        c = jnp.arange(slots)
        p_c = last - ((last - c) % slots)
        take = (p_c >= len0) & (n_valid > 0)
        idx = jnp.clip(p_c - len0, 0, C - 1)
        new_k = jnp.where(take[:, None, None], k_new[0, idx], ring_k)
        new_v = jnp.where(take[:, None, None], v_new[0, idx], ring_v)
        new_kv = {"k": kv["k"].at[lane].set(new_k),
                  "v": kv["v"].at[lane].set(new_v)}
        return attention.output_proj_serve(p, cfg, out), new_kv

    t = jnp.arange(geo.context)
    k_pos = t[None, :]
    k_valid = (t < len0 + n_valid)[None, :]

    if geo.kv_cache == "paged":
        phys = jnp.where(chunk_valid, table_row[pos_i // geo.block_size], 0)
        off = pos_i % geo.block_size
        k_pool = kv["k"].at[phys, off].set(k_new[0])
        v_pool = kv["v"].at[phys, off].set(v_new[0])
        k_c = k_pool[table_row].reshape(geo.context, *k_pool.shape[2:])[None]
        v_c = v_pool[table_row].reshape(geo.context, *v_pool.shape[2:])[None]
        new_kv = {"k": k_pool, "v": v_pool}
    else:
        wr = jnp.where(chunk_valid, pos_i, geo.context)
        k_buf = kv["k"].at[lane, wr].set(k_new[0], mode="drop")
        v_buf = kv["v"].at[lane, wr].set(v_new[0], mode="drop")
        k_c, v_c = k_buf[lane][None], v_buf[lane][None]
        new_kv = {"k": k_buf, "v": v_buf}

    out = attention.attend_serve(q, positions, k_c, v_c, k_pos, k_valid,
                                 window=None, softcap=cfg.attn_logit_softcap)
    return attention.output_proj_serve(p, cfg, out), new_kv


# ---------------------------------------------------------------------------
# Block + full-model programs
# ---------------------------------------------------------------------------

def _apply_block(bp, bc, cfg, x, mixer_fn):
    bp = transformer._cast_params(bp, jnp.dtype(cfg.dtype))
    h = layers.apply_norm(bp["norm1"], x, cfg.norm_type)
    mix, bc = mixer_fn(bp["mixer"], h, bc)
    x = x + mix
    h = layers.apply_norm(bp["norm2"], x, cfg.norm_type)
    if cfg.is_moe:
        y, _ = moe.apply_moe(bp["ffn"], cfg, h)
    else:
        y = layers.apply_mlp(bp["ffn"], h, cfg.mlp_type)
    return x + y, bc


def decode_step(params, cfg: ModelConfig, geo: Geometry, cache,
                tokens, lens, alive, tables):
    """tokens/lens/alive: (width,); tables: (width, blocks_per_seq)
    -> (logits (width, vocab) f32, new_cache)."""
    compute = jnp.dtype(cfg.dtype)
    x = layers.embed_tokens(params["embed"], tokens[:, None]).astype(compute)

    def block_fn(bp, bc, kind, window, x):
        return _apply_block(
            bp, bc, cfg, x,
            lambda mp, h, kv: _decode_attend(mp, cfg, geo, h, kv, window,
                                             lens, alive, tables))

    x, cache = transformer.apply_stack_serve(params["layers"], cache, cfg,
                                             x, block_fn)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_type)
    logits = layers.lm_logits(params["head"], params["embed"], x,
                              cfg.tie_embeddings)
    return logits[:, 0].astype(jnp.float32), cache


def prefill_chunk(params, cfg: ModelConfig, geo: Geometry, cache,
                  tokens, len0, n_valid, lane, table_row):
    """tokens: (C,); len0/n_valid/lane scalars; table_row: (blocks_per_seq,)
    -> (logits (vocab,) f32 at the last valid position, new_cache)."""
    compute = jnp.dtype(cfg.dtype)
    x = layers.embed_tokens(params["embed"], tokens[None, :]).astype(compute)

    def block_fn(bp, bc, kind, window, x):
        return _apply_block(
            bp, bc, cfg, x,
            lambda mp, h, kv: _prefill_attend(mp, cfg, geo, h, kv, window,
                                              len0, n_valid, lane, table_row))

    x, cache = transformer.apply_stack_serve(params["layers"], cache, cfg,
                                             x, block_fn)
    x_last = x[:, jnp.clip(n_valid - 1, 0, tokens.shape[0] - 1)][:, None]
    x_last = layers.apply_norm(params["final_norm"], x_last, cfg.norm_type)
    logits = layers.lm_logits(params["head"], params["embed"], x_last,
                              cfg.tie_embeddings)
    return logits[0, 0].astype(jnp.float32), cache


@functools.lru_cache(maxsize=None)
def build_programs(cfg: ModelConfig, geo: Geometry):
    """Returns (decode, prefill) jitted with the cache donated.  ``prefill``
    specializes per chunk length C (the engine buckets C, keeping the
    executable count = len(chunk_buckets)).  Memoized per (cfg, geometry)
    — both frozen dataclasses — so every engine over the same shapes
    shares one set of executables (placement still follows the argument
    shardings, so TP and single-device engines coexist)."""
    def _decode(params, cache, tokens, lens, alive, tables):
        return decode_step(params, cfg, geo, cache, tokens, lens, alive,
                           tables)

    def _prefill(params, cache, tokens, len0, n_valid, lane, table_row):
        return prefill_chunk(params, cfg, geo, cache, tokens, len0, n_valid,
                             lane, table_row)

    return (jax.jit(_decode, donate_argnums=(1,)),
            jax.jit(_prefill, donate_argnums=(1,)))
