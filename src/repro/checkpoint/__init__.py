from repro.checkpoint import checkpoint, elastic  # noqa: F401
