"""The paper's technique as a first-class LM-training feature: variance-
reduced gradient corrections over the finite sum of M fixed microbatches.

At LM scale the paper's f_i (one data sample) becomes f_i = loss of the
i-th FIXED microbatch of the worker's shard (the data pipeline replays
microbatch i every epoch — the finite-sum structure is preserved; see
repro/data/synthetic.py). Three corrections:

  * ``centralvr`` — Algorithm 1/2: per-index gradient table (M param-sized
    slots), anchor gbar frozen over the epoch, refreshed from the running
    accumulator at epoch end. 1 gradient per step.
  * ``svrg``      — Algorithm 4: snapshot params + anchor; correction
    g(x) - g(y) + gbar needs a SECOND gradient at the snapshot (2 grads
    per step, no table — the memory/compute trade of Table 1). The anchor
    is the epoch-averaged gradient (the synchronous full-gradient pass of
    classic SVRG does not exist at LM scale; the epoch average is the
    CentralVR-style anchor, recorded as an adaptation).
  * ``saga``      — Algorithm 5: table + anchor updated EVERY step
    (running mean). The high-communication-frequency contrast case.

All states are pytrees shaped like params (with a leading (M,) table axis
for table modes) so they shard exactly like params (FSDP'd tables in the
optimized mode).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map


class VRState(NamedTuple):
    table: Any          # (M, ...) per leaf, or () for svrg
    gbar: Any           # anchor
    gtilde: Any         # running accumulator
    snapshot: Any       # params snapshot (svrg) or ()
    idx: jax.Array      # current microbatch index in [0, M)


def init_vr(mode: str, params, M: int) -> Optional[VRState]:
    """VR state dtype FOLLOWS the param dtype: f32 masters get f32 tables/
    anchors (the faithful default); bf16 masters (the optimized large-model
    profile) get bf16 VR state — halving both the VR memory footprint and
    the FSDP gather traffic of the SVRG snapshot pass (§Perf It.6)."""
    if mode == "none":
        return None
    zeros = tmap(lambda p: jnp.zeros(p.shape, p.dtype)
                 if jnp.issubdtype(p.dtype, jnp.floating)
                 else jnp.zeros(p.shape, jnp.float32), params)
    if mode == "svrg":
        table = ()
        # p + 0 forces a fresh buffer: a same-dtype astype can alias the
        # param, and aliased leaves break donation (donate-twice error in
        # the epoch-scan runtime, which donates the whole TrainState)
        snapshot = tmap(lambda p: p + 0, params)
    else:
        table = tmap(lambda z: jnp.zeros((M,) + z.shape, z.dtype), zeros)
        snapshot = ()
    return VRState(table=table, gbar=zeros,
                   gtilde=tmap(jnp.zeros_like, zeros),
                   snapshot=snapshot, idx=jnp.zeros((), jnp.int32))


def correct(mode: str, state: VRState, g, M: int, *, g_snap=None,
            params=None, idx=None):
    """One VR step (mode is STATIC). Returns (corrected_grads, new_state).

    g: fresh minibatch gradient at current params.
    g_snap: gradient of the SAME minibatch at the snapshot (svrg only).
    params: current params (svrg snapshot refresh at epoch end).
    idx: optional SCALAR override of state.idx. Workers step in lockstep,
        so the microbatch index is step % M on every worker — but under
        vmap the per-worker state.idx is a BATCHED predicate, and a
        batched lax.switch executes all M table branches and selects
        (M× full-table traffic per step). Callers that know the scalar
        step (the train step / epoch scan) pass it here so the switch
        stays unbatched and touches one slot.
    """
    i = state.idx if idx is None else idx
    at_epoch_end = i == (M - 1)

    if mode == "svrg":
        v = tmap(lambda a, b, c: a.astype(c.dtype) - b.astype(c.dtype)
                 + c, g, g_snap, state.gbar)
        gtilde = tmap(lambda t, a: t + a.astype(t.dtype) / M,
                      state.gtilde, g)

        def refresh(_):
            # epoch end: y <- x, gbar <- epoch average, reset accumulator
            return VRState((), gtilde,
                           tmap(jnp.zeros_like, gtilde),
                           tmap(lambda p: p + 0, params),
                           jnp.zeros((), jnp.int32))

        def keep(_):
            return VRState((), state.gbar, gtilde, state.snapshot,
                           i + 1)

        return v, jax.lax.cond(at_epoch_end, refresh, keep, None)

    # table modes: correction v = g - table[i] + gbar.
    # Table slot access goes through lax.switch over STATIC indices: a
    # vmapped dynamic-slice/update over an FSDP-sharded table trips the
    # SPMD partitioner (verifier error "slice dim size > dynamic slice
    # dimension" on the 2-pod mesh); static slices partition cleanly and
    # are cheaper than a gather. M is small (config vr_table_size).
    old = jax.lax.switch(
        i, [(lambda m: lambda: tmap(lambda t: t[m], state.table))(m)
            for m in range(M)])
    v = tmap(lambda a, o, c: a.astype(o.dtype) - o + c, g, old,
             state.gbar)
    table = jax.lax.switch(
        i, [(lambda m: lambda: tmap(
            lambda t, a: t.at[m].set(a.astype(t.dtype)),
            state.table, g))(m) for m in range(M)])

    if mode == "saga":
        # anchor tracks the table mean every step (Alg 5 line 9)
        gbar = tmap(lambda c, a, o: c + (a.astype(c.dtype) - o) / M,
                    state.gbar, g, old)
        return v, VRState(table, gbar, state.gtilde, (),
                          (i + 1) % M)

    # centralvr: anchor frozen; accumulator refreshed at epoch end
    gtilde = tmap(lambda t, a: t + a.astype(t.dtype) / M,
                  state.gtilde, g)

    def roll(_):
        return VRState(table, gtilde, tmap(jnp.zeros_like, gtilde),
                       (), jnp.zeros((), jnp.int32))

    def keep(_):
        return VRState(table, state.gbar, gtilde, (), i + 1)

    return v, jax.lax.cond(at_epoch_end, roll, keep, None)


def apply(mode: str, state: VRState, g, M: int, *, lr: float, g_snap=None,
          params=None, idx=None, interpret: bool = False):
    """Fused VR correction + SGD parameter update: the arithmetic of
    ``correct`` followed by ``optimizers.sgd`` / ``apply_updates``, with
    the param-sized elementwise work (correction, step, table row,
    anchor/accumulator update) dispatched to the ``kernels/vr_update``
    Pallas kernel as ONE launch over the flattened param pytree
    (DESIGN.md §Fused kernels hot-path).

    Returns (new_params, new_state). SGD only — the kernel bakes the
    plain ``x - lr*v`` step; stateful optimizers keep the unfused path.
    ``params`` here is the live pre-update iterate (it is both the x the
    kernel steps and, for svrg at epoch end, the snapshot source —
    matching ``correct``'s pre-update refresh). The kernel computes in
    f32 and results are cast back to each state leaf's dtype, so bf16
    profiles agree to cast precision rather than bit-for-bit.
    """
    from repro.kernels.vr_update import ops as vr_ops

    i = state.idx if idx is None else idx
    at_epoch_end = i == (M - 1)

    if mode == "svrg":
        x_new, _, gto, _ = vr_ops.vr_update_inline(
            params, g, g_snap, state.gbar, state.gtilde,
            eta=lr, m=M, saga=False, interpret=interpret)
        gtilde = tmap(lambda t, a: a.astype(t.dtype), state.gtilde, gto)

        def refresh(_):
            return VRState((), gtilde, tmap(jnp.zeros_like, gtilde),
                           tmap(lambda p: p + 0, params),
                           jnp.zeros((), jnp.int32))

        def keep(_):
            return VRState((), state.gbar, gtilde, state.snapshot, i + 1)

        return x_new, jax.lax.cond(at_epoch_end, refresh, keep, None)

    # table modes: the slot read/write stays a lax.switch over static
    # indices (same SPMD-partitioner reasoning as ``correct``); the row
    # content comes out of the kernel's table lane.
    old = jax.lax.switch(
        i, [(lambda m: lambda: tmap(lambda t: t[m], state.table))(m)
            for m in range(M)])
    x_new, row, gto, gbo = vr_ops.vr_update_inline(
        params, g, old, state.gbar, state.gtilde,
        eta=lr, m=M, saga=(mode == "saga"), interpret=interpret)
    table = jax.lax.switch(
        i, [(lambda m: lambda: tmap(
            lambda t, a: t.at[m].set(a.astype(t.dtype)),
            state.table, row))(m) for m in range(M)])

    if mode == "saga":
        gbar = tmap(lambda c, a: a.astype(c.dtype), state.gbar, gbo)
        return x_new, VRState(table, gbar, state.gtilde, (), (i + 1) % M)

    # centralvr: anchor frozen (kernel passes it through); accumulator
    # from the kernel's gtilde lane, swapped in at epoch end
    gtilde = tmap(lambda t, a: a.astype(t.dtype), state.gtilde, gto)

    def roll(_):
        return VRState(table, gtilde, tmap(jnp.zeros_like, gtilde),
                       (), jnp.zeros((), jnp.int32))

    def keep(_):
        return VRState(table, state.gbar, gtilde, (), i + 1)

    return x_new, jax.lax.cond(at_epoch_end, roll, keep, None)


def grads_per_step(mode: str) -> int:
    """Table 1: gradient evaluations per iteration."""
    return 2 if mode == "svrg" else 1


def storage_multiplier(mode: str, M: int) -> float:
    """Extra param-sized buffers held by the VR state."""
    if mode == "none":
        return 0.0
    if mode == "svrg":
        return 3.0            # snapshot + gbar + gtilde
    return float(M) + 2.0     # table + gbar + gtilde
