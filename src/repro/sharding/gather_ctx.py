"""Explicit per-layer weight-gather context (manual ZeRO-3).

With 2D-sharded weights (fsdp x tensor) and batch-sharded activations,
GSPMD's strategy choice for the layer matmuls is free to defer partial
sums into activation-sized all-reduces — measured at 800 MB x 2 x 1280
executions (f32-promoted!) on qwen1.5-110b/train_4k, dwarfing the 50 MB
bf16 weight gather the ZeRO pattern intends (EXPERIMENTS.md §Perf It.6).

The fix is to make the gather EXPLICIT: when a block casts its weights to
compute dtype, each 2D-sharded leaf is constrained to its FSDP-UNSHARDED
spec. GSPMD then emits one bf16 all-gather over 'data' per weight per
layer execution (inside the remat scope, so backward re-gathers rather
than keeping the full weight resident), and every matmul sees a cleanly
tensor-parallel weight.

Context is process-global and set by the step factories before tracing
(traced functions read it at trace time only).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX: dict = {"mesh": None, "cfg": None, "sizes": None}


def enable(mesh, cfg, sizes: dict) -> None:
    _CTX.update(mesh=mesh, cfg=cfg, sizes=sizes)


def disable() -> None:
    _CTX.update(mesh=None, cfg=None, sizes=None)


def active() -> bool:
    return _CTX["mesh"] is not None


def gather_spec(path_str: str, shape) -> Optional[P]:
    """The use-time (FSDP-removed) spec for a block-relative param path,
    or None if the leaf isn't FSDP-sharded (no constraint needed)."""
    if not active():
        return None
    from repro.sharding import specs
    cfg, sizes = _CTX["cfg"], _CTX["sizes"]
    with_f = specs._param_rule(path_str, shape, cfg, "data", sizes)
    no_f = specs._param_rule(path_str, shape, cfg, None, sizes)
    if len(with_f) != len(shape) or with_f == no_f:
        return None
    no_f = specs._fix_divisibility(no_f, shape, sizes)
    return P(*no_f)


def constrain(path_str: str, w):
    spec = gather_spec(path_str, w.shape)
    if spec is None:
        return w
    return jax.lax.with_sharding_constraint(
        w, NamedSharding(_CTX["mesh"], spec))
