"""The paper's experimental substrate (§6): l2-regularized logistic and
ridge regression, with the GLM scalar-residual structure that makes the
SAGA/CentralVR gradient table O(n) scalars instead of O(n·d) vectors
(the storage observation in §2.3 of the paper).

Every f_i has the form  f_i(x) = l(a_i^T x; b_i) + lam * ||x||^2, so

    grad f_i(x) = s_i(x) * a_i + 2*lam*x,     s_i(x) = l'(a_i^T x; b_i).

We apply variance reduction to the data term only and treat the
regularizer's gradient 2*lam*x exactly (it is deterministic, so adding it
outside the correction keeps the estimator unbiased and strictly reduces
variance). The stored "gradient" for index i is therefore the scalar s_i.

Loss convention: the paper prints ``log(1 + exp(b a^T x))``; we use the
standard ``log(1 + exp(-b a^T x))`` (b in {-1,+1}) — the two differ only by
the sign of b, i.e. a relabeling of the classes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Problem(NamedTuple):
    """A finite-sum convex problem; a pytree safe to close over in jit."""

    A: jax.Array          # (n, d) features
    b: jax.Array          # (n,) labels (+-1 for logistic, real for ridge)
    lam: jnp.float32      # l2 coefficient
    kind: str             # "logistic" | "ridge"  (static)

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def d(self) -> int:
        return self.A.shape[1]


# pytree: `kind` is static metadata
jax.tree_util.register_pytree_node(
    Problem,
    lambda p: ((p.A, p.b, p.lam), p.kind),
    lambda kind, leaves: Problem(*leaves, kind=kind),
)


# ---------------------------------------------------------------------------
# Data generators (paper §6.1)
# ---------------------------------------------------------------------------

def make_logistic_data(key, n: int, d: int, lam: float = 1e-4) -> Problem:
    """Two unit-variance normals with means separated by one unit."""
    k1, k2 = jax.random.split(key)
    half = n // 2
    mu = jnp.zeros((d,)).at[0].set(0.5)
    a_pos = jax.random.normal(k1, (half, d)) + mu
    a_neg = jax.random.normal(k2, (n - half, d)) - mu
    A = jnp.concatenate([a_pos, a_neg])
    b = jnp.concatenate([jnp.ones((half,)), -jnp.ones((n - half,))])
    return Problem(A, b, jnp.float32(lam), "logistic")


def make_ridge_data(key, n: int, d: int, lam: float = 1e-4) -> Problem:
    """b = A x_true + eps, A and eps standard normal."""
    k1, k2, k3 = jax.random.split(key, 3)
    A = jax.random.normal(k1, (n, d))
    x_true = jax.random.normal(k2, (d,))
    b = A @ x_true + jax.random.normal(k3, (n,))
    return Problem(A, b, jnp.float32(lam), "ridge")


def make_problem(key, cfg) -> Problem:
    """From a :class:`repro.config.ConvexConfig`."""
    fn = make_logistic_data if cfg.problem == "logistic" else make_ridge_data
    return fn(key, cfg.n, cfg.d, cfg.lam)


# ---------------------------------------------------------------------------
# Losses / gradients
# ---------------------------------------------------------------------------

def _margins(prob: Problem, x: jax.Array) -> jax.Array:
    return prob.A @ x


def full_loss(prob: Problem, x: jax.Array) -> jax.Array:
    z = _margins(prob, x)
    if prob.kind == "logistic":
        data = jnp.mean(jnp.logaddexp(0.0, -prob.b * z))
    else:
        data = jnp.mean((z - prob.b) ** 2)
    return data + prob.lam * jnp.sum(x * x)


def scalar_residual(prob: Problem, x: jax.Array, idx) -> jax.Array:
    """s_i(x) = l'(a_i^T x; b_i) for the given indices (vectorized)."""
    a = prob.A[idx]
    bb = prob.b[idx]
    z = a @ x
    if prob.kind == "logistic":
        return -bb * jax.nn.sigmoid(-bb * z)
    return 2.0 * (z - bb)


def scalar_residual_all(prob: Problem, x: jax.Array) -> jax.Array:
    z = _margins(prob, x)
    if prob.kind == "logistic":
        return -prob.b * jax.nn.sigmoid(-prob.b * z)
    return 2.0 * (z - prob.b)


def sample_grad(prob: Problem, x: jax.Array, i) -> jax.Array:
    """grad f_i(x) (single index), regularizer included."""
    s = scalar_residual(prob, x, i)
    return s * prob.A[i] + 2.0 * prob.lam * x


def data_grad_from_scalars(prob: Problem, s: jax.Array) -> jax.Array:
    """(1/n) sum_j s_j a_j — the data term of the mean gradient."""
    return prob.A.T @ s / prob.n


def full_grad(prob: Problem, x: jax.Array) -> jax.Array:
    s = scalar_residual_all(prob, x)
    return data_grad_from_scalars(prob, s) + 2.0 * prob.lam * x


# ---------------------------------------------------------------------------
# Smoothness / strong-convexity constants and exact solutions (theory.py
# consumes these; tests compare measured rates against Theorem 1)
# ---------------------------------------------------------------------------

def constants(prob: Problem):
    """(mu, L) such that every f_i is mu-strongly convex, L-smooth."""
    row_sq = jnp.sum(prob.A * prob.A, axis=1)
    if prob.kind == "logistic":
        L = 0.25 * jnp.max(row_sq) + 2.0 * prob.lam
    else:
        L = 2.0 * jnp.max(row_sq) + 2.0 * prob.lam
    mu = 2.0 * prob.lam
    return mu, L


def auto_eta(prob: Problem, c: float = 0.3) -> float:
    """Practical step size c/L (the paper tunes per-problem constants; we
    derive them from the smoothness constant so every dataset shape gets a
    stable-but-fast step)."""
    _, L = constants(prob)
    return float(c / L)


def solve_exact(prob: Problem, iters: int = 100) -> jax.Array:
    """x*: closed form for ridge, Newton for logistic (d is small)."""
    n, d = prob.A.shape
    if prob.kind == "ridge":
        H = 2.0 * (prob.A.T @ prob.A) / n + 2.0 * prob.lam * jnp.eye(d)
        g = 2.0 * (prob.A.T @ prob.b) / n
        return jnp.linalg.solve(H, g)

    def newton_step(x, _):
        z = prob.A @ x
        p = jax.nn.sigmoid(-prob.b * z)
        g = prob.A.T @ (-prob.b * p) / n + 2.0 * prob.lam * x
        w = p * (1.0 - p)
        H = (prob.A * w[:, None]).T @ prob.A / n + 2.0 * prob.lam * jnp.eye(d)
        return x - jnp.linalg.solve(H, g), None

    x0 = jnp.zeros((d,))
    x, _ = jax.lax.scan(newton_step, x0, None, length=iters)
    return x


def rel_grad_norm(prob: Problem, x: jax.Array, g0: jax.Array | None = None):
    """The paper's y-axis: ||grad f(x)|| / ||grad f(x0)||."""
    g = jnp.linalg.norm(full_grad(prob, x))
    if g0 is None:
        return g
    return g / g0


def grad_norm0(prob: Problem) -> jax.Array:
    """||grad f(0)|| — the normalizer of the paper's y-axis.  Stays on
    device: the scan-based drivers divide by it inside the scan instead of
    fetching it to the host (DESIGN.md §3)."""
    return jnp.linalg.norm(full_grad(prob, jnp.zeros((prob.d,))))
