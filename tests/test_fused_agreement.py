"""Fused-vs-unfused agreement pins (ISSUE 6 tentpole, DESIGN.md §Fused
kernels hot-path).

The fused hot paths — the single-launch ``kernels/vr_update`` VR step in
the convex drivers and the Pallas rmsnorm/flash-attention forward +
fused VR correction in the LM epoch scan — must reproduce the retained
unfused oracle's trajectory:

  * convex drivers (in-process, vmap backend): every VR-family algorithm
    through the solver API at p ∈ {1, 4} — x64 is on (conftest), the
    fused kernel accumulates in the input precision, so agreement is
    near machine epsilon;
  * convex drivers under spmd (subprocess with 8 forced host devices —
    the main pytest process must keep the real single-device view, same
    rule as test_spmd_backend): fused spmd == unfused spmd for the
    sync/dsvrg/dsaga runners at p=4;
  * LM epoch scan: fused vmap == unfused vmap for every VR mode over
    TWO epochs — svrg's first epoch from a fresh snapshot is a no-op
    (g_snap == g and gbar == 0, so v == 0), so a one-epoch comparison
    would be vacuous for it;
  * contract checks: RunSpec validation of the ``fused`` axis, the
    fused-VR-requires-plain-SGD refusal, and donation safety (aliased
    buffers into the donating ``ops.vr_update`` entry point must raise,
    not silently corrupt).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

# x64 problems + in-input-precision kernel accumulation: the fused step
# is the same algebra in a different launch order
CONVEX_TOL = 1e-10

# float32 LM forward: kernel block order vs XLA fusion order
LM_TOL = dict(rtol=3e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# convex drivers, vmap backend (in-process)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,p", [
    ("centralvr", 1), ("svrg", 1), ("saga", 1),
    ("centralvr_sync", 4), ("centralvr_async", 4),
    ("dsvrg", 4), ("dsaga", 4),
])
def test_convex_fused_matches_unfused(algo, p):
    import jax

    from repro import RunSpec, solve
    from repro.config import ConvexConfig
    from repro.core import convex, distributed

    key = jax.random.PRNGKey(7)
    if p == 1:
        problem = convex.make_logistic_data(jax.random.PRNGKey(2), 48, 8)
        eta = convex.auto_eta(problem, 0.3)
    else:
        cfg = ConvexConfig(problem="logistic", n=48, d=8, workers=p)
        problem = distributed.make_distributed(jax.random.PRNGKey(2), cfg)
        eta = convex.auto_eta(problem.merged(), 0.3)

    res_u = solve(RunSpec(algo=algo, p=p, eta=eta, rounds=3), problem,
                  key=key)
    res_f = solve(RunSpec(algo=algo, p=p, eta=eta, rounds=3, fused=True),
                  problem, key=key)
    np.testing.assert_allclose(res_f.x, res_u.x, rtol=0, atol=CONVEX_TOL)
    np.testing.assert_allclose(res_f.rels, res_u.rels, rtol=CONVEX_TOL,
                               atol=CONVEX_TOL)


# ---------------------------------------------------------------------------
# convex drivers, spmd backend (forced-multi-device subprocess)
# ---------------------------------------------------------------------------

SPMD_SCRIPT = textwrap.dedent("""
    import sys
    sys.path.insert(0, "src")
    from repro.core import spmd
    spmd.force_host_devices(8)      # before the first jax operation
    import json
    import jax
    jax.config.update("jax_enable_x64", True)   # match conftest precision
    import numpy as np
    from repro.config import ConvexConfig
    from repro.core import convex, distributed

    def diff(a, b):
        return float(np.abs(np.asarray(a) - np.asarray(b)).max())

    def final_x(st):
        for attr in ("x", "x_c"):   # sync: x; dsaga (AsyncState): x_c
            if hasattr(st, attr):
                return getattr(st, attr)
        return st                   # dsvrg returns the iterate directly

    key = jax.random.PRNGKey(7)
    cfg = ConvexConfig(problem="logistic", n=48, d=8, workers=4)
    sp = distributed.make_distributed(jax.random.PRNGKey(2), cfg)
    eta = convex.auto_eta(sp.merged(), 0.3)

    out = {"device_count": jax.device_count(), "drivers": {}}
    for name, fn, kw in (
            ("sync", distributed.run_sync, {}),
            ("dsvrg", distributed.run_dsvrg, {"tau": 32}),
            ("dsaga", distributed.run_dsaga, {"fetch": "stale"})):
        st_u, rels_u = fn(sp, eta=eta, rounds=3, key=key, backend="spmd",
                          **kw)
        st_f, rels_f = fn(sp, eta=eta, rounds=3, key=key, backend="spmd",
                          fused=True, **kw)
        out["drivers"][name] = {"dx": diff(final_x(st_u), final_x(st_f)),
                                "drel": diff(rels_u, rels_f)}
    print("RESULT" + json.dumps(out))
""")


def test_convex_fused_matches_unfused_spmd():
    proc = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], cwd=ROOT,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    assert out["device_count"] == 8
    for name, d in out["drivers"].items():
        assert d["dx"] <= CONVEX_TOL, (name, d)
        assert d["drel"] <= CONVEX_TOL, (name, d)


# ---------------------------------------------------------------------------
# LM epoch scan
# ---------------------------------------------------------------------------

def _tiny_setup(vr, W):
    from repro.config import ModelConfig, TrainConfig

    cfg = ModelConfig(name="tiny-scan", family="dense", num_layers=2,
                      d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
                      vocab_size=128, dtype="float32",
                      param_dtype="float32")
    tcfg = TrainConfig(seq_len=16, global_batch=2 * W, microbatch=2,
                       optimizer="sgd", learning_rate=0.1, vr=vr,
                       vr_table_size=2, local_epoch=1)
    return cfg, tcfg


def _run_epochs(cfg, tcfg, W, fused, epochs=2):
    import jax

    from repro.train import step as tstep

    run_epoch, meta = tstep.make_epoch_runner(cfg, tcfg, W, backend="vmap",
                                              fused=fused)
    state = tstep.init_train_state(cfg, tcfg, jax.random.PRNGKey(0), W)
    losses = []
    for _ in range(epochs):
        state, ls = run_epoch(state)
        losses.append(np.asarray(ls, dtype=float))
    return state, np.concatenate([l.ravel() for l in losses])


@pytest.mark.parametrize("vr", ["centralvr", "svrg", "saga"])
@pytest.mark.parametrize("W", [1, 2])
def test_lm_fused_matches_unfused(vr, W):
    import jax

    cfg, tcfg = _tiny_setup(vr, W)
    # two epochs: svrg's first epoch from a fresh snapshot is a no-op
    st_u, loss_u = _run_epochs(cfg, tcfg, W, fused=False)
    st_f, loss_f = _run_epochs(cfg, tcfg, W, fused=True)
    for lu, lf in zip(jax.tree_util.tree_leaves(st_u.params),
                      jax.tree_util.tree_leaves(st_f.params)):
        np.testing.assert_allclose(np.asarray(lf), np.asarray(lu), **LM_TOL)
    np.testing.assert_allclose(loss_f, loss_u, **LM_TOL)
    # the unfused run must not have seen a vacuous trajectory
    assert np.all(np.isfinite(loss_u)) and loss_u.size >= 2


def test_lm_fused_auto_forward_only_with_adam():
    """fused='auto' with a non-sgd optimizer fuses only the model forward
    (no refusal); fused=True refuses — the fused VR step bakes plain SGD."""
    import jax

    from repro.train import step as tstep

    cfg, tcfg = _tiny_setup("centralvr", 1)
    import dataclasses
    tcfg = dataclasses.replace(tcfg, optimizer="adam")
    with pytest.raises(ValueError, match="plain SGD"):
        tstep.make_epoch_runner(cfg, tcfg, 1, backend="vmap", fused=True)
    run_epoch, meta = tstep.make_epoch_runner(cfg, tcfg, 1, backend="vmap",
                                              fused="auto")
    state = tstep.init_train_state(cfg, tcfg, jax.random.PRNGKey(0), 1)
    state, losses = run_epoch(state)
    assert np.all(np.isfinite(np.asarray(losses, dtype=float)))


# ---------------------------------------------------------------------------
# contracts
# ---------------------------------------------------------------------------

def test_runspec_fused_validation():
    from repro import RunSpec

    with pytest.raises(ValueError, match="fused"):
        RunSpec(algo="centralvr", eta=0.1, rounds=1, fused="yes")
    with pytest.raises(ValueError, match="no VR inner loop"):
        RunSpec(algo="sgd", eta=0.1, rounds=1, fused=True)
    # None normalizes to False; "auto" resolves per backend
    assert RunSpec(algo="centralvr", eta=0.1, rounds=1,
                   fused=None).fused is False
    assert RunSpec(algo="centralvr", eta=0.1, rounds=1,
                   fused="auto").fused == "auto"


def test_vr_update_rejects_aliased_donated_buffers():
    """``ops.vr_update`` donates all five operands; passing the same
    buffer for two of them must fail loudly (double donation), never
    silently alias the in-place update."""
    import jax.numpy as jnp

    from repro.kernels.vr_update import ops

    x = {"a": jnp.ones((64,), jnp.float32)}
    g = {"a": jnp.full((64,), 2.0, jnp.float32)}
    with pytest.raises(Exception, match="donate the same buffer twice"):
        ops.vr_update(x, x, g, g, g, eta=0.1, m=4, saga=False,
                      interpret=True)
