"""Minimal sharding-aware checkpointing: pytrees -> .npz (+ json manifest).

Arrays are gathered to host (works for sharded arrays), keyed by their
tree path; restore rebuilds into an existing abstract/concrete tree and
re-places onto the provided shardings. Deliberately orbax-free — the
container is offline and the trees here are plain dicts/NamedTuples.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out[key] = leaf
    return out, treedef


def save(path: str, tree, *, step: Optional[int] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(path, **arrays)
    manifest = {"step": step, "keys": sorted(arrays),
                "shapes": {k: list(v.shape) for k, v in arrays.items()},
                "dtypes": {k: str(v.dtype) for k, v in arrays.items()}}
    with open(path + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, like, shardings: Any = None):
    """Rebuild the tree of ``like`` (same structure) from the npz; place on
    ``shardings`` (same structure, optional)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = _flatten_with_paths(like)
    leaves = []
    for key in flat:
        if key not in data:
            raise KeyError(f"checkpoint missing {key!r}")
        leaves.append(data[key])
    flat_like = list(flat.values())
    restored = [np.asarray(a, dtype=l.dtype) for a, l in
                zip(leaves, flat_like)]
    tree = jax.tree_util.tree_unflatten(
        treedef, [jax.numpy.asarray(a) for a in restored])
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def latest_step(path: str) -> Optional[int]:
    try:
        with open(path + ".json") as f:
            return json.load(f).get("step")
    except FileNotFoundError:
        return None
