"""Algorithmic oracles for the model substrate:

  * chunked flash-style attention == naive attention (GQA, windows, softcap),
  * chunked SSD scan == naive sequential recurrence,
  * RG-LRU associative scan == step-by-step recurrence,
  * MoE sort-dispatch == dense one-hot reference (no dropping).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# property tests skip (per-test) without the hypothesis dev extra;
# plain tests in this module always run
from hypothesis_compat import given, settings, st  # noqa: E402

from repro.config import ModelConfig
from repro.models import attention, moe, rglru, ssm


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("window", [None, 7])
def test_chunked_attention_matches_naive(H, KV, window):
    key = jax.random.PRNGKey(0)
    B, S, hd = 2, 32, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, KV, hd), jnp.float32)
    out_c = attention.chunked_attention(q, k, v, window=window,
                                        q_chunk=8, kv_chunk=8)
    out_n = attention.naive_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_n),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000),
       chunks=st.sampled_from([(4, 4), (8, 16), (16, 8), (32, 32)]))
def test_chunked_attention_chunk_size_invariance(seed, chunks):
    """Output must not depend on the chunking (property test)."""
    key = jax.random.PRNGKey(seed)
    B, S, H, KV, hd = 1, 32, 2, 2, 8
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, KV, hd), jnp.float32)
    qc, kc = chunks
    out = attention.chunked_attention(q, k, v, q_chunk=qc, kv_chunk=kc)
    ref = attention.naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_attention_softcap():
    key = jax.random.PRNGKey(3)
    B, S, H, hd = 1, 16, 2, 8
    q = jax.random.normal(key, (B, S, H, hd)) * 4.0
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd)) * 4.0
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    out_c = attention.chunked_attention(q, k, v, q_chunk=4, kv_chunk=4,
                                        softcap=20.0)
    out_n = attention.naive_attention(q, k, v, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_n),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# SSD (Mamba2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_naive(chunk):
    key = jax.random.PRNGKey(1)
    B, S, H, P, N = 2, 32, 3, 4, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A_log = jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32))
    Bc = jax.random.normal(ks[2], (B, S, N), jnp.float32)
    Cc = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    h0 = jnp.zeros((B, H, P, N), jnp.float32)

    y_chunk, h_chunk = ssm._ssd_chunked(x, dt, A_log, Bc, Cc, h0, chunk)
    y_naive, h_naive = ssm.ssd_naive(x, dt, A_log, Bc, Cc, h0)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_naive),
                               atol=1e-4, rtol=1e-4)


def test_ssd_nonzero_initial_state():
    """Decode continuation: chunked scan from h0 != 0 must equal naive."""
    key = jax.random.PRNGKey(2)
    B, S, H, P, N = 1, 16, 2, 4, 8
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A_log = jnp.zeros((H,))
    Bc = jax.random.normal(ks[2], (B, S, N), jnp.float32)
    Cc = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    h0 = jax.random.normal(ks[4], (B, H, P, N), jnp.float32)
    y_c, hf_c = ssm._ssd_chunked(x, dt, A_log, Bc, Cc, h0, 4)
    y_n, hf_n = ssm.ssd_naive(x, dt, A_log, Bc, Cc, h0)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_n),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hf_c), np.asarray(hf_n),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_rglru_scan_matches_steps():
    cfg = ModelConfig(name="t", family="hybrid", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
                      vocab_size=64, rglru_heads=2,
                      block_pattern=("rec", "local"), local_window=8)
    p = rglru.init_rglru(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 20
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    h_scan, h_last = rglru.rglru_scan(p, x)
    h = jnp.zeros((B, cfg.d_model))
    outs = []
    for t in range(S):
        o, h = rglru.rglru_step(p, x[:, t:t + 1], h)
        outs.append(o[:, 0])
    h_steps = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(h_scan), np.asarray(h_steps),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                               atol=1e-5, rtol=1e-5)


def test_rglru_decay_bounded():
    """|a_t| < 1 always: the recurrence is contractive (stability)."""
    cfg = ModelConfig(name="t", family="hybrid", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=1, head_dim=8, d_ff=32,
                      vocab_size=64, rglru_heads=2, block_pattern=("rec",))
    p = rglru.init_rglru(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 16)) * 10.0
    log_a, _ = rglru._gates(p, x)
    assert np.all(np.asarray(log_a) < 0.0)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_cfg(E=4, K=2, shared=False):
    return ModelConfig(name="t", family="moe", num_layers=2, d_model=16,
                       num_heads=2, num_kv_heads=2, head_dim=8, d_ff=32,
                       vocab_size=64, num_experts=E, num_experts_per_tok=K,
                       moe_d_ff=8,
                       shared_expert_d_ff=16 if shared else 0,
                       shared_expert_gate=shared)


def _moe_dense_reference(p, cfg, x):
    """One-hot dense dispatch (no capacity limit)."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    topk_p, topk_e = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    topk_p = topk_p / topk_p.sum(-1, keepdims=True)
    w = jnp.zeros((xt.shape[0], cfg.num_experts)).at[
        jnp.arange(xt.shape[0])[:, None], topk_e].set(topk_p)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["wg"])) * jnp.einsum(
        "td,edf->tef", xt, p["wu"])
    y_all = jnp.einsum("tef,efd->ted", h, p["wd"])
    y = jnp.einsum("ted,te->td", y_all, w)
    if "shared" in p:
        from repro.models import layers
        sh = layers.apply_mlp(p["shared"], xt, "swiglu")
        if "shared_gate" in p:
            sh = sh * jax.nn.sigmoid(xt @ p["shared_gate"])
        y = y + sh
    return y.reshape(B, S, d)


@pytest.mark.slow
@pytest.mark.parametrize("shared", [False, True])
def test_moe_sort_dispatch_matches_dense_reference(shared):
    cfg = _moe_cfg(shared=shared)
    p = moe.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = moe.apply_moe(p, cfg, x, capacity_factor=float(cfg.num_experts))
    y_ref = _moe_dense_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)
    assert float(aux) > 0.0


@pytest.mark.slow
def test_moe_capacity_drops_overflow():
    """With capacity 'too small', output != reference but stays finite and
    the kept tokens' contributions are a subset (bounded norm)."""
    cfg = _moe_cfg()
    p = moe.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y_small, _ = moe.apply_moe(p, cfg, x, capacity_factor=0.25)
    y_full, _ = moe.apply_moe(p, cfg, x, capacity_factor=4.0)
    assert np.isfinite(np.asarray(y_small)).all()
    n_small = float(jnp.linalg.norm(y_small))
    n_full = float(jnp.linalg.norm(y_full))
    assert n_small < n_full


@pytest.mark.slow
def test_moe_load_balance_loss_uniform_router_is_minimal():
    """aux ~= coef for a perfectly uniform router (Switch normalization)."""
    cfg = _moe_cfg()
    p = moe.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    p = dict(p, router=jnp.zeros_like(p["router"]))
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64, cfg.d_model))
    _, aux = moe.apply_moe(p, cfg, x)
    assert abs(float(aux) - cfg.router_aux_coef) < 0.2 * cfg.router_aux_coef
