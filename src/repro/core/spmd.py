"""SPMD multi-device execution backend for the convex driver runtime.

The default backend simulates the p workers with a stacked leading axis
under ``jax.vmap`` — numerically identical to p processes, but every shard
lives on ONE device.  This module is the second backend (DESIGN.md §2):
the same local-epoch primitives run under ``jax.shard_map`` over a real
``jax.sharding.Mesh`` with one worker per device, so each worker's
``(ns, d)`` shard, VR table, and gradient accumulator are resident on its
own device and the paper's central server becomes collective communication
(``jax.lax.pmean`` over the worker axis) instead of a ``mean(axis=0)``.

On this container the mesh is CPU-simulated: ``force_host_devices(n)``
(shared by ``launch/mesh.py`` and the tests) forces the host platform to
present n devices via XLA_FLAGS — it must run before the jax backend
initializes, but after ``import jax`` is fine (device state is lazy).

Sampling is data, not code (the async event schedule's rule, DESIGN.md §3,
extended to RNG): every permutation/index draw is precomputed on the host
with EXACTLY the key splits the vmap drivers perform, then shipped to the
mesh sharded along the worker axis.  This is deliberate — on this jax
version, XLA's multi-device CPU partitioner miscompiles in-shard
``jax.random.permutation``/``randint`` in larger programs (every device
silently receives device 0's draw; the spmd/vmap disagreement that exposed
it is pinned by ``tests/test_spmd_backend.py``), and shipping the draws
also guarantees both backends consume identical randomness by
construction, so the only numerical divergence left is collective
reduction order.  (``check_rep=False`` on every runner for a related
reason: this jax version's replication checker rejects scan carries that
enter unreplicated and leave pmean-replicated, which is the shape of
every round loop here; correctness is pinned by the vmap-agreement tests
instead.)

The asynchronous drivers (CentralVR-Async, D-SAGA) run their deterministic
event schedule as ROUNDS OF CONCURRENT EVENTS: ``runtime.wave_partition``
groups the flat schedule into waves containing each worker at most once
(byte-identical event order), every worker of a wave runs its local epoch
from the central state it fetched at its previous event — a stale snapshot
carried per worker on its own device — and the Algorithm-3 delta pushes
``x += dx/p`` are applied at the wave boundary in the schedule's event
order (each worker's fresh fetch is the central state immediately after
its own event, reconstructed as a rank-prefix over the wave's
all-gathered deltas).  Same delta algebra, so the trajectories match the
event-serial scan within float32 tolerance.  D-SAGA requires the
``fetch="stale"`` discipline for this (see ``distributed.run_dsaga``);
instant-fetch D-SAGA remains event-serial and refuses ``backend="spmd"``.

Backend contract (pinned by ``tests/test_spmd_backend.py``):

  * trajectories agree with the event-equivalent vmap driver within
    float32 tolerance — including the async drivers, round-robin and
    heterogeneous-speed schedules alike;
  * worker state is genuinely placed: each shard of the ``(p, ns)`` tables
    maps to a distinct device;
  * instant-fetch D-SAGA (a serial dependency chain between events) raises
    ``NotImplementedError`` from ``distributed.py`` rather than silently
    falling back.
"""
from __future__ import annotations

import functools
import os
import re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import convex, runtime
from repro.core.convex import Problem
from repro.obs import stage as obs_stage
from repro.prox import operators as proxops

WORKER_AXIS = "workers"

_COUNT_FLAG = "--xla_force_host_platform_device_count"


# ---------------------------------------------------------------------------
# Host-device simulation + mesh construction
# ---------------------------------------------------------------------------

def force_host_devices(n: int) -> None:
    """Make the CPU host platform present ``n`` devices (XLA_FLAGS).

    Safe to call after ``import jax`` but only before the backend
    initializes (first ``jax.devices()`` / first op); afterwards it is a
    no-op if enough devices already exist and an error otherwise.  Both
    ``launch/mesh.py`` and the spmd tests go through here so the flag is
    spelled in exactly one place.
    """
    from jax._src import xla_bridge

    if xla_bridge.backends_are_initialized():
        if jax.device_count() >= n:
            return
        raise RuntimeError(
            f"jax already initialized with {jax.device_count()} device(s); "
            f"force_host_devices({n}) must run before the first jax "
            "operation (importing jax is fine — touching devices is not)")
    flags = os.environ.get("XLA_FLAGS", "")
    existing = re.search(rf"{_COUNT_FLAG}=(\d+)", flags)
    if existing:
        # at-least-n semantics, same as the post-init branch: never lower
        # a count someone already forced (e.g. a user-exported XLA_FLAGS)
        if int(existing.group(1)) < n:
            flags = re.sub(rf"{_COUNT_FLAG}=\d+", f"{_COUNT_FLAG}={n}",
                           flags)
    else:
        flags = (flags + f" {_COUNT_FLAG}={n}").strip()
    os.environ["XLA_FLAGS"] = flags


def worker_mesh(p: int) -> Mesh:
    """A 1-D mesh of p devices, one CentralVR worker per device."""
    devs = jax.devices()
    if len(devs) < p:
        raise RuntimeError(
            f"spmd backend needs {p} devices, found {len(devs)}; on CPU "
            f"call repro.core.spmd.force_host_devices({p}) before the "
            f"first jax operation (or set "
            f'XLA_FLAGS="{_COUNT_FLAG}={p}")')
    return Mesh(np.asarray(devs[:p]), (WORKER_AXIS,))


def process_worker_mesh(p: int) -> Mesh:
    """A GLOBAL 1-D worker mesh spanning every process of a
    ``jax.distributed`` world (DESIGN.md §Multi-host & elasticity).

    The execution model has three tiers: single-process vmap (the
    event-serial reference), single-process spmd (this module's
    ``worker_mesh`` over local simulated host devices), and the
    multi-process tier, where each process owns a contiguous block of the
    p workers (``procmesh.worker_blocks``).  On accelerator backends the
    block maps onto this global mesh and the runners here execute it
    under ``shard_map``; on CPU, XLA cannot compile cross-process
    computations, so ``core/procmesh.py`` runs the blocks as local jitted
    programs and exchanges wave-boundary deltas through the coordination
    service instead — this helper then only validates the world shape.
    """
    devs = jax.devices()
    if len(devs) < p:
        raise RuntimeError(
            f"process mesh needs {p} devices across the world, found "
            f"{len(devs)} over {jax.process_count()} process(es); grow "
            "the world or lower p")
    if jax.process_count() > 1 and p % jax.process_count():
        raise RuntimeError(
            f"process mesh: p={p} workers do not divide evenly over "
            f"{jax.process_count()} processes; shard_map needs equal "
            "per-process blocks (the KV-store engines in core/procmesh.py "
            "accept uneven blocks)")
    return Mesh(np.asarray(devs[:p]), (WORKER_AXIS,))


def _check_mesh(mesh: Optional[Mesh], p: int) -> Mesh:
    mesh = mesh if mesh is not None else worker_mesh(p)
    if mesh.devices.size != p:
        raise ValueError(
            f"mesh has {mesh.devices.size} devices but the problem has "
            f"{p} workers; the spmd backend places exactly one worker "
            "per mesh device")
    return mesh


def _put(mesh: Mesh, sharded_tree, replicated_tree, worker_dim=0):
    """Place worker-stacked leaves sharded along ``worker_dim`` and
    everything else replicated, so the jitted runners see consistent input
    shardings (mixing mesh-sharded and single-device-committed args is an
    error)."""
    spec = P(*([None] * worker_dim + [WORKER_AXIS]))
    shard = NamedSharding(mesh, spec)
    repl = NamedSharding(mesh, P())
    return (jax.device_put(sharded_tree, shard),
            jax.device_put(replicated_tree, repl))


# ---------------------------------------------------------------------------
# Host-side RNG precompute — bit-identical to the vmap drivers' draws
# ---------------------------------------------------------------------------

def _round_perms(keys: jax.Array, p: int, ns: int) -> jax.Array:
    """(rounds, p, ns) permutations: per round, split the round key into p
    and draw each worker's epoch permutation — exactly ``sync_round``."""
    return jax.vmap(lambda k: jax.vmap(
        lambda kk: jax.random.permutation(kk, ns))(jax.random.split(k, p))
    )(keys)


def _round_indices(keys: jax.Array, p: int, ns: int, tau: int) -> jax.Array:
    """(rounds, p, tau) uniform index draws — exactly the vmapped
    ``jax.random.randint(kk, (tau,), 0, ns)`` of the local-loop drivers."""
    return jax.vmap(lambda k: jax.vmap(
        lambda kk: jax.random.randint(kk, (tau,), 0, ns))(
        jax.random.split(k, p)))(keys)


# ---------------------------------------------------------------------------
# In-shard metric helpers
# ---------------------------------------------------------------------------

def _rel_grad_norm(local: Problem, x: jax.Array, g0: jax.Array,
                   prox=None, eta=None) -> jax.Array:
    """The paper's y-axis on the GLOBAL objective, from inside a shard:
    per-shard data-term means are equal-weighted (every worker holds ns
    samples), so their pmean is the merged problem's data gradient.  With
    a prox, the smooth norm becomes the composite gradient-mapping norm —
    the same metric ``convex.rel_grad_norm(..., prox=)`` reports, so the
    vmap/spmd agreement pins cover the prox'd trajectories too."""
    s = convex.scalar_residual_all(local, x)
    data = jax.lax.pmean(convex.data_grad_from_scalars(local, s), WORKER_AXIS)
    full = data + 2.0 * local.lam * x
    if prox is None:
        return jnp.linalg.norm(full) / g0
    return jnp.linalg.norm(proxops.grad_map(prox, x, full, eta)) / g0


def _full_grad(local: Problem, x: jax.Array) -> jax.Array:
    """Global full gradient via collective: pmean of per-shard full
    gradients (the replicated 2·lam·x term averages to itself)."""
    return jax.lax.pmean(convex.full_grad(local, x), WORKER_AXIS)


# ---------------------------------------------------------------------------
# CentralVR-Sync (Algorithm 2) under shard_map
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _sync_runner(mesh: Mesh, kind: str, fused=None, prox=None):
    """One compiled executable per (mesh, problem kind, fused params, prox
    spec): init epoch + the whole round scan inside a single jitted
    shard_map.  Cached so warm calls skip shard_map re-construction and
    hit the jit cache.  ``fused`` is the static kernel-params tuple from
    ``fused.make_params`` and ``prox`` a static ProxSpec-or-None
    (both hashable, so they extend the cache key).  Prox placement mirrors
    ``distributed.sync_round`` exactly: per local step, then once more
    after the central pmean (the wave-boundary ordering, DESIGN.md §2)."""
    from repro.core.distributed import _local_centralvr_epoch, _local_sgd_epoch

    def body(A, b, lam, eta, g0, perm0, perms):
        A, b, perm0 = A[0], b[0], perm0[0]    # this worker's shard
        local = Problem(A, b, lam, kind)

        # --- init: one plain-SGD epoch per worker, then average (line 2)
        x0 = jnp.zeros((A.shape[1],), dtype=A.dtype)
        x_w, table, acc = _local_sgd_epoch(A, b, lam, kind, x0, eta, perm0,
                                           prox=prox)
        x = proxops.apply_prox(prox, jax.lax.pmean(x_w, WORKER_AXIS), eta)
        gbar = jax.lax.pmean(acc, WORKER_AXIS)

        # --- communication rounds (lines 4-18): local epoch, then the
        # central average of (x, gbar) as a collective pmean
        def one_round(carry, perm):
            x, table, gbar = carry
            x_w, table, acc = _local_centralvr_epoch(
                A, b, lam, kind, x, table, gbar, eta, perm[0], fused=fused,
                prox=prox)
            x = proxops.apply_prox(prox, jax.lax.pmean(x_w, WORKER_AXIS),
                                   eta)
            gbar = jax.lax.pmean(acc, WORKER_AXIS)
            rel = _rel_grad_norm(local, x, g0, prox=prox, eta=eta)
            return (x, table, gbar), rel

        (x, table, gbar), rels = jax.lax.scan(one_round, (x, table, gbar),
                                              perms)
        return x, table[None], gbar, rels

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(WORKER_AXIS), P(WORKER_AXIS), P(), P(), P(),
                  P(WORKER_AXIS), P(None, WORKER_AXIS)),
        out_specs=(P(), P(WORKER_AXIS), P(), P()), check_rep=False))


def run_sync(sp, *, eta: float, rounds: int, key: jax.Array,
             mesh: Optional[Mesh] = None, fused=False, prox=None):
    """Algorithm 2 with one worker per device (DESIGN.md §2, spmd backend).
    Same RNG draws as the vmap driver (precomputed on host), so the
    trajectories agree within reduction-order float noise."""
    from repro.core import fused as fusedmod
    from repro.core.distributed import SyncState

    px = proxops.parse(prox) if prox is not None else None
    fused_t = fusedmod.make_params(fused, eta, sp.lam, prox=px)
    mesh = _check_mesh(mesh, sp.p)
    k_init, k_run = jax.random.split(key)
    g0 = convex.grad_norm0(sp.merged(), prox=px, eta=eta)
    perm0 = jax.vmap(lambda kk: jax.random.permutation(kk, sp.ns))(
        jax.random.split(k_init, sp.p))
    perms = _round_perms(jax.random.split(k_run, rounds), sp.p, sp.ns)
    (A, b, perm0), (lam, eta, g0) = _put(
        mesh, (sp.A, sp.b, perm0), (sp.lam, jnp.asarray(eta), g0))
    (perms,), () = _put(mesh, (perms,), (), worker_dim=1)
    x, tables, gbar, rels = obs_stage.staged_call(
        _sync_runner(mesh, sp.kind, fused_t, px),
        A, b, lam, eta, g0, perm0, perms, _label="spmd/centralvr_sync")
    return SyncState(x=x, tables=tables, gbar=gbar), rels


# ---------------------------------------------------------------------------
# Distributed SVRG (Algorithm 4) under shard_map
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _dsvrg_runner(mesh: Mesh, kind: str, fused=None, prox=None,
                  snapshot: str = "last"):
    """Prox placement and snapshot selection mirror
    ``distributed._dsvrg_scan`` exactly: prox per inner step and once more
    after the cross-worker pmean; snapshot anchors last/avg/rand with the
    rand index host-precomputed and shipped replicated (``snap``), so both
    backends pick the same inner iterate."""
    def body(A, b, lam, eta, g0, idx, snap):
        A, b = A[0], b[0]
        local = Problem(A, b, lam, kind)
        x0 = jnp.zeros((A.shape[1],), dtype=A.dtype)

        def round_(x, ins):
            idx_r, r = ins
            xbar = x
            gbar = _full_grad(local, xbar)   # sync step (line 5)

            if fused is not None:
                # snapshot=="last" here (run_dsvrg falls back otherwise)
                from repro.core import fused as fusedmod
                sbar = convex.scalar_residual_all(local, xbar)
                xl = fusedmod.svrg_steps(A, b, kind, xbar, sbar, gbar,
                                         idx_r[0], fused)
            else:
                def step(xl, i):
                    g = (convex.scalar_residual(local, xl, i) * A[i]
                         - convex.scalar_residual(local, xbar, i) * A[i]
                         + gbar + 2.0 * lam * (xl - xbar))
                    xl = proxops.apply_prox(prox, xl - eta * g, eta)
                    return xl, (xl if snapshot != "last" else None)

                xl, traj = jax.lax.scan(step, xbar, idx_r[0])
                if snapshot == "avg":
                    xl = traj.mean(0)
                elif snapshot == "rand":
                    xl = traj[r]
            x = proxops.apply_prox(prox, jax.lax.pmean(xl, WORKER_AXIS),
                                   eta)
            rel = _rel_grad_norm(local, x, g0, prox=prox, eta=eta)
            return x, rel

        return jax.lax.scan(round_, x0, (idx, snap))

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(WORKER_AXIS), P(WORKER_AXIS), P(), P(), P(),
                  P(None, WORKER_AXIS), P()),
        out_specs=(P(), P()), check_rep=False))


def run_dsvrg(sp, *, eta: float, rounds: int, key: jax.Array, tau: int = 0,
              mesh: Optional[Mesh] = None, fused=False, prox=None,
              snapshot: str = "last"):
    from repro.core import fused as fusedmod

    px = proxops.parse(prox) if prox is not None else None
    fused_t = (fusedmod.make_params(fused, eta, sp.lam, prox=px)
               if snapshot == "last" else None)
    tau = tau or 2 * sp.ns
    mesh = _check_mesh(mesh, sp.p)
    g0 = convex.grad_norm0(sp.merged(), prox=px, eta=eta)
    idx = _round_indices(jax.random.split(key, rounds), sp.p, sp.ns, tau)
    # same draw as distributed.run_dsvrg (fold_in off the main key stream)
    snap = (jax.random.randint(jax.random.fold_in(key, 1), (rounds,),
                               0, tau)
            if snapshot == "rand" else jnp.zeros((rounds,), jnp.int32))
    (A, b), (lam, eta, g0, snap) = _put(
        mesh, (sp.A, sp.b), (sp.lam, jnp.asarray(eta), g0, snap))
    (idx,), () = _put(mesh, (idx,), (), worker_dim=1)
    return obs_stage.staged_call(
        _dsvrg_runner(mesh, sp.kind, fused_t, px, snapshot),
        A, b, lam, eta, g0, idx, snap, _label="spmd/dsvrg")


# ---------------------------------------------------------------------------
# Minibatch baselines under shard_map
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _dist_sgd_runner(mesh: Mesh, kind: str):
    def body(A, b, lam, g0, idx, etas):
        A, b = A[0], b[0]
        local = Problem(A, b, lam, kind)
        x0 = jnp.zeros((A.shape[1],), dtype=A.dtype)

        def round_(x, ins):
            idx_r, eta_l = ins

            def step(xl, i):
                g = (convex.scalar_residual(local, xl, i) * A[i]
                     + 2.0 * lam * xl)
                return xl - eta_l * g, None

            xl, _ = jax.lax.scan(step, x, idx_r[0])
            x_new = jax.lax.pmean(xl, WORKER_AXIS)
            return x_new, _rel_grad_norm(local, x_new, g0)

        return jax.lax.scan(round_, x0, (idx, etas))

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(WORKER_AXIS), P(WORKER_AXIS), P(), P(),
                  P(None, WORKER_AXIS), P()),
        out_specs=(P(), P()), check_rep=False))


def run_dist_sgd(sp, *, eta: float, rounds: int, key: jax.Array,
                 tau: int = 0, decay: float = 0.0,
                 mesh: Optional[Mesh] = None):
    tau = tau or sp.ns
    mesh = _check_mesh(mesh, sp.p)
    g0 = convex.grad_norm0(sp.merged())
    idx = _round_indices(jax.random.split(key, rounds), sp.p, sp.ns, tau)
    etas = eta / (1.0 + decay * jnp.arange(rounds) * tau) ** 0.5
    (A, b), (lam, g0, etas) = _put(
        mesh, (sp.A, sp.b), (sp.lam, g0, etas))
    (idx,), () = _put(mesh, (idx,), (), worker_dim=1)
    return obs_stage.staged_call(_dist_sgd_runner(mesh, sp.kind),
                                 A, b, lam, g0, idx, etas,
                                 _label="spmd/dist_sgd")


@functools.lru_cache(maxsize=None)
def _easgd_runner(mesh: Mesh, kind: str):
    def body(A, b, lam, alpha, g0, idx, etas):
        A, b = A[0], b[0]
        local = Problem(A, b, lam, kind)
        d = A.shape[1]
        xc0 = jnp.zeros((d,), dtype=A.dtype)
        xl0 = jnp.zeros((d,), dtype=A.dtype)

        def round_(carry, ins):
            xc, xl = carry
            idx_r, eta_l = ins

            def comm_block(carry, idx_tau):
                xl, xc_view = carry

                def step(x, i):
                    g = (convex.scalar_residual(local, x, i) * A[i]
                         + 2.0 * lam * x)
                    return x - eta_l * g, None

                xl, _ = jax.lax.scan(step, xl, idx_tau)
                diff = xl - xc_view
                return (xl - alpha * diff, xc_view + alpha * diff), diff

            (xl, _), diffs = jax.lax.scan(comm_block, (xl, xc), idx_r[0])
            # center update: sum of worker contributions / p == pmean
            xc = xc + alpha * jax.lax.pmean(diffs.sum(0), WORKER_AXIS)
            rel = _rel_grad_norm(local, xc, g0)
            return (xc, xl), rel

        (xc, xl), rels = jax.lax.scan(round_, (xc0, xl0), (idx, etas))
        return xc, xl[None], rels

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(WORKER_AXIS), P(WORKER_AXIS), P(), P(), P(),
                  P(None, WORKER_AXIS), P()),
        out_specs=(P(), P(WORKER_AXIS), P()), check_rep=False))


def run_easgd(sp, *, eta: float, rounds: int, key: jax.Array, tau: int = 16,
              rho: float = 1.0, decay: float = 0.0,
              mesh: Optional[Mesh] = None):
    mesh = _check_mesh(mesh, sp.p)
    alpha = min(0.9 / sp.p, eta * rho * tau)
    steps_per_round = max(sp.ns // tau, 1)
    g0 = convex.grad_norm0(sp.merged())
    idx = _round_indices(jax.random.split(key, rounds), sp.p, sp.ns,
                         steps_per_round * tau)
    idx = idx.reshape(rounds, sp.p, steps_per_round, tau)
    etas = eta / (1.0 + decay * jnp.arange(rounds) * sp.ns) ** 0.5
    (A, b), (lam, alpha, g0, etas) = _put(
        mesh, (sp.A, sp.b), (sp.lam, jnp.asarray(alpha), g0, etas))
    (idx,), () = _put(mesh, (idx,), (), worker_dim=1)
    xc, _, rels = obs_stage.staged_call(
        _easgd_runner(mesh, sp.kind), A, b, lam, alpha, g0, idx, etas,
        _label="spmd/easgd")
    return xc, rels


@functools.lru_cache(maxsize=None)
def _ps_svrg_runner(mesh: Mesh, kind: str):
    def body(A, b, lam, eta, g0, idx):
        A, b = A[0], b[0]
        local = Problem(A, b, lam, kind)
        x0 = jnp.zeros((A.shape[1],), dtype=A.dtype)

        def round_(x, idx_r):
            xbar = x
            gbar = _full_grad(local, xbar)

            def step(x, ii):
                # this worker's index of the server step's (p,) draw
                i = ii[0]
                g_w = ((convex.scalar_residual(local, x, i)
                        - convex.scalar_residual(local, xbar, i)) * A[i]
                       + gbar + 2.0 * lam * (x - xbar))
                g = jax.lax.pmean(g_w, WORKER_AXIS)
                return x - eta * g, None

            x, _ = jax.lax.scan(step, x, idx_r)
            return x, _rel_grad_norm(local, x, g0)

        return jax.lax.scan(round_, x0, idx)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(WORKER_AXIS), P(WORKER_AXIS), P(), P(), P(),
                  P(None, None, WORKER_AXIS)),
        out_specs=(P(), P()), check_rep=False))


def run_ps_svrg(sp, *, eta: float, rounds: int, key: jax.Array,
                epoch_mult: int = 2, mesh: Optional[Mesh] = None):
    mesh = _check_mesh(mesh, sp.p)
    g0 = convex.grad_norm0(sp.merged())
    inner = epoch_mult * sp.ns
    # (rounds, inner, p): per server step, one index per worker — exactly
    # the vmap driver's randint(ks, (p,)) stream
    idx = jax.vmap(lambda k: jax.vmap(
        lambda ks: jax.random.randint(ks, (sp.p,), 0, sp.ns))(
        jax.random.split(k, inner)))(jax.random.split(key, rounds))
    (A, b), (lam, eta, g0) = _put(
        mesh, (sp.A, sp.b), (sp.lam, jnp.asarray(eta), g0))
    (idx,), () = _put(mesh, (idx,), (), worker_dim=2)
    return obs_stage.staged_call(_ps_svrg_runner(mesh, sp.kind),
                                 A, b, lam, eta, g0, idx,
                                 _label="spmd/ps_svrg")


# ---------------------------------------------------------------------------
# Async drivers (Algorithms 3 & 5) as concurrency waves under shard_map
# ---------------------------------------------------------------------------

def _scatter_events(draws, schedule, slot, shape):
    """Arrange per-event host-precomputed draws ``(total, ...)`` — computed
    in flat schedule order with EXACTLY the event-serial drivers' key
    splits — into the ``(rounds, W, p, ...)`` wave layout of
    ``runtime.wave_partition``.  Inactive (padding) slots keep a zeros
    filler: index 0 is valid everywhere and the runner masks those
    workers' results out."""
    rounds, width, p = shape
    draws = np.asarray(draws)
    out = np.zeros((rounds * width, p) + draws.shape[1:], dtype=draws.dtype)
    out[slot, schedule] = draws
    return out.reshape((rounds, width, p) + draws.shape[1:])


def _wave_push(x_c, gbar_c, dxs, dgs, rk, my_rank, alpha, alpha_g):
    """Apply a wave's delta pushes to the central state and reconstruct
    this worker's fresh fetch.  ``dxs``/``dgs`` are the all-gathered
    (p, d) per-worker deltas (zero where inactive); the serial scan adds
    them one event at a time, so worker w's fetch — the central state
    immediately after ITS event — is the rank-prefix sum ``rk <= my_rank``
    over the wave (inactive workers carry the rank sentinel p and a zero
    delta, so they never contribute).  Returns (x_c', gbar_c', x_f, g_f)."""
    pre = (rk <= my_rank)[:, None]
    x_f = x_c + alpha * jnp.where(pre, dxs, 0.0).sum(0)
    g_f = gbar_c + alpha_g * jnp.where(pre, dgs, 0.0).sum(0)
    x_c = x_c + alpha * dxs.sum(0)
    gbar_c = gbar_c + alpha_g * dgs.sum(0)
    return x_c, gbar_c, x_f, g_f


@functools.lru_cache(maxsize=None)
def _async_runner(mesh: Mesh, kind: str, fused=None, prox=None):
    """CentralVR-Async (Algorithm 3) with one worker per device: the whole
    wave schedule in one jitted shard_map.  Each worker's stale snapshot
    (x_fetch, gbar_fetch), previous contribution (x_old, gbar_old), and
    scalar table live on its own device; the central (x_c, gbar_c) are
    replicated and advanced at wave boundaries.  Prox placement mirrors
    ``distributed.async_event``: the central accumulator stays linear in
    the deltas (the wave prefix-sum reconstruction requires it) and each
    worker prox's its fetched copy at epoch start; the metric evaluates
    at ``prox(x_c)``."""
    from repro.core.distributed import _local_centralvr_epoch, _local_sgd_epoch

    p = int(mesh.devices.size)
    alpha = 1.0 / p

    def body(A, b, lam, eta, g0, perm0, active, rank, perms):
        A, b, perm0 = A[0], b[0], perm0[0]    # this worker's shard
        local = Problem(A, b, lam, kind)
        w_idx = jax.lax.axis_index(WORKER_AXIS)

        # --- init == async_init: one SGD epoch per worker, average, and
        # every worker's previous contribution / fetch set to that iterate
        x0 = jnp.zeros((A.shape[1],), dtype=A.dtype)
        x_w, table, acc = _local_sgd_epoch(A, b, lam, kind, x0, eta, perm0,
                                           prox=prox)
        x_c = proxops.apply_prox(prox, jax.lax.pmean(x_w, WORKER_AXIS), eta)
        gbar_c = jax.lax.pmean(acc, WORKER_AXIS)
        carry0 = (x_c, gbar_c, table, x_c, gbar_c, x_c, gbar_c)

        def one_round(carry, xs):
            act_r, rank_r, perm_r = xs

            def one_wave(carry, wv):
                (x_c, gbar_c, table, x_old, gbar_old,
                 x_fetch, gbar_fetch) = carry
                act, rk, perm = wv
                # every worker traces the epoch; inactive results are
                # masked (round-robin schedules have no inactive slots)
                x_new, table_new, gtilde = _local_centralvr_epoch(
                    A, b, lam, kind,
                    proxops.apply_prox(prox, x_fetch, eta), table,
                    gbar_fetch, eta, perm[0], fused=fused, prox=prox)
                on = act[w_idx]
                dx = jnp.where(on, x_new - x_old, 0.0)
                dg = jnp.where(on, gtilde - gbar_old, 0.0)
                dxs = jax.lax.all_gather(dx, WORKER_AXIS)
                dgs = jax.lax.all_gather(dg, WORKER_AXIS)
                x_c, gbar_c, x_f, g_f = _wave_push(
                    x_c, gbar_c, dxs, dgs, rk, rk[w_idx], alpha, alpha)
                table = jnp.where(on, table_new, table)
                x_old = jnp.where(on, x_new, x_old)
                gbar_old = jnp.where(on, gtilde, gbar_old)
                x_fetch = jnp.where(on, x_f, x_fetch)
                gbar_fetch = jnp.where(on, g_f, gbar_fetch)
                return (x_c, gbar_c, table, x_old, gbar_old,
                        x_fetch, gbar_fetch), None

            carry, _ = jax.lax.scan(one_wave, carry, (act_r, rank_r, perm_r))
            rel = _rel_grad_norm(local,
                                 proxops.apply_prox(prox, carry[0], eta),
                                 g0, prox=prox, eta=eta)
            return carry, rel

        carry, rels = jax.lax.scan(one_round, carry0, (active, rank, perms))
        x_c, gbar_c, table, x_old, gbar_old, x_fetch, gbar_fetch = carry
        return (x_c, gbar_c, table[None], x_old[None], gbar_old[None],
                x_fetch[None], gbar_fetch[None], rels)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(WORKER_AXIS), P(WORKER_AXIS), P(), P(), P(),
                  P(WORKER_AXIS), P(), P(), P(None, None, WORKER_AXIS)),
        out_specs=(P(), P(), P(WORKER_AXIS), P(WORKER_AXIS), P(WORKER_AXIS),
                   P(WORKER_AXIS), P(WORKER_AXIS), P()), check_rep=False))


def _wave_inputs(mesh, sp, schedule, draws):
    """Common wave-layout plumbing: partition the schedule, scatter the
    per-event draws into (rounds, W, p, ...), and place everything —
    active/rank replicated, draws sharded along the worker axis."""
    active, rank, slot = runtime.wave_partition(schedule, sp.p)
    waved = _scatter_events(draws, schedule, slot, active.shape)
    (), (active, rank) = _put(mesh, (), (jnp.asarray(active),
                                         jnp.asarray(rank)))
    (waved,), () = _put(mesh, (waved,), (), worker_dim=2)
    return active, rank, waved


def run_async(sp, *, eta: float, rounds: int, key: jax.Array, speeds=None,
              mesh: Optional[Mesh] = None, fused=False, prox=None):
    """Algorithm 3 as concurrency waves (DESIGN.md §2, spmd-async mode).
    Identical schedule, identical RNG draws, and identical delta algebra
    as ``distributed.run_async`` — the event-serial reference it is pinned
    against."""
    from repro.core import fused as fusedmod
    from repro.core.distributed import AsyncState

    px = proxops.parse(prox) if prox is not None else None
    fused_t = fusedmod.make_params(fused, eta, sp.lam, prox=px)
    mesh = _check_mesh(mesh, sp.p)
    k_init, k_run = jax.random.split(key)
    g0 = convex.grad_norm0(sp.merged(), prox=px, eta=eta)
    # init draws: exactly sync_init's splits (async_init delegates to it)
    perm0 = jax.vmap(lambda kk: jax.random.permutation(kk, sp.ns))(
        jax.random.split(k_init, sp.p))
    schedule = runtime.event_schedule(sp.p, rounds, speeds)
    # per-event draws: exactly async_event's permutation(keys[t], ns)
    perms = jax.vmap(lambda k: jax.random.permutation(k, sp.ns))(
        jax.random.split(k_run, schedule.size))
    (A, b, perm0), (lam, eta, g0) = _put(
        mesh, (sp.A, sp.b, perm0), (sp.lam, jnp.asarray(eta), g0))
    active, rank, perms = _wave_inputs(mesh, sp, schedule, perms)
    (x_c, gbar_c, tables, x_old, gbar_old, x_fetch, gbar_fetch,
     rels) = obs_stage.staged_call(
        _async_runner(mesh, sp.kind, fused_t, px),
        A, b, lam, eta, g0, perm0, active, rank, perms,
        _label="spmd/centralvr_async")
    return AsyncState(x_c=x_c, gbar_c=gbar_c, tables=tables, x_old=x_old,
                      gbar_old=gbar_old, x_fetch=x_fetch,
                      gbar_fetch=gbar_fetch), rels


@functools.lru_cache(maxsize=None)
def _dsaga_runner(mesh: Mesh, kind: str, literal_scaling: bool, fused=None,
                  prox=None):
    """Stale-fetch D-SAGA (Algorithm 5 with Algorithm 3's fetch
    discipline) as concurrency waves — the spmd execution of
    ``distributed.dsaga_event_stale`` (prox'd fetch, linear central
    accumulator, metric at ``prox(x_c)``)."""
    from repro.core.distributed import _local_saga_steps

    p = int(mesh.devices.size)
    alpha = 1.0 / p
    alpha_g = alpha if literal_scaling else 1.0

    def body(A, b, lam, eta, g0, active, rank, idx):
        A, b = A[0], b[0]
        local = Problem(A, b, lam, kind)
        n_global = p * A.shape[0]
        w_idx = jax.lax.axis_index(WORKER_AXIS)

        # --- init == dsaga_init: tables at x0, central gbar = table mean
        x0 = jnp.zeros((A.shape[1],), dtype=A.dtype)
        table = convex.scalar_residual_all(local, x0)
        gbar_c = jax.lax.pmean(
            convex.data_grad_from_scalars(local, table), WORKER_AXIS)
        carry0 = (x0, gbar_c, table, x0, gbar_c, x0, gbar_c)

        def one_round(carry, xs):
            act_r, rank_r, idx_r = xs

            def one_wave(carry, wv):
                (x_c, gbar_c, table, x_old, gbar_old,
                 x_fetch, gbar_fetch) = carry
                act, rk, idx_w = wv
                x_new, table_new, gb = _local_saga_steps(
                    A, b, lam, kind,
                    proxops.apply_prox(prox, x_fetch, eta), table,
                    gbar_fetch, eta, n_global, idx_w[0], fused=fused,
                    prox=prox)
                on = act[w_idx]
                dx = jnp.where(on, x_new - x_old, 0.0)
                if literal_scaling:
                    dg = jnp.where(on, gb - gbar_old, 0.0)
                else:
                    dg = jnp.where(on, gb - gbar_fetch, 0.0)
                dxs = jax.lax.all_gather(dx, WORKER_AXIS)
                dgs = jax.lax.all_gather(dg, WORKER_AXIS)
                x_c, gbar_c, x_f, g_f = _wave_push(
                    x_c, gbar_c, dxs, dgs, rk, rk[w_idx], alpha, alpha_g)
                table = jnp.where(on, table_new, table)
                x_old = jnp.where(on, x_new, x_old)
                gbar_old = jnp.where(on, gb, gbar_old)
                x_fetch = jnp.where(on, x_f, x_fetch)
                gbar_fetch = jnp.where(on, g_f, gbar_fetch)
                return (x_c, gbar_c, table, x_old, gbar_old,
                        x_fetch, gbar_fetch), None

            carry, _ = jax.lax.scan(one_wave, carry, (act_r, rank_r, idx_r))
            rel = _rel_grad_norm(local,
                                 proxops.apply_prox(prox, carry[0], eta),
                                 g0, prox=prox, eta=eta)
            return carry, rel

        carry, rels = jax.lax.scan(one_round, carry0, (active, rank, idx))
        x_c, gbar_c, table, x_old, gbar_old, x_fetch, gbar_fetch = carry
        return (x_c, gbar_c, table[None], x_old[None], gbar_old[None],
                x_fetch[None], gbar_fetch[None], rels)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(WORKER_AXIS), P(WORKER_AXIS), P(), P(), P(),
                  P(), P(), P(None, None, WORKER_AXIS)),
        out_specs=(P(), P(), P(WORKER_AXIS), P(WORKER_AXIS), P(WORKER_AXIS),
                   P(WORKER_AXIS), P(WORKER_AXIS), P()), check_rep=False))


def run_dsaga(sp, *, eta: float, rounds: int, key: jax.Array, tau: int = 100,
              literal_scaling: bool = False, speeds=None,
              mesh: Optional[Mesh] = None, fused=False, prox=None):
    """Stale-fetch Algorithm 5 as concurrency waves (DESIGN.md §2).
    Pinned against ``distributed.run_dsaga(fetch="stale")``, the
    event-serial scan with the same fetch discipline, schedule, and RNG."""
    from repro.core import fused as fusedmod
    from repro.core.distributed import AsyncState

    px = proxops.parse(prox) if prox is not None else None
    fused_t = fusedmod.make_params(fused, eta, sp.lam, prox=px)
    mesh = _check_mesh(mesh, sp.p)
    g0 = convex.grad_norm0(sp.merged(), prox=px, eta=eta)
    schedule = runtime.event_schedule(sp.p, rounds, speeds)
    # per-event draws: exactly dsaga_event's randint(keys[t], (tau,), 0, ns)
    idx = jax.vmap(lambda k: jax.random.randint(k, (tau,), 0, sp.ns))(
        jax.random.split(key, schedule.size))
    (A, b), (lam, eta, g0) = _put(
        mesh, (sp.A, sp.b), (sp.lam, jnp.asarray(eta), g0))
    active, rank, idx = _wave_inputs(mesh, sp, schedule, idx)
    (x_c, gbar_c, tables, x_old, gbar_old, x_fetch, gbar_fetch,
     rels) = obs_stage.staged_call(
        _dsaga_runner(mesh, sp.kind, bool(literal_scaling), fused_t, px),
        A, b, lam, eta, g0, active, rank, idx, _label="spmd/dsaga")
    return AsyncState(x_c=x_c, gbar_c=gbar_c, tables=tables, x_old=x_old,
                      gbar_old=gbar_old, x_fetch=x_fetch,
                      gbar_fetch=gbar_fetch), rels


# ---------------------------------------------------------------------------
# Algorithm 1 (single worker) on a mesh device
# ---------------------------------------------------------------------------

def run_centralvr(prob: Problem, *, eta: float, epochs: int, key: jax.Array,
                  sampling: str = "permutation", x0=None,
                  mesh: Optional[Mesh] = None, fused=False, prox=None):
    """Algorithm 1 has no worker axis to shard — ``backend="spmd"`` means
    "execute on the mesh": the problem is placed on the mesh's first
    device and the standard device-resident scan runs there, so a launcher
    can address one API regardless of backend."""
    from repro.core import centralvr

    mesh = mesh if mesh is not None else worker_mesh(1)
    dev = mesh.devices.ravel()[0]
    prob = jax.device_put(prob, dev)
    key = jax.device_put(key, dev)
    if x0 is not None:
        x0 = jax.device_put(x0, dev)
    return centralvr.run(prob, eta=eta, epochs=epochs, key=key,
                         sampling=sampling, x0=x0, fused=fused, prox=prox)
