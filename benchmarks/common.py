"""Shared benchmark utilities: timing, CSV emission, result storage."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "benchmarks")


def timed(fn: Callable, *args, repeat: int = 3, **kw):
    """Median wall time (us) of fn(*args) after one warmup."""
    fn(*args, **kw)
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def timed_cold_warm(fn: Callable, *args, repeat: int = 3, **kw):
    """(cold_s, warm_s, last): wall time of the FIRST call (compile
    included for jit-cached drivers), the median of ``repeat`` subsequent
    calls, and the LAST call's return value (so callers can record
    provenance without re-executing the measured work).  Blocks on the
    returned pytree so async dispatch can't hide work."""
    import jax

    t0 = time.perf_counter()
    last = jax.block_until_ready(fn(*args, **kw))
    cold = time.perf_counter() - t0
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        last = jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return cold, times[len(times) // 2], last


def emit(rows: List[Dict], name: str) -> None:
    """Print the required CSV (name,us_per_call,derived) and persist."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', 0):.1f},"
              f"{r.get('derived', '')}")
