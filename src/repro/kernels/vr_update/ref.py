"""Pure-jnp oracle for the fused VR update."""
from __future__ import annotations

import jax.numpy as jnp


def vr_update_ref(x, g, g_old, gbar, gtilde, *, eta: float, m: int,
                  saga: bool = False):
    v = g - g_old + gbar
    x_new = (x.astype(jnp.float32) - eta * v).astype(x.dtype)
    table_new = g
    gtilde_new = gtilde + g / m
    gbar_new = gbar + (g - g_old) / m if saga else gbar
    return x_new, table_new, gtilde_new, gbar_new
