from repro.optim import optimizers, vr_wrapper  # noqa: F401
