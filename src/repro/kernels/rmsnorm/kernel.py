"""Fused RMSNorm Pallas kernel (TPU target).

Every block in the zoo runs 2 RMSNorms per layer on the residual stream;
unfused, XLA emits square -> reduce -> rsqrt -> mul as separate HBM passes
over a (tokens, d_model) tensor. The fused kernel reads x once per tile
and writes y once: tiles are (rows_blk, d) — the full feature dim stays
resident so the row reduction happens in VMEM in one pass.

VMEM: rows_blk=256, d=8192 (largest arch) f32 -> 8 MiB in+out tiles; ops.py
drops rows_blk to fit smaller d or tighter budgets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x2d, scale, *, eps: float = 1e-6, rows_blk: int = 256,
            interpret: bool = False):
    """x2d: (rows, d) with rows % rows_blk == 0 (ops.py pads)."""
    rows, d = x2d.shape
    assert rows % rows_blk == 0, (rows, rows_blk)
    fn = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // rows_blk,),
        in_specs=[
            pl.BlockSpec((rows_blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows_blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x2d.dtype),
        interpret=interpret,
    )
    return fn(x2d, scale)
