"""CI regression guard over the benchmark artifacts (DESIGN.md §7).

Reads ``BENCH_drivers.json`` (written by ``benchmarks/driver_throughput.py``
— the ``--quick`` harness run regenerates it) and fails if any driver's
warm scan-runtime speedup over the seed host loop drops below the floor:
the device-resident scan runtime losing to the host loop it replaced is a
performance regression, whatever absolute wall clock the runner has.

    python benchmarks/check_regression.py [--path BENCH_drivers.json]
                                          [--floor 1.0]

Exit status 1 on regression — the benchmark-smoke CI job gates on it.
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default="BENCH_drivers.json",
                    help="driver-throughput artifact to check")
    ap.add_argument("--floor", type=float, default=1.0,
                    help="minimum acceptable warm scan-vs-host-loop "
                         "speedup")
    args = ap.parse_args(argv)

    with open(args.path) as f:
        rows = json.load(f)["rows"]
    if not rows:
        print(f"{args.path} has no rows", file=sys.stderr)
        return 1

    bad = []
    for r in rows:
        speedup = r["speedup_warm"]
        status = "ok" if speedup >= args.floor else "REGRESSION"
        print(f"{r['name']}: scan vs host loop {speedup:.1f}x warm "
              f"[{status}]")
        if speedup < args.floor:
            bad.append(r["name"])
    if bad:
        print(f"speedup below {args.floor:.2f}x floor for: "
              f"{', '.join(bad)}", file=sys.stderr)
        return 1
    print(f"all {len(rows)} drivers at or above the {args.floor:.2f}x "
          "floor")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
