"""Configuration system for the repro framework.

Frozen dataclasses + a registry keyed by ``--arch`` id. Every assigned
architecture registers a :class:`ModelConfig` in ``repro.configs.<id>``;
the paper's convex experiments use :class:`ConvexConfig`.

Design rules:
  * configs are immutable (hashable, safe as jit static args),
  * ``reduced()`` produces the CPU-smoke variant of the same family,
  * input shapes are global: the sharding layer decides per-device sizes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters for one model in the zoo."""

    name: str
    family: str                      # one of FAMILIES
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # --- attention flavour ---
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None   # None = full attention
    attn_logit_softcap: Optional[float] = None
    pad_heads_to: int = 0            # TP alignment: pad Q heads to this
                                     # count with MASKED (inert) heads
    # --- norms / mlp ---
    norm_type: str = "rmsnorm"       # "rmsnorm" | "layernorm"
    mlp_type: str = "swiglu"         # "swiglu" | "gelu"
    mlp_bias: bool = False
    # --- embeddings ---
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0             # 0 -> dense MLP
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    shared_expert_d_ff: int = 0      # 0 -> no shared expert
    shared_expert_gate: bool = False
    router_aux_coef: float = 0.01
    moe_capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0               # d_state; 0 -> no ssm
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64              # SSD chunk length
    # --- hybrid (RecurrentGemma) ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec","rec","attn"); () -> all "attn" or all "ssm"
    local_window: int = 0            # local-attention window for hybrid blocks
    rglru_heads: int = 0
    # --- modality frontend stub ---
    frontend: Optional[str] = None   # None | "vision" | "audio"
    frontend_tokens: int = 0         # prompt-prefix embedding tokens supplied by the stub
    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: num_heads must divide by num_kv_heads")

    # -- derived sizes ------------------------------------------------------
    @property
    def padded_heads(self) -> int:
        """Physical Q-head count: num_heads, or pad_heads_to when set.
        Padded heads are zero-masked in attention (exact semantics) and
        exist purely so the head axis divides the tensor-parallel axis."""
        return max(self.pad_heads_to, self.num_heads) \
            if self.pad_heads_to else self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode is natively sub-quadratic in memory."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, length num_layers."""
        if self.family == "ssm":
            return ("ssm",) * self.num_layers
        if self.block_pattern:
            pat = self.block_pattern
            return tuple(pat[i % len(pat)] for i in range(self.num_layers))
        return ("attn",) * self.num_layers

    def param_count(self) -> int:
        """Exact parameter count (embeddings included once if tied)."""
        d, h = self.d_model, self.head_dim
        n_attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        if self.qkv_bias:
            n_attn += self.q_dim + 2 * self.kv_dim
        if self.qk_norm:
            n_attn += 2 * h
        if self.mlp_type == "swiglu":
            n_mlp_dense = 3 * d * self.d_ff
        else:
            n_mlp_dense = 2 * d * self.d_ff + (self.d_ff + d if self.mlp_bias else 0)
        if self.is_moe:
            per_exp = 3 * d * self.moe_d_ff
            n_mlp = self.num_experts * per_exp + d * self.num_experts
            if self.shared_expert_d_ff:
                n_mlp += 3 * d * self.shared_expert_d_ff + (d if self.shared_expert_gate else 0)
        else:
            n_mlp = n_mlp_dense
        # ssm block params (in_proj for x,z,B,C,dt; out_proj; conv; A,D,dt_bias, norm)
        d_inner = self.ssm_expand * d
        nheads = max(d_inner // max(self.ssm_head_dim, 1), 1)
        n_ssm = (d * (2 * d_inner + 2 * self.ssm_state + nheads)
                 + d_inner * d + 4 * (d_inner + 2 * self.ssm_state)
                 + 3 * nheads + d_inner)
        # rg-lru block: wx_in, wy_in, out (3*d*dr) + conv (5dr) + lambda (dr)
        # + block-diagonal gates wa, wi (2*dr^2/heads)
        w = self.rglru_heads or self.num_heads
        d_rec = d
        n_rec = (3 * d * d_rec + 6 * d_rec + 2 * d_rec * d_rec // w)
        n_local = n_attn
        per_kind = {"attn": n_attn + n_mlp, "ssm": n_ssm,
                    "rec": n_rec + n_mlp_dense, "local": n_local + n_mlp_dense}
        total = 0
        for k in self.layer_kinds():
            total += per_kind[k] + 2 * d  # two norms per block
        total += d  # final norm
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.frontend is not None:
            total += d * d  # projector stub
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dead = (self.num_experts - self.num_experts_per_tok) * 3 * d * self.moe_d_ff
        return self.param_count() - dead * self.num_layers // 1

    def reduced(self) -> "ModelConfig":
        """CPU-smoke variant of the same family (2 layers, tiny dims)."""
        kv = min(self.num_kv_heads, 2)
        heads = max(2, min(4, self.num_heads))
        heads = heads - heads % kv if heads % kv else heads
        pat = self.block_pattern[: max(len(self.block_pattern), 0)]
        return replace(
            self,
            name=self.name + "-reduced",
            num_layers=2 if not pat else max(2, len(pat)),
            d_model=128,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            num_experts=min(self.num_experts, 4) if self.is_moe else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2) if self.is_moe else 0,
            moe_d_ff=64 if self.is_moe else 0,
            shared_expert_d_ff=64 if self.shared_expert_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=8 if self.ssm_state else self.ssm_chunk,
            local_window=min(self.local_window, 16) if self.local_window else 0,
            rglru_heads=2 if self.rglru_heads else 0,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else None,
            frontend_tokens=min(self.frontend_tokens, 8) if self.frontend else 0,
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned, global sizes)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


# ---------------------------------------------------------------------------
# Training / runtime configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainConfig:
    seq_len: int = 4096
    global_batch: int = 256
    microbatch: int = 0              # 0 -> no gradient accumulation
    learning_rate: float = 3e-4
    weight_decay: float = 0.0
    optimizer: str = "adam"          # "sgd" | "momentum" | "adam" | "adamw"
    # --- the paper's technique ---
    vr: str = "none"                 # "none" | "centralvr" | "svrg" | "saga"
    vr_table_size: int = 8           # M index-groups for centralvr/saga tables
    local_epoch: int = 1             # K local steps between (x, ḡ) communications
    async_mode: bool = False         # CentralVR-Async delta algebra
    # --- memory policy ---
    remat: str = "block"             # "none" | "block" | "full"
    dp_replicated: bool = False      # paper-faithful pure-DP (no FSDP) when True
    seed: int = 0


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axis_names

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    def data_axes(self) -> Tuple[str, ...]:
        """Axes over which the batch (and CentralVR workers) are sharded."""
        return tuple(a for a in self.axis_names if a in ("pod", "data"))


# ---------------------------------------------------------------------------
# Convex (paper §6) configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ConvexConfig:
    problem: str = "logistic"        # "logistic" | "ridge" | "huber" | ...
    n: int = 5000                    # samples (per worker in distributed runs)
    d: int = 20
    lam: float = 1e-4                # l2 regularizer (paper value)
    outlier_frac: float = 0.0        # label corruption rate (robust runs)
    huber_delta: float = 1.0         # Huber/pseudo-Huber transition scale
    learning_rate: float = 0.1
    epochs: int = 30
    seed: int = 0
    # distributed
    workers: int = 1
    method: str = "centralvr"        # core/ algorithm id
    tau: int = 0                     # communication period (0 -> one local epoch)
    async_mode: bool = False


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ModelConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}") from None


def list_archs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


def _ensure_loaded() -> None:
    if not _REGISTRY:
        import repro.configs  # noqa: F401  (registers everything)


def apply_overrides(cfg, overrides: dict):
    """``replace`` with string-typed values coerced to the field type."""
    coerced = {}
    fields = {f.name: f for f in dataclasses.fields(cfg)}
    for k, v in overrides.items():
        if k not in fields:
            raise KeyError(f"{type(cfg).__name__} has no field {k!r}")
        t = fields[k].type
        if isinstance(v, str):
            if "int" in str(t):
                v = int(v)
            elif "float" in str(t):
                v = float(v)
            elif "bool" in str(t):
                v = v.lower() in ("1", "true", "yes")
        coerced[k] = v
    return replace(cfg, **coerced)
