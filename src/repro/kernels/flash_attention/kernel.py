"""Causal GQA flash attention, Pallas TPU kernel (forward).

TPU adaptation of the flash algorithm: the grid's LAST dimension iterates
kv blocks SEQUENTIALLY per (head, q-block) — TPU grids execute in order on
a core, so the online-softmax running state lives in VMEM scratch across
grid steps instead of a CUDA thread-block register file. Block shapes keep
the MXU busy ((q_blk, hd) x (hd, kv_blk) matmuls with hd=64..256) and the
working set in VMEM:

    q tile (q_blk, hd) + k/v tiles (kv_blk, hd) + scratch (q_blk, kv_blk)
    ~ (128*256 + 2*128*256 + 128*128) * 4B ~ 0.5 MiB  << ~16 MiB VMEM.

GQA: the grid runs per Q head; the k/v BlockSpec index_map folds the
q-head -> kv-head mapping (h // group) so no kv replication is
materialized in HBM. Sliding windows mask inside the same kernel — this is
what serves the dense archs' ``long_500k`` variant.

The pure-jnp oracle is models/attention.chunked_attention (itself checked
against the naive quadratic reference).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  q_blk: int, kv_blk: int, nk: int, scale: float,
                  window, seq_len: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # (q_blk, hd)
    k = k_ref[0]                                   # (kv_blk, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (q_blk, kv_blk)

    q_pos = qi * q_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = kj * kv_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos <= q_pos
    if window is not None:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * corr
                    + jax.lax.dot_general(
                        p.astype(v_ref.dtype), v_ref[0],
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, window=None, q_blk: int = 128,
                    kv_blk: int = 128, interpret: bool = False):
    """q: (B, S, H, hd); k, v: (B, S, KV, hd) -> (B, S, H, hd). Causal.

    S must be a multiple of the block sizes (ops.py pads otherwise).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q_blk = min(q_blk, S)
    kv_blk = min(kv_blk, S)
    nq, nk = S // q_blk, S // kv_blk
    assert nq * q_blk == S and nk * kv_blk == S

    # layout: heads major so one grid row streams one head's sequence
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)

    def kv_row(bh):                 # q row (b*H + h) -> kv row (b*KV + h//G)
        return (bh // H) * KV + (bh % H) // G

    grid = (B * H, nq, nk)
    fn = pl.pallas_call(
        functools.partial(_flash_kernel, q_blk=q_blk, kv_blk=kv_blk, nk=nk,
                          scale=1.0 / (hd ** 0.5), window=window,
                          seq_len=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_blk, hd), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, kv_blk, hd),
                         lambda bh, qi, kj: (kv_row(bh), kj, 0)),
            pl.BlockSpec((1, kv_blk, hd),
                         lambda bh, qi, kj: (kv_row(bh), kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_blk, hd),
                               lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, hd), jnp.float32),
        ],
        interpret=interpret,
    )
    out = fn(qh, kh, vh)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
