"""Paged KV-cache geometry and the host-side block allocator.

The serving runtime stores full-attention KV in a POOL of fixed-size
blocks shared by every layer: physical block ``b`` of layer L lives at
``pool_L[b]`` and one per-sequence BLOCK TABLE (``(width, blocks_per_seq)``
int32, shared across layers) maps a sequence's logical block index to the
physical id.  Memory then scales with LIVE tokens (allocated blocks)
instead of ``width × max_seq_len``, and a retired sequence's blocks return
to the free list for reuse.  Sliding-window layers keep their (already
bounded) per-lane ring buffers; ``kv_cache="dense"`` swaps the pool for
per-lane dense buffers of the SAME padded context width — the pure-JAX
oracle the paged path is pinned against bit-for-bit
(``tests/test_serve.py``).

Physical block 0 is the TRASH block: never allocated, the write target of
dead decode lanes and padded prefill positions, and never reachable
through a block table (0 doubles as the table's "unallocated" marker), so
garbage writes are invisible by construction.

Allocation is lazy (a block is grabbed only when the sequence's length
first crosses into it) but admission is conservative: the scheduler
reserves a sequence's worst-case block count up front and admits only
when the reservation fits, so a running sequence can never hit an empty
pool mid-decode (DESIGN.md §Serving, "admission rule").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List


@dataclass(frozen=True)
class Geometry:
    """Static shape bundle for the jitted serving programs (hashable, so
    it can be closed over / used as a jit static)."""

    width: int                 # decode batch lanes
    block_size: int
    blocks_per_seq: int        # block-table width per lane
    num_blocks: int            # pool size INCLUDING the trash block 0
    kv_cache: str              # "paged" | "dense"

    def __post_init__(self):
        if self.kv_cache not in ("paged", "dense"):
            raise ValueError(f"kv_cache: unknown mode {self.kv_cache!r}")
        if self.width < 1 or self.block_size < 1 or self.blocks_per_seq < 1:
            raise ValueError("Geometry: width/block_size/blocks_per_seq "
                             "must be positive")
        if self.kv_cache == "paged" and self.num_blocks < 2:
            raise ValueError("Geometry: paged pool needs >= 2 blocks "
                             "(block 0 is the reserved trash block)")

    @property
    def context(self) -> int:
        """Padded per-sequence context width (= max servable seq len)."""
        return self.blocks_per_seq * self.block_size

    def blocks_for(self, total_len: int) -> int:
        """Blocks covering positions [0, total_len - 1); the LAST generated
        token's KV is never written, hence the -1."""
        last_written = max(total_len - 2, 0)
        return last_written // self.block_size + 1


class BlockAllocator:
    """Deterministic free-list allocator over physical ids 1..num_blocks-1.

    LIFO reuse (the most recently freed block is handed out first) keeps
    reuse observable in tests and maximizes page-locality.  Reservations
    implement the conservative admission rule: ``reserve(lane, n)`` holds
    n blocks for that lane, each ``alloc(lane)`` consumes one, and
    ``release(lane, ids)`` returns the allocated ids plus any unused
    reservation.  ``available()`` is what admission checks.
    """

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._reserved: Dict[int, int] = {}
        # stats (engine telemetry / tests)
        self.alloc_count = 0
        self.reuse_count = 0
        self._ever: set = set()

    def available(self) -> int:
        return len(self._free) - sum(self._reserved.values())

    def reserve(self, lane: int, n: int) -> None:
        if n > self.available():
            raise RuntimeError(
                f"reserve({n}) exceeds available blocks ({self.available()})")
        self._reserved[lane] = self._reserved.get(lane, 0) + n

    def alloc(self, lane: int) -> int:
        if self._reserved.get(lane, 0) <= 0:
            raise RuntimeError(f"lane {lane}: alloc without reservation")
        if not self._free:
            raise RuntimeError("block pool exhausted despite reservation "
                               "(allocator invariant broken)")
        self._reserved[lane] -= 1
        blk = self._free.pop()
        self.alloc_count += 1
        if blk in self._ever:
            self.reuse_count += 1
        self._ever.add(blk)
        return blk

    def release(self, lane: int, ids) -> None:
        """Free a retired lane's allocated blocks + drop its reservation."""
        self._reserved.pop(lane, None)
        for blk in ids:
            if not 0 < blk < self.num_blocks:
                raise ValueError(f"release: bad block id {blk}")
            self._free.append(int(blk))

    @property
    def in_use(self) -> int:
        return (self.num_blocks - 1) - len(self._free)
