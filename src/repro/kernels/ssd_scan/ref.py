"""Oracle for the SSD chunk-scan kernel: the pure-jnp chunked SSD from the
model (itself verified against the naive sequential recurrence)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import _ssd_chunked


def ssd_scan_ref(la, x, Bc, Cc, *, chunk: int):
    """Same flat signature as the kernel: la (BH,S), x (BH,S,P),
    Bc/Cc (B,S,N) with heads grouped."""
    BH, S = la.shape
    P = x.shape[-1]
    B_, N = Bc.shape[0], Bc.shape[-1]
    H = BH // B_
    # reshape to the model layout (B, S, H, P)
    x4 = x.reshape(B_, H, S, P).transpose(0, 2, 1, 3)
    la4 = la.reshape(B_, H, S).transpose(0, 2, 1)
    # _ssd_chunked takes dt & A_log; reconstruct via la = a*dt with a=-1,
    # dt=-la  and x_in*dt = x  =>  pass x/dt with dt=-la... simpler: use
    # dt=1, A_log chosen per-step impossible. Instead call with
    # dt = -la (>0) and A_log = 0 => a = -1 => a*dt = la. x must then be
    # divided by dt before the call since _ssd_chunked multiplies by dt.
    dt = -la4
    safe = jnp.maximum(dt, 1e-30)
    x_div = x4 / safe[..., None]
    y, _ = _ssd_chunked(x_div, dt, jnp.zeros((H,)), Bc, Cc,
                        jnp.zeros((B_, H, P, N)), chunk)
    return y.transpose(0, 2, 1, 3).reshape(BH, S, P)
