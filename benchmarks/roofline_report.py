"""Roofline report: reads results/dryrun/<mesh>/*.json (written by
repro.launch.dryrun) and emits the EXPERIMENTS.md §Roofline table +
hillclimb-candidate selection (worst roofline fraction / most
collective-bound / most representative of the paper's technique).
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


def load(mesh: str = "pod"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, mesh, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def one_liner(r):
    """What would move the dominant term down."""
    rf = r["roofline"]
    b = rf["bottleneck"]
    if b == "compute":
        if rf["useful_fraction"] < 0.3:
            return ("compute-bound with low useful fraction: cut remat "
                    "recompute / redundant replicated compute (shard the "
                    "mixer over 'model')")
        return "compute-bound near useful peak: more chips or lower remat"
    if b == "memory":
        return ("memory-bound: bf16 the f32 elementwise pipes, fuse VR "
                "update (Pallas vr_update), larger microbatch per device")
    return ("collective-bound: raise CentralVR local_epoch K (fewer "
            "epoch-boundary exchanges), overlap FSDP gathers with compute")


def run(quick: bool = False, mesh: str = "pod"):
    recs = load(mesh)
    rows = []
    for r in recs:
        rf = r["roofline"]
        t = {"compute": rf["t_compute"], "memory": rf["t_memory"],
             "collective": rf["t_collective"]}
        dom = max(t.values())
        frac = rf["t_compute"] / max(dom, 1e-12)  # roofline fraction
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}/{mesh}",
            "us_per_call": dom * 1e6,
            "derived": (f"bottleneck={rf['bottleneck']};"
                        f"Tc_ms={rf['t_compute'] * 1e3:.2f};"
                        f"Tm_ms={rf['t_memory'] * 1e3:.2f};"
                        f"Tx_ms={rf['t_collective'] * 1e3:.3f};"
                        f"useful={rf['useful_fraction']:.3f};"
                        f"roofline_frac={frac:.3f};"
                        f"peak_GiB={(rf['peak_memory_bytes'] or 0) / 2**30:.1f}"),
            "fix": one_liner(r),
            "record": {k: r.get(k) for k in
                       ("arch", "shape", "workers", "vr", "comm_every",
                        "compile_s", "window")},
        })
    if rows:
        # hillclimb candidate selection
        train_rows = [r for r in rows if "train" in r["name"] or
                      "train_4k" in r["name"]]
        by_frac = min(rows, key=lambda r: float(
            r["derived"].split("roofline_frac=")[1].split(";")[0]))
        by_coll = max(rows, key=lambda r: float(
            r["derived"].split("Tx_ms=")[1].split(";")[0]))
        rows.append({"name": "roofline/hillclimb-picks", "us_per_call": 0,
                     "derived": (f"worst_frac={by_frac['name']};"
                                 f"most_collective={by_coll['name']};"
                                 f"paper_representative=qwen2-7b/train_4k")})
    emit(rows, f"roofline_{mesh}")
    return rows


def markdown_table(mesh: str = "pod") -> str:
    recs = load(mesh)
    lines = [
        "| arch | shape | mode | T_comp ms | T_mem ms | T_coll ms | "
        "bottleneck | useful | peak GiB/dev | what moves it |",
        "|" + "---|" * 10,
    ]
    for r in recs:
        rf = r["roofline"]
        peak = (rf.get("peak_memory_bytes") or 0) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['mode']} "
            f"| {rf['t_compute'] * 1e3:.1f} | {rf['t_memory'] * 1e3:.1f} "
            f"| {rf['t_collective'] * 1e3:.2f} | {rf['bottleneck']} "
            f"| {rf['useful_fraction']:.3f} | {peak:.1f} "
            f"| {one_liner(r)} |")
    return "\n".join(lines)


if __name__ == "__main__":
    run()
    print(markdown_table())
