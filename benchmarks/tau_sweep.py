"""Communication-period sensitivity (the paper's §6.2 robustness study):

* D-SAGA at tau in {10, 100, 1000} — "relatively stable", degrading at
  very large tau (the paper reports slowdown at tau=10000);
* EASGD at tau in {4, 16, 64} — "nearly insensitive";
* CentralVR-Sync at local epochs K in {1, 2, 4} between exchanges — the
  paper's claim that the epoch-frozen anchor tolerates LOW communication
  frequency (this is the LM TrainConfig.local_epoch knob, exercised here
  on the convex substrate where ground truth is measurable).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.config import ConvexConfig
from repro.core import baselines, convex, distributed


def run(quick: bool = False):
    rows = []
    n, d, p = (400, 50, 4) if quick else (1500, 200, 8)
    rounds = 10 if quick else 16
    cfg = ConvexConfig(problem="logistic", n=n, d=d, workers=p)
    sp = distributed.make_distributed(jax.random.PRNGKey(0), cfg)
    eta = convex.auto_eta(sp.merged(), 0.4)
    key = jax.random.PRNGKey(1)

    # --- D-SAGA tau sweep ---
    taus = (10, 100, 1000) if not quick else (10, 100)
    finals = {}
    for tau in taus:
        # equal total local iterations across settings
        r = max((rounds * n) // tau, 2)
        _, rels = distributed.run_dsaga(sp, eta=eta / 2, rounds=r, key=key,
                                        tau=tau)
        finals[tau] = float(rels[-1])
    stable = max(finals.values()) < 1.0 and all(
        np.isfinite(v) for v in finals.values())
    rows.append({
        "name": "tau_sweep/d-saga",
        "us_per_call": 0.0,
        "derived": (";".join(f"tau{t}={v:.2e}" for t, v in finals.items())
                    + f";stable={'yes' if stable else 'no'}"),
    })

    # --- EASGD tau sweep ---
    finals = {}
    for tau in (4, 16, 64):
        _, rels = baselines.run_easgd(sp, eta=eta, rounds=rounds, key=key,
                                      tau=tau)
        finals[tau] = float(rels[-1])
    spread = max(finals.values()) / max(min(finals.values()), 1e-12)
    rows.append({
        "name": "tau_sweep/easgd",
        "us_per_call": 0.0,
        "derived": (";".join(f"tau{t}={v:.2e}" for t, v in finals.items())
                    + f";insensitive={'yes' if spread < 10 else 'no'}"),
    })

    # --- CentralVR local epochs between exchanges ---
    # K local epochs before averaging: run K rounds without communication
    # by chaining sync rounds on detached workers, then average
    finals = {}
    for K in (1, 2, 4):
        st = distributed.sync_init(sp, eta, jax.random.PRNGKey(2))
        merged = sp.merged()
        g0 = float(np.linalg.norm(np.asarray(convex.full_grad(
            merged, np.zeros(sp.d)))))
        total = rounds
        comms = 0
        keys = jax.random.split(jax.random.PRNGKey(3), total)
        import jax.numpy as jnp
        for r in range(total):
            # one local epoch on every worker WITHOUT averaging
            perms = jax.vmap(lambda k: jax.random.permutation(k, sp.ns))(
                jax.random.split(keys[r], sp.p))
            if r % K == 0 and r > 0:
                pass
            xs, tables, accs = jax.vmap(
                lambda A, b, table, perm, x0, gb: distributed.
                _local_centralvr_epoch(A, b, sp.lam, sp.kind, x0, table,
                                       gb, eta, perm)
            )(sp.A, sp.b, st.tables,
              perms,
              jnp.broadcast_to(st.x, (sp.p, sp.d)) if st.x.ndim == 1
              else st.x,
              jnp.broadcast_to(st.gbar, (sp.p, sp.d)) if st.gbar.ndim == 1
              else st.gbar)
            if (r + 1) % K == 0:
                st = distributed.SyncState(x=xs.mean(0), tables=tables,
                                           gbar=accs.mean(0))
                comms += 1
            else:
                # keep workers detached: store per-worker states
                st = distributed.SyncState(x=xs, tables=tables, gbar=accs)
        x_final = st.x.mean(0) if st.x.ndim > 1 else st.x
        rel = float(np.linalg.norm(np.asarray(
            convex.full_grad(merged, x_final))) / g0)
        finals[K] = (rel, comms)
    rows.append({
        "name": "tau_sweep/centralvr-local-epochs",
        "us_per_call": 0.0,
        "derived": ";".join(
            f"K{k}={v:.2e}(comms={c})" for k, (v, c) in finals.items()),
    })
    emit(rows, "tau_sweep")
    return rows


if __name__ == "__main__":
    run()
