"""Checkpoint round-trip of driver/VR state (DESIGN.md §8).

Interrupting a CentralVR run at an epoch boundary, saving the VR state
through ``checkpoint/``, restoring, and continuing must reproduce the
uninterrupted trajectory — the VR table and epoch-frozen gbar are part of
the algorithm state, so any drop or dtype change in the round-trip shows
up as a diverged trajectory.
"""
import jax
import numpy as np

from repro.checkpoint import checkpoint
from repro.config import ConvexConfig
from repro.core import centralvr, convex, distributed

TOL = dict(rtol=3e-5, atol=1e-7)


def test_centralvr_roundtrip_continues_trajectory(tmp_path):
    prob = convex.make_logistic_data(jax.random.PRNGKey(0), 96, 9)
    eta = convex.auto_eta(prob, 0.3)
    g0 = convex.grad_norm0(prob)
    k_init, k_run = jax.random.split(jax.random.PRNGKey(3))
    keys = jax.random.split(k_run, 6)

    # uninterrupted reference (fresh init: _run_scan donates its state)
    st_full, rels_full = centralvr._run_scan(
        prob, centralvr.init_state(prob, eta, k_init), eta, g0, keys,
        "permutation")

    # first half, save at the epoch boundary
    st_half, rels_a = centralvr._run_scan(
        prob, centralvr.init_state(prob, eta, k_init), eta, g0, keys[:3],
        "permutation")
    path = str(tmp_path / "centralvr.npz")
    checkpoint.save(path, st_half, step=3)
    assert checkpoint.latest_step(path) == 3

    # restore into the same structure and continue with the same key tail
    restored = checkpoint.restore(path, like=st_half)
    for got, want in zip(restored, st_half):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    _, rels_b = centralvr._run_scan(prob, restored, eta, g0, keys[3:],
                                    "permutation")

    rels_joined = np.concatenate([np.asarray(rels_a), np.asarray(rels_b)])
    np.testing.assert_allclose(rels_joined, np.asarray(rels_full), **TOL)


def test_lm_epoch_scan_resume_continues_trajectory(tmp_path):
    """LM analogue of the CentralVR round-trip: save at an epoch-scan
    boundary from ``train/loop.py``, restore with ``resume=True``, and
    the continued per-step loss trajectory must match an uninterrupted
    run (the data pipeline is stateless fold_in, the VR table/anchor and
    optimizer state ride the checkpoint)."""
    from repro.config import ModelConfig, TrainConfig
    from repro.train import loop

    cfg = ModelConfig(name="tiny-resume", family="dense", num_layers=2,
                      d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
                      vocab_size=128, dtype="float32",
                      param_dtype="float32")
    tcfg = TrainConfig(seq_len=16, global_batch=4, microbatch=2,
                       optimizer="adam", learning_rate=1e-3,
                       vr="centralvr", vr_table_size=2, local_epoch=1)

    full = loop.run_training(cfg, tcfg, epochs=4, workers=2, log_every=0)
    path = str(tmp_path / "lm.npz")
    first = loop.run_training(cfg, tcfg, epochs=2, workers=2,
                              checkpoint_path=path, checkpoint_every=2,
                              log_every=0)
    assert checkpoint.latest_step(path) == 2 * 2   # epoch boundary
    resumed = loop.run_training(cfg, tcfg, epochs=4, workers=2,
                                checkpoint_path=path, resume=True,
                                log_every=0)
    assert len(resumed.losses) == len(full.losses) - len(first.losses)
    np.testing.assert_allclose(first.losses + resumed.losses, full.losses,
                               **TOL)
    np.testing.assert_allclose(resumed.final_eval_loss,
                               full.final_eval_loss, **TOL)


def test_sync_state_roundtrip(tmp_path):
    """Distributed driver state (stacked per-worker tables) survives the
    flat-npz round-trip with structure and values intact."""
    cfg = ConvexConfig(problem="ridge", n=32, d=6, workers=3)
    sp = distributed.make_distributed(jax.random.PRNGKey(1), cfg)
    eta = convex.auto_eta(sp.merged(), 0.3)
    st, _ = distributed.run_sync(sp, eta=eta, rounds=2,
                                 key=jax.random.PRNGKey(2))
    path = str(tmp_path / "sync.npz")
    checkpoint.save(path, st, step=2)
    restored = checkpoint.restore(path, like=st)
    assert isinstance(restored, distributed.SyncState)
    for got, want in zip(restored, st):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
