"""Per-architecture smoke tests (assigned requirement): a REDUCED variant of
each family (2 layers, d_model<=512, <=4 experts) runs one forward/train
step and one decode step on CPU; output shapes and finiteness asserted.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.configs import ASSIGNED_ARCHS
from repro.models import model

# whole-module: subprocess compiles / many reduced-arch compiles — fast lane skips these (DESIGN.md §5)
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _batch(cfg, key, B=2, S=16):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend and cfg.frontend_tokens:
        batch["frontend_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), dtype=jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_config_limits(arch):
    cfg = get_arch(arch).reduced()
    assert cfg.num_layers <= 4
    assert cfg.d_model <= 512
    assert (cfg.num_experts or 0) <= 4
    assert cfg.family == get_arch(arch).family


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_train_step(arch, key):
    cfg = get_arch(arch).reduced()
    params = model.init_params(cfg, key)
    batch = _batch(cfg, key)

    loss, grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss)), arch
    # one SGD step and a second loss evaluation must stay finite
    params2 = jax.tree_util.tree_map(
        lambda p, g: p - 0.01 * g.astype(p.dtype), params, grads)
    loss2 = model.loss_fn(params2, cfg, batch)
    assert np.isfinite(float(loss2)), arch
    for leaf in jax.tree_util.tree_leaves(grads):
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all(), arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_logit_shapes(arch, key):
    cfg = get_arch(arch).reduced()
    params = model.init_params(cfg, key)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    logits, aux = model.forward(params, cfg, batch)
    S_total = S + (cfg.frontend_tokens if cfg.frontend else 0)
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step(arch, key):
    cfg = get_arch(arch).reduced()
    params = model.init_params(cfg, key)
    B, max_len = 2, 16
    cache = model.init_cache(cfg, B, max_len)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    for pos in range(3):
        logits, cache = model.decode_step(params, cfg, tok, cache, pos)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all(), arch
        tok = jnp.argmax(logits, -1)[:, None]


@pytest.mark.parametrize("arch", ["qwen2-7b", "mamba2-130m",
                                  "recurrentgemma-2b", "musicgen-large"])
def test_decode_matches_forward(arch, key):
    """Token-by-token decode reproduces the full forward logits (f32)."""
    cfg = dataclasses.replace(get_arch(arch).reduced(), dtype="float32",
                              sliding_window=None)
    params = model.init_params(cfg, key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits_full, _ = model.forward(params, cfg, {"tokens": toks})
    cache = model.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cfg, toks[:, t:t + 1], cache, t)
        outs.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(logits_full), atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "qwen2-moe-a2.7b"])
def test_moe_decode_matches_forward_without_dropping(arch, key):
    cfg = dataclasses.replace(get_arch(arch).reduced(), dtype="float32",
                              moe_capacity_factor=8.0)
    params = model.init_params(cfg, key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits_full, _ = model.forward(params, cfg, {"tokens": toks})
    cache = model.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cfg, toks[:, t:t + 1], cache, t)
        outs.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(logits_full), atol=2e-4, rtol=2e-3)


def test_param_count_formula_matches_actual():
    """config.param_count() (used for MODEL_FLOPS in the roofline) must
    match the instantiated tree on reduced variants."""
    for arch in ASSIGNED_ARCHS:
        cfg = get_arch(arch).reduced()
        params = model.init_params(cfg, jax.random.PRNGKey(1))
        actual = model.param_count_actual(params)
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.02, (
            arch, actual, predicted)
