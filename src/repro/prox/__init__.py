"""repro.prox — composite objectives: proximal operators and sparse
lazy-correction drivers (DESIGN.md §Composite objectives).

Lazy re-exports (``import repro.prox`` must stay jax-free until used):

  * ``ProxSpec`` / ``parse`` / ``apply`` / ``penalty`` — operator library
  * ``run_sparse`` — lazy CentralVR on CSR-style sparse features
"""
from __future__ import annotations

_LAZY = {
    "ProxSpec": ("repro.prox.operators", "ProxSpec"),
    "parse": ("repro.prox.operators", "parse"),
    "apply": ("repro.prox.operators", "apply"),
    "apply_prox": ("repro.prox.operators", "apply_prox"),
    "penalty": ("repro.prox.operators", "penalty"),
    "names": ("repro.prox.operators", "names"),
    "is_elementwise": ("repro.prox.operators", "is_elementwise"),
    "numeric_prox": ("repro.prox.operators", "numeric_prox"),
    "run_sparse": ("repro.prox.lazy", "run_sparse"),
    "sparsify": ("repro.prox.lazy", "sparsify"),
    "make_sparse_data": ("repro.prox.lazy", "make_sparse_data"),
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(name) from None
    import importlib
    value = getattr(importlib.import_module(mod_name), attr)
    globals()[name] = value
    return value
