"""CI regression guard over the benchmark artifacts (DESIGN.md §7).

Gates THREE artifacts (the ``--quick`` harness run regenerates all):

  * ``BENCH_drivers.json`` (``benchmarks/driver_throughput.py``) — every
    driver's warm scan-runtime speedup over the seed host loop must stay
    at or above the floor;
  * ``BENCH_train.json`` (``benchmarks/train_throughput.py``) — every
    epoch-scan path (``scan-vmap``, ``scan-spmd``) must stay at or above
    the floor against the seed per-step host path (``speedup_vs_host``);
  * ``BENCH_serve.json`` (``benchmarks/serve_throughput.py``) — the
    continuous-batching engine must not serve slower than the legacy
    per-token host loop it replaces: rows carrying
    ``decode_speedup_vs_host`` gate at the serve floor (1.0) and rows
    carrying ``prefill_speedup_vs_host`` (the prompt-len-128 chunked
    prefill pair) at the prefill floor (5.0).  Rows with
    ``estimated: true`` (CPU-simulated tensor parallelism) are printed
    but exempt, the same convention as interpret-mode fused rows.

The device-resident runtimes losing to the host loops they replaced is a
performance regression whatever absolute wall clock the runner has.  A
missing or row-less artifact is itself a failure — a gate that silently
passes because the bench never ran guards nothing.

FUSED rows (``fused: true``, emitted by both benches as twins of their
unfused configuration) are gated separately: ``speedup_vs_unfused`` must
stay at or above the fused floor — a fused Pallas hot path slower than
the unfused oracle it replaces means the kernel dispatch is a
pessimization.  Rows with ``interpret: true`` (CPU emulation of the
kernels — the only option off-TPU) are printed but EXEMPT: interpret
mode measures the emulator, not the kernel, and the agreement tests
already pin its numerics.  Fused rows are excluded from the legacy
gates, which pin the unfused runtimes against the seed host paths.

TELEMETRY rows (``telemetry: true``, the ``-obs`` twins) are printed
with their overhead-vs-off ratio but never gated: they measure the
recorder's observation cost, and the telemetry-OFF base rows are what
the floors protect (enabling telemetry must not be able to fail CI).

PROX rows (``prox`` set, the ``-l1``/``-elasticnet`` twins) are printed
with their overhead-vs-smooth ratio but never gated, and are excluded
from the legacy scan-vs-host gates (the seed host loops predate
composite objectives).  The SPARSE row (``speedup_sparse_vs_dense``)
gates the lazy CSR driver against the dense prox'd oracle at the sparse
floor (1.0) whenever its ``nnz_frac <= 0.05`` — the low-density regime
the lazy catch-up exists for; denser or ``estimated: true`` rows are
printed as exempt.

    python benchmarks/check_regression.py [--path BENCH_drivers.json]
                                          [--train-path BENCH_train.json]
                                          [--serve-path BENCH_serve.json]
                                          [--floor 1.0]
                                          [--fused-floor 1.0]
                                          [--serve-floor 1.0]
                                          [--serve-prefill-floor 5.0]
                                          [--compile-floor 0]
                                          [--report report.json]

``--compile-floor SECONDS`` additionally gates every row's ``cold_s``
(first-invocation wall clock, jit compile included) across all three
artifacts — 0 (the default) disables the gate; rows without a
``cold_s`` field are printed as exempt.

Exit status 1 on regression — the benchmark-smoke CI job gates on it.
``--report`` additionally writes a machine-readable JSON gate report
(every gate decision + the overall verdict) that the CI lane uploads as
an artifact, so a red gate is diagnosable from the artifact alone.
"""
from __future__ import annotations

import argparse
import json
import sys


def _load_rows(path: str):
    """Rows of one artifact; missing/unreadable/empty is a hard failure."""
    try:
        with open(path) as f:
            rows = json.load(f)["rows"]
    except (OSError, KeyError, TypeError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable bench artifact ({e}); run "
              "`python benchmarks/run.py --quick` first", file=sys.stderr)
        return None
    if not rows:
        print(f"{path} has no rows", file=sys.stderr)
        return None
    return rows


def _gate(rows, speedup_key: str, floor: float, what: str, report):
    """Names of rows whose speedup is below the floor (prints each row)."""
    bad = []
    for r in rows:
        speedup = r[speedup_key]
        status = "ok" if speedup >= floor else "REGRESSION"
        print(f"{r['name']}: {what} {speedup:.1f}x warm [{status}]")
        report.append({"name": r["name"], "gate": speedup_key,
                       "value": speedup, "floor": floor, "status": status})
        if speedup < floor:
            bad.append(r["name"])
    return bad


def _show_telemetry(rows, report):
    """Telemetry twins: printed + reported, never gated."""
    for r in rows:
        over = r.get("overhead_vs_off")
        print(f"{r['name']}: telemetry overhead "
              f"{over:.2f}x vs off [informational]")
        report.append({"name": r["name"], "gate": "overhead_vs_off",
                       "value": over, "floor": None,
                       "status": "informational"})


def _gate_fused(rows, floor: float, report):
    """Gate fused twin rows on ``speedup_vs_unfused``; interpret-mode
    rows (CPU kernel emulation) are printed as exempt and not gated."""
    bad = []
    gated = 0
    for r in rows:
        speedup = r["speedup_vs_unfused"]
        if r.get("interpret"):
            print(f"{r['name']}: fused vs unfused {speedup:.2f}x warm "
                  "[exempt: interpret]")
            report.append({"name": r["name"],
                           "gate": "speedup_vs_unfused",
                           "value": speedup, "floor": None,
                           "status": "exempt:interpret"})
            continue
        gated += 1
        status = "ok" if speedup >= floor else "REGRESSION"
        print(f"{r['name']}: fused vs unfused {speedup:.2f}x warm "
              f"[{status}]")
        report.append({"name": r["name"], "gate": "speedup_vs_unfused",
                       "value": speedup, "floor": floor, "status": status})
        if speedup < floor:
            bad.append(r["name"])
    return bad, gated


def _show_prox(rows, report):
    """Prox twins: overhead vs the smooth configuration, printed and
    reported but never gated — the host loops they would gate against
    predate composite objectives, and the smooth base rows already hold
    the floor."""
    for r in rows:
        over = r.get("overhead_vs_smooth")
        if over is None:
            continue
        print(f"{r['name']}: prox overhead {over:.2f}x vs smooth "
              "[informational]")
        report.append({"name": r["name"], "gate": "overhead_vs_smooth",
                       "value": over, "floor": None,
                       "status": "informational"})


def _gate_sparse(rows, floor: float, report):
    """Gate sparse-lazy rows on ``speedup_sparse_vs_dense`` at the floor
    when the density qualifies (``nnz_frac <= 0.05`` — the regime the
    lazy catch-up exists for); denser rows and ``estimated: true`` rows
    are printed as exempt."""
    bad = []
    gated = 0
    for r in rows:
        speedup = r["speedup_sparse_vs_dense"]
        frac = r.get("nnz_frac", 1.0)
        if r.get("estimated") or frac > 0.05:
            why = "estimated" if r.get("estimated") else "dense"
            print(f"{r['name']}: sparse vs dense {speedup:.2f}x warm "
                  f"@nnz/d={frac:.2%} [exempt: {why}]")
            report.append({"name": r["name"],
                           "gate": "speedup_sparse_vs_dense",
                           "value": speedup, "floor": None,
                           "status": f"exempt:{why}"})
            continue
        gated += 1
        status = "ok" if speedup >= floor else "REGRESSION"
        print(f"{r['name']}: sparse vs dense {speedup:.2f}x warm "
              f"@nnz/d={frac:.2%} [{status}]")
        report.append({"name": r["name"],
                       "gate": "speedup_sparse_vs_dense",
                       "value": speedup, "floor": floor, "status": status})
        if speedup < floor:
            bad.append(r["name"])
    return bad, gated


def _gate_compile(rows, ceiling: float, report):
    """Gate every row carrying ``cold_s`` (first-invocation wall clock,
    compile included) against the compile-time ceiling; rows without the
    field (older twins, derived rows) are printed as exempt.  A compile
    blow-up is a regression even when warm throughput holds — it is the
    cost every fresh CI job and every elastic rejoin pays."""
    bad = []
    for r in rows:
        cold = r.get("cold_s")
        if cold is None:
            print(f"{r['name']}: no cold_s recorded [exempt: no-cold]")
            report.append({"name": r["name"], "gate": "cold_s",
                           "value": None, "floor": None,
                           "status": "exempt:no-cold"})
            continue
        status = "ok" if cold <= ceiling else "REGRESSION"
        print(f"{r['name']}: cold {cold:.2f}s vs {ceiling:.0f}s compile "
              f"ceiling [{status}]")
        report.append({"name": r["name"], "gate": "cold_s", "value": cold,
                       "floor": ceiling, "status": status})
        if cold > ceiling:
            bad.append(r["name"])
    return bad


def _gate_serve(rows, decode_floor: float, prefill_floor: float, report):
    """Gate engine rows on decode/prefill speedup vs the host-loop twin;
    ``estimated: true`` rows (CPU-simulated TP) are printed as exempt."""
    bad = []
    gated = exempt = 0
    checks = (("decode_speedup_vs_host", decode_floor,
               "decode vs host loop"),
              ("prefill_speedup_vs_host", prefill_floor,
               "prefill vs host loop"))
    for r in rows:
        for key, floor, what in checks:
            if key not in r:
                continue
            speedup = r[key]
            if r.get("estimated"):
                exempt += 1
                print(f"{r['name']}: {what} {speedup:.2f}x "
                      "[exempt: estimated]")
                report.append({"name": r["name"], "gate": key,
                               "value": speedup, "floor": None,
                               "status": "exempt:estimated"})
                continue
            gated += 1
            status = "ok" if speedup >= floor else "REGRESSION"
            print(f"{r['name']}: {what} {speedup:.2f}x [{status}]")
            report.append({"name": r["name"], "gate": key,
                           "value": speedup, "floor": floor,
                           "status": status})
            if speedup < floor:
                bad.append(r["name"])
    return bad, gated, exempt


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default="BENCH_drivers.json",
                    help="driver-throughput artifact to check")
    ap.add_argument("--train-path", default="BENCH_train.json",
                    help="train-throughput artifact to check")
    ap.add_argument("--serve-path", default="BENCH_serve.json",
                    help="serve-throughput artifact to check")
    ap.add_argument("--floor", type=float, default=1.0,
                    help="minimum acceptable warm speedup over the seed "
                         "host path")
    ap.add_argument("--fused-floor", type=float, default=1.0,
                    help="minimum acceptable fused-vs-unfused warm speedup "
                         "(compiled-backend rows only; interpret exempt)")
    ap.add_argument("--serve-floor", type=float, default=1.0,
                    help="minimum acceptable engine decode speedup over "
                         "the legacy host-loop serving path")
    ap.add_argument("--serve-prefill-floor", type=float, default=5.0,
                    help="minimum acceptable chunked-prefill speedup over "
                         "per-token prefill at prompt-len 128")
    ap.add_argument("--sparse-floor", type=float, default=1.0,
                    help="minimum acceptable sparse-lazy speedup over the "
                         "dense prox'd oracle at nnz/d <= 5% (denser and "
                         "estimated rows exempt)")
    ap.add_argument("--compile-floor", type=float, default=0.0,
                    help="maximum allowed cold_s (first invocation, "
                         "compile included) for any bench row; 0 disables "
                         "the gate; rows without cold_s are exempt")
    ap.add_argument("--report", default="",
                    help="write a machine-readable JSON gate report here")
    args = ap.parse_args(argv)

    failed = False
    fused_rows = []
    compile_rows = []
    report = []

    rows = _load_rows(args.path)
    if rows is None:
        failed = True
    else:
        compile_rows += rows
        fused_rows += [r for r in rows if r.get("fused")]
        _show_telemetry([r for r in rows if r.get("telemetry")], report)
        _show_prox([r for r in rows
                    if r.get("prox") and not r.get("sparse")], report)
        sparse_rows = [r for r in rows
                       if "speedup_sparse_vs_dense" in r]
        legacy = [r for r in rows
                  if not r.get("fused") and not r.get("telemetry")
                  and not r.get("prox") and not r.get("sparse")]
        bad = _gate(legacy, "speedup_warm", args.floor, "scan vs host loop",
                    report)
        if bad:
            print(f"speedup below {args.floor:.2f}x floor for: "
                  f"{', '.join(bad)}", file=sys.stderr)
            failed = True
        else:
            print(f"all {len(legacy)} drivers at or above the "
                  f"{args.floor:.2f}x floor")
        if sparse_rows:
            bad, gated = _gate_sparse(sparse_rows, args.sparse_floor,
                                      report)
            if bad:
                print(f"sparse-vs-dense speedup below "
                      f"{args.sparse_floor:.2f}x floor for: "
                      f"{', '.join(bad)}", file=sys.stderr)
                failed = True
            elif gated:
                print(f"all {gated} gated sparse rows at or above the "
                      f"{args.sparse_floor:.2f}x floor")

    rows = _load_rows(args.train_path)
    if rows is None:
        failed = True
    else:
        compile_rows += rows
        fused_rows += [r for r in rows if r.get("fused")]
        _show_telemetry([r for r in rows if r.get("telemetry")], report)
        scan = [r for r in rows
                if r["path"].startswith("scan-") and not r.get("fused")
                and not r.get("telemetry")]
        if not scan:
            print(f"{args.train_path} has no scan-path rows",
                  file=sys.stderr)
            failed = True
        else:
            bad = _gate(scan, "speedup_vs_host", args.floor,
                        "epoch scan vs seed host path", report)
            if bad:
                print(f"train speedup below {args.floor:.2f}x floor for: "
                      f"{', '.join(bad)}", file=sys.stderr)
                failed = True
            else:
                print(f"all {len(scan)} train scan paths at or above the "
                      f"{args.floor:.2f}x floor")

    rows = _load_rows(args.serve_path)
    if rows is None:
        failed = True
    else:
        compile_rows += rows
        bad, gated, exempt = _gate_serve(rows, args.serve_floor,
                                         args.serve_prefill_floor, report)
        if bad:
            print(f"serve speedup below floor for: {', '.join(bad)}",
                  file=sys.stderr)
            failed = True
        elif not gated:
            print(f"{args.serve_path} has no gated engine rows",
                  file=sys.stderr)
            failed = True
        else:
            print(f"all {gated} gated serve rows at or above their floors "
                  f"({exempt} estimated rows exempt)")

    if fused_rows:
        bad, gated = _gate_fused(fused_rows, args.fused_floor, report)
        if bad:
            print(f"fused speedup below {args.fused_floor:.2f}x floor "
                  f"for: {', '.join(bad)}", file=sys.stderr)
            failed = True
        else:
            exempt = len(fused_rows) - gated
            print(f"all {gated} gated fused rows at or above the "
                  f"{args.fused_floor:.2f}x floor ({exempt} interpret-mode "
                  "rows exempt)")

    if args.compile_floor > 0 and compile_rows:
        bad = _gate_compile(compile_rows, args.compile_floor, report)
        if bad:
            print(f"cold_s above the {args.compile_floor:.0f}s compile "
                  f"ceiling for: {', '.join(bad)}", file=sys.stderr)
            failed = True
        else:
            print(f"all cold_s rows within the {args.compile_floor:.0f}s "
                  "compile ceiling")

    if args.report:
        payload = {
            "failed": failed,
            "floor": args.floor,
            "compile_floor": args.compile_floor,
            "fused_floor": args.fused_floor,
            "serve_floor": args.serve_floor,
            "serve_prefill_floor": args.serve_prefill_floor,
            "artifacts": {"drivers": args.path, "train": args.train_path,
                          "serve": args.serve_path},
            "gates": report,
        }
        with open(args.report, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote gate report to {args.report}")

    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
