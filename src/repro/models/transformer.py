"""Decoder stack: heterogeneous block kinds (attn / local / ssm / rec),
layers stacked per pattern-position and lax.scan-ned over super-blocks so
the HLO stays small at 80 layers; per-super-block remat policy.

Layout: the layer pattern (cfg.layer_kinds) has period ``pat_len``;
``n_super = num_layers // pat_len`` super-blocks are scanned with stacked
params; the remainder layers (e.g. recurrentgemma's 26 = 8*3 + 2) are
unrolled as an explicit tail.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention, layers, moe, rglru, ssm


def pattern_info(cfg: ModelConfig):
    kinds = cfg.layer_kinds()
    pat = cfg.block_pattern or (kinds[0],)
    pat_len = len(pat)
    n_super = cfg.num_layers // pat_len
    n_tail = cfg.num_layers - n_super * pat_len
    return pat, pat_len, n_super, kinds[n_super * pat_len:]


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------

def init_block(cfg: ModelConfig, kind: str, key, dtype) -> Dict[str, Any]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": layers.init_norm(cfg, dtype)}
    if kind in ("attn", "local"):
        p["mixer"] = attention.init_attn(cfg, k1, dtype)
    elif kind == "ssm":
        p["mixer"] = ssm.init_ssm(cfg, k1, dtype)
    elif kind == "rec":
        p["mixer"] = rglru.init_rglru(cfg, k1, dtype)
    else:
        raise ValueError(kind)
    if kind != "ssm":                       # ssm blocks have no separate MLP
        p["norm2"] = layers.init_norm(cfg, dtype)
        if cfg.is_moe:
            p["ffn"] = moe.init_moe(cfg, k2, dtype)
        else:
            p["ffn"] = layers.init_mlp(cfg, k3, dtype)
    return p


def _cast_params(p, dtype):
    """Cast float params to the compute dtype at point of use (params are
    stored in param_dtype, typically f32, for optimizer stability), and —
    when the explicit weight-gather context is active — constrain each
    2D-sharded leaf to its FSDP-unsharded spec so the ZeRO gather is one
    bf16 all-gather per weight per layer execution instead of deferred
    activation-sized partial sums (see sharding/gather_ctx.py)."""
    from repro.sharding import gather_ctx

    def one(path, w):
        if not jnp.issubdtype(w.dtype, jnp.floating):
            return w
        w = w.astype(dtype)
        if gather_ctx.active():
            ps = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                          for k in path)
            w = gather_ctx.constrain(ps, w)
        return w

    return jax.tree_util.tree_map_with_path(one, p)


def apply_block_train(p, cfg: ModelConfig, kind: str, x,
                      window: Optional[int] = None):
    """Returns (x, aux)."""
    p = _cast_params(p, jnp.dtype(cfg.dtype))
    h = layers.apply_norm(p["norm1"], x, cfg.norm_type)
    if kind == "attn":
        mix = attention.attend_train(p["mixer"], cfg, h, window=window)
    elif kind == "local":
        mix = attention.attend_train(p["mixer"], cfg, h,
                                     window=cfg.local_window)
    elif kind == "ssm":
        mix = ssm.apply_ssm_train(p["mixer"], cfg, h)
    else:
        mix = rglru.apply_rec_train(p["mixer"], cfg, h)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        h = layers.apply_norm(p["norm2"], x, cfg.norm_type)
        if cfg.is_moe:
            y, aux = moe.apply_moe(p["ffn"], cfg, h)
        else:
            y = layers.apply_mlp(p["ffn"], h, cfg.mlp_type)
        x = x + y
    return x, aux


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype):
    if kind == "attn":
        return attention.init_cache(cfg, batch, max_len, dtype)
    if kind == "local":
        return attention.init_cache(cfg, batch, max_len, dtype,
                                    window=cfg.local_window)
    if kind == "ssm":
        return ssm.init_ssm_cache(cfg, batch, dtype)
    return rglru.init_rec_cache(cfg, batch, dtype)


def apply_block_decode(p, cfg: ModelConfig, kind: str, x, cache, pos):
    p = _cast_params(p, jnp.dtype(cfg.dtype))
    h = layers.apply_norm(p["norm1"], x, cfg.norm_type)
    if kind == "attn":
        mix, cache = attention.attend_decode(p["mixer"], cfg, h, cache, pos)
    elif kind == "local":
        mix, cache = attention.attend_decode(p["mixer"], cfg, h, cache, pos,
                                             window=cfg.local_window)
    elif kind == "ssm":
        mix, cache = ssm.apply_ssm_decode(p["mixer"], cfg, h, cache)
    else:
        mix, cache = rglru.apply_rec_decode(p["mixer"], cfg, h, cache)
    x = x + mix
    if "ffn" in p:
        h = layers.apply_norm(p["norm2"], x, cfg.norm_type)
        if cfg.is_moe:
            y, _ = moe.apply_moe(p["ffn"], cfg, h)
        else:
            y = layers.apply_mlp(p["ffn"], h, cfg.mlp_type)
        x = x + y
    return x, cache


# ---------------------------------------------------------------------------
# Stack init: stacked super-blocks + tail
# ---------------------------------------------------------------------------

def init_stack(cfg: ModelConfig, key, dtype):
    pat, pat_len, n_super, tail_kinds = pattern_info(cfg)

    def init_one_super(k):
        ks = jax.random.split(k, pat_len)
        return [init_block(cfg, kind, kk, dtype)
                for kind, kk in zip(pat, ks)]

    keys = jax.random.split(key, n_super + 1)
    stacked = jax.vmap(init_one_super)(keys[:n_super])
    tail = [init_block(cfg, kind, jax.random.fold_in(keys[-1], i), dtype)
            for i, kind in enumerate(tail_kinds)]
    return {"stack": stacked, "tail": tail}


def _remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)   # "block": save only layer inputs


def apply_stack_train(p, cfg: ModelConfig, x, *, remat: str = "block",
                      window: Optional[int] = None, act_sharding=None):
    """x: (B, S, d) -> (x, total_aux). ``act_sharding`` pins the residual
    stream's sharding at block boundaries (batch over 'data' in FSDP mode)
    so GSPMD gathers WEIGHTS per layer, never the (much larger) activations
    — without it the partitioner is free to all-gather the batch."""
    pat, pat_len, n_super, tail_kinds = pattern_info(cfg)

    def constrain(x):
        if act_sharding is not None:
            return jax.lax.with_sharding_constraint(x, act_sharding)
        return x

    def super_body(carry, sp):
        x, aux = carry
        for j, kind in enumerate(pat):
            x, a = apply_block_train(sp[j], cfg, kind, x, window=window)
            x = constrain(x)
            aux = aux + a
        return (x, aux), None

    body = _remat_wrap(super_body, remat)
    (x, aux), _ = jax.lax.scan(body, (constrain(x), jnp.zeros((), jnp.float32)),
                               p["stack"])
    for tp, kind in zip(p["tail"], tail_kinds):
        x, a = apply_block_train(tp, cfg, kind, x, window=window)
        x = constrain(x)
        aux = aux + a
    return x, aux


def init_stack_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    pat, pat_len, n_super, tail_kinds = pattern_info(cfg)

    def one_super(_):
        return [init_block_cache(cfg, kind, batch, max_len, dtype)
                for kind in pat]

    stacked = jax.vmap(one_super)(jnp.arange(n_super))
    tail = [init_block_cache(cfg, kind, batch, max_len, dtype)
            for kind in tail_kinds]
    return {"stack": stacked, "tail": tail}


def apply_stack_serve(p, cache, cfg: ModelConfig, x, block_fn):
    """Generic serve-runtime stack walk: like :func:`apply_stack_decode`
    but the per-block transform is supplied by the caller —
    ``block_fn(block_params, block_cache, kind, layer_window, x)`` returns
    ``(x, new_block_cache)``.  The serve runtime threads per-lane
    positions, block tables, and paged pools through its closure; the
    scan-over-super-blocks layout (small HLO at 80 layers) is shared with
    the train/decode paths.  ``layer_window`` resolves the per-kind
    sliding window (cfg.sliding_window for 'attn', cfg.local_window for
    'local') so block_fn sees one uniform contract."""
    pat, pat_len, n_super, tail_kinds = pattern_info(cfg)

    def win(kind):
        return cfg.local_window if kind == "local" else cfg.sliding_window

    def super_body(x, inp):
        sp, sc = inp
        new_sc = []
        for j, kind in enumerate(pat):
            x, c = block_fn(sp[j], sc[j], kind, win(kind), x)
            new_sc.append(c)
        return x, new_sc

    x, new_stack = jax.lax.scan(super_body, x, (p["stack"], cache["stack"]))
    new_tail = []
    for tp, tc, kind in zip(p["tail"], cache["tail"], tail_kinds):
        x, c = block_fn(tp, tc, kind, win(kind), x)
        new_tail.append(c)
    return x, {"stack": new_stack, "tail": new_tail}


def init_stack_serve_cache(cfg: ModelConfig, make_block_cache):
    """Serve-cache pytree with the stack/tail structure of
    :func:`init_stack_cache`; ``make_block_cache(kind, layer_window)``
    builds one layer's cache (paged pool / ring / dense lane buffer)."""
    pat, pat_len, n_super, tail_kinds = pattern_info(cfg)

    def win(kind):
        return cfg.local_window if kind == "local" else cfg.sliding_window

    def one_super(_):
        return [make_block_cache(kind, win(kind)) for kind in pat]

    stacked = jax.vmap(one_super)(jnp.arange(n_super))
    tail = [make_block_cache(kind, win(kind)) for kind in tail_kinds]
    return {"stack": stacked, "tail": tail}


def apply_stack_decode(p, cache, cfg: ModelConfig, x, pos):
    """x: (B, 1, d) -> (x, new_cache)."""
    pat, pat_len, n_super, tail_kinds = pattern_info(cfg)

    def super_body(x, inp):
        sp, sc = inp
        new_sc = []
        for j, kind in enumerate(pat):
            x, c = apply_block_decode(sp[j], cfg, kind, x, sc[j], pos)
            new_sc.append(c)
        return x, new_sc

    x, new_stack = jax.lax.scan(super_body, x, (p["stack"], cache["stack"]))
    new_tail = []
    for tp, tc, kind in zip(p["tail"], cache["tail"], tail_kinds):
        x, c = apply_block_decode(tp, cfg, kind, x, tc, pos)
        new_tail.append(c)
    return x, {"stack": new_stack, "tail": new_tail}
