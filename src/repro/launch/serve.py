"""Serving launcher: thin client of the repro.serve runtime.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --requests 8 --prompt-len 32 --max-new 16 --width 4 --pattern burst

Attention-family architectures run on the continuous-batching engine
(paged KV cache + chunked prefill, serve/engine.py); recurrent stacks
(ssm / rec) and frontends fall back to the legacy static-batch host loop
(serve/legacy.py).  ``--path legacy`` forces the old path, ``--tp N``
shards decode over N model-parallel devices (simulated on CPU hosts via
forced host devices when needed).
"""
from __future__ import annotations

import argparse


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--vary-new", action="store_true",
                    help="cycle max_new over {1,3/4,1/2,1/4}x so lanes "
                         "retire at different steps")
    ap.add_argument("--pattern", default="burst",
                    choices=("burst", "uniform", "poisson"))
    ap.add_argument("--gap", type=int, default=4,
                    help="mean decode-steps between arrivals")
    ap.add_argument("--width", type=int, default=4,
                    help="decode batch lanes (engine) / static batch "
                         "size (legacy)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--kv-cache", default="paged",
                    choices=("paged", "dense"))
    ap.add_argument("--chunk-buckets", default="16,64,128",
                    help="comma-separated prefill chunk sizes")
    ap.add_argument("--path", default="auto",
                    choices=("auto", "engine", "legacy"))
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree for engine decode")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs", default="", metavar="PATH",
                    help="record telemetry (admit/prefill/decode/retire "
                         "spans + report) to this JSONL file")
    from repro.launch.compile_cache import add_compile_cache_arg
    add_compile_cache_arg(ap)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    if args.tp > 1:
        import jax
        if len(jax.devices()) < args.tp:
            raise SystemExit(
                f"--tp {args.tp} needs {args.tp} devices but only "
                f"{len(jax.devices())} are visible; simulate with "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    from repro.launch.compile_cache import enable_compile_cache
    cache_dir = enable_compile_cache(args.compile_cache)

    import jax

    from repro import obs
    from repro.config import get_arch
    from repro.models import model
    from repro.serve import (ServeEngine, check_arch, run_host_loop,
                             synthetic_trace)

    if args.obs:
        obs.enable(args.obs)
    if cache_dir:
        print(f"compile cache: {cache_dir}")

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    path = args.path
    if path == "auto":
        try:
            check_arch(cfg)
            path = "engine"
        except ValueError as e:
            print(f"engine unavailable ({e}); using legacy host loop")
            path = "legacy"

    params = model.init_params(cfg, jax.random.PRNGKey(args.seed))
    trace = synthetic_trace(args.requests, pattern=args.pattern,
                            prompt_len=args.prompt_len,
                            max_new=args.max_new, gap=args.gap,
                            vary_new=args.vary_new, seed=args.seed)

    if path == "legacy":
        rep = run_host_loop(cfg, trace, params=params, width=args.width)
    else:
        mesh = None
        if args.tp > 1:
            from repro.launch.mesh import make_test_mesh
            mesh = make_test_mesh(model_axis=args.tp)
        max_len = args.prompt_len + args.max_new
        eng = ServeEngine(
            cfg, params, width=args.width, block_size=args.block_size,
            max_seq_len=max_len, kv_cache=args.kv_cache,
            chunk_buckets=tuple(int(c) for c in
                                args.chunk_buckets.split(",")),
            mesh=mesh, seed=args.seed)
        eng.warmup()
        rep = eng.run(trace)

    s = rep.summary()
    cold = sum(rep.compile_s.values())
    print(f"[{path}] {s['requests']} requests, {s['steps']} steps: "
          f"prefill {s['prefill_tokens']} tok @ {s['prefill_tok_s']:.1f} "
          f"tok/s; decode {s['decode_tokens']} tok @ "
          f"{s['decode_tok_s']:.1f} tok/s; latency p50 "
          f"{s['latency_p50_s'] * 1e3:.1f}ms p95 "
          f"{s['latency_p95_s'] * 1e3:.1f}ms; compile {cold:.2f}s")
    print("sample token ids:", rep.results[0].tokens[:16])
    if args.obs:
        obs.disable()
        print(f"wrote telemetry to {args.obs}")
    return rep


if __name__ == "__main__":
    main()
