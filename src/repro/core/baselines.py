"""Comparison baselines used in the paper's experiments (§6.2):

  * plain (distributed) SGD with periodic averaging,
  * EASGD — elastic averaging SGD [36], constant & decaying step sizes,
  * PS-SVRG — asynchronous parameter-server SVRG [29].

All run on the same :class:`ShardedProblem` substrate as the proposed
methods so convergence-per-gradient-evaluation comparisons are exact.

Every driver here is device-resident (DESIGN.md §3): one jitted
``lax.scan`` over epochs/rounds, the relative-grad-norm metric computed
inside the scan, decaying step-size schedules precomputed on the host and
shipped as scan inputs, and the iterate state donated into the runner.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import convex, runtime
from repro.core.convex import Problem
from repro.core.distributed import ShardedProblem
from repro.obs import stage as obs_stage
from repro.prox import operators as proxops


# ---------------------------------------------------------------------------
# Sequential SGD / SVRG / SAGA (single worker, for Fig. 1)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnames=("x",))
def _sgd_scan(prob: Problem, x, g0, keys, etas):
    def one_epoch(x, xs):
        runtime.TRACES.inc("sgd_epoch")
        k, eta_l = xs
        perm = jax.random.permutation(k, prob.n)

        def body(x, i):
            g = (convex.scalar_residual(prob, x, i) * prob.A[i]
                 + 2.0 * prob.lam * x)
            return x - eta_l * g, None

        x, _ = jax.lax.scan(body, x, perm)
        return x, convex.rel_grad_norm(prob, x, g0)

    return jax.lax.scan(one_epoch, x, (keys, etas))


def run_sgd(prob: Problem, *, eta: float, epochs: int, key: jax.Array,
            decay: float = 0.0):
    """Plain SGD, permutation sampling; eta_l = eta / (1 + decay*l).
    Validation is a ``solver.RunSpec`` build (DESIGN.md §Solver API)."""
    from repro.core import solver
    solver.RunSpec(algo="sgd", eta=float(eta), rounds=epochs, decay=decay)
    x = jnp.zeros((prob.d,))
    g0 = convex.grad_norm0(prob)
    keys = jax.random.split(key, epochs)
    etas = eta / (1.0 + decay * jnp.arange(epochs))
    return obs_stage.staged_call(_sgd_scan, prob, x, g0, keys, etas,
                                 _label="solve/sgd")


@functools.partial(jax.jit,
                   static_argnames=("inner", "fused", "prox", "snapshot"),
                   donate_argnames=("x",))
def _svrg_scan(prob: Problem, x, eta, g0, keys, inner: int, fused=None,
               prox=None, snapshot: str = "last", snap_idx=None):
    """``snapshot`` picks the next epoch's anchor from the inner
    trajectory — ``last`` (historical program, byte-identical), ``avg``
    (mean of inner iterates), or ``rand`` (uniform inner iterate, index
    host-precomputed in ``snap_idx``): the snapshot options of SVRG [17].
    ``prox`` applies per inner step (proximal SVRG, Xiao & Zhang)."""
    def one_epoch(x, xs):
        if snapshot == "rand":
            k, r = xs
        else:
            k = xs
        runtime.TRACES.inc("svrg_epoch")
        xbar = x
        gbar = convex.full_grad(prob, xbar)
        idx = jax.random.randint(k, (inner,), 0, prob.n)

        if fused is not None:
            # snapshot=="last" here (run_svrg falls back to unfused for
            # avg/rand); the fused tuple carries its own prox copy
            from repro.core import fused as fusedmod
            sbar = convex.scalar_residual_all(prob, xbar)
            x = fusedmod.svrg_steps(prob.A, prob.b, prob.kind, xbar, sbar,
                                    gbar, idx, fused)
            return x, convex.rel_grad_norm(prob, x, g0, prox=prox, eta=eta)

        def body(x, i):
            g = ((convex.scalar_residual(prob, x, i)
                  - convex.scalar_residual(prob, xbar, i)) * prob.A[i]
                 + gbar + 2.0 * prob.lam * (x - xbar))
            x = proxops.apply_prox(prox, x - eta * g, eta)
            return x, (x if snapshot != "last" else None)

        x, traj = jax.lax.scan(body, x, idx)
        if snapshot == "avg":
            x = traj.mean(0)
        elif snapshot == "rand":
            x = traj[r]
        return x, convex.rel_grad_norm(prob, x, g0, prox=prox, eta=eta)

    xs = (keys, snap_idx) if snapshot == "rand" else keys
    return jax.lax.scan(one_epoch, x, xs)


def run_svrg(prob: Problem, *, eta: float, epochs: int, key: jax.Array,
             inner: int = 0, fused=False, prox=None, snapshot: str = "last"):
    """SVRG [17]: snapshot + full gradient every epoch; update (3).
    Gradient evaluations per outer epoch: n (full grad) + 2*inner.
    Validation is a ``solver.RunSpec`` build (``inner`` maps onto the
    spec's ``tau`` axis — DESIGN.md §Solver API)."""
    from repro.core import fused as fusedmod
    from repro.core import solver
    spec = solver.RunSpec(algo="svrg", eta=float(eta), rounds=epochs,
                          tau=inner or None, fused=fused,
                          prox=proxops.canonical(prox), snapshot=snapshot)
    px = proxops.parse(spec.prox) if spec.prox is not None else None
    fused_t = (fusedmod.make_params(spec.fused, eta, prob.lam, prox=px)
               if snapshot == "last" else None)
    inner = inner or prob.n
    x = jnp.zeros((prob.d,))
    g0 = convex.grad_norm0(prob, prox=px, eta=eta)
    keys = jax.random.split(key, epochs)
    snap_idx = (jax.random.randint(jax.random.fold_in(key, 1), (epochs,),
                                   0, inner)
                if snapshot == "rand" else None)
    # grad evals per epoch: n + 2*inner (3n at inner=n)
    return obs_stage.staged_call(_svrg_scan, prob, x, eta, g0, keys,
                                 _label="solve/svrg", inner=inner,
                                 fused=fused_t, prox=px, snapshot=snapshot,
                                 snap_idx=snap_idx)


@functools.partial(jax.jit, static_argnames=("fused", "prox"),
                   donate_argnames=("carry",))
def _saga_scan(prob: Problem, carry, eta, g0, keys, fused=None, prox=None):
    def one_epoch(carry, k):
        runtime.TRACES.inc("saga_epoch")
        x, table, gbar = carry
        idx = jax.random.randint(k, (prob.n,), 0, prob.n)

        if fused is not None:
            from repro.core import fused as fusedmod
            x, table, gbar = fusedmod.saga_steps(
                prob.A, prob.b, prob.kind, x, table, gbar, prob.n, idx,
                fused)
            return (x, table, gbar), convex.rel_grad_norm(prob, x, g0,
                                                          prox=prox, eta=eta)

        def body(carry, i):
            x, table, gbar = carry
            s_new = convex.scalar_residual(prob, x, i)
            v = (s_new - table[i]) * prob.A[i] + gbar + 2.0 * prob.lam * x
            gbar = gbar + (s_new - table[i]) * prob.A[i] / prob.n
            table = table.at[i].set(s_new)
            return (proxops.apply_prox(prox, x - eta * v, eta),
                    table, gbar), None

        (x, table, gbar), _ = jax.lax.scan(body, (x, table, gbar), idx)
        rel = convex.rel_grad_norm(prob, x, g0, prox=prox, eta=eta)
        return (x, table, gbar), rel

    return jax.lax.scan(one_epoch, carry, keys)


def run_saga(prob: Problem, *, eta: float, epochs: int, key: jax.Array,
             fused=False, prox=None):
    """SAGA [12]: update (4), table mean refreshed every iteration.
    1 gradient evaluation per iteration; table init at x0.
    Validation is a ``solver.RunSpec`` build (DESIGN.md §Solver API)."""
    from repro.core import fused as fusedmod
    from repro.core import solver
    spec = solver.RunSpec(algo="saga", eta=float(eta), rounds=epochs,
                          fused=fused, prox=proxops.canonical(prox))
    px = proxops.parse(spec.prox) if spec.prox is not None else None
    fused_t = fusedmod.make_params(spec.fused, eta, prob.lam, prox=px)
    x = jnp.zeros((prob.d,))
    g0 = convex.grad_norm0(prob, prox=px, eta=eta)
    table = convex.scalar_residual_all(prob, x)
    gbar = convex.data_grad_from_scalars(prob, table)
    keys = jax.random.split(key, epochs)
    (x, table, gbar), rels = obs_stage.staged_call(
        _saga_scan, prob, (x, table, gbar), eta, g0, keys,
        _label="solve/saga", fused=fused_t, prox=px)
    return x, rels


# ---------------------------------------------------------------------------
# Distributed baselines
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("tau",),
                   donate_argnames=("x",))
def _dist_sgd_scan(sp: ShardedProblem, x, g0, keys, etas, tau: int):
    merged = sp.merged()

    def round_(x, xs):
        runtime.TRACES.inc("dist_sgd_round")
        k, eta_l = xs

        def local(A, b, kk):
            prob = Problem(A, b, sp.lam, sp.kind)
            idx = jax.random.randint(kk, (tau,), 0, sp.ns)

            def body(xl, i):
                g = (convex.scalar_residual(prob, xl, i) * A[i]
                     + 2.0 * sp.lam * xl)
                return xl - eta_l * g, None

            xl, _ = jax.lax.scan(body, x, idx)
            return xl

        xs_w = jax.vmap(local)(sp.A, sp.b, jax.random.split(k, sp.p))
        x = xs_w.mean(0)
        return x, convex.rel_grad_norm(merged, x, g0)

    return jax.lax.scan(round_, x, (keys, etas))


def run_dist_sgd(sp: ShardedProblem, *, eta: float, rounds: int,
                 key: jax.Array, tau: int = 0, decay: float = 0.0,
                 backend: str = "vmap", mesh=None):
    """Distributed SGD: tau local steps (default: one local epoch), then
    average — the 'one-shot-averaging per round' baseline.
    Validation is a ``solver.RunSpec`` build (DESIGN.md §Solver API)."""
    from repro.core import solver
    spec = solver.RunSpec(algo="dist_sgd", p=sp.p, eta=float(eta),
                          rounds=rounds, backend=backend,
                          tau=tau or None, decay=decay)
    if spec.backend == "spmd":
        from repro.core import spmd
        return spmd.run_dist_sgd(sp, eta=eta, rounds=rounds, key=key,
                                 tau=tau, decay=decay, mesh=mesh)
    tau = tau or sp.ns
    x = jnp.zeros((sp.d,))
    g0 = convex.grad_norm0(sp.merged())
    keys = jax.random.split(key, rounds)
    etas = eta / (1.0 + decay * jnp.arange(rounds) * tau) ** 0.5
    return obs_stage.staged_call(_dist_sgd_scan, sp, x, g0, keys, etas,
                                 _label="solve/dist_sgd", tau=tau)


@functools.partial(jax.jit, static_argnames=("tau", "steps_per_round"),
                   donate_argnames=("xc", "xs"))
def _easgd_scan(sp: ShardedProblem, xc, xs, alpha, g0, keys, etas,
                tau: int, steps_per_round: int):
    merged = sp.merged()

    def round_(carry, ins):
        runtime.TRACES.inc("easgd_round")
        xc, xs = carry
        k, eta_l = ins

        def local(A, b, xl, kk):
            prob = Problem(A, b, sp.lam, sp.kind)
            idx = jax.random.randint(kk, (steps_per_round * tau,), 0, sp.ns)
            idx = idx.reshape(steps_per_round, tau)

            def comm_block(carry, idx_tau):
                xl, xc_view = carry

                def body(x, i):
                    g = (convex.scalar_residual(prob, x, i) * A[i]
                         + 2.0 * sp.lam * x)
                    return x - eta_l * g, None

                xl, _ = jax.lax.scan(body, xl, idx_tau)
                diff = xl - xc_view
                # symmetric elastic move; the center's share is applied
                # after the vmap (sum of worker contributions)
                return (xl - alpha * diff, xc_view + alpha * diff), diff

            (xl, _), diffs = jax.lax.scan(comm_block, (xl, xc), idx)
            return xl, diffs.sum(0)

        xs, diffs = jax.vmap(local)(sp.A, sp.b, xs,
                                    jax.random.split(k, sp.p))
        xc = xc + alpha * diffs.sum(0) / sp.p
        rel = convex.rel_grad_norm(merged, xc, g0)
        return (xc, xs), rel

    (xc, xs), rels = jax.lax.scan(round_, (xc, xs), (keys, etas))
    return xc, xs, rels


def run_easgd(sp: ShardedProblem, *, eta: float, rounds: int, key: jax.Array,
              tau: int = 16, rho: float = 1.0, decay: float = 0.0,
              backend: str = "vmap", mesh=None):
    """EASGD [36]: workers do tau local SGD steps, then the elastic update
      x_s <- x_s - alpha*(x_s - xc),  xc <- xc + alpha*sum_s(x_s - xc)/p'
    with alpha = eta*rho (the paper's beta=p*alpha convention, symmetric
    moving-average form). Step size optionally decays as eta0/(1+gamma*k)^.5
    on a local clock, as in [36]/§6.2.

    Validation is a ``solver.RunSpec`` build (DESIGN.md §Solver API).
    """
    from repro.core import solver
    spec = solver.RunSpec(algo="easgd", p=sp.p, eta=float(eta),
                          rounds=rounds, backend=backend,
                          tau=tau or None, decay=decay)
    if spec.backend == "spmd":
        from repro.core import spmd
        return spmd.run_easgd(sp, eta=eta, rounds=rounds, key=key, tau=tau,
                              rho=rho, decay=decay, mesh=mesh)
    p = sp.p
    alpha = min(0.9 / p, eta * rho * tau)   # stability-capped elastic rate
    xc = jnp.zeros((sp.d,))
    xs = jnp.zeros((p, sp.d))
    steps_per_round = max(sp.ns // tau, 1)
    g0 = convex.grad_norm0(sp.merged())
    keys = jax.random.split(key, rounds)
    etas = eta / (1.0 + decay * jnp.arange(rounds) * sp.ns) ** 0.5
    xc, _, rels = obs_stage.staged_call(
        _easgd_scan, sp, xc, xs, alpha, g0, keys, etas,
        _label="solve/easgd", tau=tau, steps_per_round=steps_per_round)
    return xc, rels


@functools.partial(jax.jit, static_argnames=("inner",),
                   donate_argnames=("x",))
def _ps_svrg_scan(sp: ShardedProblem, x, eta, g0, keys, inner: int):
    merged = sp.merged()

    def round_(x, k):
        runtime.TRACES.inc("ps_svrg_round")
        xbar = x
        gbar = convex.full_grad(merged, xbar)

        def body(x, ks):
            # each worker contributes one corrected gradient; the server
            # applies their average (p gradients -> one server step)
            i = jax.random.randint(ks, (sp.p,), 0, sp.ns)

            def worker_grad(A, b, ii):
                prob = Problem(A, b, sp.lam, sp.kind)
                return ((convex.scalar_residual(prob, x, ii)
                         - convex.scalar_residual(prob, xbar, ii)) * A[ii]
                        + gbar + 2.0 * sp.lam * (x - xbar))

            g = jax.vmap(worker_grad)(sp.A, sp.b, i).mean(0)
            return x - eta * g, None

        x, _ = jax.lax.scan(body, x, jax.random.split(k, inner))
        return x, convex.rel_grad_norm(merged, x, g0)

    return jax.lax.scan(round_, x, keys)


def run_ps_svrg(sp: ShardedProblem, *, eta: float, rounds: int,
                key: jax.Array, epoch_mult: int = 2,
                backend: str = "vmap", mesh=None):
    """Parameter-server SVRG [29]: every worker streams one corrected
    gradient per step to the server (communication every iteration — the
    high-bandwidth regime the paper contrasts against). Simulated with
    synchronized arrivals (staleness 0, the method's best case); epoch
    size 2n as recommended in [29]. Per round: one full gradient + 2
    gradient evaluations per inner step per worker.
    Validation is a ``solver.RunSpec`` build (DESIGN.md §Solver API)."""
    from repro.core import solver
    spec = solver.RunSpec(algo="ps_svrg", p=sp.p, eta=float(eta),
                          rounds=rounds, backend=backend)
    if spec.backend == "spmd":
        from repro.core import spmd
        return spmd.run_ps_svrg(sp, eta=eta, rounds=rounds, key=key,
                                epoch_mult=epoch_mult, mesh=mesh)
    x = jnp.zeros((sp.d,))
    g0 = convex.grad_norm0(sp.merged())
    inner = epoch_mult * sp.ns
    keys = jax.random.split(key, rounds)
    return obs_stage.staged_call(_ps_svrg_scan, sp, x, eta, g0, keys,
                                 _label="solve/ps_svrg", inner=inner)
