"""Prox'd trajectory agreement pins (ISSUE 10 tentpole, DESIGN.md
§Composite objectives).

The prox/snapshot axes must not fork numerics across execution paths:

  * FUSED == UNFUSED: every VR-family algorithm with an elementwise prox
    (l1, elasticnet, box) produces the same trajectory through the Pallas
    ``vr_update`` prox epilogue as through the unfused oracle (x64,
    1e-10);
  * VMAP == SPMD (subprocess with 8 forced host devices, same rule as
    test_spmd_backend): the prox'd sync/async/dsvrg/dsaga runners on the
    mesh match the stacked vmap drivers, including the snapshot anchors
    ("rand" draws its per-round index from the same host-precomputed
    fold_in stream in both backends);
  * SPARSE == DENSE: the lazy CSR driver (``prox/lazy.py``) replays the
    dense prox'd CentralVR trajectory exactly — same RNG splits, same
    arithmetic restricted to row supports, closed-form catch-up for
    everything skipped (1e-10 in x64 — the tentpole acceptance pin);
  * SNAPSHOT strategies change the trajectory they are supposed to
    change ("avg"/"rand" differ from "last") and nothing else (smooth
    defaults stay bit-identical to the pre-prox program);
  * ROBUST losses solve end-to-end and RunSpec refuses the invalid
    combinations pre-JAX, naming the offending field.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

# x64 + same algebra in a different launch/communication order
CONVEX_TOL = 1e-10

PROXES = ("l1:0.01", "elasticnet:0.01:0.001", "box:-0.5:0.5")


def _problem(p):
    import jax

    from repro.config import ConvexConfig
    from repro.core import convex, distributed

    if p == 1:
        prob = convex.make_logistic_data(jax.random.PRNGKey(2), 48, 8)
        return prob, convex.auto_eta(prob, 0.3)
    cfg = ConvexConfig(problem="logistic", n=48, d=8, workers=p)
    sp = distributed.make_distributed(jax.random.PRNGKey(2), cfg)
    return sp, convex.auto_eta(sp.merged(), 0.3)


# ---------------------------------------------------------------------------
# fused == unfused with a prox epilogue (vmap, in-process)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,p", [
    ("centralvr", 1), ("svrg", 1), ("saga", 1),
    ("centralvr_sync", 4), ("centralvr_async", 4),
    ("dsvrg", 4), ("dsaga", 4),
])
@pytest.mark.parametrize("prox", PROXES)
def test_fused_matches_unfused_with_prox(algo, p, prox):
    import jax

    from repro import RunSpec, solve

    problem, eta = _problem(p)
    key = jax.random.PRNGKey(7)
    res_u = solve(RunSpec(algo=algo, p=p, eta=eta, rounds=3, prox=prox),
                  problem, key=key)
    res_f = solve(RunSpec(algo=algo, p=p, eta=eta, rounds=3, prox=prox,
                          fused=True), problem, key=key)
    np.testing.assert_allclose(res_f.x, res_u.x, rtol=0, atol=CONVEX_TOL)
    np.testing.assert_allclose(res_f.rels, res_u.rels, rtol=CONVEX_TOL,
                               atol=CONVEX_TOL)
    # the prox actually did something (box/l1 clamp the logistic iterate)
    res_s = solve(RunSpec(algo=algo, p=p, eta=eta, rounds=3), problem,
                  key=key)
    assert float(np.abs(res_u.x - res_s.x).max()) > 1e-8


# ---------------------------------------------------------------------------
# vmap == spmd with prox + snapshot axes (forced-multi-device subprocess)
# ---------------------------------------------------------------------------

SPMD_SCRIPT = textwrap.dedent("""
    import sys
    sys.path.insert(0, "src")
    from repro.core import spmd
    spmd.force_host_devices(8)      # before the first jax operation
    import json
    import jax
    jax.config.update("jax_enable_x64", True)   # match conftest precision
    import numpy as np
    from repro.config import ConvexConfig
    from repro.core import convex, distributed

    def diff(a, b):
        return float(np.abs(np.asarray(a) - np.asarray(b)).max())

    def final_x(st):
        for attr in ("x", "x_c"):
            if hasattr(st, attr):
                return getattr(st, attr)
        return st

    key = jax.random.PRNGKey(7)
    cfg = ConvexConfig(problem="logistic", n=48, d=8, workers=4)
    sp = distributed.make_distributed(jax.random.PRNGKey(2), cfg)
    eta = convex.auto_eta(sp.merged(), 0.3)

    out = {"device_count": jax.device_count(), "drivers": {}}
    cases = (
        ("sync-l1", distributed.run_sync, {"prox": "l1:0.01"}),
        ("sync-box", distributed.run_sync, {"prox": "box:-0.5:0.5"}),
        ("async-l1", distributed.run_async, {"prox": "l1:0.01"}),
        ("dsvrg-rand-l1", distributed.run_dsvrg,
         {"tau": 32, "prox": "l1:0.01", "snapshot": "rand"}),
        ("dsvrg-avg-en", distributed.run_dsvrg,
         {"tau": 32, "prox": "elasticnet:0.01:0.001", "snapshot": "avg"}),
        ("dsaga-l1", distributed.run_dsaga,
         {"fetch": "stale", "prox": "l1:0.01"}),
    )
    for name, fn, kw in cases:
        st_v, rels_v = fn(sp, eta=eta, rounds=3, key=key, backend="vmap",
                          **kw)
        st_s, rels_s = fn(sp, eta=eta, rounds=3, key=key, backend="spmd",
                          **kw)
        out["drivers"][name] = {"dx": diff(final_x(st_v), final_x(st_s)),
                                "drel": diff(rels_v, rels_s)}
    print("RESULT" + json.dumps(out))
""")


@pytest.mark.slow
def test_prox_vmap_matches_spmd():
    proc = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], cwd=ROOT,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    assert out["device_count"] == 8
    for name, d in out["drivers"].items():
        assert d["dx"] <= CONVEX_TOL, (name, d)
        assert d["drel"] <= CONVEX_TOL, (name, d)


# ---------------------------------------------------------------------------
# sparse lazy == dense oracle (the tentpole acceptance pin)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["ridge", "logistic"])
@pytest.mark.parametrize("prox", [None, "l1:0.02"])
def test_sparse_lazy_matches_dense_oracle(kind, prox):
    import jax

    from repro.core import centralvr
    from repro.prox import lazy

    prob = lazy.make_sparse_data(jax.random.PRNGKey(7), 48, 40, 3,
                                 kind=kind)
    key = jax.random.PRNGKey(2)
    st_d, rels_d, ge_d = centralvr.run(prob, eta=0.05, epochs=4, key=key,
                                       prox=prox)
    st_s, rels_s, ge_s = lazy.run_sparse(prob, eta=0.05, epochs=4, key=key,
                                         prox=prox)
    np.testing.assert_allclose(np.asarray(st_s.x), np.asarray(st_d.x),
                               rtol=0, atol=CONVEX_TOL)
    np.testing.assert_allclose(np.asarray(st_s.table),
                               np.asarray(st_d.table), rtol=0,
                               atol=CONVEX_TOL)
    np.testing.assert_allclose(np.asarray(rels_s), np.asarray(rels_d),
                               rtol=CONVEX_TOL, atol=CONVEX_TOL)
    np.testing.assert_array_equal(np.asarray(ge_s), np.asarray(ge_d))
    if prox is not None:
        # the l1 run produced a genuinely sparse iterate
        assert float(np.mean(np.asarray(st_s.x) == 0.0)) > 0.3


def test_sparse_route_through_runspec():
    """sampling="sparse" on the solver API routes Algorithm 1 through the
    lazy driver and matches the dense permutation route exactly."""
    import jax

    from repro import RunSpec, solve
    from repro.prox import lazy

    prob = lazy.make_sparse_data(jax.random.PRNGKey(7), 48, 40, 3)
    dense = solve(RunSpec(algo="centralvr", eta=0.05, rounds=3, seed=2,
                          prox="l1:0.02"), prob)
    sparse = solve(RunSpec(algo="centralvr", eta=0.05, rounds=3, seed=2,
                           prox="l1:0.02", sampling="sparse"), prob)
    np.testing.assert_allclose(sparse.x, dense.x, rtol=0, atol=CONVEX_TOL)
    np.testing.assert_allclose(sparse.rels, dense.rels, rtol=CONVEX_TOL,
                               atol=CONVEX_TOL)


def test_sparse_lazy_guards():
    import jax
    import jax.numpy as jnp

    from repro.core.convex import Problem
    from repro.prox import lazy

    prob = lazy.make_sparse_data(jax.random.PRNGKey(7), 16, 12, 2)
    with pytest.raises(ValueError, match="lam == 0"):
        lazy.run_sparse(Problem(prob.A, prob.b, jnp.asarray(1e-3),
                                prob.kind),
                        eta=0.05, epochs=1, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="'l1'"):
        lazy.run_sparse(prob, eta=0.05, epochs=1,
                        key=jax.random.PRNGKey(0), prox="box:-1:1")
    with pytest.raises(ValueError, match="drop nonzeros"):
        lazy.sparsify(prob, width=1)


# ---------------------------------------------------------------------------
# snapshot strategies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,p", [("svrg", 1), ("dsvrg", 4)])
def test_snapshot_axes_change_the_anchor(algo, p):
    import jax

    from repro import RunSpec, solve

    problem, eta = _problem(p)
    key = jax.random.PRNGKey(7)
    runs = {snap: solve(RunSpec(algo=algo, p=p, eta=eta, rounds=3,
                                snapshot=snap), problem, key=key)
            for snap in ("last", "avg", "rand")}
    # explicit "last" == default (the historical program)
    default = solve(RunSpec(algo=algo, p=p, eta=eta, rounds=3), problem,
                    key=key)
    np.testing.assert_array_equal(np.asarray(runs["last"].x),
                                  np.asarray(default.x))
    # avg/rand re-anchor: the trajectories genuinely differ
    for snap in ("avg", "rand"):
        assert float(np.abs(runs[snap].x - runs["last"].x).max()) > 1e-8
        assert np.all(np.isfinite(runs[snap].rels))


def test_snapshot_refuses_fused():
    from repro import RunSpec

    with pytest.raises(ValueError, match="snapshot"):
        RunSpec(algo="svrg", eta=0.1, rounds=1, snapshot="avg", fused=True)


# ---------------------------------------------------------------------------
# robust losses end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["huber", "pseudo_huber"])
def test_robust_losses_solve(kind):
    import jax

    from repro import RunSpec, solve
    from repro.core import convex

    prob = convex.make_huber_data(jax.random.PRNGKey(3), 64, 8, 1e-3,
                                  delta=1.0, outliers=0.1, kind=kind)
    eta = convex.auto_eta(prob, 0.3)
    res = solve(RunSpec(algo="centralvr", eta=eta, rounds=6,
                        prox="l1:0.001"), prob, key=jax.random.PRNGKey(7))
    assert res.rels[-1] < 0.5          # it converges, robustly
    assert np.all(np.isfinite(res.rels))


# ---------------------------------------------------------------------------
# RunSpec contracts (pre-JAX, field-named errors)
# ---------------------------------------------------------------------------

def test_runspec_prox_contracts():
    from repro import RunSpec

    with pytest.raises(ValueError, match="RunSpec.prox"):
        RunSpec(algo="sgd", eta=0.1, rounds=1, prox="l1:0.01")
    with pytest.raises(ValueError, match="RunSpec.fused"):
        RunSpec(algo="centralvr", eta=0.1, rounds=1,
                prox="group_l2:0.01:4", fused=True)
    with pytest.raises(ValueError, match="unknown prox operator"):
        RunSpec(algo="centralvr", eta=0.1, rounds=1, prox="nope:1")
    with pytest.raises(ValueError, match="RunSpec.snapshot"):
        RunSpec(algo="saga", eta=0.1, rounds=1, snapshot="avg")
    with pytest.raises(ValueError, match="RunSpec.sampling"):
        RunSpec(algo="svrg", eta=0.1, rounds=1, sampling="sparse")
    with pytest.raises(ValueError, match="RunSpec.prox"):
        RunSpec(algo="centralvr", eta=0.1, rounds=1, sampling="sparse",
                prox="elasticnet:0.01:0.001")
    # stored canonically: params resolved, asdict round-trips
    spec = RunSpec(algo="centralvr", eta=0.1, rounds=1, prox="l1")
    assert spec.prox == "l1:0.001"
    spec = RunSpec(algo="dsvrg", p=2, eta=0.1, rounds=1, snapshot="rand")
    assert spec.snapshot == "rand"


def test_provenance_carries_prox_and_snapshot():
    import jax

    from repro import RunSpec, solve
    from repro.obs import schema

    problem, eta = _problem(1)
    res = solve(RunSpec(algo="centralvr", eta=eta, rounds=2,
                        prox="l1:0.01"), problem, key=jax.random.PRNGKey(7))
    prov = res.provenance()
    assert prov["spec"]["prox"] == "l1:0.01"
    assert "prox" in schema.PROVENANCE_SPEC_KEYS
    assert "snapshot" in schema.PROVENANCE_SPEC_KEYS
