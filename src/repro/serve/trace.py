"""Deterministic synthetic request traces for serving benchmarks/tests.

The continuous-batching scheduler is clocked by the DECODE-STEP counter,
not wall time: a request with ``arrival = a`` becomes visible once the
engine has executed ``a`` decode steps.  That makes every benchmark row
and equivalence test exactly reproducible — same trace, same admission
order, same token streams — while still exercising real churn (lanes
retiring and admitting mid-flight).

Arrival patterns:
  * ``burst``   — everything arrives at step 0 (queueing-dominated);
  * ``uniform`` — one request every ``gap`` steps (steady state);
  * ``poisson`` — exponential inter-arrivals from a seeded RandomState
                  with mean ``gap`` (bursty but reproducible).

Prompt token ids are derived per-request from (seed, rid), independent of
trace order, so sequential and batched servings of the same request see
identical prompts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

ARRIVAL_PATTERNS = ("burst", "uniform", "poisson")


@dataclass(frozen=True)
class Request:
    rid: int
    arrival: int            # decode-step clock at which it becomes visible
    prompt_len: int
    max_new: int            # greedy tokens to generate (>= 1)
    seed: int = 0

    def __post_init__(self):
        if self.prompt_len < 1 or self.max_new < 1:
            raise ValueError(f"request {self.rid}: prompt_len and max_new "
                             "must be >= 1")

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.max_new


def prompt_tokens(req: Request, vocab: int) -> np.ndarray:
    """(prompt_len,) int32, a pure function of (seed, rid)."""
    rs = np.random.RandomState((req.seed * 1_000_003 + req.rid) % (2 ** 31))
    return rs.randint(0, vocab, size=req.prompt_len).astype(np.int32)


def synthetic_trace(n: int, *, pattern: str = "burst", prompt_len: int = 32,
                    max_new: int = 16, gap: int = 4, vary_new: bool = False,
                    prompt_lens: Optional[Sequence[int]] = None,
                    seed: int = 0) -> List[Request]:
    """n requests with deterministic arrivals.  ``vary_new`` cycles max_new
    over {max_new, 3/4, 1/2, 1/4 of it} so lanes retire at different steps
    (the case continuous batching wins on); ``prompt_lens`` overrides the
    uniform prompt length per request (cycled)."""
    if pattern not in ARRIVAL_PATTERNS:
        raise ValueError(f"pattern {pattern!r} not in {ARRIVAL_PATTERNS}")
    rs = np.random.RandomState(seed % (2 ** 31) + 17)
    arrivals: List[int] = []
    t = 0.0
    for i in range(n):
        if pattern == "burst":
            arrivals.append(0)
        elif pattern == "uniform":
            arrivals.append(i * gap)
        else:
            arrivals.append(int(t))
            t += rs.exponential(scale=max(gap, 1))
    news = [max(1, max_new * f // 4) for f in (4, 3, 2, 1)]
    out = []
    for i, a in enumerate(arrivals):
        pl = prompt_lens[i % len(prompt_lens)] if prompt_lens else prompt_len
        mn = news[i % 4] if vary_new else max_new
        out.append(Request(rid=i, arrival=a, prompt_len=int(pl),
                           max_new=int(mn), seed=seed))
    return out
