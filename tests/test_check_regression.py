"""The CI benchmark regression gate (benchmarks/check_regression.py):
gates BOTH runtime artifacts and fails loudly on missing/empty artifacts
instead of passing silently (DESIGN.md §7)."""
import json
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
SCRIPT = os.path.join(ROOT, "benchmarks", "check_regression.py")


def _drivers_artifact(speedup):
    return {"rows": [{"name": "drivers/sync-p2", "speedup_warm": speedup}]}


def _train_artifact(speedup):
    return {"rows": [
        {"name": "train_throughput/host-w2", "path": "host",
         "speedup_vs_host": 1.0},
        {"name": "train_throughput/scan-vmap-w2", "path": "scan-vmap",
         "speedup_vs_host": speedup},
    ]}


def _serve_artifact(decode=1.5, prefill=8.0, extra=()):
    return {"rows": [
        {"name": "serve_throughput/host-loop-w4", "path": "host-loop"},
        {"name": "serve_throughput/engine-paged-w4", "path": "engine-paged",
         "decode_speedup_vs_host": decode},
        {"name": "serve_throughput/engine-prefill128",
         "path": "engine-paged", "prefill_speedup_vs_host": prefill},
        *extra,
    ]}


def _run(tmp_path, drivers, train, serve="default"):
    if serve == "default":
        serve = _serve_artifact()
    args = [sys.executable, SCRIPT, "--floor", "1.0"]
    for flag, payload, fname in (("--path", drivers, "drv.json"),
                                 ("--train-path", train, "trn.json"),
                                 ("--serve-path", serve, "srv.json")):
        p = tmp_path / fname
        if payload is not None:
            p.write_text(json.dumps(payload))
        args += [flag, str(p)]
    return subprocess.run(args, capture_output=True, text=True)


def test_passing_artifacts_exit_zero(tmp_path):
    r = _run(tmp_path, _drivers_artifact(2.0), _train_artifact(3.0))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "scan vs host loop" in r.stdout
    assert "epoch scan vs seed host path" in r.stdout


def test_driver_regression_fails(tmp_path):
    r = _run(tmp_path, _drivers_artifact(0.5), _train_artifact(3.0))
    assert r.returncode == 1
    assert "REGRESSION" in r.stdout


def test_train_regression_fails(tmp_path):
    """The train artifact is gated too — only the scan-path rows, against
    the seed host path."""
    r = _run(tmp_path, _drivers_artifact(2.0), _train_artifact(0.9))
    assert r.returncode == 1
    assert "train speedup below" in r.stderr


def _write(tmp_path, name, payload):
    p = os.path.join(tmp_path, name)
    with open(p, "w") as f:
        json.dump(payload, f)
    return p


def test_host_rows_not_gated(tmp_path):
    """The host reference row is 1.0x by construction and must not trip
    the gate when the floor rises."""
    dp = _write(tmp_path, "d.json", _drivers_artifact(5.0))
    tp = _write(tmp_path, "t.json", _train_artifact(5.0))
    sp = _write(tmp_path, "s.json", _serve_artifact(5.0))
    r = subprocess.run(
        [sys.executable, SCRIPT, "--floor", "2.0", "--path", dp,
         "--train-path", tp, "--serve-path", sp],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


def test_missing_artifact_fails(tmp_path):
    r = _run(tmp_path, None, _train_artifact(3.0))
    assert r.returncode == 1
    assert "unreadable bench artifact" in r.stderr


def test_empty_rows_fail(tmp_path):
    r = _run(tmp_path, {"rows": []}, _train_artifact(3.0))
    assert r.returncode == 1
    assert "has no rows" in r.stderr


def test_non_object_artifact_fails_with_guidance(tmp_path):
    """A truncated/corrupted artifact whose top level is a JSON array must
    hit the designed failure message, not an unhandled traceback."""
    r = _run(tmp_path, [], _train_artifact(3.0))
    assert r.returncode == 1
    assert "unreadable bench artifact" in r.stderr


def test_train_without_scan_rows_fails(tmp_path):
    r = _run(tmp_path, _drivers_artifact(2.0),
             {"rows": [{"name": "train_throughput/host-w1", "path": "host",
                        "speedup_vs_host": 1.0}]})
    assert r.returncode == 1
    assert "no scan-path rows" in r.stderr


def test_report_written_with_gate_decisions(tmp_path):
    """--report dumps every gate decision + the verdict as JSON (the CI
    artifact a red gate is diagnosed from)."""
    dp = _write(tmp_path, "d.json", _drivers_artifact(2.0))
    tp = _write(tmp_path, "t.json", _train_artifact(3.0))
    sp = _write(tmp_path, "s.json", _serve_artifact())
    rp = str(tmp_path / "r.json")
    r = subprocess.run(
        [sys.executable, SCRIPT, "--floor", "1.0", "--path", dp,
         "--train-path", tp, "--serve-path", sp, "--report", rp],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    with open(rp) as f:
        report = json.load(f)
    assert report["failed"] is False
    assert report["floor"] == 1.0
    assert report["artifacts"] == {"drivers": dp, "train": tp, "serve": sp}
    by_name = {g["name"]: g for g in report["gates"]}
    assert by_name["drivers/sync-p2"]["status"] == "ok"
    assert by_name["train_throughput/scan-vmap-w2"]["status"] == "ok"
    assert by_name["serve_throughput/engine-paged-w4"]["status"] == "ok"
    assert by_name["serve_throughput/engine-prefill128"]["status"] == "ok"


def test_report_records_failure_verdict(tmp_path):
    r = _run(tmp_path, _drivers_artifact(0.5), _train_artifact(3.0))
    assert r.returncode == 1
    dp = _write(tmp_path, "d2.json", _drivers_artifact(0.5))
    tp = _write(tmp_path, "t2.json", _train_artifact(3.0))
    sp = _write(tmp_path, "s2.json", _serve_artifact())
    rp = tmp_path / "r2.json"
    r = subprocess.run(
        [sys.executable, SCRIPT, "--floor", "1.0", "--path", dp,
         "--train-path", tp, "--serve-path", sp, "--report", str(rp)],
        capture_output=True, text=True)
    assert r.returncode == 1
    report = json.loads(rp.read_text())
    assert report["failed"] is True
    assert any(g["status"] == "REGRESSION" for g in report["gates"])


def test_telemetry_rows_reported_but_never_gated(tmp_path):
    """The -obs twins measure observation cost: an arbitrarily large
    overhead must not fail the gate, but the row lands in the report as
    informational."""
    drivers = _drivers_artifact(2.0)
    drivers["rows"].append({"name": "drivers/async-p8-obs",
                            "telemetry": True, "overhead_vs_off": 50.0})
    dp = _write(tmp_path, "d.json", drivers)
    tp = _write(tmp_path, "t.json", _train_artifact(3.0))
    sp = _write(tmp_path, "s.json", _serve_artifact())
    rp = tmp_path / "r.json"
    r = subprocess.run(
        [sys.executable, SCRIPT, "--floor", "1.0", "--path", dp,
         "--train-path", tp, "--serve-path", sp, "--report", str(rp)],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "informational" in r.stdout
    report = json.loads(rp.read_text())
    twin = [g for g in report["gates"]
            if g["name"] == "drivers/async-p8-obs"]
    assert twin == [{"name": "drivers/async-p8-obs",
                     "gate": "overhead_vs_off", "value": 50.0,
                     "floor": None, "status": "informational"}]


def test_serve_decode_regression_fails(tmp_path):
    """The engine decoding slower than the legacy host loop it replaces
    is a gated regression."""
    r = _run(tmp_path, _drivers_artifact(2.0), _train_artifact(3.0),
             serve=_serve_artifact(decode=0.8))
    assert r.returncode == 1
    assert "serve speedup below floor" in r.stderr
    assert "engine-paged-w4" in r.stderr


def test_serve_prefill_regression_fails(tmp_path):
    """Chunked prefill must stay >= 5x per-token prefill at prompt 128."""
    r = _run(tmp_path, _drivers_artifact(2.0), _train_artifact(3.0),
             serve=_serve_artifact(prefill=3.0))
    assert r.returncode == 1
    assert "engine-prefill128" in r.stderr


def test_serve_estimated_rows_exempt(tmp_path):
    """CPU-simulated TP rows carry estimated:true and are informational,
    same convention as interpret-mode fused rows."""
    tp_row = {"name": "serve_throughput/engine-tp2", "path": "engine-tp",
              "estimated": True, "decode_speedup_vs_host": 0.1}
    r = _run(tmp_path, _drivers_artifact(2.0), _train_artifact(3.0),
             serve=_serve_artifact(extra=[tp_row]))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "exempt: estimated" in r.stdout


def test_serve_missing_artifact_fails(tmp_path):
    r = _run(tmp_path, _drivers_artifact(2.0), _train_artifact(3.0),
             serve=None)
    assert r.returncode == 1
    assert "unreadable bench artifact" in r.stderr


def test_serve_without_gated_rows_fails(tmp_path):
    """An artifact holding only host-loop / estimated rows guards
    nothing and must fail loudly."""
    serve = {"rows": [{"name": "serve_throughput/host-loop-w4",
                       "path": "host-loop"}]}
    r = _run(tmp_path, _drivers_artifact(2.0), _train_artifact(3.0),
             serve=serve)
    assert r.returncode == 1
    assert "no gated engine rows" in r.stderr


def _run_compile(tmp_path, cold_s, ceiling):
    drivers = {"rows": [dict(_drivers_artifact(2.0)["rows"][0],
                             **({} if cold_s is None
                                else {"cold_s": cold_s}))]}
    args = [sys.executable, SCRIPT, "--floor", "1.0",
            "--compile-floor", str(ceiling)]
    for flag, payload, fname in (("--path", drivers, "drv.json"),
                                 ("--train-path", _train_artifact(3.0),
                                  "trn.json"),
                                 ("--serve-path", _serve_artifact(),
                                  "srv.json")):
        p = tmp_path / fname
        p.write_text(json.dumps(payload))
        args += [flag, str(p)]
    return subprocess.run(args, capture_output=True, text=True)


def test_compile_floor_gates_cold_s(tmp_path):
    r = _run_compile(tmp_path, cold_s=45.0, ceiling=10)
    assert r.returncode == 1
    assert "compile ceiling" in r.stdout
    assert "drivers/sync-p2" in r.stderr


def test_compile_floor_passes_within_ceiling(tmp_path):
    r = _run_compile(tmp_path, cold_s=45.0, ceiling=100)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "within the 100s compile ceiling" in r.stdout


def test_compile_floor_exempts_rows_without_cold_s(tmp_path):
    """Rows predating the cold_s field (or derived twins that never
    measure a cold call) are printed as exempt, not failed."""
    r = _run_compile(tmp_path, cold_s=None, ceiling=10)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "exempt: no-cold" in r.stdout


def test_committed_artifacts_pass():
    """The artifacts at the repo root (regenerated by the CI bench lane)
    satisfy the gate this repo ships with — including the compile-time
    ceiling the bench-smoke lane passes."""
    r = subprocess.run([sys.executable, SCRIPT, "--floor", "1.0",
                        "--compile-floor", "120"],
                       capture_output=True, text=True, cwd=ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
