"""Host-driven per-step reference loop — the pre-runtime execution model
(mirroring ``core/host_loop.py`` for the convex drivers, DESIGN.md §3).

One jitted step dispatched per iteration from a Python loop, with every
batch built on the host and fed across the host->device boundary. Kept
for two reasons:

  * ``tests/test_train_scan.py`` pins the epoch-scan runtime
    (``step.make_epoch_runner`` / ``loop.run_training``) to these
    trajectories — the runtime rebuild must be a pure execution-model
    change, not an algorithm change;
  * ``benchmarks/train_throughput.py`` measures the epoch scan against
    this baseline (steps/sec vs worker count, ``BENCH_train.json``).

Do not grow features here; new work goes in the epoch-scan runtime.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.config import ModelConfig, TrainConfig
from repro.data import synthetic
from repro.launch import mesh as meshlib
from repro.train import step as tstep
from repro.train.loop import LoopResult


def _epoch_batch_host(cfg, seed, step, *, workers, accum, microbatch, seq,
                      table_size):
    """Seed batch builder kept verbatim: one ``microbatch_tokens``
    dispatch per (worker, accum) pair, stacked pairwise — per-step host
    work that GROWS with the worker count, which is exactly what the
    epoch scan's on-device generation eliminates. Byte-identical tokens
    to the vectorized ``synthetic.epoch_batch`` (same fold_in chains)."""
    idx = step % table_size
    ws = []
    for w in range(workers):
        accs = [synthetic.microbatch_tokens(cfg, seed, w, idx * accum + a,
                                            microbatch, seq)
                for a in range(accum)]
        ws.append(jnp.stack(accs))
    return jnp.stack(ws)     # (W, A, mb, S)


def run_training(cfg: ModelConfig, tcfg: TrainConfig, *, steps: int,
                 mesh=None, vr_workers: str = "none",
                 workers: Optional[int] = None,
                 checkpoint_path: Optional[str] = None,
                 checkpoint_every: int = 0,
                 log_every: int = 10,
                 log_fn: Callable[[str], None] = print) -> LoopResult:
    """Per-step reference training loop (seed execution model).

    ``workers`` simulates W stacked worker copies under vmap on the
    provided mesh (defaults to the mesh-derived count). ``steps`` is an
    arbitrary step count — the epoch-scan loop drives whole epochs only.
    """
    mesh = mesh or meshlib.make_test_mesh()
    train_step, meta = tstep.make_train_step(cfg, tcfg, mesh, vr_workers,
                                             workers=workers)
    W = meta["workers"]
    accum, mb = tstep.batch_geometry(tcfg, W)

    state = tstep.init_train_state(cfg, tcfg, jax.random.PRNGKey(tcfg.seed),
                                   W)
    jit_step = jax.jit(train_step)

    def batch_for(s):
        toks = _epoch_batch_host(cfg, tcfg.seed, s, workers=W,
                                 accum=accum, microbatch=mb,
                                 seq=tcfg.seq_len,
                                 table_size=tcfg.vr_table_size)
        if W == 1:
            toks = toks[0]
        return toks

    result = LoopResult()
    t0 = time.time()
    # keep per-step metrics on device: forcing float(loss) every step
    # would block on a device->host transfer and serialize dispatch; only
    # log points pay the sync, everything else is fetched once at the end
    device_losses = []
    for s in range(steps):
        state, metrics = jit_step(state, batch_for(s))
        device_losses.append(metrics["loss"])
        if log_every and (s % log_every == 0 or s == steps - 1):
            log_fn(f"step {s:5d}  loss {float(metrics['loss']):.4f}")
        if checkpoint_path and checkpoint_every and \
                (s + 1) % checkpoint_every == 0:
            ckpt.save(checkpoint_path, state, step=s + 1)
    result.losses = [float(l) for l in jax.device_get(device_losses)]
    result.steps = steps
    result.wall_time = time.time() - t0
    result.state = state

    # held-out eval on the worker-AVERAGED params: mid-epoch the workers
    # have diverged, worker 0 alone is not the algorithm's iterate
    from repro.models import model as modellib
    ev = synthetic.eval_batch(cfg, tcfg.seed, batch=mb, seq=tcfg.seq_len)
    params = tstep.eval_params(state.params, W)
    result.final_eval_loss = float(modellib.loss_fn(
        params, cfg, {"tokens": ev}, remat="none"))
    if checkpoint_path:
        ckpt.save(checkpoint_path, state, step=steps)
    return result
