"""Theorem 1 validation: with uniform-with-replacement sampling and a step
size inside the remark's bound, the Lyapunov function

    V_m = ||x_m^0 - x*||^2 + c (fbar(x_m) - f*),   c = 2 n eta (1 - 2 L eta)

contracts at least geometrically with factor alpha (in expectation; we
check the measured multi-epoch rate against the bound with slack for the
single-sample-path noise).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import centralvr, convex, theory


def _well_conditioned_ridge(n=80, d=6, lam=0.05, seed=0):
    """Rows normalized so L is modest and mu/L is not absurdly small."""
    prob = convex.make_ridge_data(jax.random.PRNGKey(seed), n, d, lam)
    A = prob.A / jnp.linalg.norm(prob.A, axis=1, keepdims=True)
    return convex.Problem(A, prob.b, prob.lam, "ridge")


def test_alpha_and_step_bound_consistency():
    mu, L = 0.1, 2.0
    eta = theory.max_step(mu, L) * 0.99
    a = theory.alpha(eta, mu, L)
    assert 0.0 < a < 1.0
    # beyond the bound alpha may exceed 1; at eta -> 1/(2L) it must
    assert theory.alpha(0.499 / L, mu, L) > 1.0


@pytest.mark.slow
def test_theorem1_lyapunov_contraction():
    prob = _well_conditioned_ridge()
    mu, L = convex.constants(prob)
    mu, L = float(mu), float(L)
    eta = 0.5 * theory.max_step(mu, L)
    a = theory.alpha(eta, mu, L)
    assert 0.0 < a < 1.0

    xstar = convex.solve_exact(prob)
    fstar = float(convex.full_loss(prob, xstar))
    c = theory.lyapunov_c(eta, prob.n, L)

    key = jax.random.PRNGKey(1)
    state = centralvr.init_state(prob, eta, key)

    epochs = 60
    Vs = []
    keys = jax.random.split(jax.random.PRNGKey(2), epochs)
    for m in range(epochs):
        new_state, traj = centralvr.epoch_uniform(prob, state, eta, keys[m],
                                                  track_iterates=True)
        fbar = float(jnp.mean(jax.vmap(lambda x: convex.full_loss(prob, x))(traj)))
        V = float(jnp.sum((traj[0] - xstar) ** 2)) + c * (fbar - fstar)
        Vs.append(max(V, 1e-300))
        state = new_state

    # measured geometric rate over the trajectory vs the guaranteed alpha:
    # the theorem bounds E[V_{m+1}] <= alpha V_m; a single path must not
    # beat... exceed the bound on average by more than sampling slack.
    log_rate = (np.log(Vs[-1]) - np.log(Vs[0])) / (len(Vs) - 1)
    assert log_rate < np.log(a) + 0.05, (
        f"measured rate {np.exp(log_rate):.4f} vs guaranteed alpha {a:.4f}")
    # and it did actually converge substantially
    assert Vs[-1] < Vs[0] * 1e-3


def test_divergence_outside_any_reasonable_step():
    """Sanity: a step far above 1/(2L) breaks the VR update (the theorem's
    precondition is not vacuous)."""
    prob = _well_conditioned_ridge(seed=3)
    mu, L = convex.constants(prob)
    eta = 5.0 / float(L)
    state = centralvr.init_state(prob, eta, jax.random.PRNGKey(0))
    for k in jax.random.split(jax.random.PRNGKey(1), 10):
        state, _ = centralvr.epoch_uniform(prob, state, eta, k)
    assert (not np.isfinite(np.asarray(state.x)).all()
            or float(jnp.linalg.norm(convex.full_grad(prob, state.x))) > 1e2)
