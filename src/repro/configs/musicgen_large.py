"""MusicGen-large [arXiv:2306.05284] — decoder-only LM over EnCodec tokens.

48 layers, d_model=2048, 32 heads (kv=32 => plain MHA), d_ff=8192 (GELU MLP,
LayerNorm), vocab 2048 (EnCodec codebook size). The EnCodec audio codec is
the STUB frontend: the pipeline supplies codebook token embeddings; the
delay-pattern interleave of the 4 codebooks is applied token-side.
"""
from repro.config import ModelConfig, register

MUSICGEN_LARGE = register(ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    norm_type="layernorm",
    mlp_type="gelu",
    mlp_bias=True,
    frontend="audio",
    frontend_tokens=0,      # conditioning-free (unconditional generation path)
))
