"""Driver-runtime throughput: scan-based device-resident drivers vs the
seed host-loop drivers (core/host_loop), establishing the repo's perf
trajectory for the driver layer (DESIGN.md §3).

The scan side goes through the unified solver API — each measured run is
one ``repro.solve(RunSpec(...))`` call, and every artifact row embeds the
``RunResult.provenance()`` record (resolved spec + rels tail), so the
artifact states exactly what configuration produced it.

Selected rows also get a FUSED TWIN (``-fused`` suffix): the same spec
with ``fused=True``, routing the VR inner loop through the Pallas
``vr_update`` kernel. Twin rows carry ``fused``/``interpret`` flags and
``speedup_vs_unfused`` (warm unfused / warm fused); ``check_regression``
gates that ratio at the 1.0x floor on compiled Pallas backends
(interpret-mode rows — CPU — are exempt: emulating a kernel is not the
configuration the gate protects).

For each worker count p we measure, on CPU:

  * cold wall clock (first invocation — includes jit compilation; the
    host-loop model re-traces its closures EVERY invocation, and for the
    event-driven algorithms compiles p per-worker closures, so its cold
    time grows with p);
  * warm wall clock (subsequent invocations — the scan drivers hit the
    module-level jit cache; the host loop compiles again);
  * epochs/sec derived from warm wall clock.

Selected rows also get a PROX TWIN (``-l1``/``-elasticnet`` suffix): the
same spec with a composite objective, measuring the prox epilogue's
overhead vs the smooth twin (informational — prox rows are excluded from
the legacy scan-vs-host gates, which pin pre-prox configurations). One
SPARSE row (``centralvr-sparse``) runs the lazy CSR driver against the
dense prox'd oracle on the same low-density problem;
``speedup_sparse_vs_dense`` is gated at the 1.0x floor whenever
``nnz_frac <= 0.05`` (lazy catch-up must not lose to the dense
O(d)-per-step path it skips).

Writes ``BENCH_drivers.json`` at the repo root (the acceptance artifact:
scan beats host loop on wall clock at p=8) plus the standard results CSV.

    python -m benchmarks.driver_throughput [--quick]
"""
from __future__ import annotations

import dataclasses
import json
import os

try:
    import repro_bootstrap  # noqa: F401  (repo-root module/script form)
except ModuleNotFoundError:
    pass  # installed form: repro resolves without the fallback

import jax

from benchmarks.common import emit, timed_cold_warm
from repro import RunSpec, solve
from repro.config import ConvexConfig
from repro.core import convex, distributed, host_loop

ROOT = os.path.join(os.path.dirname(__file__), "..")

WORKER_COUNTS = (1, 2, 4, 8)


def _bench_pair(name, spec, problem, loop_fn, epochs, repeat):
    scan_cold, scan_warm, res = timed_cold_warm(
        lambda: solve(spec, problem), repeat=repeat)
    loop_cold, loop_warm, _ = timed_cold_warm(loop_fn, repeat=repeat)
    return {
        "name": name,
        "us_per_call": scan_warm * 1e6,
        "cold_s": scan_cold,
        "scan_cold_s": scan_cold,
        "scan_warm_s": scan_warm,
        "scan_compile_s": max(scan_cold - scan_warm, 0.0),
        "loop_cold_s": loop_cold,
        "loop_warm_s": loop_warm,
        "scan_epochs_per_s": epochs / scan_warm,
        "loop_epochs_per_s": epochs / loop_warm,
        "speedup_warm": loop_warm / scan_warm,
        "provenance": res.provenance(),
        "derived": (f"scan:cold={scan_cold:.3f}s,warm={scan_warm:.3f}s;"
                    f"loop:cold={loop_cold:.3f}s,warm={loop_warm:.3f}s;"
                    f"speedup={loop_warm / scan_warm:.1f}x"),
    }


def _fused_twin(base_row, spec, problem, epochs, repeat):
    """The same run with fused=True, measured against its unfused twin."""
    from repro import kernels

    _, interpret = kernels.resolve_fused(True)
    fspec = dataclasses.replace(spec, fused=True)
    cold, warm, res = timed_cold_warm(
        lambda: solve(fspec, problem), repeat=repeat)
    speedup = base_row["scan_warm_s"] / warm
    return {
        "name": base_row["name"] + "-fused",
        "us_per_call": warm * 1e6,
        "fused": True,
        "interpret": interpret,
        "cold_s": cold,
        "scan_cold_s": cold,
        "scan_warm_s": warm,
        "scan_compile_s": max(cold - warm, 0.0),
        "unfused_warm_s": base_row["scan_warm_s"],
        "scan_epochs_per_s": epochs / warm,
        "speedup_vs_unfused": speedup,
        "provenance": res.provenance(),
        "derived": (f"fused:cold={cold:.3f}s,warm={warm:.3f}s;"
                    f"vs_unfused={speedup:.2f}x;"
                    f"interpret={interpret}"),
    }


def _prox_twin(base_row, spec, problem, epochs, repeat, prox):
    """The same run with a composite objective (``-l1``/``-elasticnet``
    suffix): measures the prox epilogue's cost against the smooth twin.
    Prox rows have no seed host-loop counterpart (the host loop predates
    composite objectives), so ``check_regression`` prints their overhead
    but excludes them from the legacy scan-vs-host gate."""
    pspec = dataclasses.replace(spec, prox=prox)
    cold, warm, res = timed_cold_warm(
        lambda: solve(pspec, problem), repeat=repeat)
    name = prox.split(":")[0]
    return {
        "name": base_row["name"] + "-" + name,
        "prox": res.spec.prox,
        "us_per_call": warm * 1e6,
        "cold_s": cold,
        "scan_cold_s": cold,
        "scan_warm_s": warm,
        "scan_compile_s": max(cold - warm, 0.0),
        "smooth_warm_s": base_row["scan_warm_s"],
        "scan_epochs_per_s": epochs / warm,
        "overhead_vs_smooth": warm / base_row["scan_warm_s"],
        "provenance": res.provenance(),
        "derived": (f"prox:cold={cold:.3f}s,warm={warm:.3f}s;"
                    f"vs_smooth={warm / base_row['scan_warm_s']:.2f}x"),
    }


def _sparse_row(quick: bool, repeat: int):
    """Sparse lazy driver vs the dense prox'd oracle on the same problem
    (``sampling="sparse"`` vs ``"permutation"``, identical trajectories):
    the lazy catch-up must not lose to the dense O(d)-per-step path at
    low density.  ``check_regression`` gates ``speedup_sparse_vs_dense``
    at the 1.0x floor whenever ``nnz_frac <= 0.05``."""
    from repro.prox import lazy

    n, d, nnz = (96, 8192, 16) if quick else (128, 16384, 32)
    rounds = 3 if quick else 4
    prob = lazy.make_sparse_data(jax.random.PRNGKey(2), n, d, nnz)
    eta = 0.05
    dense_spec = RunSpec(algo="centralvr", eta=eta, rounds=rounds,
                         prox="l1:0.001")
    sparse_spec = dataclasses.replace(dense_spec, sampling="sparse")
    d_cold, d_warm, _ = timed_cold_warm(
        lambda: solve(dense_spec, prob), repeat=repeat)
    s_cold, s_warm, res = timed_cold_warm(
        lambda: solve(sparse_spec, prob), repeat=repeat)
    speedup = d_warm / s_warm
    return {
        "name": "drivers/centralvr-sparse",
        "sparse": True,
        "prox": res.spec.prox,
        "nnz_frac": nnz / d,
        "n": n, "d": d, "nnz": nnz,
        "us_per_call": s_warm * 1e6,
        "cold_s": s_cold,
        "scan_cold_s": s_cold,
        "scan_warm_s": s_warm,
        "scan_compile_s": max(s_cold - s_warm, 0.0),
        "dense_warm_s": d_warm,
        "scan_epochs_per_s": rounds / s_warm,
        "speedup_sparse_vs_dense": speedup,
        "provenance": res.provenance(),
        "derived": (f"sparse:warm={s_warm:.3f}s;dense:warm={d_warm:.3f}s;"
                    f"speedup={speedup:.2f}x@nnz/d={nnz / d:.2%}"),
    }


def _obs_twin(base_row, spec, problem):
    """The same run with telemetry ON (``-obs`` suffix): one recorded
    ``solve()``, with the warm cost read off the staged execute span (the
    staged path always re-lowers/re-compiles, so repeat timing would
    measure compilation; the span IS the blocked warm execution).  The
    twin quantifies telemetry overhead against the telemetry-off base row
    — ``check_regression`` prints these rows but gates only the base
    (telemetry-off) rows, which must stay at the pre-telemetry floor."""
    import tempfile

    from repro import obs
    from repro.obs import report as obs_report
    from repro.obs import schema as obs_schema

    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "run.jsonl")
        with obs.recording(path):
            res = solve(spec, problem)
        s = obs_report.summarize(obs_schema.load_rows(path))
    warm = s["warm_s"]
    return {
        "name": base_row["name"] + "-obs",
        "telemetry": True,
        "us_per_call": warm * 1e6,
        "scan_warm_s": warm,
        "obs_lower_s": s["lower_s"],
        "obs_compile_s": s["compile_s"],
        "overhead_vs_off": warm / base_row["scan_warm_s"],
        "off_speedup_vs_host": base_row["speedup_warm"],
        "n_telemetry_rows": s["n_rows"],
        "provenance": res.provenance(),
        "derived": (f"obs:warm={warm:.3f}s,compile={s['compile_s']:.3f}s;"
                    f"overhead_vs_off="
                    f"{warm / base_row['scan_warm_s']:.2f}x"),
    }


def run(quick: bool = False):
    n, d = (128, 16) if quick else (256, 64)
    rounds = 4 if quick else 8
    repeat = 2 if quick else 3
    key = jax.random.PRNGKey(0)
    rows = []

    for p in WORKER_COUNTS:
        if p == 1:
            prob = convex.make_logistic_data(jax.random.PRNGKey(2), n, d)
            eta = convex.auto_eta(prob, 0.3)
            spec = RunSpec(algo="centralvr", eta=eta, rounds=rounds)
            rows.append(_bench_pair(
                "drivers/centralvr-p1", spec, prob,
                lambda: host_loop.run(prob, eta=eta, epochs=rounds, key=key),
                rounds, repeat))
            base = rows[-1]
            rows.append(_fused_twin(base, spec, prob, rounds, repeat))
            rows.append(_prox_twin(base, spec, prob, rounds, repeat,
                                   "l1:0.001"))
            continue
        cfg = ConvexConfig(problem="logistic", n=n, d=d, workers=p)
        sp = distributed.make_distributed(jax.random.PRNGKey(2), cfg)
        eta = convex.auto_eta(sp.merged(), 0.3)
        spec = RunSpec(algo="centralvr_sync", p=p, eta=eta, rounds=rounds)
        rows.append(_bench_pair(
            f"drivers/sync-p{p}", spec, sp,
            lambda: host_loop.run_sync(sp, eta=eta, rounds=rounds, key=key),
            rounds, repeat))
        if p == max(WORKER_COUNTS):
            base = rows[-1]
            rows.append(_fused_twin(base, spec, sp, rounds, repeat))
            rows.append(_prox_twin(base, spec, sp, rounds, repeat,
                                   "elasticnet:0.001:0.0001"))
        spec = RunSpec(algo="centralvr_async", p=p, eta=eta, rounds=rounds)
        rows.append(_bench_pair(
            f"drivers/async-p{p}", spec, sp,
            lambda: host_loop.run_async(sp, eta=eta, rounds=rounds, key=key),
            rounds, repeat))
        if p == max(WORKER_COUNTS):
            base = rows[-1]
            rows.append(_fused_twin(base, spec, sp, rounds, repeat))
            rows.append(_obs_twin(base, spec, sp))

    rows.append(_sparse_row(quick, repeat))

    p8 = [r for r in rows
          if r["name"].endswith("-p8") and not r.get("telemetry")
          and not r.get("prox")]
    beats = all(r["speedup_warm"] > 1.0 for r in p8)
    payload = {
        "config": {"n_per_worker": n, "d": d, "rounds": rounds,
                   "workers": list(WORKER_COUNTS), "quick": quick,
                   "backend": jax.default_backend()},
        "rows": rows,
        "scan_beats_loop_at_p8": beats,
    }
    with open(os.path.join(ROOT, "BENCH_drivers.json"), "w") as f:
        json.dump(payload, f, indent=1)
    emit(rows, "driver_throughput")
    print(f"scan_beats_loop_at_p8={'yes' if beats else 'no'}")
    return payload


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
