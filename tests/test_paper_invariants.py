"""Structural invariants of the paper, checked exactly (not statistically):

  * the corrected gradient (Eq. 6) is unbiased for any table state,
  * one permutation epoch telescopes to a full-gradient step (Eq. 7),
  * the running accumulator equals the table mean at epoch end (line 11),
  * CentralVR with a constant step converges to x* (the VR property SGD
    lacks), and beats SGD at an equal gradient budget.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# property tests skip (per-test) without the hypothesis dev extra;
# plain tests in this module always run
from hypothesis_compat import given, settings, st  # noqa: E402

from repro.core import baselines, centralvr, convex


def _problem(seed=0, n=64, d=8, kind="logistic"):
    key = jax.random.PRNGKey(seed)
    gen = (convex.make_logistic_data if kind == "logistic"
           else convex.make_ridge_data)
    return gen(key, n, d)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), kind=st.sampled_from(["logistic", "ridge"]))
def test_corrected_gradient_unbiased(seed, kind):
    """mean_i [ (s_i(x) - table_i) a_i + gbar + 2 lam x ] == grad f(x)
    for ANY stored table — the error-correction term has mean zero."""
    prob = _problem(seed, n=32, d=6, kind=kind)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 1))
    x = jax.random.normal(k1, (prob.d,), dtype=jnp.float64)
    table = jax.random.normal(k2, (prob.n,), dtype=jnp.float64)  # arbitrary
    gbar = convex.data_grad_from_scalars(prob, table)

    s_fresh = convex.scalar_residual_all(prob, x)
    corrected = ((s_fresh - table)[:, None] * prob.A
                 + gbar + 2.0 * prob.lam * x)          # (n, d) per-index v
    np.testing.assert_allclose(
        np.asarray(corrected.mean(0)), np.asarray(convex.full_grad(prob, x)),
        rtol=1e-10, atol=1e-12)


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["logistic", "ridge"])
def test_eq7_telescoping(kind):
    """Eq. 7: x_{m+2}^0 = x_{m+1}^0 - eta * sum_j grad f_j(xtilde_{m+1}^j)
    where xtilde^j is the iterate at which index j was visited."""
    prob = _problem(3, n=40, d=5, kind=kind)
    eta = 0.01
    key = jax.random.PRNGKey(7)
    state = centralvr.init_state(prob, eta, key)
    perm = jax.random.permutation(jax.random.PRNGKey(8), prob.n)
    new_state, traj = centralvr.epoch(prob, state, eta, perm,
                                      track_iterates=True)
    # grad f_j at the iterate where j was visited (fresh table entries)
    grads = jax.vmap(
        lambda i, xk: convex.scalar_residual(prob, xk, i) * prob.A[i]
        + 2.0 * prob.lam * xk
    )(perm, traj)
    expected = state.x - eta * grads.sum(0)
    np.testing.assert_allclose(np.asarray(new_state.x), np.asarray(expected),
                               rtol=1e-8, atol=1e-10)


def test_accumulator_equals_table_mean():
    """line 11: gbar for the next epoch == (1/n) sum_j s_j a_j (table mean)."""
    prob = _problem(5, n=48, d=6)
    state = centralvr.init_state(prob, 0.02, jax.random.PRNGKey(0))
    perm = jax.random.permutation(jax.random.PRNGKey(1), prob.n)
    new_state, _ = centralvr.epoch(prob, state, 0.02, perm)
    np.testing.assert_allclose(
        np.asarray(new_state.gbar),
        np.asarray(convex.data_grad_from_scalars(prob, new_state.table)),
        rtol=1e-9, atol=1e-11)
    # and the init epoch establishes the same invariant
    np.testing.assert_allclose(
        np.asarray(state.gbar),
        np.asarray(convex.data_grad_from_scalars(prob, state.table)),
        rtol=1e-9, atol=1e-11)


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["logistic", "ridge"])
def test_constant_step_linear_convergence(kind):
    """VR property: constant step size, convergence to x* (machine-level),
    with monotone-ish geometric decrease of the gradient norm."""
    prob = _problem(11, n=200, d=10, kind=kind)
    eta = 0.05 if kind == "logistic" else 0.004
    _, rels, _ = centralvr.run(prob, eta=eta, epochs=40,
                               key=jax.random.PRNGKey(2))
    assert rels[-1] < 1e-9, f"no linear convergence: {rels[-5:]}"
    # geometric decrease while above the numerical floor
    r = np.asarray(rels)
    above = r[r > 1e-10]
    rates = above[1:] / above[:-1]
    assert np.median(rates) < 0.9


@pytest.mark.slow
def test_centralvr_beats_sgd_equal_gradient_budget():
    """Fig. 1 headline: at the same number of gradient evaluations,
    CentralVR reaches far lower gradient norm than tuned constant-step SGD."""
    prob = _problem(13, n=300, d=12)
    epochs = 20
    _, rels_cvr, _ = centralvr.run(prob, eta=0.05, epochs=epochs,
                                   key=jax.random.PRNGKey(3))
    best_sgd = np.inf
    for eta in (0.2, 0.05, 0.01):
        _, rels = baselines.run_sgd(prob, eta=eta, epochs=epochs,
                                    key=jax.random.PRNGKey(3))
        best_sgd = min(best_sgd, float(rels[-1]))
    assert float(rels_cvr[-1]) < best_sgd * 1e-2


def test_gradient_evals_per_iteration_table1():
    """Table 1: CentralVR uses 1 gradient/iteration — epoch cost n evals.
    The run() driver reports cumulative evals in exact multiples of n."""
    prob = _problem(17, n=50, d=4)
    _, _, evals = centralvr.run(prob, eta=0.02, epochs=3,
                                key=jax.random.PRNGKey(4))
    np.testing.assert_array_equal(np.asarray(evals),
                                  np.asarray([100, 150, 200]))
