"""Fused CentralVR/SAGA update kernel (Pallas, TPU target).

The VR hot loop is pure memory traffic: per element it reads
(x, g, g_old, gbar, gtilde) and writes (x, table, gtilde[, gbar]) — 5 reads
+ 3-4 writes of param-sized buffers every step. Unfused, XLA materializes
the correction v and the updated table as separate HBM round trips; the
fused kernel streams every buffer exactly once through VMEM tiles:

    v       = g - g_old + gbar            (error-corrected gradient, Eq. 6)
    x'      = x*(1 - eta*decay) - eta*v   (SGD step; decay folds the L2 term)
    table'  = g                           (store fresh gradient)
    gtilde' = gtilde + g / M              (epoch accumulator, Alg 1 line 8)
    gbar'   = gbar + (g - g_old) / M      (SAGA mode only, Alg 5 line 9)

``decay`` is a static compile-time float (0.0 by default, which compiles to
exactly the historical kernel); the convex drivers pass decay = 2*lam so the
ridge term never needs a separate elementwise pass over x.

``prox`` is a static elementwise proximal epilogue applied to x' before the
store (composite objectives, DESIGN.md §Composite objectives) — one of
None (default: the historical kernel, bit-for-bit), ``("l1", (lam1,))``,
``("elasticnet", (lam1, lam2))``, or ``("box", (lo, hi))`` — i.e. the
elementwise subset of ``repro.prox.operators`` as (name, params) tuples.
The thresholds fold eta in at compile time, so the epilogue is a couple of
VPU ops on the tile already in registers: the prox'd composite step costs
no extra HBM traffic over the smooth one.

Tiling: flat 1-D views, (8, 1024)-element VMEM tiles (float32: 32 KiB per
operand, 8 operands -> ~256 KiB of VMEM per step, well inside the ~16 MiB
budget while deep enough to pipeline HBM reads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024
SUBLANES = 8
TILE = SUBLANES * LANES


def _prox_epilogue(xn, eta: float, prox):
    """Elementwise prox on the updated iterate, all params static; pure
    jnp.where/clip — VPU ops in both the Mosaic and interpret paths."""
    name, params = prox
    if name == "l1":
        (lam1,) = params
        t = eta * lam1
        return jnp.sign(xn) * jnp.maximum(jnp.abs(xn) - t, 0.0)
    if name == "elasticnet":
        lam1, lam2 = params
        t = eta * lam1
        shrink = 1.0 / (1.0 + 2.0 * eta * lam2)
        return jnp.sign(xn) * jnp.maximum(jnp.abs(xn) - t, 0.0) * shrink
    if name == "box":
        lo, hi = params
        return jnp.clip(xn, lo, hi)
    raise ValueError(f"non-elementwise prox {name!r} cannot fuse")


def _vr_update_kernel(x_ref, g_ref, gold_ref, gbar_ref, gtilde_ref,
                      xo_ref, tbl_ref, gto_ref, gbo_ref,
                      *, eta: float, inv_m: float, saga: bool,
                      decay: float = 0.0, prox=None):
    g = g_ref[...]
    gold = gold_ref[...]
    gbar = gbar_ref[...]
    v = g - gold + gbar
    acc_t = jnp.promote_types(x_ref.dtype, jnp.float32)
    xf = x_ref[...].astype(acc_t)
    if decay:
        xf = xf * (1.0 - eta * decay)
    xn = xf - eta * v
    if prox is not None:
        xn = _prox_epilogue(xn, eta, prox)
    xo_ref[...] = xn.astype(x_ref.dtype)
    tbl_ref[...] = g
    gto_ref[...] = gtilde_ref[...] + g * inv_m
    if saga:
        gbo_ref[...] = gbar + (g - gold) * inv_m
    else:
        gbo_ref[...] = gbar


def vr_update_flat(x, g, g_old, gbar, gtilde, *, eta: float, m: int,
                   saga: bool = False, decay: float = 0.0, prox=None,
                   interpret: bool = False):
    """All inputs flat 1-D, length a multiple of TILE (ops.py pads).
    Returns (x', table', gtilde', gbar')."""
    n = x.shape[0]
    assert n % TILE == 0, n
    grid = (n // TILE,)
    shape2 = (n // LANES, LANES)

    def r2(t):
        return t.reshape(shape2)

    block = pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0))
    out_shapes = [
        jax.ShapeDtypeStruct(shape2, x.dtype),
        jax.ShapeDtypeStruct(shape2, g.dtype),
        jax.ShapeDtypeStruct(shape2, gtilde.dtype),
        jax.ShapeDtypeStruct(shape2, gbar.dtype),
    ]
    fn = pl.pallas_call(
        functools.partial(_vr_update_kernel, eta=eta, inv_m=1.0 / m,
                          saga=saga, decay=decay, prox=prox),
        grid=grid,
        in_specs=[block] * 5,
        out_specs=[block] * 4,
        out_shape=out_shapes,
        interpret=interpret,
    )
    xo, tbl, gto, gbo = fn(r2(x), r2(g), r2(g_old), r2(gbar), r2(gtilde))
    return (xo.reshape(n), tbl.reshape(n), gto.reshape(n), gbo.reshape(n))
