"""Explicit AOT staging of the jitted scan runners, for span capture.

``solve()`` (and the driver wrappers it dispatches to) normally call
their module-level jitted runners directly: one opaque wall-clock number
that mixes trace, lower, XLA compile, and execution.  When a recorder is
active, :func:`staged_call` splits the same call into the explicit
``jit(...).lower().compile()`` pipeline and emits one span per phase:

    <label>/lower     tracing + StableHLO lowering
    <label>/compile   XLA compilation of the lowered module
    <label>/execute   running the compiled executable (blocked on, so the
                      duration is real work, not async dispatch — this is
                      the per-call WARM cost once an executable exists)

plus a ``comms_hlo`` event with the per-collective-kind result bytes of
the compiled module (``roofline/analysis.collective_bytes`` — the same
result-shape convention as the roofline reports), which is the measured
cross-check of the analytical comms model ``solve()`` embeds in
provenance.

With telemetry OFF the call goes straight through to the jitted function
— same executable, same jit cache, zero overhead.  The staged path
deliberately bypasses the jit cache (AOT lowering always re-lowers), so
a telemetry-on call always observes a real, nonzero compile phase.

Convention: dynamic arguments positional, static arguments as keywords.
The compiled executable is invoked with the dynamic arguments only
(statics are baked in at lowering; jax rejects re-passing them).
Donation declared on the runner is honored by the compiled call exactly
as by the jitted one.
"""
from __future__ import annotations

from repro.obs import recorder as _recorder


def staged_call(fn, *args, _label: str, **statics):
    """Call jitted ``fn(*args, **statics)``; staged with spans when a
    recorder is active, a plain (cached) call otherwise."""
    rec = _recorder.active()
    if rec is None:
        return fn(*args, **statics)

    import jax

    try:
        with rec.span(f"{_label}/lower"):
            lowered = fn.lower(*args, **statics)
        with rec.span(f"{_label}/compile"):
            compiled = lowered.compile()
    except (AttributeError, TypeError, NotImplementedError) as e:
        # not AOT-stageable (plain callable, exotic closure): record why
        # and fall back to the ordinary call so telemetry never breaks a
        # run it is only supposed to observe
        rec.event("stage_fallback", label=_label, reason=repr(e))
        with rec.span(f"{_label}/execute"):
            return jax.block_until_ready(fn(*args, **statics))

    _record_hlo_comms(rec, _label, compiled)
    with rec.span(f"{_label}/execute"):
        return jax.block_until_ready(compiled(*args))


def _record_hlo_comms(rec, label: str, compiled) -> None:
    """Per-collective result bytes of the compiled module, best effort."""
    try:
        from repro.roofline import analysis

        rec.event("comms_hlo", label=label,
                  **analysis.collective_bytes(compiled.as_text()))
    except Exception as e:     # telemetry must never fail the run
        rec.event("comms_hlo_error", label=label, reason=repr(e))
