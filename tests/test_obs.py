"""Structured run telemetry pins (``repro.obs``, DESIGN.md
§Observability).

Four layers:

  * recorder/schema — JSONL rows round-trip through the pinned v1 schema;
    the stream cadence gate and the jax-free import contract hold;
  * the central guarantee — a telemetry-ON solve is BIT-identical to the
    telemetry-off run (same trajectory, same iterate), while its record
    carries the real lower/compile/execute span split, streamed per-round
    metrics, and the provenance event;
  * the analytical models — fetch-staleness/wave stats of the
    deterministic event schedule and bytes-per-collective comms, pinned
    against hand-computed values, with ``comms._MODELS`` covering the
    registry exactly;
  * the golden provenance row shape (``schema.PROVENANCE_KEYS``) that
    every BENCH artifact embeds — set-equal in BOTH directions, so adding
    or dropping a field is a deliberate two-sided edit.
"""
import json
import os
import subprocess
import sys
import threading

import jax
import numpy as np
import pytest

import repro
from repro import RunSpec, obs, solve
from repro.config import ConvexConfig
from repro.core import distributed, runtime
from repro.obs import comms, report, schema, staleness

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _sharded(p=2, n=24, d=6):
    cfg = ConvexConfig(problem="logistic", n=n, d=d, workers=p)
    return distributed.make_distributed(jax.random.PRNGKey(0), cfg)


# ---------------------------------------------------------------------------
# Recorder + schema
# ---------------------------------------------------------------------------

def test_recorder_rows_validate_and_roundtrip(tmp_path):
    path = str(tmp_path / "run.jsonl")
    with obs.recording(path, run_id="fixed-id") as rec:
        rec.event("custom", payload={"k": 1})
        rec.metric("loss", step=3, value=0.25)
        with rec.span("phase/a", tag="x"):
            pass
    n = schema.validate_file(path)
    rows = schema.load_rows(path)
    assert n == len(rows) == 4          # run_start + event + metric + span
    assert all(r["run"] == "fixed-id" for r in rows)
    kinds = [r["kind"] for r in rows]
    assert kinds == ["event", "event", "metric", "span"]
    span_row = rows[-1]
    assert span_row["name"] == "phase/a" and span_row["dur_s"] >= 0.0
    # timestamps are monotone relative to the recorder's start
    assert [r["t"] for r in rows] == sorted(r["t"] for r in rows)


def test_stream_every_gates_metric_cadence(tmp_path):
    path = str(tmp_path / "run.jsonl")
    with obs.recording(path, stream_every=3) as rec:
        for step in range(10):
            rec.metric("rel", step=step, value=float(step))
    steps = [r["step"] for r in schema.load_rows(path)
             if r["kind"] == "metric"]
    assert steps == [0, 3, 6, 9]


def test_schema_rejects_malformed_rows():
    ok = {"v": schema.SCHEMA_VERSION, "run": "r", "t": 0.0,
          "kind": "metric", "name": "m", "step": 0, "value": 1.0}
    assert schema.validate_row(dict(ok)) == ok
    with pytest.raises(schema.SchemaError, match="missing base fields"):
        schema.validate_row({"kind": "event", "name": "e"})
    with pytest.raises(schema.SchemaError, match="schema version"):
        schema.validate_row({**ok, "v": 999})
    with pytest.raises(schema.SchemaError, match="unknown row kind"):
        schema.validate_row({**ok, "kind": "frobnicate"})
    bad = dict(ok)
    del bad["value"]
    with pytest.raises(schema.SchemaError, match="missing required fields"):
        schema.validate_row(bad)
    with pytest.raises(schema.SchemaError, match="has no rows"):
        schema.validate_rows([])


def test_telemetry_off_is_the_default_and_recording_scopes():
    assert obs.active() is None
    assert not obs.stream_active()
    with obs.recording(os.devnull) as rec:
        assert obs.active() is rec
        assert obs.stream_active()
    assert obs.active() is None


def test_import_repro_obs_never_imports_jax():
    """The recorder/schema/report layer is stdlib-only: the CLI tooling
    (``repro.launch.obs``) must work on machines without the toolchain,
    and enabling telemetry must not reorder jax initialization."""
    code = ("import sys; import repro.obs; import repro.launch.obs; "
            "sys.exit(1 if any(m == 'jax' or m.startswith('jax.') "
            "for m in sys.modules) else 0)")
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(ROOT, "src"),
                    os.environ.get("PYTHONPATH", "")]))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True)
    assert r.returncode == 0, (
        "import repro.obs pulled in jax\n" + r.stdout + r.stderr)


# ---------------------------------------------------------------------------
# The central guarantee: telemetry observes, never perturbs
# ---------------------------------------------------------------------------

def test_recorded_async_solve_is_bit_identical_with_full_record(tmp_path):
    """One heterogeneous-speeds async solve, off then on: trajectories and
    final iterates EXACTLY equal, while the record carries the span split,
    the streamed per-round metric, and the provenance event with the
    staleness histogram + comms model."""
    sp = _sharded(p=2)
    spec = RunSpec(algo="centralvr_async", p=2, eta=0.05, rounds=4,
                   speeds=(2.0, 1.0))
    off = solve(spec, sp)

    path = str(tmp_path / "run.jsonl")
    with obs.recording(path):
        on = solve(spec, sp)

    np.testing.assert_array_equal(np.asarray(off.rels), np.asarray(on.rels))
    np.testing.assert_array_equal(off.x, on.x)

    rows = schema.load_rows(path)
    schema.validate_rows(rows)
    s = report.summarize(rows)
    # the staged path always re-lowers, so the split is real and nonzero
    assert s["lower_s"] > 0 and s["compile_s"] > 0 and s["warm_s"] > 0
    names = {r["name"] for r in rows if r["kind"] == "span"}
    assert {"solve/centralvr_async/lower", "solve/centralvr_async/compile",
            "solve/centralvr_async/execute"} <= names
    # one streamed metric row per recorded round
    assert s["metrics"]["rel"]["count"] == int(np.asarray(on.rels).size)
    assert s["metrics"]["rel"]["last_value"] == pytest.approx(on.final_rel)

    prov = [r for r in rows if r["kind"] == "event"
            and r["name"] == "provenance"]
    assert len(prov) == 1
    assert prov[0]["staleness"]["histogram"]
    assert prov[0]["comms"]["bytes_per_round"] > 0
    # the rendered report round-trips without jax
    text = report.render(rows)
    assert "phase split" in text and "streamed metrics" in text


def test_disable_degrades_cached_streaming_executable(tmp_path):
    """An executable compiled WITH the streaming callback stays in jax's
    jit cache after ``obs.disable()``; its callback must degrade to a
    silent no-op (the host side re-checks the active recorder), not an
    error and not a write to a closed file."""
    from repro.obs import stream

    @jax.jit
    def f(x):
        stream.scan_metric("rel", 0, x)
        return x * 2

    path = str(tmp_path / "run.jsonl")
    with obs.recording(path):
        assert float(jax.block_until_ready(f(1.0))) == 2.0
    n_rows = len(schema.load_rows(path))
    assert any(r["kind"] == "metric" for r in schema.load_rows(path))
    # same cached executable, recorder gone: callback fires, emits nothing
    assert float(jax.block_until_ready(f(3.0))) == 6.0
    assert len(schema.load_rows(path)) == n_rows


def test_staged_call_falls_back_on_plain_callables(tmp_path):
    """A producer handing ``staged_call`` something without ``.lower``
    still runs (with an execute span + a stage_fallback event) — telemetry
    must never fail a run it only observes."""
    from repro.obs import stage

    path = str(tmp_path / "run.jsonl")
    with obs.recording(path):
        out = stage.staged_call(lambda v: v * 2, jax.numpy.arange(3.0),
                                _label="t/plain")
    np.testing.assert_array_equal(np.asarray(out), [0.0, 2.0, 4.0])
    rows = schema.load_rows(path)
    assert any(r["name"] == "stage_fallback" for r in rows)
    assert any(r["kind"] == "span" and r["name"] == "t/plain/execute"
               for r in rows)


def test_train_loop_emits_structured_epoch_rows(tmp_path):
    """The epoch loop's recorder path: structured ``train_epoch`` rows and
    epoch spans alongside the legacy ``log_fn`` shim, plus the final
    ``train_done`` summary — and the recorded run trains to the same
    result as the bare one."""
    from test_train_scan import tiny_cfg, tiny_tcfg

    from repro.train import loop

    cfg, tcfg = tiny_cfg(), tiny_tcfg(1)
    bare = loop.run_training(cfg, tcfg, epochs=2, workers=1, log_every=0)

    path = str(tmp_path / "train.jsonl")
    lines = []
    with obs.recording(path):
        res = loop.run_training(cfg, tcfg, epochs=2, workers=1,
                                log_fn=lines.append)
    np.testing.assert_allclose(res.losses, bare.losses, rtol=1e-6)

    rows = schema.load_rows(path)
    schema.validate_rows(rows)
    epoch_rows = [r for r in rows if r["name"] == "train_epoch"]
    assert [r["epoch"] for r in epoch_rows] == [0, 1]
    E = tcfg.vr_table_size * tcfg.local_epoch
    assert [r["step"] for r in epoch_rows] == [E, 2 * E]
    assert all(r["workers"] == 1 for r in epoch_rows)
    # the log_fn shim is unchanged: one line per logged epoch
    assert len(lines) == 2 and all("loss" in ln for ln in lines)
    # first epoch staged (span split or recorded fallback), rest spanned
    names = [r["name"] for r in rows if r["kind"] == "span"]
    assert any(n.startswith("train/epoch") for n in names)
    assert "train/eval" in names
    done = [r for r in rows if r["name"] == "train_done"]
    assert len(done) == 1 and done[0]["epochs"] == 2
    assert done[0]["eval_loss"] == pytest.approx(res.final_eval_loss)


# ---------------------------------------------------------------------------
# Analytical models: staleness / waves / comms
# ---------------------------------------------------------------------------

def test_staleness_round_robin_pins():
    """Round-robin p=4: each worker's first event measures against the
    shared t=0 fetch (staleness = t, one each of 0..3); every post-warmup
    event sees exactly p-1 = 3 other updates; one full wave per round."""
    p, rounds = 4, 3
    st = staleness.staleness_stats(runtime.event_schedule(p, rounds), p)
    assert st["events"] == p * rounds and st["rounds"] == rounds
    assert st["histogram"] == {"0": 1, "1": 1, "2": 1,
                               "3": p * rounds - 3}
    assert st["min"] == 0 and st["max"] == p - 1
    assert st["mean"] == pytest.approx((0 + 1 + 2 + 3 * 9) / 12)
    assert st["waves_per_round_mean"] == 1.0
    assert st["waves_per_round_max"] == 1
    assert st["wave_occupancy_mean"] == 1.0


def test_staleness_heterogeneous_speeds_spread_the_histogram():
    """A 4x-faster worker refetches often (low staleness) and forces the
    slow worker to see many interleaved updates (staleness above p-1);
    rounds split into multiple partially-occupied waves."""
    p, rounds = 2, 8
    sched = runtime.event_schedule(p, rounds, speeds=(4.0, 1.0))
    st = staleness.staleness_stats(sched, p)
    assert st["events"] == p * rounds
    assert st["max"] > p - 1                  # the slow worker's fetches
    assert "0" in st["histogram"]             # back-to-back fast events
    assert st["waves_per_round_mean"] > 1.0
    assert st["wave_occupancy_mean"] < 1.0
    assert sum(st["histogram"].values()) == st["events"]


def test_staleness_rejects_ragged_schedule():
    with pytest.raises(ValueError, match="not a multiple"):
        staleness.staleness_stats(np.zeros(5, dtype=np.int64), p=2)


def test_comms_model_pins():
    # Algorithm-2 sync boundary: 2 all-reduces of the (d,) iterate/gbar
    sync = comms.comms_model("centralvr_sync", p=4, d=8, rounds=5)
    assert sync["allreduce_bytes_per_round"] == 2 * 8 * 4
    assert sync["p2p_bytes_per_round"] == 0
    assert sync["total_bytes"] == 5 * 2 * 8 * 4
    # async event: (dx, dgbar) up + (x_c, gbar_c) down, p events per round
    asy = comms.comms_model("centralvr_async", p=4, d=8, rounds=5)
    assert asy["allreduce_bytes_per_round"] == 0
    assert asy["events_per_round"] == 4
    assert asy["p2p_bytes_per_round"] == 4 * (8 * 4) * 4
    # the event count is overridable (uneven schedules)
    asy2 = comms.comms_model("centralvr_async", p=4, d=8, rounds=5,
                             events_per_round=6)
    assert asy2["p2p_bytes_per_round"] == 4 * (8 * 4) * 6
    # single-worker algorithms move nothing
    assert comms.comms_model("sgd", p=1, d=8, rounds=5)["total_bytes"] == 0
    with pytest.raises(ValueError, match="no comms model"):
        comms.comms_model("nope", p=1, d=1, rounds=1)


def test_comms_models_cover_the_registry_exactly():
    """Adding a registry algorithm without a comms model (or retiring one
    without cleaning up) fails here, not in a benchmark run — naming the
    offending algorithm, not just dumping two sets."""
    missing, extra = comms.coverage_gaps(repro.algorithms())
    assert not missing, (
        f"registry algorithms with no comms model: {list(missing)} — add "
        f"a _MODELS row in obs/comms.py")
    assert not extra, (
        f"comms models for retired algorithms: {list(extra)} — drop the "
        f"_MODELS row in obs/comms.py")


# ---------------------------------------------------------------------------
# Trace-probe accounting (runtime.TRACES)
# ---------------------------------------------------------------------------

def test_traces_delta_scopes_increments():
    runtime.TRACES.inc("obs_test_outside")
    with runtime.traces_delta() as delta:
        runtime.TRACES.inc("obs_test_inside", 2)
    assert delta == {"obs_test_inside": 2}
    with runtime.traces_delta() as delta:
        pass
    assert delta == {}


def test_trace_counter_is_race_safe():
    """Concurrent inc() from many threads (the spmd factories and the
    streamed-callback path both drive the probe off the main thread) must
    not lose increments to the read-modify-write race."""
    counter = runtime._TraceCounter()
    threads = [threading.Thread(
        target=lambda: [counter.inc("k") for _ in range(1000)])
        for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert counter.snapshot() == {"k": 8000}
    counter.clear()
    assert counter.snapshot() == {}


# ---------------------------------------------------------------------------
# Golden provenance row shape
# ---------------------------------------------------------------------------

def test_provenance_row_matches_golden_schema():
    """Set-equality BOTH directions against ``schema.PROVENANCE_KEYS`` /
    ``PROVENANCE_SPEC_KEYS`` on a real async run: a new field must be
    added to the golden tuples deliberately, a dropped/renamed one fails
    immediately (BENCH artifacts embed these rows)."""
    sp = _sharded(p=2)
    res = solve(RunSpec(algo="centralvr_async", p=2, eta=0.05, rounds=3,
                        speeds=(2.0, 1.0)), sp)
    row = res.provenance()
    assert set(row) == set(schema.PROVENANCE_KEYS)
    assert set(row["spec"]) == set(schema.PROVENANCE_SPEC_KEYS)
    assert row["schema_v"] == schema.SCHEMA_VERSION
    assert row["comms"]["algo"] == "centralvr_async"
    assert sum(row["staleness"]["histogram"].values()) == 2 * 3
    json.dumps(row)     # JSON-able end to end

    # bulk-synchronous runs carry comms but no staleness record
    sync = solve(RunSpec(algo="centralvr_sync", p=2, eta=0.05, rounds=3),
                 sp).provenance()
    assert set(sync) == set(schema.PROVENANCE_KEYS)
    assert sync["staleness"] is None
    assert sync["comms"]["n_allreduce_per_round"] == 2


# ---------------------------------------------------------------------------
# CLI (repro.launch.obs)
# ---------------------------------------------------------------------------

def test_obs_cli_report_and_validate(tmp_path):
    from repro.launch import obs as obs_cli

    path = str(tmp_path / "run.jsonl")
    with obs.recording(path) as rec:
        rec.metric("rel", step=0, value=1.0)
        with rec.span("solve/x/compile"):
            pass
    summary = str(tmp_path / "summary.json")
    assert obs_cli.main(["report", path, "--json", summary]) == 0
    with open(summary) as f:
        assert json.load(f)["n_rows"] == 3

    assert obs_cli.main(["validate", path]) == 0
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"v": 1, "kind": "event"}\n')
    assert obs_cli.main(["validate", path, str(bad)]) == 1
