"""Streamed in-scan metrics: a cadence-gated ``jax.debug.callback`` path.

The device-resident drivers compute their convergence metric INSIDE a
jitted ``lax.scan`` (DESIGN.md §3) — without this module the whole
trajectory only reaches the host after the last round.  When a recorder
is active, the drivers trace their scan with ``stream=True`` (a STATIC
argument, so the jit cache keys on it and the telemetry-off executable is
byte-identical to the pre-telemetry program) and the scan body calls
:func:`scan_metric`: one host callback per round carrying (step, value),
cadence-gated host-side by the recorder's ``stream_every``.

Guarantees (pinned by ``tests/test_obs.py``):

  * the callback only OBSERVES the metric scalar — it never touches the
    donated state buffers, so donation safety is unchanged;
  * trajectories are bit-identical with telemetry on vs off
    (``jax.debug.callback`` has no data-flow effect on the scan carry).

Caveat (DESIGN.md §Observability): the spmd ``shard_map`` runners do NOT
stream — a callback inside a shard_map program fires once per device with
per-shard values, which is noise, not a metric.  SPMD runs record spans +
the analytical comms/staleness models instead.
"""
from __future__ import annotations

from repro.obs import recorder as _recorder


def stream_active() -> bool:
    """Trace-time switch the drivers consult: stream iff a recorder is
    installed.  The result becomes a STATIC jit argument, so flipping
    telemetry selects a separate, consistent executable."""
    return _recorder.active() is not None


def _emit(name: str, step, value) -> None:
    rec = _recorder.active()
    if rec is not None:     # a cached streaming executable may outlive it
        rec.metric(name, int(step), float(value))


def scan_metric(name: str, step, value) -> None:
    """Emit (step, value) from inside traced code.  Call ONLY under a
    ``stream=True`` trace; the host side re-checks the active recorder, so
    a cached streaming executable running after ``obs.disable()`` degrades
    to a no-op callback instead of an error."""
    import jax

    jax.debug.callback(lambda s, v: _emit(name, s, v), step, value)
