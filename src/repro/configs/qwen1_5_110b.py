"""Qwen1.5-110B [hf:Qwen/Qwen1.5 family card] — dense flagship: 80L,
d_model=8192, GQA 64Q/8KV, d_ff=49152, QKV bias. The memory-stress arch."""
from repro.config import ModelConfig, register

QWEN1_5_110B = register(ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm_type="rmsnorm",
    mlp_type="swiglu",
))
