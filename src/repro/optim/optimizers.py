"""Gradient transformations (optax-style minimal API, self-contained).

Each optimizer is a (init, update) pair:
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)), params, updates)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree_util.tree_map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(grads, m, params=None):
        m = jax.tree_util.tree_map(lambda mm, g: beta * mm + g, m, grads)
        return jax.tree_util.tree_map(lambda mm: -lr * mm, m), m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    """Adam / AdamW (decoupled decay when weight_decay > 0)."""

    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(mu=jax.tree_util.tree_map(f32, params),
                         nu=jax.tree_util.tree_map(f32, params),
                         count=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        c = state.count + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(m, v, p):
            u = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u

        updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, AdamState(mu=mu, nu=nu, count=c)

    return Optimizer(init, update)


def make(name: str, lr: float, weight_decay: float = 0.0) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr)
    if name == "adam":
        return adam(lr)
    if name == "adamw":
        return adam(lr, weight_decay=weight_decay or 0.01)
    raise ValueError(f"unknown optimizer {name!r}")
