"""Sparse features with LAZY variance-reduced updates (DESIGN.md
§Composite objectives, sparse lazy corrections).

On sparse data the CentralVR step touches only the nonzero coordinates of
the sampled row through its correction term — but the epoch-frozen mean
gradient ``gbar`` and the prox are DENSE: every step, every untouched
coordinate j still moves by the same fixed map

    psi(z) = S_c(z + b_j),     b_j = -eta * gbar_j,   c = eta * lam1

(soft-threshold ``S_c`` from the l1 prox; identity threshold c = 0 when no
prox is configured).  Because ``gbar`` is frozen for the whole epoch, k
skipped steps compose in closed form — psi is piecewise linear with at
most three phases (a linear drift on the coordinate's current sign side,
an absorbing-or-escaping stop at zero, and a final linear drift on the
other side), so ``psi^k`` is four masked closed-form phase advances with
ceil-counted crossing steps, not k sequential updates.  This is the
classical "lazy/just-in-time" update of sparse SGD solvers, extended to
the prox composition: per-coordinate last-touched counters record when
each coordinate was last materialized, the catch-up is applied on gather,
and one final catch-up at epoch end materializes the dense iterate.

Per-step work is O(nnz) instead of O(d); trajectories agree with the
dense prox'd CentralVR driver (``core/centralvr.py``, the oracle this
module is pinned against at 1e-10 in x64 — ``tests/test_prox_agreement``)
because the touched-coordinate update is the dense update restricted to
the row support and the catch-up reproduces the drift map exactly.

Scope: ``prob.lam == 0`` (a ridge term rescales x every step, which
densifies the drift into an affine-times-shrink map; fold L2 into the
data term or use the dense driver) and prox None or ``l1`` (the only
elementwise prox whose composition with the drift stays closed-form).
``solver.RunSpec`` enforces the same limits pre-JAX for
``sampling="sparse"``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import convex
from repro.core.convex import Problem
from repro.prox import operators as proxops


class SparseProblem(NamedTuple):
    """CSR-style fixed-width row storage: each row i holds ``width``
    DISTINCT coordinate indices ``idx[i]`` with values ``val[i]`` (zero on
    padding entries).  Distinctness is what makes padding exact: a
    zero-valued entry at coordinate j applies the plain drift map to j —
    exactly what the lazy catch-up would have done (see ``sparsify``)."""

    idx: jax.Array      # (n, width) int32, distinct within each row
    val: jax.Array      # (n, width) feature values, 0.0 on padding
    b: jax.Array        # (n,) targets/labels
    lam: jax.Array      # kept for Problem parity; must be 0 for the lazy path
    kind: str
    d: int

    @property
    def n(self):
        return self.idx.shape[0]

    @property
    def width(self):
        return self.idx.shape[1]


jax.tree_util.register_pytree_node(
    SparseProblem,
    lambda p: ((p.idx, p.val, p.b, p.lam), (p.kind, p.d)),
    lambda aux, leaves: SparseProblem(*leaves, kind=aux[0], d=aux[1]),
)


_PACK_CACHE: dict = {}      # id(A) -> (A strong ref, width, SparseProblem)
_PACK_CACHE_CAP = 4


def _cached_sparsify(prob: Problem, width: Optional[int] = None):
    """sparsify with a tiny keep-alive cache: repeated solves of the SAME
    problem (sweeps, warm benchmark calls) skip the O(n d log d) host
    repack, the way the dense drivers skip re-tracing via the jit cache.
    Keyed on ``id(prob.A)`` with the array held strongly so the id stays
    valid for exactly as long as the entry lives."""
    k = id(prob.A)
    hit = _PACK_CACHE.get(k)
    if hit is not None and hit[0] is prob.A and hit[1] == width:
        return hit[2]
    sp = sparsify(prob, width)
    if len(_PACK_CACHE) >= _PACK_CACHE_CAP:
        _PACK_CACHE.pop(next(iter(_PACK_CACHE)))
    _PACK_CACHE[k] = (prob.A, width, sp)
    return sp


def sparsify(prob: Problem, width: Optional[int] = None) -> SparseProblem:
    """Pack a dense Problem into fixed-width sparse rows, losslessly.

    ``width`` defaults to the max row support; a stable argsort on the
    zero-mask puts each row's nonzero coordinates first (in coordinate
    order) and pads from that row's zero coordinates — so indices stay
    distinct within a row and every padding value is exactly 0."""
    A = np.asarray(prob.A)
    n, d = A.shape
    mask = A != 0
    counts = mask.sum(axis=1)
    kmax = int(counts.max()) if n else 0
    w = kmax if width is None else int(width)
    if w < kmax:
        raise ValueError(
            f"sparsify: width={w} would drop nonzeros (max row support "
            f"is {kmax})")
    w = min(max(w, 1), d)
    if w < kmax:
        raise ValueError(f"sparsify: width {w} exceeds d={d}")
    order = np.argsort(~mask, axis=1, kind="stable")[:, :w]
    vals = np.take_along_axis(A, order, axis=1)
    return SparseProblem(jnp.asarray(order.astype(np.int32)),
                         jnp.asarray(vals), prob.b, prob.lam, prob.kind, d)


def make_sparse_data(key, n: int, d: int, nnz: int, *, kind: str = "ridge",
                     noise: float = 0.01) -> Problem:
    """Synthetic sparse-feature problem, lam = 0 (the lazy path's regime):
    each row draws ``nnz`` distinct coordinates uniformly, values scaled
    1/sqrt(nnz); returned DENSE so the dense drivers / metric / oracle all
    run unchanged (``run_sparse`` packs it via :func:`sparsify`)."""
    if not 1 <= nnz <= d:
        raise ValueError(f"make_sparse_data: need 1 <= nnz={nnz} <= d={d}")
    k1, k2, k3, k4 = jax.random.split(key, 4)
    u = jax.random.uniform(k1, (n, d))
    idx = jnp.argsort(u, axis=1)[:, :nnz]
    vals = jax.random.normal(k2, (n, nnz)) / jnp.sqrt(float(nnz))
    A = jnp.zeros((n, d)).at[jnp.arange(n)[:, None], idx].set(vals)
    x_star = jax.random.normal(k3, (d,)) / jnp.sqrt(float(d))
    z = A @ x_star + noise * jax.random.normal(k4, (n,))
    if kind == "logistic":
        b = jnp.sign(z)
    elif kind == "ridge":
        b = z
    else:
        raise ValueError(f"make_sparse_data: unknown kind {kind!r}")
    return Problem(A, b, jnp.asarray(0.0), kind)


# ---------------------------------------------------------------------------
# The closed-form k-fold drift map  psi^k,  psi(z) = S_c(z + b)
# ---------------------------------------------------------------------------

def _soft(z, c):
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - c, 0.0)


def lazy_apply(z, k, b, c):
    """Apply ``psi^k`` elementwise, ``psi(z) = S_c(z + b)``, in closed form.

    psi is piecewise linear: while the iterate stays strictly positive it
    moves by ``b - c`` per step, while strictly negative by ``b + c``, and
    zero is absorbing iff ``|b| <= c``.  Each loop round below (i) jumps
    to the end of the current phase in one masked closed-form advance
    (ceil-counted steps that provably keep the sign), then (ii) takes ONE
    exact psi step across the phase boundary.  A trajectory crosses at
    most three phases (sign side -> zero -> other side, each entered once
    because the drift direction is fixed), so four rounds always consume
    ``k``.  In exact arithmetic this equals k sequential applications;
    in floats the linear advance ``z + t*delta`` differs from t repeated
    additions by accumulated rounding only — well inside the 1e-10
    dense-agreement pin in x64.

    ``k`` is an int array (>= 0) broadcastable against ``z``; ``b``/``c``
    broadcast likewise.
    """
    z = jnp.asarray(z)
    rem = jnp.broadcast_to(jnp.asarray(k), z.shape).astype(jnp.int32)
    b = jnp.broadcast_to(jnp.asarray(b), z.shape)
    dp = b - c                          # per-step move while z > 0
    dn = b + c                          # per-step move while z < 0
    fin = jnp.zeros_like(rem)

    def ceil_steps(num, den):
        # largest step count that keeps the current sign: ceil(num/den)-1
        q = num / jnp.where(den == 0.0, 1.0, den)
        t = jnp.ceil(q) - 1.0
        return jnp.maximum(t, 0.0).astype(jnp.int32)

    for _ in range(4):
        pos, neg = z > 0, z < 0
        # closed-form advance within the current phase
        t_pos = jnp.where(dp >= 0, rem,
                          jnp.minimum(rem, ceil_steps(z, -dp)))
        t_neg = jnp.where(dn <= 0, rem,
                          jnp.minimum(rem, ceil_steps(-z, dn)))
        t_zero = jnp.where(jnp.abs(b) <= c, rem, fin)
        t = jnp.where(pos, t_pos, jnp.where(neg, t_neg, t_zero))
        tf = t.astype(z.dtype)
        z = jnp.where(pos, z + tf * dp, jnp.where(neg, z + tf * dn, z))
        rem = rem - t
        # one exact step across the phase boundary
        step = rem > 0
        z = jnp.where(step, _soft(z + b, c), z)
        rem = jnp.where(step, rem - 1, rem)
    return z


# ---------------------------------------------------------------------------
# Lazy epochs
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("kind", "vr"))
def _lazy_epoch(idx, val, bvec, kind: str, z, table, gbar, eta, c, perm,
                vr: bool = True):
    """One lazy epoch over ``perm``.  ``vr=True`` is the CentralVR epoch
    (correction from the scalar table, drift b = -eta*gbar); ``vr=False``
    is the plain-SGD init epoch (no correction, zero drift).  Returns the
    fully materialized (z, table, acc): the end-of-epoch catch-up brings
    every coordinate to the final step, so ``z`` IS the dense iterate."""
    n = idx.shape[0]
    d = z.shape[0]
    drift = -eta * gbar if vr else jnp.zeros_like(gbar)

    def body(carry, ti):
        z, last, table, acc = carry
        t, i = ti
        J = idx[i]
        w = val[i]
        # catch the row's coordinates up to step t (they were exact as of
        # their last touch; everything since was the pure drift map)
        zJ = lazy_apply(z[J], t - last[J], drift[J], c)
        s_new = convex._pointwise_residual(w @ zJ, bvec[i], kind)
        if vr:
            vJ = (s_new - table[i]) * w + gbar[J]
        else:
            vJ = s_new * w
        zJ = _soft(zJ - eta * vJ, c)
        z = z.at[J].set(zJ)
        last = last.at[J].set(t + 1)
        table = table.at[i].set(s_new)
        acc = acc.at[J].add(s_new * w / n)
        return (z, last, table, acc), None

    last0 = jnp.zeros((d,), jnp.int32)
    acc0 = jnp.zeros_like(z)
    (z, last, table, acc), _ = jax.lax.scan(
        body, (z, last0, table, acc0),
        (jnp.arange(n, dtype=jnp.int32), perm.astype(jnp.int32)))
    # materialize: every coordinate catches up to the end of the epoch
    z = lazy_apply(z, n - last, drift, c)
    return z, table, acc


def run_sparse(prob: Problem, *, eta: float, epochs: int, key: jax.Array,
               x0: Optional[jax.Array] = None, prox=None):
    """Algorithm 1 with lazy sparse updates — the ``sampling="sparse"``
    execution of ``centralvr.run``.  Same return shape (state, rels,
    grad_evals), same RNG splits, same arithmetic restricted to row
    supports: the dense prox'd permutation driver is the exact oracle.
    """
    from repro.core.centralvr import VRState

    if float(prob.lam) != 0.0:
        raise ValueError(
            "sparse lazy updates require lam == 0: the ridge term 2*lam*x "
            "multiplies every coordinate every step, which breaks the "
            "closed-form drift composition; use the dense driver (or fold "
            "the l2 term into the data)")
    px = proxops.parse(prox) if prox is not None else None
    if px is not None and px.name != "l1":
        raise ValueError(
            f"sparse lazy updates support prox None or 'l1', got "
            f"{px.name!r}: only the soft-threshold composes with the "
            "drift in closed form")
    c = jnp.asarray(eta * (px.params[0] if px is not None else 0.0))
    sp = _cached_sparsify(prob)
    n, d = prob.n, prob.d

    k_init, k_run = jax.random.split(key)          # == centralvr.run
    x = jnp.zeros((d,)) if x0 is None else x0
    table = jnp.zeros((n,))
    # init: one plain-SGD epoch (Algorithm 1 line 2), lazily
    perm0 = jax.random.permutation(k_init, n)
    x, table, gbar = _lazy_epoch(sp.idx, sp.val, sp.b, sp.kind, x, table,
                                 jnp.zeros((d,)), eta, c, perm0, vr=False)

    g0 = convex.grad_norm0(prob, prox=px, eta=eta)
    keys = jax.random.split(k_run, epochs)
    rels = []
    for e in range(epochs):
        perm = jax.random.permutation(keys[e], n)
        x, table, gbar = _lazy_epoch(sp.idx, sp.val, sp.b, sp.kind, x,
                                     table, gbar, eta, c, perm, vr=True)
        rels.append(convex.rel_grad_norm(prob, x, g0, prox=px, eta=eta))
    rels = jnp.stack(rels) if rels else jnp.zeros((0,))
    grad_evals = prob.n * jnp.arange(2, epochs + 2)
    return VRState(x=x, table=table, gbar=gbar), rels, grad_evals
