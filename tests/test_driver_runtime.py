"""Device-resident driver runtime pins (DESIGN.md §3).

The scan-based drivers in ``core/centralvr`` and ``core/distributed`` must
be a pure EXECUTION-MODEL change: identical per-round relative-grad-norm
trajectories to the seed host-loop drivers (kept verbatim in
``core/host_loop``), within float32 tolerance.  And the async/DSAGA event
functions must trace/compile exactly once regardless of worker count —
the seed model compiled p per-worker closures, the very scaling bug the
runtime removes.
"""
import jax
import numpy as np
import pytest

from repro.config import ConvexConfig
from repro.core import centralvr, convex, distributed, host_loop, runtime

# float32 tolerance: the trajectories go through identical arithmetic, but
# XLA may fuse differently inside vs outside the round scan
TOL = dict(rtol=3e-5, atol=1e-7)


def _prob(kind, n=96, d=9):
    key = jax.random.PRNGKey(0)
    gen = (convex.make_logistic_data if kind == "logistic"
           else convex.make_ridge_data)
    return gen(key, n, d)


def _sharded(kind, p=4, n=64, d=9, seed=0):
    cfg = ConvexConfig(problem=kind, n=n, d=d, workers=p)
    return distributed.make_distributed(jax.random.PRNGKey(seed), cfg)


def _eta(obj):
    prob = obj.merged() if hasattr(obj, "merged") else obj
    return convex.auto_eta(prob, 0.3)


@pytest.mark.parametrize("kind", ["logistic", "ridge"])
@pytest.mark.parametrize("sampling", ["permutation", "uniform"])
def test_run_matches_host_loop(kind, sampling):
    prob = _prob(kind)
    key = jax.random.PRNGKey(3)
    eta = _eta(prob)
    st_new, rels_new, ev_new = centralvr.run(
        prob, eta=eta, epochs=6, key=key, sampling=sampling)
    st_old, rels_old, ev_old = host_loop.run(
        prob, eta=eta, epochs=6, key=key, sampling=sampling)
    np.testing.assert_allclose(np.asarray(rels_new), np.asarray(rels_old),
                               **TOL)
    np.testing.assert_array_equal(np.asarray(ev_new), np.asarray(ev_old))
    np.testing.assert_allclose(np.asarray(st_new.x), np.asarray(st_old.x),
                               **TOL)


@pytest.mark.parametrize("kind", ["logistic", "ridge"])
def test_run_sync_matches_host_loop(kind):
    sp = _sharded(kind)
    key = jax.random.PRNGKey(4)
    eta = _eta(sp)
    st_new, rels_new = distributed.run_sync(sp, eta=eta, rounds=6, key=key)
    st_old, rels_old = host_loop.run_sync(sp, eta=eta, rounds=6, key=key)
    np.testing.assert_allclose(np.asarray(rels_new), np.asarray(rels_old),
                               **TOL)
    np.testing.assert_allclose(np.asarray(st_new.x), np.asarray(st_old.x),
                               **TOL)


@pytest.mark.parametrize("kind", ["logistic", "ridge"])
@pytest.mark.parametrize("speeds", [None, (1.0, 1.0, 2.0, 4.0)])
def test_run_async_matches_host_loop(kind, speeds):
    sp = _sharded(kind)
    key = jax.random.PRNGKey(5)
    eta = _eta(sp)
    st_new, rels_new = distributed.run_async(sp, eta=eta, rounds=6, key=key,
                                             speeds=speeds)
    st_old, rels_old = host_loop.run_async(sp, eta=eta, rounds=6, key=key,
                                           speeds=speeds)
    np.testing.assert_allclose(np.asarray(rels_new), np.asarray(rels_old),
                               **TOL)
    np.testing.assert_allclose(np.asarray(st_new.x_c),
                               np.asarray(st_old.x_c), **TOL)


@pytest.mark.parametrize("kind", ["logistic", "ridge"])
def test_run_dsvrg_matches_host_loop(kind):
    sp = _sharded(kind)
    key = jax.random.PRNGKey(6)
    eta = _eta(sp)
    x_new, rels_new = distributed.run_dsvrg(sp, eta=eta, rounds=6, key=key)
    x_old, rels_old = host_loop.run_dsvrg(sp, eta=eta, rounds=6, key=key)
    np.testing.assert_allclose(np.asarray(rels_new), np.asarray(rels_old),
                               **TOL)
    np.testing.assert_allclose(np.asarray(x_new), np.asarray(x_old), **TOL)


@pytest.mark.parametrize("kind", ["logistic", "ridge"])
@pytest.mark.parametrize("literal_scaling", [False, True])
def test_run_dsaga_matches_host_loop(kind, literal_scaling):
    sp = _sharded(kind)
    key = jax.random.PRNGKey(7)
    eta = _eta(sp) / 2
    st_new, rels_new = distributed.run_dsaga(
        sp, eta=eta, rounds=6, key=key, tau=32,
        literal_scaling=literal_scaling)
    st_old, rels_old = host_loop.run_dsaga(
        sp, eta=eta, rounds=6, key=key, tau=32,
        literal_scaling=literal_scaling)
    np.testing.assert_allclose(np.asarray(rels_new), np.asarray(rels_old),
                               **TOL)
    np.testing.assert_allclose(np.asarray(st_new.x_c),
                               np.asarray(st_old.x_c), **TOL)


@pytest.mark.parametrize("p", [2, 8])
def test_async_event_traces_once_regardless_of_p(p):
    """The seed model jit-compiled p per-worker event closures; the scan
    runtime must trace its single traced-index event function exactly once
    per compile, for any p.  (Python code inside a traced function runs
    once per trace and zero times on a cache hit, so runtime.TRACES is an
    exact probe.)"""
    # distinctive shapes so no other test pre-populates the jit cache
    sp = _sharded("logistic", p=p, n=44, d=7, seed=11)
    eta = _eta(sp)
    runtime.TRACES.clear()
    _, rels = distributed.run_async(sp, eta=eta, rounds=3,
                                    key=jax.random.PRNGKey(8))
    assert runtime.TRACES["async_event"] == 1, dict(runtime.TRACES)
    assert np.isfinite(np.asarray(rels)).all()
    # identical shapes again: cache hit, zero retraces
    runtime.TRACES.clear()
    distributed.run_async(sp, eta=eta, rounds=3, key=jax.random.PRNGKey(9))
    assert runtime.TRACES["async_event"] == 0, dict(runtime.TRACES)


@pytest.mark.parametrize("p", [2, 8])
def test_dsaga_event_traces_once_regardless_of_p(p):
    sp = _sharded("ridge", p=p, n=44, d=7, seed=12)
    eta = _eta(sp) / 2
    runtime.TRACES.clear()
    _, rels = distributed.run_dsaga(sp, eta=eta, rounds=3, tau=16,
                                    key=jax.random.PRNGKey(10))
    assert runtime.TRACES["dsaga_event"] == 1, dict(runtime.TRACES)
    assert np.isfinite(np.asarray(rels)).all()


def test_event_schedule_matches_seed_loop():
    """The vectorized sorted-merge schedule must be BYTE-identical to the
    seed argmin loop (kept as runtime._event_schedule_loop), including
    float-tie ordering — cumsum accumulates the same additions the loop
    performed, and ties break by lowest worker index in both."""
    cases = [(3, 5, [1.0, 2.0, 3.0]),           # the pinned satellite case
             (3, 1, [1.0, 1.0, 1.0]),           # all-tied: pure tie-break
             (4, 7, (1.0, 1.0, 2.0, 4.0)),
             (5, 11, [0.3, 1.7, 2.2, 0.9, 5.0])]
    rng = np.random.default_rng(7)
    cases += [(p, int(rng.integers(1, 9)),
               rng.uniform(0.2, 8.0, p).tolist()) for p in (2, 6, 9)]
    for p, rounds, speeds in cases:
        got = runtime.event_schedule(p, rounds, speeds)
        want = runtime._event_schedule_loop(p, rounds, speeds)
        assert got.dtype == want.dtype == np.int32
        np.testing.assert_array_equal(got, want, err_msg=str((p, rounds,
                                                              speeds)))


def test_wave_partition_byte_identical_order():
    """The spmd-async concurrency waves must be a pure REGROUPING of the
    event schedule: flattening the waves (workers of each wave in rank
    order) reproduces the schedule byte-identically, every wave contains
    each worker at most once, and waves never cross a metric-round
    boundary."""
    cases = [(3, 5, [1.0, 2.0, 3.0]), (4, 6, (1.0, 1.0, 2.0, 4.0)),
             (2, 4, None), (5, 3, None), (1, 4, None),
             (5, 7, [0.3, 1.7, 2.2, 0.9, 5.0])]
    for p, rounds, speeds in cases:
        sched = runtime.event_schedule(p, rounds, speeds)
        active, rank, slot = runtime.wave_partition(sched, p)
        assert active.shape == rank.shape
        assert active.shape[0] == rounds and active.shape[2] == p
        np.testing.assert_array_equal(runtime.wave_flatten(active, rank),
                                      sched, err_msg=str((p, rounds,
                                                          speeds)))
        # each worker at most once per wave; ranks are 0..k-1 per wave
        for r in range(rounds):
            for w in range(active.shape[1]):
                ranks = np.sort(rank[r, w][active[r, w]])
                np.testing.assert_array_equal(ranks, np.arange(ranks.size))
        assert np.all(rank[~active] == p)
        # events stay within their round: round r's events fill exactly
        # its p slots
        assert active.reshape(rounds, -1).sum(1).tolist() == [p] * rounds
        # slot maps each event into a monotonically nondecreasing wave
        assert np.all(np.diff(slot) >= 0)


def test_wave_partition_round_robin_is_one_wave():
    """Round-robin (the default schedule) is fully parallel: exactly one
    wave per round, everyone active."""
    sched = runtime.event_schedule(4, 5)
    active, rank, _ = runtime.wave_partition(sched, 4)
    assert active.shape == (5, 1, 4)
    assert active.all()
    np.testing.assert_array_equal(rank[:, 0], np.tile(np.arange(4), (5, 1)))


def test_dsaga_stale_fetch_p1_equals_instant():
    """With one worker nothing happens between a worker's events, so the
    state fetched at the previous event IS the instantaneous central
    state: fetch="stale" must be bit-identical to the default."""
    sp = _sharded("logistic", p=1, n=32, d=6, seed=13)
    key = jax.random.PRNGKey(14)
    eta = _eta(sp) / 2
    st_i, rels_i = distributed.run_dsaga(sp, eta=eta, rounds=3, key=key,
                                         tau=16)
    st_s, rels_s = distributed.run_dsaga(sp, eta=eta, rounds=3, key=key,
                                         tau=16, fetch="stale")
    np.testing.assert_array_equal(np.asarray(rels_i), np.asarray(rels_s))
    np.testing.assert_array_equal(np.asarray(st_i.x_c), np.asarray(st_s.x_c))


@pytest.mark.parametrize("kind", ["logistic", "ridge"])
def test_dsaga_stale_fetch_converges(kind):
    """The stale-fetch discipline (Algorithm 3's, applied to Algorithm 5 so
    the spmd waves commute) is a different but convergent trajectory: it
    must still drive the relative grad norm down on the toy problems."""
    sp = _sharded(kind, p=4)
    key = jax.random.PRNGKey(15)
    eta = _eta(sp) / 2
    _, rels = distributed.run_dsaga(sp, eta=eta, rounds=8, key=key, tau=32,
                                    fetch="stale")
    rels = np.asarray(rels)
    assert np.isfinite(rels).all()
    assert rels[-1] < 0.5 * rels[0], rels


def test_event_schedule_speed_weighted():
    """Faster workers fire proportionally more events; every worker's
    event count is within one of its speed share."""
    p, rounds = 4, 6
    speeds = (1.0, 1.0, 2.0, 4.0)
    sched = runtime.event_schedule(p, rounds, speeds)
    assert sched.shape == (p * rounds,)
    counts = np.bincount(sched, minlength=p)
    shares = np.asarray(speeds) / np.sum(speeds) * p * rounds
    assert np.all(np.abs(counts - shares) <= 1.0), (counts, shares)
    # round-robin default
    rr = runtime.event_schedule(3, 2)
    np.testing.assert_array_equal(rr, [0, 1, 2, 0, 1, 2])
