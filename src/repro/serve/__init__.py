"""Production serving runtime: paged KV cache, chunked prefill,
continuous batching, and (optional) tensor-parallel decode.

Layering:
  * ``cache``   — block-pool geometry + host-side allocator;
  * ``trace``   — deterministic synthetic request traces;
  * ``runtime`` — the two jitted programs (batched decode_step, bucketed
                  prefill_chunk) over paged / dense / ring layer caches;
  * ``engine``  — the continuous-batching scheduler (ServeEngine);
  * ``legacy``  — the old static-batch per-token host loop, kept as the
                  non-attention-arch fallback and the bench twin.

See DESIGN.md §Serving.
"""
from repro.serve.cache import BlockAllocator, Geometry
from repro.serve.engine import (RequestResult, ServeEngine, ServeReport,
                                serve_trace)
from repro.serve.legacy import run_host_loop
from repro.serve.runtime import SERVE_KINDS, check_arch
from repro.serve.trace import (ARRIVAL_PATTERNS, Request, prompt_tokens,
                               synthetic_trace)

__all__ = [
    "ARRIVAL_PATTERNS", "BlockAllocator", "Geometry", "Request",
    "RequestResult", "SERVE_KINDS", "ServeEngine", "ServeReport",
    "check_arch", "prompt_tokens", "run_host_loop", "serve_trace",
    "synthetic_trace",
]
