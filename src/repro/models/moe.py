"""Mixture-of-Experts layer: top-k routing with capacity-based sort dispatch
(expert-parallel friendly: the expert axis is sharded along 'model', token
dispatch lowers to all-to-all / collective-permute under GSPMD).

Dispatch strategy: tokens are argsorted by expert assignment and gathered
into a dense (E, capacity, d) buffer (dropping overflow beyond the capacity
factor, standard practice) so the expert matmuls are plain batched GEMMs —
MXU-friendly and dry-run friendly (FLOPs proportional to ACTIVE compute,
unlike one-hot-einsum dispatch whose HLO FLOPs scale with E).

Supports the two assigned MoE flavours:
  * qwen3-moe-30b-a3b — 128 routed experts, top-8, softmax-after-topk
  * qwen2-moe-a2.7b   — 60 routed top-4 + shared expert (5632) with gate
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers


def init_moe(cfg: ModelConfig, key, dtype):
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    p = {
        "router": layers._dense_init(ks[0], (d, E), d, jnp.float32),
        "wg": layers._dense_init(ks[1], (E, d, ff), d, dtype),
        "wu": layers._dense_init(ks[2], (E, d, ff), d, dtype),
        "wd": layers._dense_init(ks[3], (E, ff, d), ff, dtype),
    }
    if cfg.shared_expert_d_ff:
        sff = cfg.shared_expert_d_ff
        p["shared"] = {
            "wg": layers._dense_init(ks[4], (d, sff), d, dtype),
            "wu": layers._dense_init(ks[5], (d, sff), d, dtype),
            "wd": layers._dense_init(
                jax.random.fold_in(ks[5], 1), (sff, d), sff, dtype),
        }
        if cfg.shared_expert_gate:
            p["shared_gate"] = layers._dense_init(
                jax.random.fold_in(ks[4], 1), (d, 1), d, dtype)
    return p


def apply_moe(p, cfg: ModelConfig, x, *, capacity_factor: float = 0.0):
    """x: (B, S, d) -> (y, aux_loss). capacity_factor 0 -> cfg value."""
    capacity_factor = capacity_factor or cfg.moe_capacity_factor
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    # softmax over ALL experts, then take top-k of the probabilities and
    # renormalize (Qwen-MoE convention: norm_topk_prob=True)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_e = jax.lax.top_k(probs, K)                  # (T, K)
    topk_p = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    density = jnp.zeros((E,), jnp.float32).at[topk_e.reshape(-1)].add(1.0) / (T * K)
    router_prob = probs.mean(0)
    aux = (cfg.router_aux_coef * E * jnp.sum(density * router_prob)
           ).astype(jnp.float32)

    # ---- capacity-based sort dispatch, GATHER-ONLY on feature tensors ----
    # Scatters carrying the d-dim are poison under GSPMD with a sharded
    # token axis: each device scatters into a full-size zero buffer that is
    # then ALL-REDUCED — measured 4 GB x 2 x (A x L) executions on
    # qwen3-moe train (EXPERIMENTS.md §Perf It.10). Here scatters touch
    # only int32 INDEX vectors (bytes, not MBs); every (rows, d) movement
    # is a gather, which GSPMD lowers to all-gather/permute of the much
    # smaller bf16 sources.
    cap = max(int(capacity_factor * T * K / E), 8)
    flat_e = topk_e.reshape(-1)                               # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = topk_p.reshape(-1)

    order = jnp.argsort(flat_e)                               # stable
    e_sorted = flat_e[order]
    t_sorted = flat_t[order]
    # position within the expert's slot list
    ones = jnp.ones_like(e_sorted)
    pos_in_e = jnp.cumsum(ones) - 1
    e_start = jnp.zeros((E,), jnp.int32).at[e_sorted].add(1)
    e_start = jnp.cumsum(e_start) - e_start                   # start offset per expert
    slot = (pos_in_e - e_start[e_sorted]).astype(jnp.int32)
    keep = slot < cap
    buf_idx = jnp.where(keep, e_sorted * cap + slot, E * cap)  # overflow slot

    # source token for every buffer position (int32 scatter, tiny)
    src = jnp.full((E * cap + 1,), T, jnp.int32).at[buf_idx].set(
        t_sorted.astype(jnp.int32))[:-1]
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), x.dtype)])
    xin = xt_pad[src].reshape(E, cap, d)                       # gather

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", xin, p["wu"])
    yexp = jnp.einsum("ecf,efd->ecd", h, p["wd"])              # (E, cap, d)

    # combine, token-major: slot index for each (token, k) via the inverse
    # permutation (int32 scatter), then gather expert outputs
    inv = jnp.zeros((T * K,), jnp.int32).at[order].set(
        jnp.arange(T * K, dtype=jnp.int32))
    slot_flat = jnp.where(keep, buf_idx, E * cap)[inv]         # (T*K,)
    yexp_pad = jnp.concatenate([yexp.reshape(E * cap, d),
                                jnp.zeros((1, d), yexp.dtype)])
    contrib = yexp_pad[slot_flat].reshape(T, K, d)             # gather
    y = jnp.einsum("tkd,tk->td", contrib,
                   flat_w.reshape(T, K).astype(contrib.dtype)).astype(x.dtype)

    if "shared" in p:
        sh = layers.apply_mlp(p["shared"], xt, "swiglu")
        if "shared_gate" in p:
            g = jax.nn.sigmoid(xt @ p["shared_gate"])
            sh = sh * g
        y = y + sh

    return y.reshape(B, S, d), aux
