"""Analytical communication accounting for every registry algorithm.

Bytes-per-collective per communication round, derived from the problem
shapes — no execution required, so every ``RunResult.provenance()`` row
carries its comms model whatever backend ran (the vmap backend simulates
workers on one device; these numbers are what the SAME algorithm moves on
a real mesh).  The convention matches ``roofline/analysis.py``:
**result-shape bytes landed per worker per collective** (an all-reduce of
a (d,) float32 buffer counts d*4 bytes, whatever the wire algorithm).
The measured twin is the ``comms_hlo`` event ``obs.stage`` records from
the compiled module's collective ops when telemetry is on and the run is
staged.

Per-round models (d = parameter dimension, B = bytes per element):

  * ``centralvr_sync``  — the Algorithm-2 boundary averages x and gbar:
    2 all-reduces, d*B each.
  * ``dsvrg``           — the sync step's full-gradient all-reduce plus
    the iterate average: 2 all-reduces, d*B each.
  * ``centralvr_async`` / ``dsaga`` — per EVENT the worker pushes
    (dx, dgbar) and fetches (x_c, gbar_c): 2*d*B up + 2*d*B down,
    point-to-point with the central node; p events per round.
  * ``dist_sgd``        — iterate average: 1 all-reduce, d*B.
  * ``easgd``           — elastic exchange with the center: d*B up +
    d*B down per worker per round, point-to-point.
  * ``ps_svrg``         — snapshot full-gradient all-reduce + iterate
    average: 2 all-reduces, d*B each.
  * single-worker algorithms (``centralvr``, ``sgd``, ``svrg``,
    ``saga``) — no communication.
"""
from __future__ import annotations

from typing import Optional

BYTES_PER_EL = 4     # float32, the driver substrate dtype

# algo -> (all_reduce result buffers per round, point-to-point d-sized
#          buffers per worker per round [push + fetch], per_event flag)
_MODELS = {
    "centralvr": (0, 0, False),
    "centralvr_sync": (2, 0, False),
    "centralvr_async": (0, 4, True),
    "dsvrg": (2, 0, False),
    "dsaga": (0, 4, True),
    "sgd": (0, 0, False),
    "svrg": (0, 0, False),
    "saga": (0, 0, False),
    "dist_sgd": (1, 0, False),
    "easgd": (0, 2, False),
    "ps_svrg": (2, 0, False),
}


def coverage_gaps(algos) -> tuple:
    """(missing, extra) vs the comms models — ``missing`` are registry
    algorithms with no comms model (each needs a ``_MODELS`` row before it
    can appear in provenance), ``extra`` are stale models for retired
    algorithms.  The registry-coverage pin asserts both empty and names
    the offenders in its failure message."""
    algos = set(algos)
    return (tuple(sorted(algos - set(_MODELS))),
            tuple(sorted(set(_MODELS) - algos)))


def comms_model(algo: str, *, p: int, d: int, rounds: int,
                bytes_per_el: int = BYTES_PER_EL,
                events_per_round: Optional[int] = None) -> dict:
    """The analytical comms record embedded in provenance (JSON-able).

    ``events_per_round`` defaults to p for the event-scheduled algorithms
    (one event per worker per metric round — the schedule's construction)
    and is ignored for the bulk-synchronous ones.
    """
    if algo not in _MODELS:
        raise ValueError(f"no comms model for algorithm {algo!r}")
    n_allreduce, n_p2p, per_event = _MODELS[algo]
    buf = d * bytes_per_el
    events = (events_per_round if events_per_round is not None else p) \
        if per_event else 0
    allreduce_bytes = n_allreduce * buf
    # point-to-point buffers: per EVENT for the event-scheduled algorithms
    # (each event is one worker's push+fetch with the central node), per
    # worker per round for the bulk-synchronous exchanges (easgd)
    p2p_bytes = n_p2p * buf * (events if per_event else p)
    bytes_per_round = allreduce_bytes + p2p_bytes
    return {
        "algo": algo, "p": int(p), "d": int(d), "rounds": int(rounds),
        "bytes_per_el": int(bytes_per_el),
        "n_allreduce_per_round": int(n_allreduce),
        "allreduce_bytes_per_round": float(allreduce_bytes),
        "events_per_round": int(events),
        "p2p_bytes_per_round": float(p2p_bytes),
        "bytes_per_round": float(bytes_per_round),
        "total_bytes": float(bytes_per_round * rounds),
        "convention": "result-shape bytes per collective "
                      "(roofline/analysis.py)",
    }
