"""Mesh-shape-portable checkpoints for the elastic async runtime
(DESIGN.md §Multi-host & elasticity).

A checkpoint taken at a wave boundary carries BOTH representations of the
CentralVR-Async state:

  * the full per-worker ``AsyncState`` at the shape it was saved at —
    restoring at the SAME worker count is exact (bit-equal continuation);
  * the shape-portable core — central ``(x_c, gbar_c)`` plus the merged
    ``(n,)`` VR table — restoring at a DIFFERENT worker count re-shards
    the table contiguously and RESYNCS the per-worker fetch/old vectors
    to the central values (``core.elastic.resync_state``), the same
    handover a live repartition performs.  The trajectory from a resumed
    checkpoint is therefore pinned against an uninterrupted run at the
    new shape (``tests/test_checkpoint_roundtrip.py``).

Format matches ``checkpoint/checkpoint.py``'s conventions: one ``.npz``
of host arrays plus a ``.json`` manifest (round, shape, live worker ids).
"""
from __future__ import annotations

import json
import os
from typing import Optional, Sequence, Tuple

import numpy as np


def _norm(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def save_elastic(path: str, st, *, round_: int, live: Sequence[int],
                 p0: int) -> None:
    """Persist an ``AsyncState`` at a wave boundary.  ``live`` are the
    ORIGINAL worker ids of the current shape; ``p0`` the fleet size the
    run started with."""
    path = _norm(path)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = {k: np.asarray(v) for k, v in st._asdict().items()}
    np.savez(path, **arrays)
    p, ns = arrays["tables"].shape
    manifest = {
        "kind": "elastic_async", "round": int(round_), "p": int(p),
        "ns": int(ns), "n": int(p * ns), "d": int(arrays["x_c"].shape[0]),
        "live": [int(s) for s in live], "p0": int(p0),
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
    }
    with open(path + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def load_manifest(path: str) -> dict:
    with open(_norm(path) + ".json") as f:
        return json.load(f)


def restore_elastic(path: str, p_new: Optional[int] = None) -> Tuple:
    """Rebuild an ``AsyncState`` from a wave-boundary checkpoint.

    ``p_new=None`` (or the saved shape) restores the full per-worker
    state exactly; any other shape goes through the merged-table resync
    handover.  Returns ``(state, manifest)``."""
    from repro.core.distributed import AsyncState
    from repro.core.elastic import resync_state

    import jax.numpy as jnp

    path = _norm(path)
    manifest = load_manifest(path)
    data = np.load(path)
    if p_new is None or p_new == manifest["p"]:
        st = AsyncState(**{k: jnp.asarray(data[k])
                           for k in AsyncState._fields})
        return st, manifest
    if manifest["n"] % p_new:
        raise ValueError(
            f"restore_elastic: checkpoint has n={manifest['n']} samples, "
            f"which does not divide over p={p_new} workers")
    st = resync_state(data["x_c"], data["gbar_c"],
                      data["tables"].reshape(-1), p_new)
    return st, manifest


def latest_elastic(dirpath: str) -> Optional[str]:
    """Path (sans extension) of the highest-round elastic checkpoint in
    ``dirpath``, or None."""
    best, best_round = None, -1
    try:
        names = os.listdir(dirpath)
    except FileNotFoundError:
        return None
    for name in names:
        if not (name.startswith("elastic_") and name.endswith(".npz.json")):
            continue
        stem = os.path.join(dirpath, name[:-len(".npz.json")])
        try:
            r = load_manifest(stem)["round"]
        except (OSError, KeyError, json.JSONDecodeError):
            continue
        if r > best_round:
            best, best_round = stem, r
    return best
