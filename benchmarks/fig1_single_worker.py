"""Figure 1 reproduction: single-worker convergence per GRADIENT EVALUATION.

Four panels: logistic/toy, ridge/toy, logistic/IJCNN1-like,
ridge/MILLIONSONG-like (shape-matched synthetic stand-ins — offline
container, DESIGN.md §9). The paper's claim: CentralVR reaches a given
gradient norm in < 1/3 the gradient evaluations of SVRG/SAGA and far fewer
than SGD.

Gradient-evaluation accounting (Table 1): CentralVR and SAGA cost n evals
per epoch, SVRG costs n (snapshot full gradient) + 2n (inner corrections)
= 3n per epoch.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.config import ConvexConfig
from repro.configs.paper_convex import PRESETS
from repro.core import baselines, centralvr, convex


# (preset, eta_scale c in eta=c/L, epochs)
PANELS = [
    ("toy-logistic", 0.5, 40),
    ("toy-ridge", 0.4, 40),
    ("ijcnn1", 0.5, 16),
    ("millionsong", 0.4, 16),
]


def evals_to_eps(rels, evals_per_epoch, eps):
    r = np.asarray(rels)
    hit = np.nonzero(r < eps)[0]
    return (int(hit[0]) + 1) * evals_per_epoch if hit.size else float("inf")


def run(quick: bool = False):
    rows = []
    for preset, eta_scale, epochs in PANELS:
        cfg: ConvexConfig = PRESETS[preset]
        if quick:
            cfg = ConvexConfig(problem=cfg.problem, n=min(cfg.n, 2000),
                               d=cfg.d, lam=cfg.lam)
            epochs = 8
        key = jax.random.PRNGKey(0)
        prob = convex.make_problem(key, cfg)
        eta = convex.auto_eta(prob, eta_scale)
        n = prob.n

        # warm pass first: the scan drivers compile once per shape, so the
        # timed second call measures steady-state device throughput
        jax.block_until_ready(
            centralvr.run(prob, eta=eta, epochs=epochs, key=key))
        t0 = time.perf_counter()
        _, r_cvr, _ = centralvr.run(prob, eta=eta, epochs=epochs, key=key)
        jax.block_until_ready(r_cvr)
        t_cvr = time.perf_counter() - t0
        _, r_svrg = baselines.run_svrg(prob, eta=eta, epochs=epochs, key=key)
        _, r_saga = baselines.run_saga(prob, eta=eta, epochs=epochs, key=key)
        _, r_sgd = baselines.run_sgd(prob, eta=eta, epochs=epochs, key=key,
                                     decay=0.1)

        # target: one decade above the best CentralVR norm but no looser
        # than 1e-3 relative — the "high accuracy" regime where VR matters
        eps = min(max(float(np.asarray(r_cvr).min()) * 10, 1e-10), 1e-3)
        e_cvr = evals_to_eps(r_cvr, n, eps)
        e_svrg = evals_to_eps(r_svrg, 3 * n, eps)
        e_saga = evals_to_eps(r_saga, n, eps)
        e_sgd = evals_to_eps(r_sgd, n, eps)
        finals = (f"final:cvr={float(r_cvr[-1]):.1e},"
                  f"svrg={float(r_svrg[-1]):.1e},"
                  f"saga={float(r_saga[-1]):.1e},"
                  f"sgd={float(r_sgd[-1]):.1e}")
        rows.append({
            "name": f"fig1/{preset}",
            "us_per_call": t_cvr / epochs * 1e6,
            "derived": (f"evals_to_{eps:.1e}:"
                        f"cvr={e_cvr:.0f};svrg={e_svrg:.0f};"
                        f"saga={e_saga:.0f};sgd={e_sgd:.0f};"
                        f"speedup_vs_svrg={e_svrg / max(e_cvr, 1):.2f}x;"
                        + finals),
            "rels": {"centralvr": np.asarray(r_cvr).tolist(),
                     "svrg": np.asarray(r_svrg).tolist(),
                     "saga": np.asarray(r_saga).tolist(),
                     "sgd": np.asarray(r_sgd).tolist()},
            "eta": eta, "epochs": epochs, "n": n, "d": prob.d,
        })
    emit(rows, "fig1_single_worker")
    return rows


if __name__ == "__main__":
    run()
