"""Roofline report: reads results/dryrun/<mesh>/*.json (written by
repro.launch.dryrun) and emits the EXPERIMENTS.md §Roofline table +
hillclimb-candidate selection (worst roofline fraction / most
collective-bound / most representative of the paper's technique).

Also emits the fused-VR-step traffic section: the analytical HBM-traffic
model (``roofline.analysis.VR_TRAFFIC``) per VR mode, cross-checked
against XLA's ``compiled.cost_analysis()`` bytes for a single fused vs
unfused step — and ASSERTS the predicted reduction (the 5-read/4-write
fused launch vs the 9-read/4-write unfused chain for centralvr). The
measured side is only asserted on a compiled Pallas backend (TPU):
interpret-mode launches and CPU fusion make host-measured bytes an
estimate, recorded but exempt.

Runs as a subprocess suite under ``benchmarks/run.py`` (it initializes
jax for the traffic cross-check; the harness keeps suites isolated).
"""
from __future__ import annotations

import glob
import json
import os
import sys

try:
    import repro_bootstrap  # noqa: F401  (repo-root module/script form)
except ModuleNotFoundError:
    pass

from benchmarks.common import emit

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


def load(mesh: str = "pod"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, mesh, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def one_liner(r):
    """What would move the dominant term down."""
    rf = r["roofline"]
    b = rf["bottleneck"]
    if b == "compute":
        if rf["useful_fraction"] < 0.3:
            return ("compute-bound with low useful fraction: cut remat "
                    "recompute / redundant replicated compute (shard the "
                    "mixer over 'model')")
        return "compute-bound near useful peak: more chips or lower remat"
    if b == "memory":
        return ("memory-bound: bf16 the f32 elementwise pipes, fuse VR "
                "update (Pallas vr_update), larger microbatch per device")
    return ("collective-bound: raise CentralVR local_epoch K (fewer "
            "epoch-boundary exchanges), overlap FSDP gathers with compute")


def _measured_bytes(fn, *args):
    """XLA's static bytes-accessed for the jitted fn, or None when the
    backend's cost model does not report it (then the row is marked
    estimated-from-avals and not asserted)."""
    import jax
    try:
        ca = jax.jit(fn).lower(*args).compile().cost_analysis()
        if isinstance(ca, list):
            ca = ca[0] if ca else {}
        b = ca.get("bytes accessed")
        return None if b is None else float(b)
    except Exception:  # noqa: BLE001 — backend-dependent API surface
        return None


def vr_traffic_rows(quick: bool = False):
    """Predicted-vs-measured HBM traffic of one fused VR step per mode.

    Raises AssertionError when the analytical model stops predicting a
    traffic reduction (the tentpole's whole premise), or — on a compiled
    Pallas backend — when the measured fused/unfused byte ratio falls
    outside ±30% of it.
    """
    import jax
    import jax.numpy as jnp

    from repro import kernels
    from repro.kernels.vr_update import kernel as vrk
    from repro.kernels.vr_update import ref as vrref
    from repro.roofline import analysis

    interpret = kernels.default_interpret()
    n = vrk.TILE if quick else 4 * vrk.TILE
    x = jnp.zeros((n,), jnp.float32)
    args = (x, x, x, x, x)
    rows = []
    for mode in ("centralvr", "saga", "svrg"):
        saga = mode == "saga"
        pred_f = analysis.vr_step_traffic(n, mode, fused=True)
        pred_u = analysis.vr_step_traffic(n, mode, fused=False)
        ratio = analysis.vr_fused_traffic_ratio(mode)
        assert ratio > 1.0, (
            f"vr-traffic model predicts no reduction for {mode}: {ratio}")
        if mode in ("centralvr", "saga"):
            # the ISSUE-pinned floor: 5r/4w fused vs 9r/4w unfused
            assert ratio >= 13.0 / 9.0 - 1e-9, (mode, ratio)

        meas_f = _measured_bytes(
            lambda *a: vrk.vr_update_flat(*a, eta=0.1, m=n, saga=saga,
                                          interpret=interpret), *args)
        meas_u = _measured_bytes(
            lambda *a: vrref.vr_update_ref(*a, eta=0.1, m=n, saga=saga),
            *args)
        estimated = interpret or meas_f is None or meas_u is None
        meas_ratio = (meas_u / meas_f
                      if meas_f and meas_u else None)
        if not estimated and meas_ratio is not None:
            assert abs(meas_ratio - ratio) / ratio <= 0.30, (
                f"measured fused traffic ratio {meas_ratio:.2f} deviates "
                f">30% from the analytical {ratio:.2f} for {mode}")
        rows.append({
            "name": f"roofline/vr-traffic/{mode}",
            "us_per_call": 0,
            "mode": mode,
            "predicted_fused_bytes": pred_f["bytes"],
            "predicted_unfused_bytes": pred_u["bytes"],
            "predicted_ratio": ratio,
            "measured_fused_bytes": meas_f,
            "measured_unfused_bytes": meas_u,
            "measured_ratio": meas_ratio,
            "estimated": estimated,
            "interpret": interpret,
            "derived": (f"passes={pred_f['reads']}r/{pred_f['writes']}w vs "
                        f"{pred_u['reads']}r/{pred_u['writes']}w;"
                        f"predicted_ratio={ratio:.3f};measured_ratio="
                        + (f"{meas_ratio:.3f}" if meas_ratio else "n/a")
                        + (";estimated" if estimated else ";compiled")),
        })
    return rows


def run(quick: bool = False, mesh: str = "pod"):
    recs = load(mesh)
    rows = []
    for r in recs:
        rf = r["roofline"]
        t = {"compute": rf["t_compute"], "memory": rf["t_memory"],
             "collective": rf["t_collective"]}
        dom = max(t.values())
        frac = rf["t_compute"] / max(dom, 1e-12)  # roofline fraction
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}/{mesh}",
            "us_per_call": dom * 1e6,
            "derived": (f"bottleneck={rf['bottleneck']};"
                        f"Tc_ms={rf['t_compute'] * 1e3:.2f};"
                        f"Tm_ms={rf['t_memory'] * 1e3:.2f};"
                        f"Tx_ms={rf['t_collective'] * 1e3:.3f};"
                        f"useful={rf['useful_fraction']:.3f};"
                        f"roofline_frac={frac:.3f};"
                        f"peak_GiB={(rf['peak_memory_bytes'] or 0) / 2**30:.1f}"),
            "fix": one_liner(r),
            "record": {k: r.get(k) for k in
                       ("arch", "shape", "workers", "vr", "comm_every",
                        "compile_s", "window")},
        })
    if rows:
        # hillclimb candidate selection
        train_rows = [r for r in rows if "train" in r["name"] or
                      "train_4k" in r["name"]]
        by_frac = min(rows, key=lambda r: float(
            r["derived"].split("roofline_frac=")[1].split(";")[0]))
        by_coll = max(rows, key=lambda r: float(
            r["derived"].split("Tx_ms=")[1].split(";")[0]))
        rows.append({"name": "roofline/hillclimb-picks", "us_per_call": 0,
                     "derived": (f"worst_frac={by_frac['name']};"
                                 f"most_collective={by_coll['name']};"
                                 f"paper_representative=qwen2-7b/train_4k")})
    rows.extend(vr_traffic_rows(quick=quick))
    emit(rows, f"roofline_{mesh}")
    return rows


def run_isolated(quick: bool = False, mesh: str = "pod"):
    """Entry point for the ``benchmarks.run`` harness: fresh interpreter —
    the vr-traffic cross-check initializes jax, and the harness process
    must keep its device view untouched for the other suites (same rule
    as ``train_throughput.run_isolated``)."""
    import subprocess

    cmd = [sys.executable, "-m", "benchmarks.roofline_report"]
    if quick:
        cmd.append("--quick")
    proc = subprocess.run(cmd, cwd=ROOT, capture_output=True, text=True,
                          timeout=1800)
    sys.stdout.write(proc.stdout)
    if proc.returncode != 0:
        raise RuntimeError(
            f"roofline_report failed:\n{proc.stderr[-3000:]}")


def markdown_table(mesh: str = "pod") -> str:
    recs = load(mesh)
    lines = [
        "| arch | shape | mode | T_comp ms | T_mem ms | T_coll ms | "
        "bottleneck | useful | peak GiB/dev | what moves it |",
        "|" + "---|" * 10,
    ]
    for r in recs:
        rf = r["roofline"]
        peak = (rf.get("peak_memory_bytes") or 0) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['mode']} "
            f"| {rf['t_compute'] * 1e3:.1f} | {rf['t_memory'] * 1e3:.1f} "
            f"| {rf['t_collective'] * 1e3:.2f} | {rf['bottleneck']} "
            f"| {rf['useful_fraction']:.3f} | {peak:.1f} "
            f"| {one_liner(r)} |")
    return "\n".join(lines)


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
    print(markdown_table())
