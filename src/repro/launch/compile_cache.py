"""Persistent XLA compilation cache shared by every launcher.

First thin slice of the ROADMAP cold-start item: ``--compile-cache DIR``
(or ``REPRO_COMPILE_CACHE=DIR``) points JAX's persistent compilation
cache at a directory, so the second process-launch of the same program
deserializes executables instead of recompiling — the serve bench
records the cold-vs-warm delta per row.  Thresholds are zeroed so even
sub-second CPU test programs are cached (the default 1s floor would skip
everything the reduced configs compile).
"""
from __future__ import annotations

import os
from typing import Optional

ENV_VAR = "REPRO_COMPILE_CACHE"


def enable_compile_cache(path: Optional[str] = None) -> Optional[str]:
    """Enable the persistent cache at ``path`` (or $REPRO_COMPILE_CACHE).
    Returns the absolute cache dir, or None if neither is set.  Must run
    before the first compilation; safe to call more than once."""
    path = path or os.environ.get(ENV_VAR) or None
    if not path:
        return None
    path = os.path.abspath(path)
    os.makedirs(path, exist_ok=True)
    import jax
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    return path


def add_compile_cache_arg(parser) -> None:
    parser.add_argument("--compile-cache", default=None, metavar="DIR",
                        help="persistent XLA compilation cache dir "
                             f"(default: ${ENV_VAR} if set)")
