"""LM epoch-scan runtime pins (DESIGN.md §3 "LM epoch scan",
``train/step.make_epoch_runner`` + ``train/loop.py``).

Fast, in-process (single device, vmap backend, float32 tiny arch):

  * epoch-scan trajectories == the retained per-step host-loop reference
    (``train/host_loop.py``) within float32 tolerance, W in {1, 2} x
    vr in {none, centralvr, svrg};
  * the silent batch-accounting fallback is gone: indivisible
    global_batch raises ValueError;
  * held-out eval uses the worker-AVERAGED params, not worker 0's
    (pinned with a W>1 run stopped mid-epoch, workers diverged).

Slow, in a SUBPROCESS with 4 forced host devices (the main pytest
process must keep the real single-device view — see conftest): the spmd
backend must match vmap within float32 tolerance for W in {2, 4} with
each worker's state shard resident on its own device.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

# identical arithmetic, identical (stateless fold_in) data on both paths;
# only op fusion / collective reduction order may differ
TOL = dict(rtol=3e-5, atol=1e-6)


def tiny_cfg():
    from repro.config import ModelConfig

    return ModelConfig(name="tiny-scan", family="dense", num_layers=2,
                       d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
                       vocab_size=128, dtype="float32",
                       param_dtype="float32")


def tiny_tcfg(W, vr="centralvr", **kw):
    from repro.config import TrainConfig

    kw.setdefault("optimizer", "sgd")
    kw.setdefault("learning_rate", 0.1)
    return TrainConfig(seq_len=16, global_batch=2 * W, microbatch=2,
                       vr=vr, vr_table_size=2, local_epoch=1, **kw)


@pytest.mark.parametrize("W", [1, 2])
@pytest.mark.parametrize("vr", ["none", "centralvr", "svrg"])
def test_epoch_scan_matches_host_loop(W, vr):
    from repro.train import host_loop, loop

    cfg, tcfg = tiny_cfg(), tiny_tcfg(W, vr)
    E = tcfg.vr_table_size * tcfg.local_epoch
    ref = host_loop.run_training(cfg, tcfg, steps=2 * E, workers=W,
                                 log_every=0)
    scan = loop.run_training(cfg, tcfg, epochs=2, workers=W, log_every=0)
    assert scan.steps == ref.steps == 2 * E
    np.testing.assert_allclose(scan.losses, ref.losses, **TOL)
    np.testing.assert_allclose(scan.final_eval_loss, ref.final_eval_loss,
                               **TOL)


def test_epoch_scan_rejects_partial_epochs():
    from repro.train import loop

    with pytest.raises(ValueError, match="multiple of the communication"):
        loop.run_training(tiny_cfg(), tiny_tcfg(1), steps=3, log_every=0)


def test_unknown_backend_rejected():
    from repro.train import step as tstep

    with pytest.raises(ValueError, match="unknown backend"):
        tstep.make_epoch_runner(tiny_cfg(), tiny_tcfg(1), 1,
                                backend="pmap")


def test_indivisible_batch_raises():
    """The seed loop silently truncated accum to 1 when global_batch did
    not divide by W*microbatch; now it is a config error."""
    from repro.config import TrainConfig
    from repro.train import step as tstep

    bad = TrainConfig(seq_len=16, global_batch=6, microbatch=2)
    with pytest.raises(ValueError, match="not divisible"):
        tstep.batch_geometry(bad, 2)        # 6 % (2*2) != 0
    with pytest.raises(ValueError, match="not divisible"):
        tstep.batch_geometry(TrainConfig(global_batch=5, microbatch=0), 2)
    assert tstep.batch_geometry(TrainConfig(global_batch=8, microbatch=2),
                                2) == (2, 2)


def test_eval_uses_worker_average_not_worker0():
    """Stop a W=2 run mid-epoch (1 step into an M*K=2 epoch): the worker
    copies have diverged, and the reported eval loss must be computed at
    the central average, not worker 0's copy."""
    import jax

    from repro.data import synthetic
    from repro.models import model as modellib
    from repro.train import host_loop

    cfg, tcfg = tiny_cfg(), tiny_tcfg(2)
    res = host_loop.run_training(cfg, tcfg, steps=1, workers=2, log_every=0)
    p = res.state.params
    leaves = jax.tree_util.tree_leaves(p)
    spread = max(float(np.abs(np.asarray(l[0] - l[1])).max())
                 for l in leaves)
    assert spread > 0.0, "workers did not diverge mid-epoch"

    ev = synthetic.eval_batch(cfg, tcfg.seed, batch=2, seq=tcfg.seq_len)

    def eval_at(params):
        return float(modellib.loss_fn(params, cfg, {"tokens": ev},
                                      remat="none"))

    avg = jax.tree_util.tree_map(lambda l: (l[0] + l[1]) / 2.0, p)
    w0 = jax.tree_util.tree_map(lambda l: l[0], p)
    np.testing.assert_allclose(res.final_eval_loss, eval_at(avg), **TOL)
    assert abs(res.final_eval_loss - eval_at(w0)) > 1e-7


def test_resume_past_requested_epochs_rejected(tmp_path):
    """Resuming from a checkpoint at/past the requested epoch count must
    raise, not run zero epochs and relabel the checkpoint with an
    earlier step."""
    from repro.train import loop

    cfg, tcfg = tiny_cfg(), tiny_tcfg(1)
    path = str(tmp_path / "ck.npz")
    loop.run_training(cfg, tcfg, epochs=1, workers=1, checkpoint_path=path,
                      log_every=0)
    with pytest.raises(ValueError, match="nothing left"):
        loop.run_training(cfg, tcfg, epochs=1, workers=1,
                          checkpoint_path=path, resume=True, log_every=0)


def test_losses_device_resident_until_fetch():
    """The scan loop returns one (M*K,) loss array per epoch; the flat
    trajectory must cover every step exactly once."""
    from repro.train import loop

    cfg, tcfg = tiny_cfg(), tiny_tcfg(1)
    res = loop.run_training(cfg, tcfg, epochs=3, workers=1, log_every=0)
    assert len(res.losses) == 3 * tcfg.vr_table_size * tcfg.local_epoch
    assert res.epochs == 3
    assert all(np.isfinite(res.losses))


# ---------------------------------------------------------------------------
# SPMD backend (subprocess with forced host devices)
# ---------------------------------------------------------------------------

SCRIPT = textwrap.dedent("""
    import sys
    sys.path.insert(0, "src")
    from repro.core import spmd
    spmd.force_host_devices(4)      # before the first jax operation
    import json
    import jax
    import numpy as np
    from repro.config import ModelConfig, TrainConfig
    from repro.train import loop

    cfg = ModelConfig(name="tiny-scan", family="dense", num_layers=2,
                      d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
                      vocab_size=128, dtype="float32",
                      param_dtype="float32")
    out = {"device_count": jax.device_count(), "runs": []}
    for W in (2, 4):
        for vr in ("none", "centralvr"):
            tcfg = TrainConfig(seq_len=16, global_batch=2 * W,
                               microbatch=2, optimizer="sgd",
                               learning_rate=0.1, vr=vr, vr_table_size=2,
                               local_epoch=1)
            rv = loop.run_training(cfg, tcfg, epochs=2, workers=W,
                                   backend="vmap", log_every=0)
            rs = loop.run_training(cfg, tcfg, epochs=2, workers=W,
                                   backend="spmd", log_every=0)
            leaf = jax.tree_util.tree_leaves(rs.state.params)[0]
            devs = sorted({str(s.device)
                           for s in leaf.addressable_shards})
            out["runs"].append({
                "W": W, "vr": vr,
                "dloss": float(np.abs(np.array(rv.losses)
                                      - np.array(rs.losses)).max()),
                "deval": abs(rv.final_eval_loss - rs.final_eval_loss),
                "shard_devices": devs,
            })
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def spmd_results():
    proc = subprocess.run([sys.executable, "-c", SCRIPT], cwd=ROOT,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


@pytest.mark.slow
@pytest.mark.parametrize("W", [2, 4])
@pytest.mark.parametrize("vr", ["none", "centralvr"])
def test_spmd_backend_matches_vmap(spmd_results, W, vr):
    row = [r for r in spmd_results["runs"]
           if r["W"] == W and r["vr"] == vr][0]
    assert row["dloss"] < 3e-5, row
    assert row["deval"] < 3e-5, row


@pytest.mark.slow
@pytest.mark.parametrize("W", [2, 4])
def test_spmd_worker_state_on_distinct_devices(spmd_results, W):
    for row in [r for r in spmd_results["runs"] if r["W"] == W]:
        assert len(row["shard_devices"]) == W, row


def test_bench_artifact_structure():
    """BENCH_train.json (written by benchmarks/train_throughput.py)
    reports warm steps/sec per execution path per worker count, and the
    epoch scan clears 3x the host loop at W=4 — the acceptance artifact."""
    path = os.path.join(ROOT, "BENCH_train.json")
    assert os.path.exists(path), "run: python -m benchmarks.train_throughput"
    with open(path) as f:
        payload = json.load(f)
    rows = payload["rows"]
    for p in ("host", "host-steady", "scan-vmap", "scan-spmd"):
        for W in (1, 2, 4):
            match = [r for r in rows
                     if r["path"] == p and r["workers"] == W]
            assert match, (p, W)
            assert match[0]["steps_per_s"] > 0, match[0]
    assert payload["scan_3x_host_at_w4"], \
        [r["derived"] for r in rows if r["workers"] == 4]
