"""Training-runtime semantics on a single device:

  * VR wrapper state algebra (table cycling, anchor refresh, SVRG snapshot),
  * train_step with every vr mode makes progress and stays finite,
  * gradient accumulation == large-batch gradient,
  * checkpoint save/restore roundtrip,
  * data pipeline determinism (the finite-sum contract).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.config import TrainConfig, get_arch
from repro.data import synthetic
from repro.launch import mesh as meshlib
from repro.models import model
from repro.optim import vr_wrapper
from repro.train import step as tstep

tmap = jax.tree_util.tree_map


@pytest.fixture(scope="module")
def cfg():
    return get_arch("qwen2-7b").reduced()


def test_vr_state_cycle_and_anchor_refresh():
    params = {"w": jnp.zeros((3,), jnp.float32)}
    M = 3
    st = vr_wrapper.init_vr("centralvr", params, M)
    gs = [{"w": jnp.full((3,), float(i + 1))} for i in range(M)]
    # epoch 1: table fills; anchor stays zero until the epoch ends
    for i in range(M):
        v, st = vr_wrapper.correct("centralvr", st, gs[i], M)
        if i < M - 1:
            np.testing.assert_array_equal(np.asarray(st.gbar["w"]), 0.0)
    # after the epoch: gbar = mean of fresh grads = (1+2+3)/3 = 2
    np.testing.assert_allclose(np.asarray(st.gbar["w"]), 2.0)
    assert int(st.idx) == 0
    # epoch 2 corrections: v_i = g_i - table_i + gbar with table = g_i
    v, st2 = vr_wrapper.correct("centralvr", st, gs[0], M)
    np.testing.assert_allclose(np.asarray(v["w"]), 2.0)  # g - g + gbar


def test_vr_correction_unbiased_over_epoch():
    """Summed over one epoch, corrections == summed fresh gradients (the
    LM-scale analogue of Eq. 7's telescoping)."""
    key = jax.random.PRNGKey(0)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    M = 4
    st = vr_wrapper.init_vr("centralvr", params, M)
    # fill table (epoch 1)
    gs1 = [{"w": jax.random.normal(jax.random.fold_in(key, i), (4,))}
           for i in range(M)]
    for g in gs1:
        _, st = vr_wrapper.correct("centralvr", st, g, M)
    gs2 = [{"w": jax.random.normal(jax.random.fold_in(key, 100 + i), (4,))}
           for i in range(M)]
    vsum = jnp.zeros((4,))
    for g in gs2:
        v, st = vr_wrapper.correct("centralvr", st, g, M)
        vsum = vsum + v["w"]
    expected = sum(g["w"] for g in gs2)  # corrections telescope:
    # sum(g_i - old_i + gbar) = sum(g_i) - M*gbar + M*gbar
    # float32 state + different summation orders: ~1e-5 relative is the
    # achievable agreement (the identity is exact in real arithmetic)
    np.testing.assert_allclose(np.asarray(vsum), np.asarray(expected),
                               rtol=1e-4, atol=1e-7)


def test_svrg_snapshot_refresh():
    params = {"w": jnp.ones((2,), jnp.float32)}
    M = 2
    st = vr_wrapper.init_vr("svrg", params, M)
    g = {"w": jnp.ones((2,))}
    v, st = vr_wrapper.correct("svrg", st, g, M, g_snap=g, params=params)
    np.testing.assert_allclose(np.asarray(v["w"]), 0.0)  # g - g + 0
    new_params = {"w": jnp.full((2,), 5.0, jnp.float32)}
    v, st = vr_wrapper.correct("svrg", st, g, M, g_snap=g,
                               params=new_params)
    # epoch ended: snapshot <- new params
    np.testing.assert_allclose(np.asarray(st.snapshot["w"]), 5.0)


@pytest.mark.slow
@pytest.mark.parametrize("vr", ["none", "centralvr", "svrg", "saga"])
def test_train_step_modes_make_progress(cfg, vr):
    tcfg = TrainConfig(optimizer="sgd", learning_rate=0.1, vr=vr,
                       vr_table_size=4, local_epoch=1)
    mesh = meshlib.make_test_mesh()
    train_step, meta = tstep.make_train_step(cfg, tcfg, mesh, "none")
    assert meta["grads_per_step"] == (2 if vr == "svrg" else 1)
    state = tstep.init_train_state(cfg, tcfg, jax.random.PRNGKey(0), 1)
    js = jax.jit(train_step)
    losses = []
    for s in range(8):
        toks = synthetic.epoch_batch(cfg, 0, s, workers=1, accum=1,
                                     microbatch=2, seq=32, table_size=4)[0]
        state, m = js(state, toks)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], (vr, losses)


@pytest.mark.slow
def test_grad_accumulation_matches_big_batch(cfg):
    """(A=4, mb=1) accumulated gradient == (A=1, mb=4) gradient."""
    import dataclasses
    cfg32 = dataclasses.replace(cfg, dtype="float32")
    tcfg = TrainConfig()
    params = model.init_params(cfg32, jax.random.PRNGKey(0))
    toks = synthetic.microbatch_tokens(cfg32, 0, 0, 0, 4, 32)

    _, g_acc = tstep._local_grads(params, cfg32, tcfg,
                                  toks.reshape(4, 1, 32), None)
    _, g_big = tstep._local_grads(params, cfg32, tcfg,
                                  toks.reshape(1, 4, 32), None)
    flat_a = jax.tree_util.tree_leaves(g_acc)
    flat_b = jax.tree_util.tree_leaves(g_big)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)


def test_data_pipeline_finite_sum_contract(cfg):
    """microbatch (w, i) is IDENTICAL across epochs; different (w, i) differ."""
    a = synthetic.microbatch_tokens(cfg, 0, 1, 2, 2, 16)
    b = synthetic.microbatch_tokens(cfg, 0, 1, 2, 2, 16)
    c = synthetic.microbatch_tokens(cfg, 0, 1, 3, 2, 16)
    d = synthetic.microbatch_tokens(cfg, 0, 2, 2, 2, 16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert not np.array_equal(np.asarray(a), np.asarray(d))
    # step k uses index k mod M
    e1 = synthetic.epoch_batch(cfg, 0, 1, workers=1, accum=1, microbatch=2,
                               seq=16, table_size=4)
    e2 = synthetic.epoch_batch(cfg, 0, 5, workers=1, accum=1, microbatch=2,
                               seq=16, table_size=4)
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))


def test_checkpoint_roundtrip(cfg, tmp_path):
    tcfg = TrainConfig(optimizer="adam", vr="centralvr", vr_table_size=2)
    state = tstep.init_train_state(cfg, tcfg, jax.random.PRNGKey(0), 1)
    path = os.path.join(tmp_path, "ck.npz")
    ckpt.save(path, state, step=7)
    assert ckpt.latest_step(path) == 7
    restored = ckpt.restore(path, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_centralvr_sane_vs_sgd_lm_scale(cfg):
    """Sanity bound on the LM substrate: CentralVR's corrected updates stay
    in the same convergence regime as plain SGD over a short run (within
    2x) and strictly decrease. VR's ADVANTAGE appears near convergence —
    that claim is validated faithfully on the paper's own convex problems
    (tests/test_paper_invariants.py, benchmarks/fig1); early steep-descent
    LM steps are not the paper's comparison regime."""
    def run(vr):
        tcfg = TrainConfig(optimizer="sgd", learning_rate=0.2, vr=vr,
                           vr_table_size=4, local_epoch=1)
        mesh = meshlib.make_test_mesh()
        ts, _ = tstep.make_train_step(cfg, tcfg, mesh, "none")
        state = tstep.init_train_state(cfg, tcfg, jax.random.PRNGKey(0), 1)
        js = jax.jit(ts)
        losses = []
        for s in range(24):
            toks = synthetic.epoch_batch(cfg, 0, s, workers=1, accum=1,
                                         microbatch=2, seq=32, table_size=4)[0]
            state, m = js(state, toks)
            losses.append(float(m["loss"]))
        return losses

    cvr = run("centralvr")
    sgd = run("none")
    assert cvr[-1] < cvr[0]
    assert np.mean(cvr[-4:]) <= np.mean(sgd[-4:]) * 2.0
