"""Elastic execution for the asynchronous wave runtime (DESIGN.md
§Multi-host & elasticity).

The paper's schedule machinery already models workers that run at
different *speeds*; this module models workers that *disappear* (and come
back).  Membership changes take effect only at wave boundaries — a
metric round of p events is the coarsest wave group, and every round
boundary is a wave boundary — so a dropped worker's last completed wave
is fully applied and its unstarted events are simply never scheduled.

Determinism contract under repartition (pinned by ``tests/test_elastic.py``
and re-implemented process-parallel by ``core/procmesh.py``):

  * **survivor schedule** — the remaining rounds are re-planned with
    ``runtime.event_schedule(p_new, rounds_left, survivor speeds)`` over
    the surviving workers in ascending original-id order (the k-th
    smallest survivor becomes compact slot k;
    ``runtime.repartition_schedule``).  Nothing about the new schedule
    depends on *when* the failure was detected, only on the boundary
    round at which it took effect.
  * **state handover** — the central pair ``(x_c, gbar_c)`` is retained;
    the VR tables are re-sharded through their merged ``(n,)`` layout
    (global sample order is invariant under contiguous resharding); every
    per-worker fetch/old vector is RESYNCED to the central values —
    exactly the ``async_init`` construction, so the first post-change
    event of each worker contributes ``x_new - x_c`` and nothing is
    double-counted.  ``resync_state`` is that construction in one place.
  * **continuation RNG** — the shape segment beginning at round r draws
    its event keys from ``fold_in(fold_in(k_run, r), p_new)``
    (``segment_plan``), so an elastic run and a fresh run started at the
    new shape from the handed-over state consume identical randomness:
    the post-dropout trajectory of ``run_async_elastic`` is bit-equal to
    ``continue_async`` at the surviving worker count.

Telemetry: membership transitions emit ``worker_lost`` /
``worker_joined`` / ``repartition`` events against the active
``repro.obs`` recorder (required fields pinned in ``obs/schema.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import convex, runtime
from repro.core.distributed import (AsyncState, ShardedProblem, _async_scan,
                                    async_init, shard_problem)
from repro.obs import recorder as obs_recorder


# ---------------------------------------------------------------------------
# Membership as data
# ---------------------------------------------------------------------------

class PlannedMembership:
    """Deterministic membership: ``{round: live original worker ids}``.

    The simulation analogue of the heartbeat layer in ``core/procmesh.py``
    — tests and the launcher's ``--verify`` reference replay an observed
    fault plan through this class.  Round 0 must start with the full
    fleet; every planned shape is validated (non-empty, ids in range,
    no duplicates) before any JAX work.
    """

    def __init__(self, p: int,
                 plan: Optional[Dict[int, Sequence[int]]] = None):
        self.p = int(p)
        full = tuple(range(self.p))
        entries = {0: full}
        for r, live in (plan or {}).items():
            live_t = tuple(sorted(int(s) for s in live))
            if not live_t:
                raise ValueError(
                    f"PlannedMembership: round {r} leaves no live workers")
            if len(set(live_t)) != len(live_t):
                raise ValueError(
                    f"PlannedMembership: duplicate worker ids at round {r}: "
                    f"{live}")
            if live_t[0] < 0 or live_t[-1] >= self.p:
                raise ValueError(
                    f"PlannedMembership: worker ids at round {r} out of "
                    f"range for p={self.p}: {live}")
            entries[int(r)] = live_t
        if entries[0] != full:
            raise ValueError(
                "PlannedMembership: round 0 must start with the full fleet "
                f"(0..{self.p - 1}); drop/rejoin at later boundaries")
        self._plan = dict(sorted(entries.items()))

    def live(self, round_: int) -> Tuple[int, ...]:
        """Live original worker ids in effect at ``round_``."""
        out = self._plan[0]
        for r, live in self._plan.items():
            if r <= round_:
                out = live
            else:
                break
        return out

    def change_rounds(self) -> Tuple[int, ...]:
        return tuple(self._plan)


# ---------------------------------------------------------------------------
# Reshard / resync — the state-handover algebra
# ---------------------------------------------------------------------------

def reshard_problem(sp: ShardedProblem, p_new: int) -> ShardedProblem:
    """Contiguously re-shard the GLOBAL dataset over ``p_new`` workers.

    The merged sample order is invariant, so the global objective (and the
    rel-grad-norm metric) is unchanged; ``n`` must divide evenly — a
    silent truncation would change the objective mid-run."""
    merged = sp.merged()
    if merged.n % p_new:
        raise ValueError(
            f"elastic reshard: n={merged.n} samples do not divide over "
            f"p={p_new} workers; pick worker counts that divide n")
    return shard_problem(merged, p_new)


def merge_tables(tables) -> np.ndarray:
    """Per-worker ``(p, ns)`` VR tables -> the merged ``(n,)`` layout in
    global sample order (contiguous shards concatenate in worker order)."""
    return np.asarray(tables).reshape(-1)


def resync_state(x_c, gbar_c, table, p_new: int) -> AsyncState:
    """The wave-boundary handover state at a new shape: central pair
    retained, merged table re-sharded, per-worker fetch/old vectors reset
    to the central values (the ``async_init`` construction — the workers'
    "previous contribution" equals the current central state, so the
    first post-change events do not double-count it)."""
    table = jnp.asarray(table).reshape(-1)
    if table.shape[0] % p_new:
        raise ValueError(
            f"elastic reshard: n={table.shape[0]} table entries do not "
            f"divide over p={p_new} workers")
    x_c = jnp.asarray(x_c)
    gbar_c = jnp.asarray(gbar_c)
    return AsyncState(
        x_c=x_c, gbar_c=gbar_c, tables=table.reshape(p_new, -1),
        x_old=jnp.tile(x_c, (p_new, 1)),
        gbar_old=jnp.tile(gbar_c, (p_new, 1)),
        x_fetch=jnp.tile(x_c, (p_new, 1)),
        gbar_fetch=jnp.tile(gbar_c, (p_new, 1)))


def survivor_speeds(speeds, live: Sequence[int]):
    """Compact per-slot speeds for the surviving fleet (speeds stay
    indexed by ORIGINAL worker id so a rejoining worker gets its own speed
    back)."""
    if speeds is None:
        return None
    return tuple(float(speeds[s]) for s in live)


# ---------------------------------------------------------------------------
# Deterministic continuation plan
# ---------------------------------------------------------------------------

def segment_plan(k_run, start_round: int, rounds: int, p: int, speeds=None):
    """``(sched_rows, key_rows)`` for the shape segment beginning at
    ``start_round``: the event schedule over the remaining rounds at width
    p, with per-event keys drawn from the continuation stream
    ``fold_in(fold_in(k_run, start_round), p)`` (round 0 consumes
    ``k_run`` itself, so a never-interrupted elastic run is bit-identical
    to ``run_async``)."""
    if start_round == 0:
        k_seg = k_run
    else:
        k_seg = jax.random.fold_in(jax.random.fold_in(k_run, start_round), p)
    schedule = runtime.event_schedule(p, rounds - start_round, speeds)
    keys = jax.random.split(k_seg, schedule.size)
    return runtime.per_round(schedule, keys, p)


def continue_async(sp: ShardedProblem, st: AsyncState, *, eta: float,
                   g0, start_round: int, rounds: int, k_run,
                   speeds=None):
    """The UNINTERRUPTED run at the (possibly new) shape from a
    handed-over state — the trajectory every elastic/dropout pin compares
    against.  ``speeds`` are the compact per-slot speeds of this shape.
    Returns ``(state, rels)`` for rounds ``start_round..rounds``."""
    sched_rows, key_rows = segment_plan(k_run, start_round, rounds, sp.p,
                                        speeds)
    # _async_scan donates its state; keep the caller's copy intact
    st = jax.tree_util.tree_map(jnp.array, st)
    return _async_scan(sp, st, eta, g0, jnp.asarray(sched_rows), key_rows)


# ---------------------------------------------------------------------------
# The elastic event-serial engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ElasticResult:
    """Uniform elastic return: full metric trajectory (one entry per
    round, across every shape), final state + live set, and the
    membership transitions that actually took effect."""

    rels: np.ndarray
    state: AsyncState
    live: Tuple[int, ...]
    transitions: List[dict]

    @property
    def final_rel(self) -> float:
        return float(self.rels[-1])


def _emit_transition(rec, r: int, live_old, live_new, detect_s: float):
    lost = sorted(set(live_old) - set(live_new))
    joined = sorted(set(live_new) - set(live_old))
    if rec is not None:
        for s in lost:
            rec.event("worker_lost", worker=int(s), round=int(r),
                      detect_s=float(detect_s))
        for s in joined:
            rec.event("worker_joined", worker=int(s), round=int(r))
        rec.event("repartition", round=int(r), p_old=len(live_old),
                  p_new=len(live_new), survivors=[int(s) for s in live_new])
    return {"round": int(r), "p_old": len(live_old), "p_new": len(live_new),
            "lost": [int(s) for s in lost],
            "joined": [int(s) for s in joined],
            "live": [int(s) for s in live_new]}


def run_async_elastic(sp: ShardedProblem, *, eta: float, rounds: int, key,
                      membership: Optional[PlannedMembership] = None,
                      speeds=None, checkpoint_dir: Optional[str] = None,
                      checkpoint_every: int = 0) -> ElasticResult:
    """CentralVR-Async (Algorithm 3) under a deterministic membership
    plan: the event-serial reference for elastic execution.

    With the default (constant) membership this is bit-identical to
    ``run_async(..., backend="vmap")``; at each planned change the engine
    re-partitions per the module contract above.  ``checkpoint_dir``
    saves a mesh-shape-portable checkpoint (``checkpoint/elastic.py``) at
    every repartition boundary and, when ``checkpoint_every`` is set, at
    that round cadence too."""
    p0 = sp.p
    membership = membership or PlannedMembership(p0)
    if membership.p != p0:
        raise ValueError(
            f"membership plan is for p={membership.p}, problem has p={p0}")
    if speeds is not None and len(speeds) != p0:
        raise ValueError(
            f"speeds must have one entry per original worker (p={p0}), "
            f"got {len(speeds)}")
    # pre-JAX validation of every planned shape
    n = p0 * sp.ns
    for r in membership.change_rounds():
        reshard_ok = n % len(membership.live(r)) == 0
        if not reshard_ok:
            raise ValueError(
                f"elastic reshard: membership at round {r} has "
                f"p={len(membership.live(r))}, which does not divide "
                f"n={n}")

    k_init, k_run = jax.random.split(key)
    merged = sp.merged()
    g0 = convex.grad_norm0(merged)
    st = async_init(sp, eta, k_init)
    live = tuple(range(p0))
    sp_cur = sp

    stops = {rounds}
    stops.update(c for c in membership.change_rounds() if 0 < c < rounds)
    if checkpoint_dir and checkpoint_every:
        stops.update(range(checkpoint_every, rounds, checkpoint_every))
    rec = obs_recorder.active()
    transitions: List[dict] = []
    rels_out: List[np.ndarray] = []
    sched_rows = key_rows = None
    seg_start = 0
    r = 0
    for stop in sorted(stops):
        new_live = membership.live(r)
        if new_live != live:
            transitions.append(
                _emit_transition(rec, r, live, new_live, 0.0))
            table = merge_tables(st.tables)
            sp_cur = reshard_problem(sp, len(new_live))
            st = resync_state(st.x_c, st.gbar_c, table, len(new_live))
            live = new_live
            seg_start = r
            sched_rows = None
        if sched_rows is None:
            sched_rows, key_rows = segment_plan(
                k_run, seg_start, rounds, len(live),
                survivor_speeds(speeds, live))
        lo, hi = r - seg_start, stop - seg_start
        st, rels = _async_scan(sp_cur, st, eta, g0,
                               jnp.asarray(sched_rows[lo:hi]),
                               key_rows[lo:hi])
        rels_out.append(np.asarray(rels))
        r = stop
        if checkpoint_dir and r < rounds:
            from repro.checkpoint import elastic as ckpt
            ckpt.save_elastic(f"{checkpoint_dir}/elastic_{r:05d}", st,
                              round_=r, live=live, p0=p0)
    return ElasticResult(rels=np.concatenate(rels_out), state=st,
                         live=live, transitions=transitions)
