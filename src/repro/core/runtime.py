"""Device-resident driver runtime shared by every ``run_*`` driver.

The seed drivers ran a Python loop on the host: one jitted round closure,
one blocking ``float(rel)`` device->host transfer per round, and — for the
event-driven algorithms (CentralVR-Async, D-SAGA) — p separately jitted
per-worker closures, so compile time grew linearly in p, the very axis the
paper scales.  This module holds the pieces that let each driver become ONE
jitted ``lax.scan`` instead (DESIGN.md §3):

  * the event schedule is precomputed on the host (speed-weighted for the
    heterogeneous-cluster simulation) and shipped to the device as a
    ``(rounds, p)`` int32 array; the event function takes a *traced*
    worker index, so one executable serves every worker;
  * the relative-grad-norm metric is computed inside the scan and the whole
    trajectory comes back in a single transfer at the end of the run;
  * the state pytree is donated into the scan runner
    (``donate_argnames``), so param-, table-, and gbar-sized buffers are
    updated in place instead of being copied each round;
  * ``TRACES`` counts how many times each event/round body is traced —
    Python code in a traced function runs once per compile and zero times
    on a cache hit, so the counter is an exact retrace/compile probe
    (pinned by ``tests/test_driver_runtime.py``: one trace of the async
    event function regardless of p).
"""
from __future__ import annotations

import contextlib
import threading
from collections import Counter

import numpy as np


class _TraceCounter(Counter):
    """``Counter`` with an atomic :meth:`inc` and a consistent
    :meth:`snapshot`.  Trace-time Python runs on whatever thread asked for
    the executable — concurrent compiles (the spmd factories are
    lru-cached and jit compilation can be driven from worker threads, and
    the obs streaming callbacks fire from XLA runtime threads) must not
    lose probe increments to the read-modify-write race of ``c[k] += 1``.
    """

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._lock = threading.Lock()

    def inc(self, key: str, n: int = 1) -> None:
        with self._lock:
            self[key] = self.get(key, 0) + n

    def snapshot(self) -> dict:
        with self._lock:
            return dict(self)

    def clear(self) -> None:     # keep tests' TRACES.clear() atomic too
        with self._lock:
            super().clear()


# Trace/compile probe: incremented (``TRACES.inc(name)``) from inside scan
# bodies at trace time.
TRACES: _TraceCounter = _TraceCounter()


@contextlib.contextmanager
def traces_delta():
    """Scoped view of the trace probe: yields a dict that on exit holds
    the per-key increments that occurred inside the block.  Replaces the
    hand-rolled ``before = dict(TRACES)`` / subtract-after pattern in
    ``solve()`` and the drivers' tests."""
    before = TRACES.snapshot()
    delta: dict = {}
    try:
        yield delta
    finally:
        after = TRACES.snapshot()
        for k, v in after.items():
            d = v - before.get(k, 0)
            if d:
                delta[k] = d


def event_schedule(p: int, rounds: int, speeds=None) -> np.ndarray:
    """The asynchronous arrival order as data: a ``(rounds * p,)`` int32
    worker-index array.  ``speeds=None`` gives round-robin (effective
    staleness p-1); otherwise faster workers fire proportionally more
    events — the deterministic simulation of a heterogeneous cluster.
    Precomputed on the host once; the device scans it in one compile.

    Vectorized as a sorted merge of per-worker arrival streams: worker s's
    k-th event lands at cumsum_k(1/speeds[s]), and the greedy
    pick-the-earliest loop is exactly the (time, worker)-lexicographic
    merge of those streams.  ``np.cumsum`` accumulates sequentially, the
    same float additions as the seed loop's ``t_next[s] += 1/speeds[s]``,
    so ties — and therefore the output — are byte-identical to
    ``_event_schedule_loop`` (pinned by ``tests/test_driver_runtime.py``)
    while dropping the O(rounds·p) host loop per driver call.
    """
    if speeds is None:
        return np.tile(np.arange(p, dtype=np.int32), rounds)
    speeds = np.asarray(speeds, dtype=float)
    if speeds.shape != (p,):
        raise ValueError(f"speeds must have shape ({p},), got {speeds.shape}")
    total = rounds * p
    # Cap each worker's candidate stream: the time of the last popped
    # event is at most tau = (total + p)/sum(speeds) (every worker j has
    # at least floor(tau*speed_j) arrivals before tau, and those already
    # sum to >= total), so no worker can win more than
    # ceil(tau*speed_max) slots.  +4 slack absorbs float accumulation
    # drift.  This keeps the merge O(total) memory for near-uniform
    # speeds instead of O(total*p); only a worker fast enough to win most
    # slots pushes the cap back toward `total`.
    cap = int(np.ceil((total + p) * speeds.max() / speeds.sum())) + 4
    m = min(total, cap)
    # (p, m) arrival times: row s is the times worker s could fire
    step = np.broadcast_to((1.0 / speeds)[:, None], (p, m))
    arrivals = np.cumsum(step, axis=1)
    workers = np.broadcast_to(
        np.arange(p, dtype=np.int32)[:, None], (p, m))
    # primary key: arrival time; tie-break: lowest worker index (argmin's
    # first-minimum rule in the seed loop)
    order = np.lexsort((workers.ravel(), arrivals.ravel()))
    return np.ascontiguousarray(workers.ravel()[order[:total]])


def repartition_schedule(survivors, rounds: int, speeds=None):
    """The deterministic survivor schedule after an elastic membership
    change (DESIGN.md §Multi-host & elasticity): the k-th smallest
    surviving ORIGINAL worker id becomes compact slot k, and the
    remaining ``rounds`` are re-planned as a fresh ``event_schedule`` at
    the new width from the survivors' own speeds (``speeds`` stays
    indexed by original id).  Returns ``(schedule, id_map)`` where
    ``schedule`` is over compact slots and ``id_map[slot]`` is the
    original worker id — nothing depends on when the failure was
    detected, only on the boundary it took effect at."""
    id_map = np.asarray(sorted(int(s) for s in survivors), dtype=np.int32)
    if id_map.size == 0:
        raise ValueError("repartition_schedule: no survivors")
    if np.unique(id_map).size != id_map.size:
        raise ValueError(f"repartition_schedule: duplicate survivor ids "
                         f"{survivors}")
    sub = None if speeds is None else [float(speeds[s]) for s in id_map]
    return event_schedule(id_map.size, rounds, sub), id_map


def _event_schedule_loop(p: int, rounds: int, speeds) -> np.ndarray:
    """Seed implementation of the speed-weighted schedule, kept verbatim as
    the byte-identical reference for the vectorized merge above."""
    speeds = np.asarray(speeds, dtype=float)
    if speeds.shape != (p,):
        raise ValueError(f"speeds must have shape ({p},), got {speeds.shape}")
    t_next = 1.0 / speeds
    schedule = np.empty(rounds * p, dtype=np.int32)
    for t in range(rounds * p):
        s = int(np.argmin(t_next))
        schedule[t] = s
        t_next[s] += 1.0 / speeds[s]
    return schedule


def wave_partition(schedule: np.ndarray, p: int):
    """Partition a flat event schedule into *concurrency waves* for the
    spmd-async backend (DESIGN.md §2): within each metric round (p
    consecutive events) the events are grouped greedily into maximal waves
    that contain each worker at most once.  A worker's local epoch depends
    only on the central state it fetched at its OWN previous event, never
    on the other events of its wave, so all events of a wave can execute
    concurrently under ``shard_map``; the delta pushes are then applied at
    the wave boundary in event order (the rank below).  Round-robin
    schedules produce exactly one wave per round; heterogeneous-speed
    schedules split a round wherever a worker fires twice.

    Returns ``(active, rank, slot)``:

      * ``active``: ``(rounds, W, p)`` bool — worker s fires in wave w of
        round r (W = max waves per round; padded waves are all-inactive);
      * ``rank``: ``(rounds, W, p)`` int32 — the event's position within
        its wave (the prefix order of the stale-fetch construction);
        ``p`` sentinel where inactive;
      * ``slot``: ``(rounds * p,)`` int64 — flat wave index ``r * W + w``
        of event t, so per-event host-precomputed RNG draws can be
        scattered to their (round, wave, worker) slot.

    Concatenating the waves in order — each wave's workers sorted by rank
    — reproduces ``schedule`` byte-identically (``wave_flatten``, pinned
    by ``tests/test_driver_runtime.py``)."""
    schedule = np.asarray(schedule, dtype=np.int32)
    if schedule.size % p:
        raise ValueError(
            f"schedule size {schedule.size} is not a multiple of p={p}")
    rounds = schedule.size // p
    sched = schedule.reshape(rounds, p)
    per_round_waves = []
    for r in range(rounds):
        waves = [[]]
        seen: set = set()
        for s in sched[r].tolist():
            if s in seen:
                waves.append([])
                seen = set()
            seen.add(s)
            waves[-1].append(s)
        per_round_waves.append(waves)
    width = max(len(w) for w in per_round_waves)
    active = np.zeros((rounds, width, p), dtype=bool)
    rank = np.full((rounds, width, p), p, dtype=np.int32)
    slot = np.empty(schedule.size, dtype=np.int64)
    t = 0
    for r, waves in enumerate(per_round_waves):
        for w, wave in enumerate(waves):
            for k, s in enumerate(wave):
                active[r, w, s] = True
                rank[r, w, s] = k
                slot[t] = r * width + w
                t += 1
    return active, rank, slot


def wave_flatten(active: np.ndarray, rank: np.ndarray) -> np.ndarray:
    """Inverse of :func:`wave_partition`: the flat event schedule implied
    by the wave arrays — the byte-identical-order pin."""
    rounds, width, _ = active.shape
    out = []
    for r in range(rounds):
        for w in range(width):
            workers = np.nonzero(active[r, w])[0]
            out.extend(workers[np.argsort(rank[r, w, workers])].tolist())
    return np.asarray(out, dtype=np.int32)


def per_round(schedule: np.ndarray, keys, p: int):
    """Reshape a flat event schedule + per-event keys into per-round rows
    ``(rounds, p, ...)`` so an outer scan over rounds (emitting the metric)
    can nest an inner scan over the round's p events."""
    rounds = schedule.size // p
    sched = schedule.reshape(rounds, p)
    keys = keys.reshape((rounds, p) + keys.shape[1:])
    return sched, keys
