"""Pallas TPU kernels (validated on CPU via interpret=True against the
ref.py oracles):

  vr_update/       fused CentralVR/SAGA update (the paper's hot loop)
  flash_attention/ causal GQA flash attention (online softmax, windows)
  rmsnorm/         fused RMSNorm
  ssd_scan/        fused Mamba2 SSD chunk scan (state in VMEM scratch)

Entry points are re-exported lazily (``import repro.kernels`` stays cheap
and free of circular-import hazards):

  * ``vr_update``        — fused VR correction + step + table/anchor write
                           (pytree level, donating jit)
  * ``vr_update_inline`` — same math, un-jitted, for call sites already
                           inside a jit (the LM epoch scan)
  * ``flash_attention``  — online-softmax causal attention
  * ``rmsnorm``          — row-wise RMS normalization
  * ``ssd_scan``         — chunked SSD state-space scan

``has_pallas_support()`` / ``default_interpret()`` / ``resolve_fused()``
centralize the CPU-interpret fallback so every ``fused="auto"`` caller
agrees on the dispatch.
"""
from __future__ import annotations

import jax

__all__ = [
    "vr_update", "vr_update_inline", "flash_attention", "rmsnorm",
    "ssd_scan", "has_pallas_support", "default_interpret", "resolve_fused",
]

_LAZY = {
    "vr_update": ("repro.kernels.vr_update.ops", "vr_update"),
    "vr_update_inline": ("repro.kernels.vr_update.ops", "vr_update_inline"),
    "flash_attention": ("repro.kernels.flash_attention.ops",
                        "flash_attention"),
    "rmsnorm": ("repro.kernels.rmsnorm.ops", "rmsnorm"),
    "ssd_scan": ("repro.kernels.ssd_scan.ops", "ssd_scan"),
}


def __getattr__(name):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(name) from None
    import importlib
    value = getattr(importlib.import_module(mod_name), attr)
    globals()[name] = value        # cache for subsequent lookups
    return value


def has_pallas_support() -> bool:
    """True when the default backend compiles Pallas kernels natively.

    Mosaic lowering exists for TPU; everywhere else (the CPU test/CI
    environment) the kernels run in interpret mode.
    """
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """The interpret= value matching the current backend."""
    return not has_pallas_support()


def resolve_fused(flag):
    """Resolve a ``fused=True|False|"auto"`` flag to (fused, interpret).

    * True   -> fused everywhere; interpret-mode fallback on CPU (slow but
                exact — used by the agreement tests).
    * "auto" -> fused only where the kernels compile natively.
    * False  -> unfused oracle path.
    """
    if flag == "auto":
        return has_pallas_support(), False
    if flag is True:
        return True, default_interpret()
    if flag is False or flag is None:
        return False, False
    raise ValueError(
        f"fused must be True, False or 'auto', got {flag!r}")
