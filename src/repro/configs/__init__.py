"""Architecture registry: importing this package registers every assigned arch.

Each module defines exactly one :class:`repro.config.ModelConfig` with the
numbers from its source paper / model card (cited in the module docstring)
and calls :func:`repro.config.register`.
"""
from repro.configs import (  # noqa: F401
    paper_convex,
    qwen2_7b,
    internvl2_26b,
    mamba2_130m,
    qwen3_14b,
    musicgen_large,
    qwen3_moe_30b_a3b,
    starcoder2_15b,
    recurrentgemma_2b,
    qwen2_moe_a2_7b,
    qwen1_5_110b,
)

ASSIGNED_ARCHS = (
    "qwen2-7b",
    "internvl2-26b",
    "mamba2-130m",
    "qwen3-14b",
    "musicgen-large",
    "qwen3-moe-30b-a3b",
    "starcoder2-15b",
    "recurrentgemma-2b",
    "qwen2-moe-a2.7b",
    "qwen1.5-110b",
)
