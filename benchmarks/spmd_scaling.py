"""Scaling of the CentralVR drivers per execution backend: the vmap
single-device simulation vs the shard_map SPMD backend with one worker per
(CPU-simulated) device (DESIGN.md §2).

For each worker count p we measure cold (compile-inclusive) and warm wall
clock of a fixed-round CentralVR-Sync run (algo "sync") AND of
CentralVR-Async (algo "async" — the spmd side executes the event schedule
as concurrency waves), each cell one declarative
``repro.solve(RunSpec(...))`` call whose ``RunResult.provenance()`` is
embedded in its artifact row.  Writes ``BENCH_spmd.json`` at the repo
root (the acceptance artifact: per-algo per-backend epochs/sec for
p in {1, 2, 4}) plus the standard results CSV.

Must start in a fresh process: it forces 4 simulated host devices through
``spmd.force_host_devices`` before the first jax operation, so BOTH
backends run under the same 4-device CPU platform (the honest comparison —
on one real CPU the spmd backend pays real cross-device collective and
dispatch overhead, which is the point of measuring it).

    PYTHONPATH=src python -m benchmarks.spmd_scaling [--quick]
"""
from __future__ import annotations

import json
import os

try:
    import repro_bootstrap  # noqa: F401  (repo-root module/script form)
except ModuleNotFoundError:
    pass  # installed form: repro resolves without the fallback

ROOT = os.path.join(os.path.dirname(__file__), "..")

WORKER_COUNTS = (1, 2, 4)
BACKENDS = ("vmap", "spmd")
ALGOS = ("centralvr_sync", "centralvr_async")


def run(quick: bool = False):
    from repro.core import spmd

    spmd.force_host_devices(max(WORKER_COUNTS))
    import jax

    from benchmarks.common import emit, timed_cold_warm
    from repro import RunSpec, solve
    from repro.config import ConvexConfig
    from repro.core import convex, distributed

    n, d = (128, 16) if quick else (256, 64)
    rounds = 4 if quick else 8
    repeat = 2 if quick else 3
    rows = []

    for p in WORKER_COUNTS:
        cfg = ConvexConfig(problem="logistic", n=n, d=d, workers=p)
        sp = distributed.make_distributed(jax.random.PRNGKey(2), cfg)
        eta = convex.auto_eta(sp.merged(), 0.3)
        for algo in ALGOS:
            short = algo.replace("centralvr_", "")
            for backend in BACKENDS:
                # one declarative spec per measured cell; the async spmd
                # side is the wave-parallel staleness construction
                spec = RunSpec(algo=algo, p=p, eta=eta, rounds=rounds,
                               backend=backend)
                cold, warm, res = timed_cold_warm(
                    lambda spec=spec: solve(spec, sp), repeat=repeat)
                rows.append({
                    "name": f"spmd_scaling/{short}-{backend}-p{p}",
                    "algo": short,
                    "backend": backend,
                    "p": p,
                    "us_per_call": warm * 1e6,
                    "cold_s": cold,
                    "warm_s": warm,
                    "compile_s": max(cold - warm, 0.0),
                    "epochs_per_s": rounds / warm,
                    "provenance": res.provenance(),
                    "derived": f"cold={cold:.3f}s,warm={warm:.3f}s,"
                               f"epochs/s={rounds / warm:.1f}",
                })

    payload = {
        "config": {"n_per_worker": n, "d": d, "rounds": rounds,
                   "workers": list(WORKER_COUNTS),
                   "algos": [a.replace("centralvr_", "") for a in ALGOS],
                   "backends": list(BACKENDS),
                   "quick": quick,
                   "device_count": jax.device_count(),
                   "backend_platform": jax.default_backend()},
        "rows": rows,
    }
    with open(os.path.join(ROOT, "BENCH_spmd.json"), "w") as f:
        json.dump(payload, f, indent=1)
    emit(rows, "spmd_scaling")
    return payload


def run_isolated(quick: bool = False):
    """Entry point for the ``benchmarks.run`` harness: launch a fresh
    interpreter, because the forced host-device count must be set before
    jax initializes and every other suite must keep the real single-device
    view (see tests/conftest.py)."""
    import subprocess
    import sys

    cmd = [sys.executable, "-m", "benchmarks.spmd_scaling"]
    if quick:
        cmd.append("--quick")
    proc = subprocess.run(cmd, cwd=ROOT, capture_output=True, text=True,
                          timeout=1800)
    sys.stdout.write(proc.stdout)
    if proc.returncode != 0:
        raise RuntimeError(f"spmd_scaling failed:\n{proc.stderr[-3000:]}")


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
