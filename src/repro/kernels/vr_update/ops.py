"""jit'd public wrapper: pytree-level fused VR update.

Flattens the param pytree into one contiguous stream per buffer, pads to
the kernel tile, runs the fused kernel, and unflattens — one kernel launch
per training step regardless of tree structure.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.vr_update import kernel


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    return flat, leaves, treedef


def _unflatten(flat, leaves, treedef, dtype=None):
    out = []
    o = 0
    for l in leaves:
        chunk = flat[o:o + l.size].reshape(l.shape)
        out.append(chunk.astype(dtype or l.dtype))
        o += l.size
    return jax.tree_util.tree_unflatten(treedef, out)


@functools.partial(jax.jit, static_argnames=("eta", "m", "saga", "interpret"),
                   donate_argnums=(0, 1, 2, 3, 4))
def vr_update(x_tree, g_tree, gold_tree, gbar_tree, gtilde_tree, *,
              eta: float, m: int, saga: bool = False,
              interpret: bool = False):
    """Returns (x', table', gtilde', gbar') as pytrees like the inputs.

    All five param-sized input pytrees are DONATED: their buffers are
    reused for the outputs instead of freshly allocated each training
    step, so callers must not read the arguments after the call (the
    training step consumes its previous VR state anyway), and the five
    arguments must be distinct buffers — passing the same array twice
    raises XLA's double-donation error."""
    x, x_leaves, treedef = _flatten(x_tree)
    g = _flatten(g_tree)[0]
    gold = _flatten(gold_tree)[0]
    gbar = _flatten(gbar_tree)[0]
    gtilde = _flatten(gtilde_tree)[0]
    n = x.shape[0]
    pad = (-n) % kernel.TILE
    if pad:
        z = jnp.zeros((pad,), jnp.float32)
        x, g, gold, gbar, gtilde = (jnp.concatenate([t, z])
                                    for t in (x, g, gold, gbar, gtilde))
    xo, tbl, gto, gbo = kernel.vr_update_flat(
        x, g, gold, gbar, gtilde, eta=eta, m=m, saga=saga,
        interpret=interpret)
    return (_unflatten(xo[:n], x_leaves, treedef),
            _unflatten(tbl[:n], x_leaves, treedef, jnp.float32),
            _unflatten(gto[:n], x_leaves, treedef, jnp.float32),
            _unflatten(gbo[:n], x_leaves, treedef, jnp.float32))
