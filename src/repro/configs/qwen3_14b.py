"""Qwen3-14B [hf:Qwen/Qwen3-8B family card] — dense, GQA (40Q/8KV), qk_norm,
no QKV bias, head_dim=128."""
from repro.config import ModelConfig, register

QWEN3_14B = register(ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qkv_bias=False,
    qk_norm=True,
    rope_theta=1_000_000.0,
    norm_type="rmsnorm",
    mlp_type="swiglu",
))
