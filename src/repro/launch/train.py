"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
        --steps 100 --vr centralvr --workers data

On the production mesh this is the same entry point with --mesh production
(requires 256/512 real devices); the CPU container uses the default
single-device mesh with reduced configs.
"""
from __future__ import annotations

import argparse


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU-smoke reduced variant")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adam")
    ap.add_argument("--vr", default="centralvr",
                    choices=["none", "centralvr", "svrg", "saga"])
    ap.add_argument("--vr-table-size", type=int, default=8)
    ap.add_argument("--local-epoch", type=int, default=1)
    ap.add_argument("--workers", default="none",
                    choices=["none", "data", "pod"])
    ap.add_argument("--dp-replicated", action="store_true")
    ap.add_argument("--mesh", default="test", choices=["test", "production",
                                                       "production-multipod"])
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    from repro.config import TrainConfig, get_arch
    from repro.launch import mesh as meshlib
    from repro.train import loop

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        microbatch=args.microbatch, learning_rate=args.lr,
        optimizer=args.optimizer, vr=args.vr,
        vr_table_size=args.vr_table_size, local_epoch=args.local_epoch,
        dp_replicated=args.dp_replicated, seed=args.seed)
    if args.mesh == "production":
        mesh = meshlib.make_production_mesh()
    elif args.mesh == "production-multipod":
        mesh = meshlib.make_production_mesh(multi_pod=True)
    else:
        mesh = meshlib.make_test_mesh()

    res = loop.run_training(
        cfg, tcfg, steps=args.steps, mesh=mesh, vr_workers=args.workers,
        checkpoint_path=args.checkpoint or None,
        checkpoint_every=args.checkpoint_every)
    print(f"done: {res.steps} steps in {res.wall_time:.1f}s; "
          f"final train loss {res.losses[-1]:.4f}; "
          f"eval loss {res.final_eval_loss:.4f}")


if __name__ == "__main__":
    main()
