"""Fallback import bootstrap for script-form invocation.

``repro`` lives in ``src/`` and is normally importable either via the
editable install (``pip install -e .``, what CI does) or via
``PYTHONPATH=src`` (the tier-1 verify spelling).  Scripts under
``examples/`` and ``benchmarks/`` are also run bare —
``python examples/convex_distributed.py`` from any cwd — where neither
holds, so each script puts the repo root on ``sys.path`` and imports this
module, which adds ``src/`` only when ``repro`` doesn't already resolve:

    sys.path.insert(0, <repo root>)
    import repro_bootstrap  # noqa: F401

One helper instead of a hand-rolled ``sys.path.insert(0, "src")`` per
script (which only worked with the repo root as cwd).  Importing ``repro``
here is safe before ``spmd.force_host_devices``: the package import is
lazy and touches no jax device (see ``src/repro/__init__.py``).
"""
import os
import sys

try:
    import repro  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(
        0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "src"))
    import repro  # noqa: F401
