"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427] — hybrid: RG-LRU recurrent
blocks + local attention, pattern 2 recurrent : 1 local-attn (window 2048).

26 layers, d_model=2560, 10 heads (MQA kv=1, head_dim=256), GeGLU d_ff=7680,
vocab 256000.
"""
from repro.config import ModelConfig, register

RECURRENTGEMMA_2B = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rec", "rec", "local"),
    local_window=2048,
    rglru_heads=10,
    norm_type="rmsnorm",
    mlp_type="swiglu",      # GeGLU ~ gated MLP; gate activation is gelu in-model
    tie_embeddings=True,
    attn_logit_softcap=None,
))
