import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
#   init). This file is the ONLY place the 512-device placeholder platform
#   is forced; tests and benches see the real single CPU device.

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, WITHOUT allocating any model memory
(ShapeDtypeStruct stand-ins).

    PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 x pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multipod
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
        --shape train_4k --mesh pod [--paper-mode]

Per combo it records memory_analysis / cost_analysis / parsed collective
bytes to results/dryrun/<mesh>/<arch>__<shape>[__paper].json; the roofline
report (benchmarks/roofline_report.py, EXPERIMENTS.md §Roofline) reads
those files.

Baseline configuration (the 40-row table): the DEPLOYABLE config —
CentralVR as optimizer (table M=4 below 20B params, SVRG above), FSDP
sharding, SGD base step; on the multi-pod mesh the CentralVR workers are
the two pods (hierarchical mode: the paper's epoch-boundary exchange rides
the slow cross-pod links). --paper-mode instead replicates params along
the data axes with one CentralVR worker per data-axis group (Algorithm 2's
literal memory model) — it OOMs for the largest archs, which is part of
the §Perf story.
"""
import argparse
import dataclasses
import json
import time
import traceback


def _combo_tcfg(cfg, shape, paper_mode: bool):
    from repro.config import TrainConfig
    big = cfg.param_count() > 2e10
    return TrainConfig(
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        microbatch=1,
        optimizer="sgd",
        vr="svrg" if big else "centralvr",
        vr_table_size=4,
        local_epoch=1,
        remat="block",
        dp_replicated=paper_mode,
    )


def _arch_window(cfg, shape):
    """long_500k on quadratic-attention archs uses the sliding-window
    variant (window 4096) — the one sanctioned fallback (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return 4096
    return None


def input_shapes_for(cfg, shape, W: int, accum: int, mb: int):
    """Abstract batch arrays for the train path: (W, A, mb, S[-S_f])."""
    import jax
    import jax.numpy as jnp

    S = shape.seq_len
    n_f = cfg.frontend_tokens if cfg.frontend else 0
    toks = jax.ShapeDtypeStruct((W, accum, mb, S - n_f), jnp.int32)
    fe = (jax.ShapeDtypeStruct((W, accum, mb, n_f, cfg.d_model),
                               jnp.bfloat16) if n_f else None)
    return toks, fe


def run_combo(arch: str, shape_name: str, mesh_name: str,
              paper_mode: bool = False, out_dir: str = "results/dryrun",
              optimized: bool = False, dump_hlo: str = ""):
    """optimized=True applies the beyond-paper sharding/layout wins from
    the §Perf hillclimb (EXPERIMENTS.md): TP head padding, serving without
    FSDP (bf16 replicated-over-data weights), prefill activation pinning,
    decode KV-cache slot sharding over 'model'."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.config import INPUT_SHAPES, get_arch
    from repro.launch import mesh as meshlib
    from repro.models import model as modellib
    from repro.roofline import analysis
    from repro.sharding import specs
    from repro.train import step as tstep

    cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    window = _arch_window(cfg, shape)
    if window is not None:
        cfg = dataclasses.replace(cfg, sliding_window=window)
    if optimized and any(k in ("attn", "local") for k in cfg.layer_kinds())             and cfg.num_heads % 16:
        cfg = dataclasses.replace(
            cfg, pad_heads_to=((cfg.num_heads + 15) // 16) * 16)

    mesh = meshlib.make_production_mesh(multi_pod=(mesh_name == "multipod"))
    chips = mesh.devices.size
    t0 = time.time()
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "paper_mode": paper_mode, "chips": int(chips),
              "window": window, "optimized": optimized,
              "pad_heads_to": cfg.pad_heads_to}

    if shape.mode == "train":
        if optimized:
            # bf16 masters + bf16 VR state: halves FSDP gather traffic
            # (incl. the SVRG snapshot pass) and VR memory (§Perf It.6)
            cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
        tcfg = _combo_tcfg(cfg, shape, paper_mode)
        vr_workers = ("data" if paper_mode else
                      ("pod" if mesh_name == "multipod" else "none"))
        train_step, meta = tstep.make_train_step(cfg, tcfg, mesh, vr_workers)
        W = meta["workers"]
        # microbatch = product of data axes NOT used as worker axes (each
        # device holds 1 sequence per microbatch step); accum covers the rest
        sizes = meshlib.mesh_axis_sizes(mesh)
        w_axes = meshlib.worker_axes(mesh, vr_workers) if tcfg.vr != "none" else ()
        R = 1
        for a in ("pod", "data"):
            if a in sizes and a not in w_axes:
                R *= sizes[a]
        mb = min(R, max(shape.global_batch // W, 1))
        accum = max(shape.global_batch // (W * mb), 1)
        state_shapes = tstep.eval_shape_train_state(cfg, tcfg, W)
        sh = tstep.state_shardings(state_shapes, cfg, tcfg, mesh, vr_workers)
        toks, fe = input_shapes_for(cfg, shape, W, accum, mb)
        if W == 1:
            toks = jax.ShapeDtypeStruct(toks.shape[1:], toks.dtype)
            fe = (jax.ShapeDtypeStruct(fe.shape[1:], fe.dtype)
                  if fe is not None else None)
        bsh = tstep.batch_sharding(mesh, tcfg, vr_workers,
                                   with_fe=fe is not None)
        args = (state_shapes, toks) + ((fe,) if fe is not None else ())
        in_sh = (sh, bsh["tokens"]) + ((bsh["fe"],) if fe is not None else ())
        fn = jax.jit(train_step, in_shardings=in_sh,
                     out_shardings=(sh, None))
        record.update(workers=W, accum=accum, microbatch=mb, vr=tcfg.vr,
                      comm_every=meta["comm_every"])
        grads_per_step = meta["grads_per_step"]
        mode = "train"
    else:
        # Serving (optimized): no FSDP — weights replicated over 'data',
        # TP over 'model', stored bf16 (no optimizer states exist to
        # justify f32); §Perf #3 measured FSDP per-token gathers dominating
        # decode otherwise.
        serve_fsdp = not paper_mode and not optimized
        if optimized:
            cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
        n_f = cfg.frontend_tokens if cfg.frontend else 0
        B = shape.global_batch
        data_ok = B % 16 == 0
        dspec = ("data" if data_ok else None)
        act_sh = (NamedSharding(mesh, P(dspec, None, None))
                  if optimized and data_ok else None)
        serve_step, serve_prefill = tstep.make_serve_step(
            cfg, act_sharding=act_sh)
        if shape.mode == "prefill":
            toks = jax.ShapeDtypeStruct((B, shape.seq_len - n_f), jnp.int32)
            fe = (jax.ShapeDtypeStruct((B, n_f, cfg.d_model), jnp.bfloat16)
                  if n_f else None)
            params_shapes = jax.eval_shape(
                lambda: modellib.init_params(cfg, jax.random.PRNGKey(0)))
            psh = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s),
                specs.tree_specs(params_shapes, cfg, fsdp=serve_fsdp,
                                 axis_sizes=meshlib.mesh_axis_sizes(mesh)))
            tsh = NamedSharding(mesh, P(dspec, None))
            args = (params_shapes, toks) + ((fe,) if fe is not None else ())
            in_sh = (psh, tsh) + (
                (NamedSharding(mesh, P(dspec, None, None)),)
                if fe is not None else ())
            fn = jax.jit(serve_prefill, in_shardings=in_sh)
        else:
            cache_len = shape.seq_len
            params_shapes = jax.eval_shape(
                lambda: modellib.init_params(cfg, jax.random.PRNGKey(0)))
            cache_shapes = jax.eval_shape(
                lambda: modellib.init_cache(cfg, B, cache_len))
            psh = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s),
                specs.tree_specs(params_shapes, cfg, fsdp=serve_fsdp,
                                 axis_sizes=meshlib.mesh_axis_sizes(mesh)))

            def cspec(path, leaf):   # batch over data when divisible;
                # optimized: attention cache SLOTS over 'model' (flash-
                # decode style partial softmax) when they divide
                ps = specs._path_str(path)
                n_lead = 1 if "stack" in ps else 0
                rest = leaf.ndim - n_lead - 1
                dims = [dspec] + [None] * rest
                if (optimized and rest >= 2
                        and leaf.shape[n_lead + 1] % 16 == 0):
                    dims[1] = "model"
                return NamedSharding(mesh, P(*([None] * n_lead), *dims))

            csh = jax.tree_util.tree_map_with_path(cspec, cache_shapes)
            tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            args = (params_shapes, tok, cache_shapes, pos)
            in_sh = (psh, NamedSharding(mesh, P(dspec, None)), csh,
                     NamedSharding(mesh, P()))
            fn = jax.jit(serve_step, in_shardings=in_sh,
                         out_shardings=(None, csh))
        grads_per_step = 1
        mode = shape.mode
        record.update(workers=0, vr="none")

    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    if dump_hlo:
        with open(dump_hlo, "w") as f:
            f.write(compiled.as_text())
    t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    mem_d = {k: getattr(mem, k) for k in
             ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes")
             if hasattr(mem, k)}
    hlo = compiled.as_text()
    roof = analysis.analyze(cfg, shape, mode, mesh_name, chips,
                            cost or {}, hlo, mem_d,
                            grads_per_step=grads_per_step)
    record.update(
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        cost={k: float(v) for k, v in (cost or {}).items()
              if k in ("flops", "bytes accessed", "transcendentals")},
        memory=mem_d, roofline=roof.to_dict(),
        hlo_bytes=len(hlo))

    suffix = "__paper" if paper_mode else ""
    mesh_dir = mesh_name + ("_opt" if optimized else "")
    path = os.path.join(out_dir, mesh_dir,
                        f"{arch}__{shape_name}{suffix}.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)
    print(f"OK  {arch:20s} {shape_name:12s} {mesh_name:8s}"
          f"{' paper' if paper_mode else ''}  "
          f"lower {t_lower:.0f}s compile {t_compile:.0f}s  "
          f"bottleneck={roof.bottleneck}  "
          f"Tc={roof.t_compute*1e3:.1f}ms Tm={roof.t_memory*1e3:.1f}ms "
          f"Tx={roof.t_collective*1e3:.2f}ms")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--paper-mode", action="store_true")
    ap.add_argument("--optimized", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args(argv)

    from repro.config import INPUT_SHAPES, list_archs

    if args.all:
        combos = [(a, s) for a in list_archs() for s in INPUT_SHAPES]
    else:
        combos = [(args.arch, args.shape)]

    failures = []
    mesh_dir = args.mesh + ("_opt" if args.optimized else "")
    for arch, shape in combos:
        suffix = "__paper" if args.paper_mode else ""
        path = os.path.join(args.out, mesh_dir, f"{arch}__{shape}{suffix}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"SKIP {arch} {shape} (exists)")
            continue
        try:
            run_combo(arch, shape, args.mesh, args.paper_mode, args.out,
                      optimized=args.optimized)
        except Exception as e:  # noqa: BLE001 — record and continue
            failures.append((arch, shape, repr(e)))
            print(f"FAIL {arch} {shape}: {e}")
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall combos compiled")


if __name__ == "__main__":
    main()
