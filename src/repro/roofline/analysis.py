"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), TPU v5e constants:

    T_compute    = FLOPs_per_device / 197e12        (bf16 MXU peak)
    T_memory     = bytes_per_device / 819e9         (HBM bandwidth)
    T_collective = collective_bytes_per_device / 50e9  (ICI per link)

``compiled.cost_analysis()`` reports the PER-DEVICE partitioned module's
flops/bytes (XLA analyses the post-SPMD module), so terms divide by the
single-chip peak directly. Collective bytes are not in cost_analysis: we
parse the compiled HLO text and sum the RESULT-shape bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
(result-shape convention ~ bytes landed per device per step; recorded as
the convention in EXPERIMENTS.md).

MODEL_FLOPS uses the 6·N·D rule (6·N_active·D for MoE) for training and
2·N·D for single-token decode; the ratio MODEL_FLOPS / HLO_FLOPs measures
useful compute (remat recompute, attention quadratic work and dispatch
overhead all push it down).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

# ---------------------------------------------------------------------------
# VR-step memory traffic (param-sized HBM passes per inner-loop step)
# ---------------------------------------------------------------------------
# The fused kernels/vr_update launch touches each param-sized buffer once:
# reads {x, g, g_old, gbar, gtilde}, writes {x', table-row, gtilde', gbar'}
# = 5 reads / 4 writes regardless of mode. The unfused algebra XLA emits
# for the same step is a chain of elementwise passes, counted from the
# oracle dataflow (vr_wrapper.correct + sgd apply, per param-sized buffer
# touched):
#   centralvr  v=g-old+gbar (3r/1w), table row (1r/1w), gtilde+=g/M
#              (2r/1w), u=-lr*v; x+=u (3r/1w)              -> 9r / 4w
#   saga       centralvr's passes + gbar+=(g-old)/M re-reads the three
#              correction operands minus the gtilde pass   -> 10r / 4w
#   svrg       no table row; v=g-gsnap+gbar (3r/1w),
#              gtilde+=g/M (2r/1w), fused-negate update
#              x-=lr*v (3r/1w)                             -> 8r / 3w
VR_TRAFFIC = {
    ("centralvr", True): (5, 4), ("centralvr", False): (9, 4),
    ("saga", True): (5, 4), ("saga", False): (10, 4),
    ("svrg", True): (5, 4), ("svrg", False): (8, 3),
}


def vr_step_traffic(n_params: int, mode: str, *, fused: bool,
                    bytes_per_el: int = 4) -> dict:
    """Predicted HBM traffic of one VR inner-loop step over ``n_params``
    parameters: param-sized buffer passes per the table above."""
    reads, writes = VR_TRAFFIC[(mode, bool(fused))]
    return {"mode": mode, "fused": bool(fused), "reads": reads,
            "writes": writes, "passes": reads + writes,
            "bytes": float((reads + writes) * n_params * bytes_per_el)}


def vr_fused_traffic_ratio(mode: str) -> float:
    """Analytical unfused/fused HBM-traffic ratio for one VR step —
    13/9 for centralvr, the floor the BENCH roofline section asserts."""
    ru, wu = VR_TRAFFIC[(mode, False)]
    rf, wf = VR_TRAFFIC[(mode, True)]
    return (ru + wu) / (rf + wf)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# result shapes: one or a tuple of `dtype[d0,d1,...]`
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[0-9,]*\]\S*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes summed over the module."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(shapes))
        out[kind] += total
        counts[kind] += 1
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    return {**out, **out_counts}


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    mode: str                   # train / prefill / decode
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops_global: float
    useful_fraction: float      # MODEL_FLOPS / (HLO_FLOPs * chips)
    peak_memory_bytes: Optional[float] = None
    collectives: Optional[dict] = None

    def to_dict(self):
        return asdict(self)


def model_flops(cfg, shape, mode: str) -> float:
    """6·N·D training / 2·N·D forward rule (N active params, D tokens)."""
    n_active = cfg.active_param_count()
    if mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1          # decode: one token per seq
    return 2.0 * n_active * tokens


def analyze(cfg, shape, mode: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, memory: Optional[dict] = None,
            grads_per_step: int = 1) -> Roofline:
    """Execution-weighted terms via the HLO cost model (hlo_cost.py);
    ``cost`` (XLA's static cost_analysis) is recorded upstream for
    reference but NOT used for the terms — it counts loop bodies once."""
    from repro.roofline import hlo_cost
    hc = hlo_cost.analyze_hlo(hlo_text)
    flops = hc.flops
    byts = hc.bytes_accessed
    colls = {**{k: float(v) for k, v in hc.collective_breakdown.items()},
             **{f"n_{k}": float(v)
                for k, v in hc.collective_counts.items()}}
    cbytes = hc.collective_bytes
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = cbytes / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, mode)
    useful = mf / max(flops * chips, 1.0)
    peak = None
    if memory:
        peak = float(memory.get("temp_size_in_bytes", 0)
                     + memory.get("argument_size_in_bytes", 0))
    return Roofline(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, mode=mode,
        chips=chips, flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=cbytes, t_compute=t_c, t_memory=t_m,
        t_collective=t_x, bottleneck=bottleneck, model_flops_global=mf,
        useful_fraction=useful, peak_memory_bytes=peak, collectives=colls)


def format_table(rows) -> str:
    """Markdown table for EXPERIMENTS.md."""
    hdr = ("| arch | shape | mesh | mode | T_comp (ms) | T_mem (ms) | "
           "T_coll (ms) | bottleneck | useful | peak GiB/dev |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        peak = (f"{r.peak_memory_bytes / 2**30:.2f}"
                if r.peak_memory_bytes else "-")
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.mode} "
            f"| {r.t_compute * 1e3:.2f} | {r.t_memory * 1e3:.2f} "
            f"| {r.t_collective * 1e3:.3f} | {r.bottleneck} "
            f"| {r.useful_fraction:.2f} | {peak} |")
    return "\n".join(lines)
