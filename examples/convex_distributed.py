"""The paper's distributed experiment (§6.2), end to end: CentralVR-Sync /
-Async vs D-SVRG / D-SAGA / EASGD on weak-scaled toy data, with the
rounds-to-tolerance linear-scaling readout.

    PYTHONPATH=src python examples/convex_distributed.py [--workers 8]

``--backend spmd`` runs every driver with one worker per simulated host
device (DESIGN.md §2) — the async rows execute their event schedule as
concurrency waves (D-SAGA under the stale-fetch discipline the waves
require).
"""
import argparse
import sys

sys.path.insert(0, "src")


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--n-per-worker", type=int, default=1000)
    ap.add_argument("--d", type=int, default=200)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--backend", choices=("vmap", "spmd"), default="vmap")
    return ap.parse_args()


def main():
    args = parse_args()
    if args.backend == "spmd":
        # must precede the first jax operation (shared helper, DESIGN §2);
        # the weak-scaling sweep below also runs p in (2, 4), so force at
        # least 4 devices regardless of --workers
        from repro.core import spmd
        spmd.force_host_devices(max(args.workers, 4))

    import jax
    import numpy as np

    from repro.config import ConvexConfig
    from repro.core import baselines, distributed

    cfg = ConvexConfig(problem="logistic", n=args.n_per_worker, d=args.d,
                       workers=args.workers)
    sp = distributed.make_distributed(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    from repro.core import convex
    eta = convex.auto_eta(sp.merged(), 0.4)

    be = args.backend
    print(f"p={args.workers} workers, |Omega_s|={args.n_per_worker}, "
          f"d={args.d}, {args.rounds} communication rounds, "
          f"backend={be}\n")
    runs = {
        "CentralVR-Sync": lambda: distributed.run_sync(
            sp, eta=eta, rounds=args.rounds, key=key, backend=be)[1],
        "CentralVR-Async": lambda: distributed.run_async(
            sp, eta=eta, rounds=args.rounds, key=key, backend=be)[1],
        "CentralVR-Async (4x speed spread)": lambda: distributed.run_async(
            sp, eta=eta, rounds=args.rounds, key=key, backend=be,
            speeds=[1 + 3 * i / max(args.workers - 1, 1)
                    for i in range(args.workers)])[1],
        "Distributed-SVRG": lambda: distributed.run_dsvrg(
            sp, eta=eta, rounds=args.rounds, key=key, backend=be)[1],
        # spmd implies the stale-fetch discipline (DESIGN.md §2)
        "Distributed-SAGA": lambda: distributed.run_dsaga(
            sp, eta=eta / 2, rounds=args.rounds, key=key, backend=be,
            tau=args.n_per_worker // 2)[1],
        "EASGD": lambda: baselines.run_easgd(
            sp, eta=eta, rounds=args.rounds, key=key, backend=be)[1],
        "dist-SGD": lambda: baselines.run_dist_sgd(
            sp, eta=eta, rounds=args.rounds, key=key, backend=be)[1],
    }
    for name, fn in runs.items():
        rels = np.asarray(fn())
        print(f"{name:35s} final rel-grad-norm {rels[-1]:.2e}")

    # weak scaling: rounds to 1e-5 as p grows (the linear-scaling claim)
    print("\nweak scaling (rounds to rel-grad-norm < 1e-3):")
    for p in (2, 4, args.workers):
        cfg_p = ConvexConfig(problem="logistic", n=args.n_per_worker,
                             d=args.d, workers=p)
        sp_p = distributed.make_distributed(jax.random.PRNGKey(0), cfg_p)
        eta_p = convex.auto_eta(sp_p.merged(), 0.4)
        rels = np.asarray(distributed.run_sync(
            sp_p, eta=eta_p, rounds=args.rounds, key=key, backend=be)[1])
        hit = np.nonzero(rels < 1e-3)[0]
        r = int(hit[0]) + 1 if hit.size else f">{args.rounds}"
        print(f"  p={p:3d} (total data {p * args.n_per_worker}): {r} rounds")


if __name__ == "__main__":
    main()
