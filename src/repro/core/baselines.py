"""Comparison baselines used in the paper's experiments (§6.2):

  * plain (distributed) SGD with periodic averaging,
  * EASGD — elastic averaging SGD [36], constant & decaying step sizes,
  * PS-SVRG — asynchronous parameter-server SVRG [29].

All run on the same :class:`ShardedProblem` substrate as the proposed
methods so convergence-per-gradient-evaluation comparisons are exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import convex
from repro.core.convex import Problem
from repro.core.distributed import ShardedProblem


# ---------------------------------------------------------------------------
# Sequential SGD / SVRG / SAGA (single worker, for Fig. 1)
# ---------------------------------------------------------------------------

def run_sgd(prob: Problem, *, eta: float, epochs: int, key: jax.Array,
            decay: float = 0.0):
    """Plain SGD, permutation sampling; eta_l = eta / (1 + decay*l)."""
    x = jnp.zeros((prob.d,))
    g0 = jnp.linalg.norm(convex.full_grad(prob, x))

    @jax.jit
    def one_epoch(x, k, eta_l):
        perm = jax.random.permutation(k, prob.n)

        def body(x, i):
            g = (convex.scalar_residual(prob, x, i) * prob.A[i]
                 + 2.0 * prob.lam * x)
            return x - eta_l * g, None

        x, _ = jax.lax.scan(body, x, perm)
        return x, jnp.linalg.norm(convex.full_grad(prob, x)) / g0

    rels = []
    for l, k in enumerate(jax.random.split(key, epochs)):
        x, rel = one_epoch(x, k, eta / (1.0 + decay * l))
        rels.append(float(rel))
    return x, jnp.array(rels)


def run_svrg(prob: Problem, *, eta: float, epochs: int, key: jax.Array,
             inner: int = 0):
    """SVRG [17]: snapshot + full gradient every epoch; update (3).
    Gradient evaluations per outer epoch: n (full grad) + 2*inner."""
    inner = inner or prob.n
    x = jnp.zeros((prob.d,))
    g0 = jnp.linalg.norm(convex.full_grad(prob, x))

    @jax.jit
    def one_epoch(x, k):
        xbar = x
        gbar = convex.full_grad(prob, xbar)
        idx = jax.random.randint(k, (inner,), 0, prob.n)

        def body(x, i):
            g = ((convex.scalar_residual(prob, x, i)
                  - convex.scalar_residual(prob, xbar, i)) * prob.A[i]
                 + gbar + 2.0 * prob.lam * (x - xbar))
            return x - eta * g, None

        x, _ = jax.lax.scan(body, x, idx)
        return x, jnp.linalg.norm(convex.full_grad(prob, x)) / g0

    rels = []
    for k in jax.random.split(key, epochs):
        x, rel = one_epoch(x, k)
        rels.append(float(rel))
    # grad evals per epoch: n + 2*inner (3n at inner=n)
    return x, jnp.array(rels)


def run_saga(prob: Problem, *, eta: float, epochs: int, key: jax.Array):
    """SAGA [12]: update (4), table mean refreshed every iteration.
    1 gradient evaluation per iteration; table init at x0."""
    x = jnp.zeros((prob.d,))
    g0 = jnp.linalg.norm(convex.full_grad(prob, x))
    table = convex.scalar_residual_all(prob, x)
    gbar = convex.data_grad_from_scalars(prob, table)

    @jax.jit
    def one_epoch(carry, k):
        x, table, gbar = carry
        idx = jax.random.randint(k, (prob.n,), 0, prob.n)

        def body(carry, i):
            x, table, gbar = carry
            s_new = convex.scalar_residual(prob, x, i)
            v = (s_new - table[i]) * prob.A[i] + gbar + 2.0 * prob.lam * x
            gbar = gbar + (s_new - table[i]) * prob.A[i] / prob.n
            table = table.at[i].set(s_new)
            return (x - eta * v, table, gbar), None

        (x, table, gbar), _ = jax.lax.scan(body, (x, table, gbar), idx)
        rel = jnp.linalg.norm(convex.full_grad(prob, x)) / g0
        return (x, table, gbar), rel

    rels = []
    carry = (x, table, gbar)
    for k in jax.random.split(key, epochs):
        carry, rel = one_epoch(carry, k)
        rels.append(float(rel))
    return carry[0], jnp.array(rels)


# ---------------------------------------------------------------------------
# Distributed baselines
# ---------------------------------------------------------------------------

def run_dist_sgd(sp: ShardedProblem, *, eta: float, rounds: int,
                 key: jax.Array, tau: int = 0, decay: float = 0.0):
    """Distributed SGD: tau local steps (default: one local epoch), then
    average — the 'one-shot-averaging per round' baseline."""
    tau = tau or sp.ns
    x = jnp.zeros((sp.d,))
    merged = sp.merged()
    g0 = jnp.linalg.norm(convex.full_grad(merged, x))

    @jax.jit
    def round_(x, k, eta_l):
        def local(A, b, kk):
            prob = Problem(A, b, sp.lam, sp.kind)
            idx = jax.random.randint(kk, (tau,), 0, sp.ns)

            def body(xl, i):
                g = convex.scalar_residual(prob, xl, i) * A[i] + 2.0 * sp.lam * xl
                return xl - eta_l * g, None

            xl, _ = jax.lax.scan(body, x, idx)
            return xl

        xs = jax.vmap(local)(sp.A, sp.b, jax.random.split(k, sp.p))
        x = xs.mean(0)
        return x, jnp.linalg.norm(convex.full_grad(merged, x)) / g0

    rels = []
    for l, k in enumerate(jax.random.split(key, rounds)):
        x, rel = round_(x, k, eta / (1.0 + decay * l * tau) ** 0.5)
        rels.append(float(rel))
    return x, jnp.array(rels)


def run_easgd(sp: ShardedProblem, *, eta: float, rounds: int, key: jax.Array,
              tau: int = 16, rho: float = 1.0, decay: float = 0.0):
    """EASGD [36]: workers do tau local SGD steps, then the elastic update
      x_s <- x_s - alpha*(x_s - xc),  xc <- xc + alpha*sum_s(x_s - xc)/p'
    with alpha = eta*rho (the paper's beta=p*alpha convention, symmetric
    moving-average form). Step size optionally decays as eta0/(1+gamma*k)^.5
    on a local clock, as in [36]/§6.2.
    """
    p = sp.p
    alpha = min(0.9 / p, eta * rho * tau)   # stability-capped elastic rate
    xc = jnp.zeros((sp.d,))
    xs = jnp.zeros((p, sp.d))
    merged = sp.merged()
    g0 = jnp.linalg.norm(convex.full_grad(merged, xc))
    steps_per_round = max(sp.ns // tau, 1)

    @jax.jit
    def round_(xc, xs, k, eta_l):
        def local(A, b, xl, kk):
            prob = Problem(A, b, sp.lam, sp.kind)
            idx = jax.random.randint(kk, (steps_per_round * tau,), 0, sp.ns)
            idx = idx.reshape(steps_per_round, tau)

            def comm_block(carry, idx_tau):
                xl, xc_view = carry

                def body(x, i):
                    g = convex.scalar_residual(prob, x, i) * A[i] + 2.0 * sp.lam * x
                    return x - eta_l * g, None

                xl, _ = jax.lax.scan(body, xl, idx_tau)
                diff = xl - xc_view
                # symmetric elastic move; the center's share is applied
                # after the vmap (sum of worker contributions)
                return (xl - alpha * diff, xc_view + alpha * diff), diff

            (xl, _), diffs = jax.lax.scan(comm_block, (xl, xc), idx)
            return xl, diffs.sum(0)

        xs, diffs = jax.vmap(local)(sp.A, sp.b, xs, jax.random.split(k, p))
        xc = xc + alpha * diffs.sum(0) / p
        rel = jnp.linalg.norm(convex.full_grad(merged, xc)) / g0
        return xc, xs, rel

    rels = []
    for l, k in enumerate(jax.random.split(key, rounds)):
        eta_l = eta / (1.0 + decay * l * sp.ns) ** 0.5
        xc, xs, rel = round_(xc, xs, k, eta_l)
        rels.append(float(rel))
    return xc, jnp.array(rels)


def run_ps_svrg(sp: ShardedProblem, *, eta: float, rounds: int,
                key: jax.Array, epoch_mult: int = 2):
    """Parameter-server SVRG [29]: every worker streams one corrected
    gradient per step to the server (communication every iteration — the
    high-bandwidth regime the paper contrasts against). Simulated with
    synchronized arrivals (staleness 0, the method's best case); epoch
    size 2n as recommended in [29]. Per round: one full gradient + 2
    gradient evaluations per inner step per worker."""
    merged = sp.merged()
    x = jnp.zeros((sp.d,))
    g0 = jnp.linalg.norm(convex.full_grad(merged, x))
    inner = epoch_mult * sp.ns

    @jax.jit
    def round_(x, k):
        xbar = x
        gbar = convex.full_grad(merged, xbar)

        def body(x, ks):
            # each worker contributes one corrected gradient; the server
            # applies their average (p gradients -> one server step)
            i = jax.random.randint(ks, (sp.p,), 0, sp.ns)

            def worker_grad(A, b, ii):
                prob = Problem(A, b, sp.lam, sp.kind)
                return ((convex.scalar_residual(prob, x, ii)
                         - convex.scalar_residual(prob, xbar, ii)) * A[ii]
                        + gbar + 2.0 * sp.lam * (x - xbar))

            g = jax.vmap(worker_grad)(sp.A, sp.b, i).mean(0)
            return x - eta * g, None

        x, _ = jax.lax.scan(body, x, jax.random.split(k, inner))
        return x, jnp.linalg.norm(convex.full_grad(merged, x)) / g0

    rels = []
    for k in jax.random.split(key, rounds):
        x, rel = round_(x, k)
        rels.append(float(rel))
    return x, jnp.array(rels)
