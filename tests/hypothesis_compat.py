"""Optional-hypothesis shim (DESIGN.md §5).

`hypothesis` is a dev-only extra (requirements-dev.txt). Importing through
this module lets test files mix property-based and plain tests: with
hypothesis installed everything runs; without it, only the ``@given``
tests skip (each with a pointed reason) while the plain tests in the same
module still execute.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # degrade: property tests skip, plain tests run
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Placeholder for hypothesis.strategies: any strategy constructor
        returns None (never executed — @given skips the test first)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        return pytest.mark.skip(
            reason="hypothesis not installed "
                   "(pip install -r requirements-dev.txt)")
