"""Property tests for ``repro.prox.operators`` (ISSUE 10 tentpole).

Every registered operator is pinned three ways:

  * ALGEBRAIC properties every proximal map must satisfy on any input —
    nonexpansiveness (||prox(u) - prox(v)|| <= ||u - v||), the
    fixed-point characterization (w* minimizes g  =>  prox(w*) = w*,
    and for our operators prox(prox(w)) relates by the semigroup /
    projection laws), and output feasibility (box stays in the box,
    shrinkage never grows a coordinate for l1/elastic-net);
  * the NUMERIC ORACLE: the closed forms must match the scipy-free
    golden-section solution of the prox subproblem to 1e-6 (the oracle's
    flat-minimum comparison limit is ~1e-8 — see ``numeric_prox``);
  * the SPEC CONTRACTS: parse/canonical round-trips, registry errors
    naming the operator and its signature, elementwise classification.

Property tests run under the optional-hypothesis shim: without
hypothesis installed they skip with a pointed reason while the plain
tests still run.
"""
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa

from repro.prox import operators as proxops

SPECS = ("l1:0.05", "elasticnet:0.05:0.02", "box:-0.7:1.3", "group_l2:0.1:4")

# any finite-ish coordinate values; d = 8 keeps group_l2's groups exact
coords = st.lists(st.floats(min_value=-5.0, max_value=5.0,
                            allow_nan=False, allow_infinity=False),
                  min_size=8, max_size=8)
etas = st.floats(min_value=1e-4, max_value=2.0,
                 allow_nan=False, allow_infinity=False)


def _arr(xs):
    return np.asarray(xs, dtype=np.float64)


# ---------------------------------------------------------------------------
# algebraic properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", SPECS)
@settings(max_examples=25, deadline=None)
@given(u=coords, v=coords, eta=etas)
def test_nonexpansive(spec, u, v, eta):
    """||prox(u) - prox(v)|| <= ||u - v|| — the defining property of a
    proximal map of a convex g (it is what makes prox'd SGD stable)."""
    pu = np.asarray(proxops.apply(spec, _arr(u), eta))
    pv = np.asarray(proxops.apply(spec, _arr(v), eta))
    lhs = np.linalg.norm(pu - pv)
    rhs = np.linalg.norm(_arr(u) - _arr(v))
    assert lhs <= rhs + 1e-12


@pytest.mark.parametrize("spec", SPECS)
@settings(max_examples=25, deadline=None)
@given(u=coords, eta=etas)
def test_prox_decreases_objective(spec, u, eta):
    """z = prox(w) must achieve an objective value no worse than w itself
    in 0.5||z - w||^2 + eta*g(z) — i.e. eta*g(prox(w)) + dist cost <=
    eta*g(w)."""
    w = _arr(u)
    z = np.asarray(proxops.apply(spec, w, eta))
    gz = float(proxops.penalty(spec, z))
    gw = float(proxops.penalty(spec, w))
    if not np.isfinite(gw):        # infeasible w for the box indicator
        assert np.isfinite(gz)     # the projection lands feasible
        return
    assert 0.5 * np.sum((z - w) ** 2) + eta * gz <= eta * gw + 1e-10


@pytest.mark.parametrize("spec", SPECS)
@settings(max_examples=25, deadline=None)
@given(eta=etas)
def test_penalty_minimizer_is_fixed_point(spec, eta):
    """The minimizer of g is a fixed point of prox_{eta*g}: 0 for the
    norms, any interior point for the box."""
    w = np.zeros(8)
    if proxops.parse(spec).name == "box":
        lo, hi = proxops.parse(spec).params
        w = np.full(8, 0.5 * (lo + hi))
    z = np.asarray(proxops.apply(spec, w, eta))
    np.testing.assert_allclose(z, w, rtol=0, atol=1e-14)


@settings(max_examples=25, deadline=None)
@given(u=coords, eta=etas)
def test_l1_semigroup_and_shrinkage(u, eta):
    """Soft-threshold laws: S_a(S_b(w)) = S_{a+b}(w), and |prox(w)| <= |w|
    coordinatewise (shrinkage never grows a coordinate)."""
    w = _arr(u)
    lam = 0.07
    once = np.asarray(proxops.apply(f"l1:{lam}", w, 2.0 * eta))
    twice = np.asarray(proxops.apply(
        f"l1:{lam}", np.asarray(proxops.apply(f"l1:{lam}", w, eta)), eta))
    np.testing.assert_allclose(twice, once, rtol=0, atol=1e-12)
    assert np.all(np.abs(once) <= np.abs(w) + 1e-15)


@settings(max_examples=25, deadline=None)
@given(u=coords, eta=etas)
def test_box_is_idempotent_projection(u, eta):
    """The box prox is a projection: output feasible, idempotent, and
    independent of eta."""
    w = _arr(u)
    z1 = np.asarray(proxops.apply("box:-0.7:1.3", w, eta))
    z2 = np.asarray(proxops.apply("box:-0.7:1.3", z1, 13.0))
    assert np.all(z1 >= -0.7) and np.all(z1 <= 1.3)
    np.testing.assert_array_equal(z1, z2)
    np.testing.assert_array_equal(
        z1, np.asarray(proxops.apply("box:-0.7:1.3", w, 5.0 * eta)))


@settings(max_examples=25, deadline=None)
@given(u=coords, eta=etas)
def test_group_l2_kills_or_shrinks_whole_groups(u, eta):
    """Block soft-threshold acts per group: a group is either zeroed
    entirely or shrunk radially (direction preserved)."""
    w = _arr(u)
    z = np.asarray(proxops.apply("group_l2:0.1:4", w, eta)).reshape(2, 4)
    wg = w.reshape(2, 4)
    for zg, wgi in zip(z, wg):
        nz = np.linalg.norm(zg)
        nw = np.linalg.norm(wgi)
        assert nz <= nw + 1e-12
        if nz > 0:       # shrunk, not zeroed: same direction
            np.testing.assert_allclose(zg / nz, wgi / nw, rtol=0, atol=1e-9)


# ---------------------------------------------------------------------------
# closed forms vs the numeric oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", SPECS)
@settings(max_examples=20, deadline=None)
@given(u=coords, eta=etas)
def test_closed_form_matches_numeric_oracle(spec, u, eta):
    w = _arr(u)
    closed = np.asarray(proxops.apply(spec, w, eta))
    numeric = np.asarray(proxops.numeric_prox(spec, w, eta))
    np.testing.assert_allclose(closed, numeric, rtol=0, atol=1e-6)


def test_numeric_oracle_plain():
    """One deterministic oracle pin per operator (runs without
    hypothesis): a fixed vector with positive/negative/small coords."""
    w = np.array([2.0, -1.5, 0.03, -0.02, 0.9, -0.9, 4.0, -4.0])
    for spec in SPECS:
        closed = np.asarray(proxops.apply(spec, w, 0.7))
        numeric = np.asarray(proxops.numeric_prox(spec, w, 0.7))
        np.testing.assert_allclose(closed, numeric, rtol=0, atol=1e-6,
                                   err_msg=spec)


# ---------------------------------------------------------------------------
# spec contracts
# ---------------------------------------------------------------------------

def test_parse_canonical_roundtrip():
    for spec in SPECS + ("l1", "elasticnet:0.3", "box", "group_l2:1e-2:8"):
        ps = proxops.parse(spec)
        assert proxops.parse(ps) is ps                      # idempotent
        canon = proxops.canonical(spec)
        assert proxops.parse(canon) == ps                   # round-trips
        assert proxops.canonical(canon) == canon            # stable
    assert proxops.canonical(None) is None


def test_parse_errors_name_the_operator():
    with pytest.raises(ValueError, match="unknown prox operator 'l2'"):
        proxops.parse("l2:0.1")
    with pytest.raises(ValueError, match="at most 1"):
        proxops.parse("l1:0.1:0.2")
    with pytest.raises(ValueError, match="must be a number"):
        proxops.parse("l1:abc")
    with pytest.raises(ValueError, match="empty box"):
        proxops.parse("box:1:-1")
    with pytest.raises(ValueError, match="positive integer"):
        proxops.parse("group_l2:0.1:2.5")
    with pytest.raises(ValueError, match="lam1 must be >= 0"):
        proxops.parse("l1:-0.1")


def test_elementwise_classification():
    assert proxops.is_elementwise(None)
    assert proxops.is_elementwise("l1:0.1")
    assert proxops.is_elementwise("elasticnet:0.1:0.1")
    assert proxops.is_elementwise("box:-1:1")
    assert not proxops.is_elementwise("group_l2:0.1:4")


def test_apply_prox_none_is_identity_and_grad_map_reduces():
    w = np.array([1.0, -2.0, 0.5])
    g = np.array([0.3, -0.1, 0.2])
    out = proxops.apply_prox(None, w, 0.1)
    assert out is w                                   # literally untouched
    np.testing.assert_allclose(np.asarray(proxops.grad_map(None, w, g, 0.1)),
                               0.1 * g, rtol=0, atol=0)
    assert float(proxops.penalty(None, w)) == 0.0


def test_group_l2_rejects_indivisible_dimension():
    with pytest.raises(ValueError, match="not divisible"):
        proxops.apply("group_l2:0.1:3", np.zeros(8), 0.1)
