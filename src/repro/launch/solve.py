"""Run any registry algorithm — or a sweep over the whole registry —
through the unified solver API (DESIGN.md §Solver API).

    python -m repro.launch.solve --list
    python -m repro.launch.solve --algo centralvr_sync --quick
    python -m repro.launch.solve --algo dsaga --fetch stale --tau 50
    python -m repro.launch.solve --algo centralvr_async --backend spmd \
        --workers 4 --speeds 1,1,2,4
    python -m repro.launch.solve --sweep --quick --json sweep.json

Every run is one ``repro.solve(RunSpec(...), ConvexConfig(...))`` call;
the printed row and the optional ``--json`` dump are
``RunResult.provenance()`` records, the same rows the benchmark artifacts
embed.  ``--backend spmd`` forces the simulated host devices before the
first jax operation (the DESIGN.md §2 constraint); during a sweep,
algorithms without an SPMD program fall back to vmap with a note.
"""
from __future__ import annotations

import argparse
import json


def parse_args(argv=None):
    ap = argparse.ArgumentParser(
        description="Unified solver CLI: one RunSpec per run.")
    ap.add_argument("--algo", default="",
                    help="registry algorithm name (see --list)")
    ap.add_argument("--sweep", action="store_true",
                    help="run every registry algorithm")
    ap.add_argument("--list", action="store_true",
                    help="print the registry (caps + doc) and exit")
    ap.add_argument("--problem",
                    choices=("logistic", "ridge", "huber", "pseudo_huber"),
                    default="logistic")
    ap.add_argument("--outlier-frac", type=float, default=0.0,
                    help="label corruption rate (robust-loss experiments)")
    ap.add_argument("--huber-delta", type=float, default=1.0,
                    help="Huber/pseudo-Huber transition scale")
    ap.add_argument("--n", type=int, default=0,
                    help="samples per worker (0 -> 1000, or 64 in --quick)")
    ap.add_argument("--d", type=int, default=0,
                    help="feature dim (0 -> 50, or 8 in --quick)")
    ap.add_argument("--workers", "-p", type=int, default=0,
                    help="worker count for distributed algos "
                         "(0 -> 4, or 2 in --quick)")
    ap.add_argument("--rounds", type=int, default=0,
                    help="communication rounds / epochs "
                         "(0 -> 10, or 3 in --quick)")
    ap.add_argument("--eta", type=float, default=0.0,
                    help="step size (0 -> auto from the smoothness const)")
    ap.add_argument("--backend", choices=("vmap", "spmd"), default="vmap")
    ap.add_argument("--fetch", choices=("instant", "stale"), default="",
                    help="D-SAGA fetch discipline")
    ap.add_argument("--speeds", default="",
                    help="comma list of per-worker relative speeds "
                         "(async algos)")
    ap.add_argument("--tau", type=int, default=0,
                    help="local steps per event/round where supported")
    ap.add_argument("--prox", default="",
                    help="composite objective: prox spec 'name[:p1[:p2]]' "
                         "(l1:lam1, elasticnet:lam1:lam2, box:lo:hi, "
                         "group_l2:lam1:size); VR algorithms only")
    ap.add_argument("--lam2", type=float, default=0.0,
                    help="elastic-net quadratic weight: upgrades --prox "
                         "l1:lam1 to elasticnet:lam1:lam2")
    ap.add_argument("--snapshot", choices=("last", "avg", "rand"),
                    default="",
                    help="VR anchor strategy (svrg/dsvrg take avg/rand)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metric-every", type=int, default=1)
    ap.add_argument("--quick", action="store_true",
                    help="tiny CI-smoke sizes")
    ap.add_argument("--json", default="",
                    help="write RunResult.provenance() rows to this path")
    ap.add_argument("--obs", default="", metavar="PATH",
                    help="record structured run telemetry (spans, streamed "
                         "metrics, comms/staleness) to this JSONL file; "
                         "render with `python -m repro.launch.obs report`")
    ap.add_argument("--stream-every", type=int, default=1,
                    help="streamed in-scan metric cadence (with --obs)")
    ap.add_argument("--profile", default="", metavar="DIR",
                    help="capture a jax.profiler trace of the runs into "
                         "this directory (open with TensorBoard/Perfetto)")
    from repro.launch.compile_cache import add_compile_cache_arg
    add_compile_cache_arg(ap)
    return ap.parse_args(argv)


def build_spec(args, name, workers, rounds):
    """One RunSpec from the flag surface, honoring the algorithm's
    capability record (flags an algorithm doesn't take are only an error
    when the user set them explicitly for a single --algo run)."""
    import repro

    caps = repro.REGISTRY[name].caps
    backend = args.backend
    note = ""
    if backend == "spmd" and not caps.spmd_ok:
        if not args.sweep:
            # let RunSpec raise its field-named error
            return repro.RunSpec(algo=name, backend=backend), ""
        backend, note = "vmap", " (no spmd program: ran vmap)"
    kw = dict(algo=name, p=workers if caps.distributed else 1,
              rounds=rounds, backend=backend, seed=args.seed,
              metric_every=args.metric_every)
    if args.eta:
        kw["eta"] = args.eta
    # a flag the algorithm doesn't take is dropped during a sweep but kept
    # for a single --algo run, so RunSpec surfaces the capability mismatch
    # instead of silently ignoring what the user asked for
    if args.tau and (caps.accepts_tau or not args.sweep):
        kw["tau"] = args.tau
    if args.fetch and (caps.accepts_fetch or not args.sweep):
        kw["fetch"] = args.fetch
    if args.speeds and (caps.accepts_speeds or not args.sweep):
        kw["speeds"] = tuple(float(s) for s in args.speeds.split(","))
    prox = resolve_prox(args)
    if prox and (caps.accepts_prox or not args.sweep):
        kw["prox"] = prox
    elif prox:
        note += " (no prox support: ran smooth)"
    if args.snapshot and (args.snapshot in caps.snapshots or not args.sweep):
        kw["snapshot"] = args.snapshot
    elif args.snapshot and args.snapshot != "last":
        note += f" (no {args.snapshot!r} snapshot: ran 'last')"
    return repro.RunSpec(**kw), note


def resolve_prox(args) -> str:
    """--prox [+ --lam2] -> a prox spec string. ``--lam2`` is sugar for
    the elastic-net quadratic: it upgrades ``--prox l1:lam1`` to
    ``elasticnet:lam1:lam2`` (and overrides an explicit elasticnet lam2)."""
    from repro.prox import operators as proxops

    if not args.prox:
        if args.lam2:
            raise SystemExit("--lam2 needs --prox l1:... or elasticnet:... "
                             "(it sets the elastic-net quadratic weight)")
        return ""
    ps = proxops.parse(args.prox)
    if args.lam2:
        if ps.name == "l1":
            ps = proxops.parse(f"elasticnet:{ps.params[0]:g}:{args.lam2:g}")
        elif ps.name == "elasticnet":
            ps = proxops.parse(
                f"elasticnet:{ps.params[0]:g}:{args.lam2:g}")
        else:
            raise SystemExit(f"--lam2 does not apply to prox {ps.name!r}")
    return proxops.canonical(ps)


def main(argv=None) -> int:
    args = parse_args(argv)
    import repro

    if args.list:
        for name in repro.algorithms():
            e = repro.REGISTRY[name]
            c = e.caps
            flags = [k for k, v in
                     (("distributed", c.distributed), ("spmd", c.spmd_ok),
                      ("async", c.is_async), ("fetch", c.accepts_fetch),
                      ("speeds", c.accepts_speeds), ("tau", c.accepts_tau),
                      ("prox", c.accepts_prox),
                      ("snapshot=" + "|".join(c.snapshots),
                       len(c.snapshots) > 1))
                     if v]
            print(f"{name:16s} [{', '.join(flags)}] {e.doc}")
        return 0
    if not args.sweep and not args.algo:
        print("need --algo NAME, --sweep, or --list")
        return 2

    n = args.n or (64 if args.quick else 1000)
    d = args.d or (8 if args.quick else 50)
    workers = args.workers or (2 if args.quick else 4)
    rounds = args.rounds or (3 if args.quick else 10)

    if args.backend == "spmd":
        # must precede the first jax operation (DESIGN.md §2); solve()
        # would do this too, but the CLI forces the full sweep width once
        from repro.core import spmd
        spmd.force_host_devices(max(workers, 1))
    from repro.launch.compile_cache import enable_compile_cache
    enable_compile_cache(args.compile_cache)

    from repro.config import ConvexConfig

    cfg = ConvexConfig(problem=args.problem, n=n, d=d, seed=args.seed,
                       outlier_frac=args.outlier_frac,
                       huber_delta=args.huber_delta)
    names = repro.algorithms() if args.sweep else [args.algo]

    from repro import obs

    if args.obs:
        obs.enable(args.obs, stream_every=args.stream_every)
    if args.profile:
        import jax
        jax.profiler.start_trace(args.profile)
    try:
        rows = []
        for name in names:
            spec, note = build_spec(args, name, workers, rounds)
            res = repro.solve(spec, cfg)
            rows.append(res.provenance())
            print(f"{name:16s} backend={spec.backend:4s} p={spec.p} "
                  f"rounds={spec.rounds} eta={res.spec.eta:.3g} "
                  f"final rel-grad-norm {res.final_rel:.3e} "
                  f"[{res.wall_s:.2f}s]{note}")
    finally:
        if args.profile:
            jax.profiler.stop_trace()
            print(f"wrote profiler trace to {args.profile}")
        if args.obs:
            obs.disable()
            print(f"wrote telemetry to {args.obs}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {len(rows)} provenance rows to {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
