"""Roofline machinery unit tests: the HLO cost model must weight loop
bodies by trip count (the whole reason it exists), price dots correctly,
and find collectives in sharded modules.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import analysis, hlo_cost


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_weighting():
    w = jnp.ones((64, 64), jnp.float32)

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    c = hlo_cost.analyze_hlo(_hlo(f, jnp.ones((64, 64), jnp.float32)))
    np.testing.assert_allclose(c.flops, 2 * 64**3 * 7, rtol=1e-6)


def test_nested_scan_weighting():
    w = jnp.ones((32, 32), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(cc, _):
                return cc @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    c = hlo_cost.analyze_hlo(_hlo(f, jnp.ones((32, 32), jnp.float32)))
    np.testing.assert_allclose(c.flops, 2 * 32**3 * 15, rtol=1e-6)


def test_xla_cost_analysis_counts_loops_once():
    """The reason hlo_cost exists — if XLA ever fixes this, we can switch."""
    w = jnp.ones((64, 64), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    compiled = jax.jit(f).lower(jnp.ones((64, 64), jnp.float32)).compile()
    # cost_analysis() returned a one-element list of dicts in older jax
    # and returns the dict directly in newer versions — accept both
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    static_flops = ca["flops"]
    assert static_flops < 2 * 64**3 * 2   # counts ~one body, not ten


def test_dot_flops_with_batch_dims():
    a = jnp.ones((4, 16, 32), jnp.float32)
    b = jnp.ones((4, 32, 8), jnp.float32)

    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    c = hlo_cost.analyze_hlo(_hlo(f, a, b))
    np.testing.assert_allclose(c.flops, 2 * 4 * 16 * 32 * 8, rtol=1e-6)


@pytest.mark.slow
def test_collective_detection_in_sharded_module():
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.roofline import hlo_cost
        mesh = jax.make_mesh((8,), ("data",))
        sh = NamedSharding(mesh, P("data", None))

        def f(x):
            return x.sum(axis=0, keepdims=True) * jnp.ones_like(x)

        t = jax.jit(f, in_shardings=sh, out_shardings=sh).lower(
            jax.ShapeDtypeStruct((64, 32), jnp.float32)).compile().as_text()
        c = hlo_cost.analyze_hlo(t)
        print("COLL", c.collective_bytes > 0)
    """)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "COLL True" in out.stdout


def test_dynamic_slice_not_charged_full_buffer():
    big = jnp.ones((1000, 256), jnp.float32)

    def f(big, i):
        def body(c, i):
            sl = jax.lax.dynamic_slice_in_dim(big, i, 1, 0)  # (1, 256)
            return c + sl.sum(), None
        out, _ = jax.lax.scan(body, jnp.zeros(()),
                              jnp.arange(100, dtype=jnp.int32))
        return out

    c = hlo_cost.analyze_hlo(_hlo(f, big, jnp.zeros((), jnp.int32)))
    # full-buffer charging would be >= 100 iters * 1MB = 100MB
    assert c.bytes_accessed < 20e6, c.bytes_accessed


def test_roofline_terms_and_bottleneck():
    from repro.config import INPUT_SHAPES, get_arch
    cfg = get_arch("qwen2-7b")
    shape = INPUT_SHAPES["train_4k"]
    hlo = """ENTRY %main (p: f32[8,8]) -> f32[8,8] {
  %p = f32[8,8]{1,0} parameter(0)
  %ar = f32[8,8]{1,0} all-reduce(%p), to_apply=%x
  ROOT %d = f32[8,8]{1,0} dot(%p, %ar), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    r = analysis.analyze(cfg, shape, "train", "pod", 256, {}, hlo, None)
    assert r.t_collective > 0
    assert r.bottleneck in ("compute", "memory", "collective")
    assert r.model_flops_global == 6.0 * cfg.active_param_count() * \
        shape.global_batch * shape.seq_len


def test_format_table_smoke():
    from repro.config import INPUT_SHAPES, get_arch
    cfg = get_arch("qwen2-7b")
    r = analysis.analyze(cfg, INPUT_SHAPES["train_4k"], "train", "pod", 256,
                         {}, "ENTRY %m (p: f32[2]) -> f32[2] {\n}\n", None)
    table = analysis.format_table([r])
    assert "qwen2-7b" in table and "train_4k" in table
