"""CentralVR, single-worker case (Algorithm 1 of the paper).

The update (Eqs. 5-6):

    x <- x - eta * ( grad f_i(x) - grad f_i(xtilde_i) + gbar )

with gbar = (1/n) sum_j grad f_j(xtilde_j) frozen over the epoch and
refreshed at epoch end from the running accumulator gtilde (line 11).

Storage uses the GLM scalar-residual structure (one scalar per sample, the
paper's own observation in §2.3); the regularizer gradient 2*lam*x is added
exactly outside the correction (see core/convex.py docstring).

Both sampling modes of the paper are implemented:
  * permutation sampling (§2.2, the practical default) — the accumulator
    identity makes one epoch an exact full-gradient step in aggregate
    (Eq. 7), which ``tests/test_paper_invariants.py`` checks bit-for-bit;
  * uniform-with-replacement (§3) — the regime of Theorem 1.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import convex, runtime
from repro.core.convex import Problem
from repro.obs import stage as obs_stage
from repro.obs import stream as obs_stream
from repro.prox import operators as proxops


class VRState(NamedTuple):
    x: jax.Array        # (d,) iterate
    table: jax.Array    # (n,) stored scalar residuals s_j = l'(a_j^T xtilde_j)
    gbar: jax.Array     # (d,) data term of the epoch-frozen mean gradient


# ---------------------------------------------------------------------------
# Initialization (Algorithm 1, line 2: one epoch of plain SGD)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("prox",))
def init_state(prob: Problem, eta: float, key: jax.Array,
               x0: Optional[jax.Array] = None, prox=None) -> VRState:
    x0 = jnp.zeros((prob.d,)) if x0 is None else x0
    perm = jax.random.permutation(key, prob.n)

    def body(carry, i):
        x, table, acc = carry
        s = convex.scalar_residual(prob, x, i)
        g = s * prob.A[i] + 2.0 * prob.lam * x
        table = table.at[i].set(s)
        acc = acc + s * prob.A[i] / prob.n
        x_next = proxops.apply_prox(prox, x - eta * g, eta)
        return (x_next, table, acc), None

    init = (x0, jnp.zeros((prob.n,)), jnp.zeros((prob.d,)))
    (x, table, acc), _ = jax.lax.scan(body, init, perm)
    return VRState(x=x, table=table, gbar=acc)


# ---------------------------------------------------------------------------
# One epoch
# ---------------------------------------------------------------------------

def epoch(prob: Problem, state: VRState, eta: float, order: jax.Array,
          *, track_iterates: bool = False, fused=None, prox=None):
    """Run n CentralVR updates visiting ``order`` (a permutation for the
    practical variant, i.i.d. uniform draws for the Theorem-1 variant).

    Returns the new state (gbar <- gtilde per line 11) and, optionally, the
    iterate trajectory for Lyapunov-function measurements.

    ``fused``: static kernel params from :func:`fused.make_params`, or
    ``None`` for the unfused oracle body.  The fused path runs the
    correction + step + accumulator write as one ``vr_update`` launch per
    step (DESIGN.md §Fused kernels hot-path); eta — and the prox epilogue,
    when one is configured — ride in the params, so ``prox`` here only
    shapes the unfused body.
    """
    if fused is not None:
        from repro.core import fused as fusedmod
        x, table, acc, traj = fusedmod.centralvr_epoch(
            prob.A, prob.b, prob.kind, state.x, state.table, state.gbar,
            order, fused, track=track_iterates)
        return VRState(x=x, table=table, gbar=acc), traj

    def body(carry, i):
        x, table, acc = carry
        s_new = convex.scalar_residual(prob, x, i)
        # v = (s_new - s_old) a_i + gbar + 2 lam x   (Eq. 6, scalar form)
        v = (s_new - table[i]) * prob.A[i] + state.gbar + 2.0 * prob.lam * x
        x_next = proxops.apply_prox(prox, x - eta * v, eta)
        table = table.at[i].set(s_new)
        acc = acc + s_new * prob.A[i] / prob.n
        return (x_next, table, acc), (x if track_iterates else None)

    init = (state.x, state.table, jnp.zeros((prob.d,)))
    (x, table, acc), traj = jax.lax.scan(body, init, order)
    # permutation sampling: every index is visited exactly once, so the
    # running accumulator IS the table mean (line 11: gbar <- gtilde)
    gbar_next = acc
    return VRState(x=x, table=table, gbar=gbar_next), traj


def epoch_uniform(prob: Problem, state: VRState, eta: float, key: jax.Array,
                  *, track_iterates: bool = False, fused=None, prox=None):
    """Theorem-1 regime: i.i.d. uniform sampling, gbar refreshed from table."""
    idx = jax.random.randint(key, (prob.n,), 0, prob.n)
    if fused is not None:
        from repro.core import fused as fusedmod
        x, table, _, traj = fusedmod.centralvr_epoch(
            prob.A, prob.b, prob.kind, state.x, state.table, state.gbar,
            idx, fused, track=track_iterates)
        gbar_next = convex.data_grad_from_scalars(prob, table)
        return VRState(x=x, table=table, gbar=gbar_next), traj

    def body(carry, i):
        x, table = carry
        s_new = convex.scalar_residual(prob, x, i)
        v = (s_new - table[i]) * prob.A[i] + state.gbar + 2.0 * prob.lam * x
        x_next = proxops.apply_prox(prox, x - eta * v, eta)
        table = table.at[i].set(s_new)
        return (x_next, table), (x if track_iterates else None)

    (x, table), traj = jax.lax.scan(body, (state.x, state.table), idx)
    gbar_next = convex.data_grad_from_scalars(prob, table)
    return VRState(x=x, table=table, gbar=gbar_next), traj


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("sampling", "fused", "stream", "prox"),
                   donate_argnames=("state",))
def _run_scan(prob: Problem, state: VRState, eta, g0, keys, sampling: str,
              fused=None, stream: bool = False, prox=None):
    """The whole Algorithm-1 run as one executable: a scan over epochs with
    the relative-grad-norm metric computed on device.  ``state`` is donated
    so the (n,) table and (d,) iterate/gbar update in place."""

    def one_epoch(state, xs):
        i, k = xs if stream else (None, xs)
        runtime.TRACES.inc("centralvr_epoch")
        if sampling == "permutation":
            order = jax.random.permutation(k, prob.n)
            new_state, _ = epoch(prob, state, eta, order, fused=fused,
                                 prox=prox)
        else:
            new_state, _ = epoch_uniform(prob, state, eta, k, fused=fused,
                                         prox=prox)
        rel = convex.rel_grad_norm(prob, new_state.x, g0, prox=prox, eta=eta)
        if stream:
            obs_stream.scan_metric("rel", i, rel)
        return new_state, rel

    # `stream` is STATIC: telemetry off traces the exact pre-telemetry
    # program (DESIGN.md §Observability)
    xs = (jnp.arange(keys.shape[0]), keys) if stream else keys
    return jax.lax.scan(one_epoch, state, xs)


def run(prob: Problem, *, eta: float, epochs: int, key: jax.Array,
        sampling: str = "permutation", x0: Optional[jax.Array] = None,
        backend: str = "vmap", mesh=None, fused=False, prox=None):
    """Full Algorithm 1. Returns (final state, per-epoch relative grad norms,
    gradient-evaluation counts). 1 gradient evaluation per iteration
    (Table 1 row 'CentralVR'), plus the n initialization evaluations.

    Device-resident: the epoch loop is a single jitted ``lax.scan``; the
    per-epoch metric trajectory comes back in one transfer (DESIGN.md §3).

    ``backend``: Algorithm 1 is single-worker, so ``"spmd"`` simply places
    the run on the mesh's first device — the parameter exists so launchers
    can address every driver through one switch (DESIGN.md §2).

    Validation is a ``solver.RunSpec`` build (DESIGN.md §Solver API).
    """
    from repro.core import fused as fusedmod
    from repro.core import solver
    spec = solver.RunSpec(algo="centralvr", eta=float(eta), rounds=epochs,
                          backend=backend, sampling=sampling, fused=fused,
                          prox=proxops.canonical(prox))
    px = proxops.parse(spec.prox) if spec.prox is not None else None
    if spec.sampling == "sparse":
        from repro.prox import lazy
        return lazy.run_sparse(prob, eta=eta, epochs=epochs, key=key,
                               x0=x0, prox=px)
    if spec.backend == "spmd":
        from repro.core import spmd
        return spmd.run_centralvr(prob, eta=eta, epochs=epochs, key=key,
                                  sampling=sampling, x0=x0, mesh=mesh,
                                  fused=fused, prox=spec.prox)
    # the fused tuple carries its own copy of the (elementwise) prox for
    # the kernel epilogue; ``px`` still shapes the init epoch, the metric,
    # and the unfused body — the epoch dispatcher ignores it when fused
    fused_t = fusedmod.make_params(spec.fused, eta, prob.lam, prox=px)
    k_init, k_run = jax.random.split(key)
    state = init_state(prob, eta, k_init, x0=x0, prox=px)
    g0 = convex.grad_norm0(prob, prox=px, eta=eta)
    keys = jax.random.split(k_run, epochs)
    state, rels = obs_stage.staged_call(
        _run_scan, prob, state, eta, g0, keys, _label="solve/centralvr",
        sampling=sampling, fused=fused_t, prox=px,
        stream=obs_stream.stream_active())
    grad_evals = prob.n * jnp.arange(2, epochs + 2)
    return state, rels, grad_evals
