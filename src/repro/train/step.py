"""Train / serve step factories.

The CentralVR worker model under SPMD (DESIGN.md §2): worker copies are a
LEADING AXIS on every state leaf, sharded over the worker mesh axes, and
the per-worker local step is vmapped — each device group computes its own
worker's step, no cross-worker traffic. The paper's epoch-boundary
server exchange is a mean over the worker axis (lowers to one all-reduce
over the worker mesh axes), executed only when step % (M*K) == M*K-1 —
this is THE communication-frequency lever the paper contributes, and it is
directly visible in the dry-run HLO as a conditional collective.

Modes (TrainConfig.vr / vr_workers):
  vr="none", W=1       — classic sync data-parallel SGD/Adam: loss is the
                         global-batch mean, GSPMD all-reduces gradients
                         EVERY step (the baseline the paper beats).
  vr=..., workers=data — paper-faithful CentralVR-Sync: full model copy
                         per data-axis group (dp_replicated).
  vr=..., workers=pod  — hierarchical (beyond-paper): FSDP inside a pod,
                         CentralVR across pods; cross-pod traffic only at
                         epoch boundaries.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, TrainConfig
from repro.launch import mesh as meshlib
from repro.models import model
from repro.optim import optimizers, vr_wrapper
from repro.sharding import specs

tmap = jax.tree_util.tree_map


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    vr_state: Any       # VRState or () when vr="none"
    step: jax.Array


def _loss(params, cfg, tcfg, tokens, fe, act_sharding=None):
    batch = {"tokens": tokens}
    if fe is not None:
        batch["frontend_embeds"] = fe
    return model.loss_fn(params, cfg, batch, remat=tcfg.remat,
                         act_sharding=act_sharding)


def _local_grads(params, cfg, tcfg, tokens, fe, act_sharding=None):
    """tokens: (A, mb, S); gradient accumulated over A microbatches.

    Gradients are taken against a COMPUTE-DTYPE (bf16) copy of the params,
    cast ONCE outside the accumulation loop: every per-microbatch FSDP
    weight all-gather then moves bf16 instead of the f32 masters, and the
    backward cotangents (incl. the deferred partial-sum all-reduces GSPMD
    emits for 2D-sharded weights) stay bf16 — measured ~2x collective cut
    on qwen1.5-110b/train_4k (EXPERIMENTS.md §Perf It.6). The f32 masters
    are touched only by the optimizer/VR update, once per step.
    """
    A = tokens.shape[0]
    compute = jnp.dtype(cfg.dtype)
    params_c = tmap(
        lambda p: p.astype(compute)
        if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
    lg = jax.value_and_grad(_loss)

    def acc(carry, xs):
        loss_acc, g_acc = carry
        t, f = xs
        loss, g = lg(params_c, cfg, tcfg, t, f, act_sharding)
        g_acc = tmap(lambda a, b: a + b.astype(jnp.float32) / A, g_acc, g)
        return (loss_acc + loss / A, g_acc), None

    g0 = tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if fe is None:
        def acc_nofe(carry, t):
            return acc(carry, (t, None))
        (loss, grads), _ = jax.lax.scan(acc_nofe, (jnp.zeros(()), g0), tokens)
    else:
        (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros(()), g0),
                                        (tokens, fe))
    return loss, grads


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh,
                    vr_workers: str = "none"):
    """Returns (train_step(state, tokens, fe), meta dict)."""
    W = meshlib.worker_count(mesh, vr_workers) if tcfg.vr != "none" else 1
    M = tcfg.vr_table_size
    K = tcfg.local_epoch
    comm_every = M * K
    opt = optimizers.make(tcfg.optimizer, tcfg.learning_rate,
                          tcfg.weight_decay)
    mode = tcfg.vr

    # In FSDP mode, pin the residual stream to batch-over-'data' so the
    # partitioner gathers per-layer WEIGHTS (ZeRO-3 semantics), not the
    # activations, and enable the explicit per-layer weight-gather context
    # (manual ZeRO; §Perf It.6). Only when the 'data' axis actually shards
    # the batch (W==1, or pod-level workers with data free).
    act_sharding = None
    if (not tcfg.dp_replicated and "data" in mesh.axis_names
            and mesh.devices.size > 1):
        w_axes = (meshlib.worker_axes(mesh, vr_workers)
                  if tcfg.vr != "none" else ())
        if "data" not in w_axes:
            act_sharding = NamedSharding(mesh, P("data", None, None))
            from repro.sharding import gather_ctx
            gather_ctx.enable(mesh, cfg, meshlib.mesh_axis_sizes(mesh))

    def per_worker(params, vr_state, opt_state, tokens, fe):
        loss, g = _local_grads(params, cfg, tcfg, tokens, fe, act_sharding)
        if mode == "svrg":
            _, g_snap = _local_grads(vr_state.snapshot, cfg, tcfg, tokens,
                                     fe, act_sharding)
            v, vr_state = vr_wrapper.correct(mode, vr_state, g, M,
                                             g_snap=g_snap, params=params)
        elif mode != "none":
            v, vr_state = vr_wrapper.correct(mode, vr_state, g, M,
                                             params=params)
        else:
            v = g
        updates, opt_state = opt.update(v, opt_state, params)
        params = optimizers.apply_updates(params, updates)
        return params, vr_state, opt_state, loss

    def train_step(state: TrainState, tokens, fe=None):
        """tokens: (W, A, mb, S) when W>1 else (A, mb, S)."""
        if W > 1:
            params, vr_state, opt_state, loss = jax.vmap(
                per_worker, in_axes=(0, 0, 0, 0, 0 if fe is not None else None)
            )(state.params, state.vr_state, state.opt_state, tokens, fe)
            loss = loss.mean()

            def communicate(args):
                params, vr_state = args
                # Algorithm 2 lines 16-18: average x and gbar across the
                # worker axis (one all-reduce over the worker mesh axes);
                # tables/accumulators stay local
                params = tmap(
                    lambda p: jnp.broadcast_to(p.mean(0, keepdims=True),
                                               p.shape).astype(p.dtype),
                    params)
                if mode != "none":
                    gbar = tmap(
                        lambda g: jnp.broadcast_to(g.mean(0, keepdims=True),
                                                   g.shape),
                        vr_state.gbar)
                    vr_state = vr_state._replace(gbar=gbar)
                return params, vr_state

            boundary = (state.step + 1) % comm_every == 0
            params, vr_state = jax.lax.cond(
                boundary, communicate, lambda a: a, (params, vr_state))
        else:
            params, vr_state, opt_state, loss = per_worker(
                state.params, state.vr_state, state.opt_state, tokens, fe)
        return TrainState(params, opt_state, vr_state, state.step + 1), {
            "loss": loss}

    meta = {"workers": W, "comm_every": comm_every,
            "grads_per_step": vr_wrapper.grads_per_step(mode),
            "vr_storage_mult": vr_wrapper.storage_multiplier(mode, M)}
    return train_step, meta


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key, W: int
                     ) -> TrainState:
    """Concrete init (small models / examples). Workers start identical."""
    params = model.init_params(cfg, key)
    opt = optimizers.make(tcfg.optimizer, tcfg.learning_rate,
                          tcfg.weight_decay)
    opt_state = opt.init(params)
    vr_state = (vr_wrapper.init_vr(tcfg.vr, params, tcfg.vr_table_size)
                if tcfg.vr != "none" else ())
    state = TrainState(params, opt_state, vr_state, jnp.zeros((), jnp.int32))
    if W > 1:
        def rep(x):
            return jnp.broadcast_to(x[None], (W,) + x.shape)
        state = TrainState(tmap(rep, params), tmap(rep, opt_state),
                           tmap(rep, vr_state) if vr_state != () else (),
                           state.step)
    return state


def eval_shape_train_state(cfg: ModelConfig, tcfg: TrainConfig, W: int):
    """Abstract TrainState (ShapeDtypeStructs, no allocation) — dry-run."""
    return jax.eval_shape(
        functools.partial(init_train_state, cfg, tcfg, W=W),
        jax.random.PRNGKey(0))


def state_shardings(state_shapes, cfg: ModelConfig, tcfg: TrainConfig, mesh,
                    vr_workers: str):
    w_axes = (meshlib.worker_axes(mesh, vr_workers)
              if tcfg.vr != "none" else ())
    spec_tree = specs.tree_specs(state_shapes, cfg,
                                 fsdp=not tcfg.dp_replicated,
                                 worker_axes=w_axes,
                                 axis_sizes=meshlib.mesh_axis_sizes(mesh))
    return tmap(lambda s: NamedSharding(mesh, s), spec_tree)


def batch_sharding(mesh, tcfg: TrainConfig, vr_workers: str, *, with_fe=False):
    w_axes = (meshlib.worker_axes(mesh, vr_workers)
              if tcfg.vr != "none" else ())
    data_axes = tuple(a for a in ("pod", "data")
                      if a in mesh.axis_names and a not in w_axes)
    tok = specs.batch_specs(w_axes, data_axes)
    out = {"tokens": NamedSharding(mesh, tok)}
    if with_fe:
        out["fe"] = NamedSharding(mesh, P(*(tuple(tok) + (None,))))
    return out


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig, act_sharding=None):
    def serve_step(params, token, cache, pos):
        return model.decode_step(params, cfg, token, cache, pos)

    def serve_prefill(params, tokens, fe=None):
        """Returns LAST-position logits (B, vocab) — the generation
        use-case. Materializing all (B, S, vocab) f32 logits costs 40
        GiB/device at 32k x 152k vocab (§Perf It.4); scoring workloads
        should stream positions instead."""
        batch = {"tokens": tokens}
        if fe is not None:
            batch["frontend_embeds"] = fe
        logits, _ = model.forward(params, cfg, batch, remat="none",
                                  act_sharding=act_sharding)
        return logits[:, -1]

    return serve_step, serve_prefill
