"""The run recorder: structured telemetry rows to a JSONL sink.

One :class:`Recorder` per run: every row it writes carries the run id, a
monotonic timestamp relative to the recorder's start, and a ``kind``
(``span`` / ``metric`` / ``event``) whose required fields are pinned by
``obs/schema.py``.  Rows are appended to one JSONL file under a lock, so
host callbacks firing from XLA's runtime threads (the streamed in-scan
metric path, ``obs/stream.py``) interleave safely with the main thread's
spans.

Telemetry is OFF by default: the module-level active recorder is ``None``
until :func:`enable` (or the :func:`recording` context manager) installs
one, and every producer in the runtime checks :func:`active` first — the
telemetry-off hot path is the exact pre-telemetry program (DESIGN.md
§Observability).  Zero dependencies beyond the stdlib; importing this
module never imports jax.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
import uuid
from typing import IO, Optional

SCHEMA_VERSION = 1

_LOCK = threading.Lock()          # guards the module-level active recorder
_ACTIVE: Optional["Recorder"] = None


class Recorder:
    """JSONL telemetry sink for one run.

    ``path`` is the target file (created/truncated on construction; parent
    directories are created).  ``stream_every`` gates the streamed in-scan
    metric cadence: a ``metric`` row is dropped unless
    ``step % stream_every == 0`` (the final step of a stream is the
    producer's responsibility — drivers emit every round and the recorder
    keeps the cadence subset, so enabling telemetry never changes what the
    scan computes).
    """

    def __init__(self, path: str, *, run_id: Optional[str] = None,
                 stream_every: int = 1):
        if stream_every < 1:
            raise ValueError(f"stream_every: need >= 1, got {stream_every}")
        self.path = str(path)
        self.run_id = run_id or (
            time.strftime("%Y%m%d-%H%M%S") + "-" + uuid.uuid4().hex[:6])
        self.stream_every = int(stream_every)
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        self._f: Optional[IO[str]] = open(self.path, "w")
        self.event("run_start", pid=os.getpid(),
                   wall=time.time())

    # -- row plumbing -------------------------------------------------------

    def _write(self, row: dict) -> None:
        with self._lock:
            if self._f is None:      # closed: late callbacks drop silently
                return
            self._f.write(json.dumps(row, default=str) + "\n")
            self._f.flush()

    def _row(self, kind: str, name: str, **fields) -> dict:
        return {"v": SCHEMA_VERSION, "run": self.run_id,
                "t": time.perf_counter() - self._t0,
                "kind": kind, "name": name, **fields}

    # -- producers ----------------------------------------------------------

    def event(self, name: str, **fields) -> None:
        """A point-in-time structured row (counters, provenance, rows)."""
        self._write(self._row("event", name, **fields))

    def metric(self, name: str, step: int, value: float, **fields) -> None:
        """A streamed scalar; cadence-gated by ``stream_every``."""
        step = int(step)
        if step % self.stream_every:
            return
        self._write(self._row("metric", name, step=step, value=float(value),
                              **fields))

    @contextlib.contextmanager
    def span(self, name: str, **fields):
        """Timed phase: emits one ``span`` row with ``t0``/``dur_s`` on
        exit (exceptions still close the span, flagged ``failed``)."""
        t0 = time.perf_counter() - self._t0
        try:
            yield self
        except BaseException:
            self._write(self._row("span", name, t0=t0,
                                  dur_s=time.perf_counter() - self._t0 - t0,
                                  failed=True, **fields))
            raise
        self._write(self._row("span", name, t0=t0,
                              dur_s=time.perf_counter() - self._t0 - t0,
                              **fields))

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None


# ---------------------------------------------------------------------------
# Module-level active recorder (the switch every producer checks)
# ---------------------------------------------------------------------------

def active() -> Optional[Recorder]:
    """The installed recorder, or None (telemetry off — the default)."""
    return _ACTIVE


def enable(path: str, *, run_id: Optional[str] = None,
           stream_every: int = 1) -> Recorder:
    """Install a recorder writing to ``path``; replaces (and closes) any
    previously active one."""
    global _ACTIVE
    rec = Recorder(path, run_id=run_id, stream_every=stream_every)
    with _LOCK:
        old, _ACTIVE = _ACTIVE, rec
    if old is not None:
        old.close()
    return rec


def disable() -> None:
    """Uninstall (and close) the active recorder, if any."""
    global _ACTIVE
    with _LOCK:
        old, _ACTIVE = _ACTIVE, None
    if old is not None:
        old.close()


@contextlib.contextmanager
def recording(path: str, *, run_id: Optional[str] = None,
              stream_every: int = 1):
    """Scoped telemetry: enable for the block, always disable after."""
    rec = enable(path, run_id=run_id, stream_every=stream_every)
    try:
        yield rec
    finally:
        disable()


@contextlib.contextmanager
def span(name: str, **fields):
    """Span against the ACTIVE recorder; an exact no-op when telemetry is
    off (so producers can wrap phases unconditionally)."""
    rec = active()
    if rec is None:
        yield None
    else:
        with rec.span(name, **fields):
            yield rec
