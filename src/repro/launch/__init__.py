# NOTE: launch.dryrun must be executed as a script/module entry point so its
# XLA_FLAGS device-count override precedes jax init; do not import it here.
from repro.launch import mesh  # noqa: F401
