"""Architecture zoo: unified decoder stack covering dense / MoE / SSM /
hybrid / VLM-stub / audio-stub families (see repro/configs)."""
from repro.models import attention, layers, model, moe, rglru, ssm, transformer  # noqa: F401
