"""Distributed algorithms of the paper, on the convex substrate:

  * CentralVR-Sync   (Algorithm 2)
  * CentralVR-Async  (Algorithm 3) — delta algebra + staleness simulator
  * Distributed SVRG (Algorithm 4)
  * Distributed SAGA (Algorithm 5)

Workers are simulated SPMD-style: the p local shards are stacked along a
leading axis and local epochs run under ``jax.vmap`` (numerically identical
to p independent processes; on the real mesh the same code runs under
``shard_map`` — see ``repro/train`` for the LM-scale version). The central
server of the paper is realized as an average across the worker axis — on
a TPU pod this is the epoch-boundary ``pmean`` (DESIGN.md §2).

Asynchrony: TPUs are SPMD, so CentralVR-Async's lock-free arrival order is
modelled by a deterministic staleness schedule: at event t (round-robin
over workers, optionally with heterogeneous speeds), worker s runs its
epoch from the central state it fetched at its *previous* event — i.e.
effective staleness p-1 events, the natural value for a round-robin
server. The *delta* form of the central update (x += dx/p) is kept exactly
as in Algorithm 3; the paper argues this is what makes fast workers unable
to bias the average.

Because every event depends only on the central state its worker fetched
at its OWN previous event, the schedule also admits a device-parallel
execution: ``backend="spmd"`` partitions it into concurrency waves
(``runtime.wave_partition``) and runs each wave under ``shard_map`` with
one worker per device, the delta pushes applied at the wave boundary in
event order — same algebra, same trajectories to float32 tolerance
(``core/spmd.py``, DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import convex, runtime
from repro.core.convex import Problem
from repro.obs import stage as obs_stage
from repro.obs import stream as obs_stream
from repro.prox import operators as proxops


class ShardedProblem(NamedTuple):
    """p stacked local shards; the global objective is the mean over all
    p * ns samples (§4 of the paper)."""

    A: jax.Array    # (p, ns, d)
    b: jax.Array    # (p, ns)
    lam: jnp.float32
    kind: str

    @property
    def p(self):
        return self.A.shape[0]

    @property
    def ns(self):
        return self.A.shape[1]

    @property
    def d(self):
        return self.A.shape[2]

    def local(self, s) -> Problem:
        return Problem(self.A[s], self.b[s], self.lam, self.kind)

    def merged(self) -> Problem:
        return Problem(self.A.reshape(-1, self.d), self.b.reshape(-1),
                       self.lam, self.kind)


jax.tree_util.register_pytree_node(
    ShardedProblem,
    lambda p: ((p.A, p.b, p.lam), p.kind),
    lambda kind, leaves: ShardedProblem(*leaves, kind=kind),
)


def check_backend(backend: str, *, spmd_ok: bool = True, algo: str = ""):
    """Validate a driver ``backend=`` argument (DESIGN.md §2).

    ``vmap`` is the stacked-axis single-device simulation and the default
    everywhere; ``spmd`` is the one-worker-per-device shard_map backend in
    ``core/spmd.py``.  Every driver has an SPMD program now — the async
    drivers run their event schedule as concurrency waves
    (``runtime.wave_partition``) — EXCEPT instant-fetch D-SAGA, whose
    events form a serial dependency chain (each event reads the central
    state as updated by the previous one): that mode passes
    ``spmd_ok=False`` and gets a clear error instead of a silent
    fallback."""
    if backend not in ("vmap", "spmd"):
        raise ValueError(
            f"unknown backend {backend!r}: expected 'vmap' or 'spmd'")
    if backend == "spmd" and not spmd_ok:
        raise NotImplementedError(
            f"{algo} is event-serial (every event reads the central state "
            "written by the previous event), so it has no worker-parallel "
            "SPMD execution; use backend='vmap', or fetch='stale' for the "
            "wave-parallel staleness construction (DESIGN.md §2)")
    return backend


def shard_problem(prob: Problem, p: int) -> ShardedProblem:
    n = (prob.n // p) * p
    return ShardedProblem(prob.A[:n].reshape(p, -1, prob.d),
                          prob.b[:n].reshape(p, -1), prob.lam, prob.kind)


def make_distributed(key, cfg) -> ShardedProblem:
    """Paper §6.2: each worker gets its OWN toy dataset of size cfg.n
    (total data scales linearly with workers — the weak-scaling setup)."""
    keys = jax.random.split(key, cfg.workers)
    probs = [convex.make_problem(k, cfg) for k in keys]
    return ShardedProblem(jnp.stack([q.A for q in probs]),
                          jnp.stack([q.b for q in probs]),
                          jnp.float32(cfg.lam), probs[0].kind)


# ---------------------------------------------------------------------------
# Local epoch primitives (vmapped over the worker axis)
# ---------------------------------------------------------------------------

def _local_centralvr_epoch(A, b, lam, kind, x, table, gbar, eta, perm,
                           fused=None, prox=None):
    """One CentralVR epoch on one worker's shard (Alg 2 lines 6-12).

    ``fused``: static kernel params from ``fused.make_params`` — routes
    the per-step update through the ``vr_update`` Pallas kernel (one
    launch per step) instead of the unfused oracle body.  ``prox``: a
    static ProxSpec (or None) — the proximal step is applied per local
    step, ``x <- prox_{eta*g}(x - eta*v)`` (DESIGN.md §Composite
    objectives); when ``fused`` is set the prox rides inside the kernel
    params and this argument is ignored (the tuple carries its own copy)."""
    if fused is not None:
        from repro.core import fused as fusedmod
        x, table, acc, _ = fusedmod.centralvr_epoch(
            A, b, kind, x, table, gbar, perm, fused)
        return x, table, acc
    prob = Problem(A, b, lam, kind)
    ns = A.shape[0]

    def body(carry, i):
        x, table, acc = carry
        s_new = convex.scalar_residual(prob, x, i)
        v = (s_new - table[i]) * A[i] + gbar + 2.0 * lam * x
        table = table.at[i].set(s_new)
        acc = acc + s_new * A[i] / ns
        return (proxops.apply_prox(prox, x - eta * v, eta), table, acc), None

    (x, table, acc), _ = jax.lax.scan(body, (x, table, jnp.zeros_like(x)), perm)
    return x, table, acc   # acc = local gtilde (data term)


def _local_sgd_epoch(A, b, lam, kind, x, eta, perm, prox=None):
    prob = Problem(A, b, lam, kind)
    ns = A.shape[0]

    def body(carry, i):
        x, table, acc = carry
        s = convex.scalar_residual(prob, x, i)
        g = s * A[i] + 2.0 * lam * x
        table = table.at[i].set(s)
        acc = acc + s * A[i] / ns
        return (proxops.apply_prox(prox, x - eta * g, eta), table, acc), None

    init = (x, jnp.zeros((ns,)), jnp.zeros_like(x))
    (x, table, acc), _ = jax.lax.scan(body, init, perm)
    return x, table, acc


class SyncState(NamedTuple):
    x: jax.Array        # (d,) shared iterate
    tables: jax.Array   # (p, ns) per-worker scalar tables
    gbar: jax.Array     # (d,) shared epoch-frozen mean gradient (data term)


# ---------------------------------------------------------------------------
# CentralVR-Sync (Algorithm 2)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("prox",))
def sync_init(sp: ShardedProblem, eta: float, key: jax.Array,
              prox=None) -> SyncState:
    """Init with one plain-SGD epoch per worker, then average (line 2).
    With a prox, locals take prox'd SGD steps and the central average gets
    one more prox (the wave-boundary ordering, DESIGN.md §2)."""
    keys = jax.random.split(key, sp.p)
    perms = jax.vmap(lambda k: jax.random.permutation(k, sp.ns))(keys)
    x0 = jnp.zeros((sp.d,))
    xs, tables, accs = jax.vmap(
        lambda A, b, perm: _local_sgd_epoch(A, b, sp.lam, sp.kind, x0, eta,
                                            perm, prox=prox)
    )(sp.A, sp.b, perms)
    return SyncState(x=proxops.apply_prox(prox, xs.mean(0), eta),
                     tables=tables, gbar=accs.mean(0))


def sync_round(sp: ShardedProblem, st: SyncState, eta: float, key: jax.Array,
               fused=None, prox=None) -> SyncState:
    """One communication round: a full local epoch everywhere, then the
    central average of (x, gbar) — Algorithm 2 lines 4-18.  Composite
    objectives apply the prox per local step AND once more after the
    central average: the averaged iterate of prox'd locals is not itself
    a prox output (mean of sparse vectors is dense), so the wave boundary
    re-projects it (DESIGN.md §2 ordering note)."""
    keys = jax.random.split(key, sp.p)
    perms = jax.vmap(lambda k: jax.random.permutation(k, sp.ns))(keys)
    xs, tables, accs = jax.vmap(
        lambda A, b, table, perm: _local_centralvr_epoch(
            A, b, sp.lam, sp.kind, st.x, table, st.gbar, eta, perm,
            fused=fused, prox=prox)
    )(sp.A, sp.b, st.tables, perms)
    # central node: average x and gbar (lines 16-18); on a pod: pmean
    return SyncState(x=proxops.apply_prox(prox, xs.mean(0), eta),
                     tables=tables, gbar=accs.mean(0))


@functools.partial(jax.jit, static_argnames=("fused", "stream", "prox"),
                   donate_argnames=("st",))
def _sync_scan(sp: ShardedProblem, st: SyncState, eta, g0, keys, fused=None,
               stream: bool = False, prox=None):
    merged = sp.merged()

    def step(st, xs):
        i, k = xs if stream else (None, xs)
        runtime.TRACES.inc("sync_round")
        st = sync_round(sp, st, eta, k, fused=fused, prox=prox)
        rel = convex.rel_grad_norm(merged, st.x, g0, prox=prox, eta=eta)
        if stream:
            obs_stream.scan_metric("rel", i, rel)
        return st, rel

    # `stream` is STATIC: the telemetry-off trace below is byte-identical
    # to the pre-telemetry program (DESIGN.md §Observability)
    xs = (jnp.arange(keys.shape[0]), keys) if stream else keys
    return jax.lax.scan(step, st, xs)


def run_sync(sp: ShardedProblem, *, eta: float, rounds: int, key: jax.Array,
             backend: str = "vmap", mesh=None, fused=False, prox=None):
    """Algorithm 2 end to end: one jitted scan over communication rounds,
    metric on device, state donated (DESIGN.md §3).

    ``backend="spmd"`` runs the same rounds under ``shard_map`` with one
    worker per device of ``mesh`` (default: a mesh over the first p
    devices); the central average becomes a ``pmean`` (DESIGN.md §2).

    Thin wrapper contract (DESIGN.md §Solver API): argument validation is
    a ``solver.RunSpec`` build, so this signature and ``solve()`` fail
    identically on invalid combinations."""
    from repro.core import fused as fusedmod
    from repro.core import solver
    spec = solver.RunSpec(algo="centralvr_sync", p=sp.p, eta=float(eta),
                          rounds=rounds, backend=backend, fused=fused,
                          prox=proxops.canonical(prox))
    if spec.backend == "spmd":
        from repro.core import spmd
        return spmd.run_sync(sp, eta=eta, rounds=rounds, key=key, mesh=mesh,
                             fused=fused, prox=spec.prox)
    px = proxops.parse(spec.prox) if spec.prox is not None else None
    fused_t = fusedmod.make_params(spec.fused, eta, sp.lam, prox=px)
    k_init, k_run = jax.random.split(key)
    st = sync_init(sp, eta, k_init, prox=px)
    g0 = convex.grad_norm0(sp.merged(), prox=px, eta=eta)
    keys = jax.random.split(k_run, rounds)
    return obs_stage.staged_call(
        _sync_scan, sp, st, eta, g0, keys,
        _label="solve/centralvr_sync",
        fused=fused_t, stream=obs_stream.stream_active(), prox=px)


# ---------------------------------------------------------------------------
# CentralVR-Async (Algorithm 3)
# ---------------------------------------------------------------------------

class AsyncState(NamedTuple):
    x_c: jax.Array        # central iterate
    gbar_c: jax.Array     # central mean gradient (data term)
    tables: jax.Array     # (p, ns)
    x_old: jax.Array      # (p, d) each worker's previous sent x
    gbar_old: jax.Array   # (p, d) each worker's previous sent gbar
    x_fetch: jax.Array    # (p, d) central x as of each worker's last fetch
    gbar_fetch: jax.Array # (p, d)


def async_init(sp: ShardedProblem, eta: float, key: jax.Array,
               prox=None) -> AsyncState:
    st = sync_init(sp, eta, key, prox=prox)
    p = sp.p
    # Algorithm 3 line 2 sets x_old = gbar_old = 0 with x_c = x0; starting
    # instead from the SGD-init iterate requires the workers' "previous
    # contribution" to equal that iterate, otherwise the first p events
    # add the init point a second time (x_c <- x_init + mean(x_s) instead
    # of mean(x_s)). Same algebra, transient removed.
    return AsyncState(
        x_c=st.x, gbar_c=st.gbar, tables=st.tables,
        x_old=jnp.tile(st.x, (p, 1)), gbar_old=jnp.tile(st.gbar, (p, 1)),
        x_fetch=jnp.tile(st.x, (p, 1)), gbar_fetch=jnp.tile(st.gbar, (p, 1)),
    )


def async_event(sp: ShardedProblem, st: AsyncState, s, eta: float,
                key: jax.Array, fused=None, prox=None) -> AsyncState:
    """Worker s completes one local epoch computed from its stale fetch,
    sends (dx, dgbar); the central node applies x += dx/p (Alg 3 l.18-21);
    the worker then fetches the fresh central state.

    Composite objectives: the central accumulator x_c must stay LINEAR in
    the pushed deltas (the spmd wave backend reconstructs fetches by
    prefix sums over them), so the prox is never applied to x_c itself —
    each worker prox's its FETCHED copy at epoch start instead, and the
    metric/final iterate evaluate at ``prox(x_c)`` (DESIGN.md §Composite
    objectives).

    ``s`` may be a concrete int or a TRACED index: the stacked (p, ns)
    tables are read with dynamic gathers (``sp.A[s]``) and written with
    ``.at[s].set``, so one compiled executable serves every worker — the
    event schedule becomes data, not code (DESIGN.md §3)."""
    p = sp.p
    alpha = 1.0 / p
    perm = jax.random.permutation(key, sp.ns)
    x_new, table, gtilde = _local_centralvr_epoch(
        sp.A[s], sp.b[s], sp.lam, sp.kind,
        proxops.apply_prox(prox, st.x_fetch[s], eta), st.tables[s],
        st.gbar_fetch[s], eta, perm, fused=fused, prox=prox)
    dx = x_new - st.x_old[s]
    dg = gtilde - st.gbar_old[s]
    x_c = st.x_c + alpha * dx
    gbar_c = st.gbar_c + alpha * dg
    return AsyncState(
        x_c=x_c, gbar_c=gbar_c,
        tables=st.tables.at[s].set(table),
        x_old=st.x_old.at[s].set(x_new),
        gbar_old=st.gbar_old.at[s].set(gtilde),
        x_fetch=st.x_fetch.at[s].set(x_c),        # receive updated x
        gbar_fetch=st.gbar_fetch.at[s].set(gbar_c),
    )


@functools.partial(jax.jit, static_argnames=("fused", "stream", "prox"),
                   donate_argnames=("st",))
def _async_scan(sp: ShardedProblem, st: AsyncState, eta, g0, schedule, keys,
                fused=None, stream: bool = False, prox=None):
    """The full event schedule in one executable: an outer scan over rounds
    (emitting the metric every p events, as the host loop did) nests an
    inner scan over each round's p events.  The worker index is TRACED —
    exactly one trace/compile of ``async_event`` regardless of p."""
    merged = sp.merged()

    def one_round(st, xs):
        if stream:
            i, sched_row, key_row = xs
        else:
            sched_row, key_row = xs

        def one_event(st, sk):
            runtime.TRACES.inc("async_event")
            s, k = sk
            return async_event(sp, st, s, eta, k, fused=fused,
                               prox=prox), None

        st, _ = jax.lax.scan(one_event, st, (sched_row, key_row))
        # metric at the feasible point prox(x_c) — x_c itself stays linear
        rel = convex.rel_grad_norm(
            merged, proxops.apply_prox(prox, st.x_c, eta), g0,
            prox=prox, eta=eta)
        if stream:
            obs_stream.scan_metric("rel", i, rel)
        return st, rel

    xs = ((jnp.arange(schedule.shape[0]), schedule, keys) if stream
          else (schedule, keys))
    return jax.lax.scan(one_round, st, xs)


def run_async(sp: ShardedProblem, *, eta: float, rounds: int, key: jax.Array,
              speeds=None, backend: str = "vmap", mesh=None, fused=False,
              prox=None):
    """``rounds`` epochs per worker. ``speeds``: optional per-worker relative
    speeds; faster workers fire proportionally more events (heterogeneous
    cluster simulation). Default: round-robin (staleness p-1).

    The speed-weighted schedule is precomputed on the host, shipped as a
    (rounds, p) int32 array, and scanned on device in a single compile.

    ``backend="spmd"`` executes the SAME schedule as rounds of concurrent
    events: each worker's epoch starts from the central state it fetched
    at its previous event — a per-worker stale snapshot already carried by
    the delta algebra — so all events of a concurrency wave
    (``runtime.wave_partition``) run in parallel under ``shard_map``, one
    worker per device of ``mesh``, and the ``x += dx/p`` delta pushes are
    applied at the wave boundary in the schedule's event order
    (DESIGN.md §2).  Trajectories match this event-serial path within
    float32 tolerance (pinned by ``tests/test_spmd_backend.py``).

    Validation is a ``solver.RunSpec`` build (DESIGN.md §Solver API)."""
    from repro.core import fused as fusedmod
    from repro.core import solver
    spec = solver.RunSpec(
        algo="centralvr_async", p=sp.p, eta=float(eta), rounds=rounds,
        backend=backend, fused=fused,
        speeds=None if speeds is None else tuple(float(s) for s in speeds),
        prox=proxops.canonical(prox))
    if spec.backend == "spmd":
        from repro.core import spmd
        return spmd.run_async(sp, eta=eta, rounds=rounds, key=key,
                              speeds=spec.speeds, mesh=mesh, fused=fused,
                              prox=spec.prox)
    px = proxops.parse(spec.prox) if spec.prox is not None else None
    fused_t = fusedmod.make_params(spec.fused, eta, sp.lam, prox=px)
    k_init, k_run = jax.random.split(key)
    st = async_init(sp, eta, k_init, prox=px)
    g0 = convex.grad_norm0(sp.merged(), prox=px, eta=eta)
    schedule = runtime.event_schedule(sp.p, rounds, spec.speeds)
    keys = jax.random.split(k_run, schedule.size)
    sched, keys = runtime.per_round(schedule, keys, sp.p)
    return obs_stage.staged_call(
        _async_scan, sp, st, eta, g0, jnp.asarray(sched), keys,
        _label="solve/centralvr_async",
        fused=fused_t, stream=obs_stream.stream_active(), prox=px)


# ---------------------------------------------------------------------------
# Distributed SVRG (Algorithm 4)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("tau", "fused", "stream", "prox",
                                    "snapshot"),
                   donate_argnames=("x",))
def _dsvrg_scan(sp: ShardedProblem, x, eta, g0, keys, tau: int, fused=None,
                stream: bool = False, prox=None, snapshot: str = "last",
                snap_idx=None):
    """``snapshot`` selects the next-round anchor each worker contributes
    (then averaged across workers): ``last`` = final inner iterate (the
    historical program, byte-identical), ``avg`` = mean of the tau inner
    iterates, ``rand`` = the inner iterate at a host-precomputed uniform
    index (``snap_idx``, one shared draw per round so vmap and spmd pick
    the same one) — the SVRG options of Johnson & Zhang.  ``prox`` applies
    per inner step and once more after the cross-worker average."""
    merged = sp.merged()

    def round_(x, xs):
        if snapshot == "rand":
            xs, r = xs[:-1], xs[-1]
            xs = xs[0] if len(xs) == 1 else xs
        step_i, k = xs if stream else (None, xs)
        runtime.TRACES.inc("dsvrg_round")
        xbar = x
        gbar = convex.full_grad(merged, xbar)   # sync step (line 5)

        def local(A, b, kk):
            prob = Problem(A, b, sp.lam, sp.kind)
            idx = jax.random.randint(kk, (tau,), 0, sp.ns)

            if fused is not None:
                # snapshot=="last" here: run_dsvrg falls back to unfused
                # for avg/rand (and RunSpec refuses an explicit fused=True)
                from repro.core import fused as fusedmod
                sbar = convex.scalar_residual_all(prob, xbar)
                return fusedmod.svrg_steps(A, b, sp.kind, xbar, sbar, gbar,
                                           idx, fused)

            def body(xl, i):
                g = (convex.scalar_residual(prob, xl, i) * A[i]
                     - convex.scalar_residual(prob, xbar, i) * A[i]
                     + gbar + 2.0 * sp.lam * (xl - xbar))
                xl = proxops.apply_prox(prox, xl - eta * g, eta)
                return xl, (xl if snapshot != "last" else None)

            xl, traj = jax.lax.scan(body, xbar, idx)
            if snapshot == "avg":
                return traj.mean(0)
            if snapshot == "rand":
                return traj[r]
            return xl

        xl_all = jax.vmap(local)(sp.A, sp.b, jax.random.split(k, sp.p))
        x = proxops.apply_prox(prox, xl_all.mean(0), eta)
        rel = convex.rel_grad_norm(merged, x, g0, prox=prox, eta=eta)
        if stream:
            obs_stream.scan_metric("rel", step_i, rel)
        return x, rel

    xs = (jnp.arange(keys.shape[0]), keys) if stream else keys
    if snapshot == "rand":
        xs = (xs + (snap_idx,)) if isinstance(xs, tuple) else (xs, snap_idx)
    return jax.lax.scan(round_, x, xs)


def run_dsvrg(sp: ShardedProblem, *, eta: float, rounds: int, key: jax.Array,
              tau: int = 0, backend: str = "vmap", mesh=None, fused=False,
              prox=None, snapshot: str = "last"):
    """tau local steps from the shared snapshot (default tau = 2*ns, the
    paper's recommendation from [17]); gbar = full gradient at the snapshot
    (the synchronization step); then average x across workers.
    2 gradient evaluations per iteration (Table 1).  One jitted scan over
    rounds (DESIGN.md §3); ``backend="spmd"`` places one worker per mesh
    device and the averages/sync gradient become collectives.

    ``snapshot`` in {"last", "avg", "rand"} picks the anchor each worker
    feeds the cross-worker average (see ``_dsvrg_scan``); avg/rand need
    the inner trajectory, which the fused kernel does not materialize, so
    they run unfused (``fused="auto"`` silently falls back here,
    ``fused=True`` is refused by RunSpec pre-JAX).

    Validation is a ``solver.RunSpec`` build (DESIGN.md §Solver API)."""
    from repro.core import fused as fusedmod
    from repro.core import solver
    spec = solver.RunSpec(algo="dsvrg", p=sp.p, eta=float(eta),
                          rounds=rounds, backend=backend, tau=tau or None,
                          fused=fused, prox=proxops.canonical(prox),
                          snapshot=snapshot)
    if spec.backend == "spmd":
        from repro.core import spmd
        return spmd.run_dsvrg(sp, eta=eta, rounds=rounds, key=key, tau=tau,
                              mesh=mesh, fused=fused, prox=spec.prox,
                              snapshot=snapshot)
    px = proxops.parse(spec.prox) if spec.prox is not None else None
    fused_t = (fusedmod.make_params(spec.fused, eta, sp.lam, prox=px)
               if snapshot == "last" else None)
    tau = tau or 2 * sp.ns
    x = jnp.zeros((sp.d,))
    g0 = convex.grad_norm0(sp.merged(), prox=px, eta=eta)
    keys = jax.random.split(key, rounds)
    # one shared uniform anchor index per round, drawn off the main key
    # stream (fold_in) so last/avg trajectories are unaffected
    snap_idx = (jax.random.randint(jax.random.fold_in(key, 1), (rounds,),
                                   0, tau)
                if snapshot == "rand" else None)
    return obs_stage.staged_call(
        _dsvrg_scan, sp, x, eta, g0, keys, _label="solve/dsvrg",
        tau=tau, fused=fused_t, stream=obs_stream.stream_active(),
        prox=px, snapshot=snapshot, snap_idx=snap_idx)


# ---------------------------------------------------------------------------
# Distributed SAGA (Algorithm 5)
# ---------------------------------------------------------------------------

class DSagaState(NamedTuple):
    x_c: jax.Array
    gbar_c: jax.Array
    tables: jax.Array     # (p, ns) scalar residuals
    x_old: jax.Array      # (p, d)
    gbar_old: jax.Array   # (p, d) — literal mode: previous local final gbar


def _local_saga_steps(A, b, lam, kind, x, table, gbar, eta, n_global, idx,
                      fused=None, prox=None):
    """tau local SAGA steps on one worker's shard (Alg 5 lines 5-11): VR
    step from the scalar table, running-mean gbar update with the GLOBAL
    1/n scaling (line 9, §5.2).  The single spelling shared by both fetch
    disciplines and the spmd wave runner — the vmap-vs-spmd agreement
    pins rely on these being the same arithmetic (and, when ``fused`` is
    set, the same single-launch kernel step — the fused tuple carries its
    own prox copy)."""
    if fused is not None:
        from repro.core import fused as fusedmod
        return fusedmod.saga_steps(A, b, kind, x, table, gbar, n_global,
                                   idx, fused)
    prob = Problem(A, b, lam, kind)

    def body(carry, i):
        x, table, gbar = carry
        s_new = convex.scalar_residual(prob, x, i)
        v = (s_new - table[i]) * A[i] + gbar + 2.0 * lam * x
        gbar = gbar + (s_new - table[i]) * A[i] / n_global
        table = table.at[i].set(s_new)
        return (proxops.apply_prox(prox, x - eta * v, eta), table, gbar), None

    (x, table, gbar), _ = jax.lax.scan(body, (x, table, gbar), idx)
    return x, table, gbar


def dsaga_event(sp: ShardedProblem, st: DSagaState, s, eta: float, tau: int,
                key, literal_scaling: bool = False,
                fused=None, prox=None) -> DSagaState:
    """Worker s: tau local SAGA steps from its fetched central state, then
    the delta push (Alg 5 lines 12-20). Events interleave round-robin — the
    async arrival order, one at a time (the paper's implementation is
    'locked': one worker updates the server at a time, §6.2).  ``s`` may be
    a traced index (dynamic gathers on the stacked tables), so one compiled
    event function serves all p workers."""
    alpha = 1.0 / sp.p
    alpha_g = alpha if literal_scaling else 1.0
    idx = jax.random.randint(key, (tau,), 0, sp.ns)
    # prox the FETCHED copy at block start; x_c itself stays linear in the
    # pushed deltas (same rationale as async_event)
    x, table, gbar = _local_saga_steps(
        sp.A[s], sp.b[s], sp.lam, sp.kind,
        proxops.apply_prox(prox, st.x_c, eta), st.tables[s], st.gbar_c,
        eta, sp.p * sp.ns, idx, fused=fused, prox=prox)
    dx = x - st.x_old[s]
    if literal_scaling:
        dg = gbar - st.gbar_old[s]       # printed line 13
    else:
        dg = gbar - st.gbar_c            # own contribution only
    return DSagaState(
        x_c=st.x_c + alpha * dx,
        gbar_c=st.gbar_c + alpha_g * dg,
        tables=st.tables.at[s].set(table),
        x_old=st.x_old.at[s].set(x),
        gbar_old=st.gbar_old.at[s].set(gbar),
    )


@jax.jit
def dsaga_init(sp: ShardedProblem) -> DSagaState:
    """Tables at x0 (Alg 5 lines 2-3), central gbar = global table mean."""
    x0 = jnp.zeros((sp.d,))
    s_all = jax.vmap(lambda A, b: convex.scalar_residual_all(
        Problem(A, b, sp.lam, sp.kind), x0))(sp.A, sp.b)
    gbar0 = jnp.einsum("psd,ps->d", sp.A, s_all) / (sp.p * sp.ns)
    return DSagaState(x_c=x0, gbar_c=gbar0, tables=s_all,
                      x_old=jnp.tile(x0, (sp.p, 1)),
                      gbar_old=jnp.tile(gbar0, (sp.p, 1)))


def dsaga_init_stale(sp: ShardedProblem) -> AsyncState:
    """Stale-fetch D-SAGA start state: ``dsaga_init`` plus per-worker fetch
    snapshots initialized to the central values (every worker's first event
    starts from the true t=0 state, exactly like ``async_init``)."""
    st = dsaga_init(sp)
    return AsyncState(
        x_c=st.x_c, gbar_c=st.gbar_c, tables=st.tables,
        x_old=st.x_old, gbar_old=st.gbar_old,
        x_fetch=jnp.tile(st.x_c, (sp.p, 1)),
        gbar_fetch=jnp.tile(st.gbar_c, (sp.p, 1)),
    )


def dsaga_event_stale(sp: ShardedProblem, st: AsyncState, s, eta: float,
                      tau: int, key, literal_scaling: bool = False,
                      fused=None, prox=None) -> AsyncState:
    """Algorithm 5 with Algorithm 3's fetch discipline: worker s runs its
    tau local SAGA steps from the central state it fetched at its PREVIOUS
    event (``st.x_fetch[s]``/``st.gbar_fetch[s]``) instead of the
    instantaneous central state ``dsaga_event`` reads.  This is the
    event-serial reference for the spmd-async backend (DESIGN.md §2): the
    stale snapshot removes the event-to-event serial dependency, so all
    events of a concurrency wave commute and can run under ``shard_map``.
    The delta algebra is unchanged — dx against the worker's previous sent
    x, dgbar against its fetched gbar (its own table-update contribution,
    the §5.2 semantics), server coefficients exactly as ``dsaga_event``.
    ``s`` may be a traced index, as everywhere in this runtime."""
    alpha = 1.0 / sp.p
    alpha_g = alpha if literal_scaling else 1.0
    idx = jax.random.randint(key, (tau,), 0, sp.ns)
    x, table, gbar = _local_saga_steps(
        sp.A[s], sp.b[s], sp.lam, sp.kind,
        proxops.apply_prox(prox, st.x_fetch[s], eta), st.tables[s],
        st.gbar_fetch[s], eta, sp.p * sp.ns, idx, fused=fused, prox=prox)
    dx = x - st.x_old[s]
    if literal_scaling:
        dg = gbar - st.gbar_old[s]       # printed line 13
    else:
        dg = gbar - st.gbar_fetch[s]     # own contribution only
    x_c = st.x_c + alpha * dx
    gbar_c = st.gbar_c + alpha_g * dg
    return AsyncState(
        x_c=x_c, gbar_c=gbar_c,
        tables=st.tables.at[s].set(table),
        x_old=st.x_old.at[s].set(x),
        gbar_old=st.gbar_old.at[s].set(gbar),
        x_fetch=st.x_fetch.at[s].set(x_c),
        gbar_fetch=st.gbar_fetch.at[s].set(gbar_c),
    )


@functools.partial(jax.jit,
                   static_argnames=("tau", "literal_scaling", "stale",
                                    "fused", "stream", "prox"),
                   donate_argnames=("st",))
def _dsaga_scan(sp: ShardedProblem, st, eta, g0, schedule, keys,
                tau: int, literal_scaling: bool, stale: bool, fused=None,
                stream: bool = False, prox=None):
    """One scan runner for both fetch disciplines: ``stale`` selects the
    event function (and the matching state type — DSagaState for instant,
    AsyncState for stale) at trace time."""
    merged = sp.merged()
    event = dsaga_event_stale if stale else dsaga_event
    trace_key = "dsaga_event_stale" if stale else "dsaga_event"

    def one_round(st, xs):
        if stream:
            i, sched_row, key_row = xs
        else:
            sched_row, key_row = xs

        def one_event(st, sk):
            runtime.TRACES.inc(trace_key)
            s, k = sk
            return event(sp, st, s, eta, tau, k, literal_scaling,
                         fused=fused, prox=prox), None

        st, _ = jax.lax.scan(one_event, st, (sched_row, key_row))
        rel = convex.rel_grad_norm(
            merged, proxops.apply_prox(prox, st.x_c, eta), g0,
            prox=prox, eta=eta)
        if stream:
            obs_stream.scan_metric("rel", i, rel)
        return st, rel

    xs = ((jnp.arange(schedule.shape[0]), schedule, keys) if stream
          else (schedule, keys))
    return jax.lax.scan(one_round, st, xs)


def run_dsaga(sp: ShardedProblem, *, eta: float, rounds: int, key: jax.Array,
              tau: int = 100, literal_scaling: bool = False,
              backend: str = "vmap", fetch: str | None = None,
              speeds=None, mesh=None, fused=False, prox=None):
    """Algorithm 5. Each worker runs tau SAGA steps with its local table;
    the running mean gbar is updated with the GLOBAL 1/n scaling (§5.2);
    deltas (dx, dgbar) are pushed with server coefficient alpha.

    Delta semantics for gbar: Algorithm 5 as literally printed computes
    dgbar against the worker's own previous *final* local gbar and applies
    server coefficient alpha=1/p. That delta embeds the central drift
    caused by OTHER workers between the two events (the local gbar starts
    from the fetched central value), so with alpha=1 it echoes and
    diverges, and with alpha=1/p the server's gbar lags the true table
    mean by a factor ~p and convergence plateaus (we measured both; see
    EXPERIMENTS.md §D-SAGA delta semantics). The §5.2 prose — "the previous
    contribution to the average from that local worker is just replaced by
    the new contribution ... gbar is built from the most recent gradient
    computations at each index" — pins down the intended semantics:
    the delta must isolate the worker's OWN table-update contribution,
    i.e. dgbar = gbar_local_final - gbar_fetched (the sum of its 1/n-scaled
    table updates this block), applied with coefficient 1 (indices are
    disjoint across workers, so the sum keeps the server gbar exactly equal
    to the global table mean at every event). That is the default here;
    ``literal_scaling=True`` reproduces the printed lines for comparison.

    Fetch discipline: the default ``fetch="instant"`` is the locked serial
    model (each event reads the central state left by the previous event —
    the seed semantics, pinned against ``host_loop.run_dsaga``);
    ``fetch="stale"`` is Algorithm 3's discipline applied to Algorithm 5
    (each worker starts from the central state fetched at its own previous
    event), which removes the event-to-event serial dependency and is
    therefore what the wave-parallel spmd backend executes.
    ``backend="spmd"`` defaults to (and requires) ``fetch="stale"``:
    instant fetch has no worker-parallel program and raises.  ``speeds``
    weights the event schedule exactly as in :func:`run_async`.

    Like CentralVR-Async, the whole event schedule runs as one jitted scan
    with a traced worker index — one executable regardless of p.

    Validation — including the fetch-default resolution and the
    fetch='instant'+spmd refusal — is a ``solver.RunSpec`` build
    (DESIGN.md §Solver API).
    """
    from repro.core import fused as fusedmod
    from repro.core import solver
    spec = solver.RunSpec(
        algo="dsaga", p=sp.p, eta=float(eta), rounds=rounds,
        backend=backend, fetch=fetch,
        speeds=None if speeds is None else tuple(float(s) for s in speeds),
        tau=tau, fused=fused, prox=proxops.canonical(prox))
    fetch = spec.fetch
    if spec.backend == "spmd":
        from repro.core import spmd
        return spmd.run_dsaga(sp, eta=eta, rounds=rounds, key=key, tau=tau,
                              literal_scaling=literal_scaling,
                              speeds=spec.speeds, mesh=mesh, fused=fused,
                              prox=spec.prox)
    px = proxops.parse(spec.prox) if spec.prox is not None else None
    fused_t = fusedmod.make_params(spec.fused, eta, sp.lam, prox=px)
    g0 = convex.grad_norm0(sp.merged(), prox=px, eta=eta)
    schedule = runtime.event_schedule(sp.p, rounds, spec.speeds)
    keys = jax.random.split(key, schedule.size)
    sched, keys = runtime.per_round(schedule, keys, sp.p)
    st = dsaga_init_stale(sp) if fetch == "stale" else dsaga_init(sp)
    return obs_stage.staged_call(
        _dsaga_scan, sp, st, eta, g0, jnp.asarray(sched), keys,
        _label="solve/dsaga", tau=tau, literal_scaling=literal_scaling,
        stale=(fetch == "stale"), fused=fused_t,
        stream=obs_stream.stream_active(), prox=px)
