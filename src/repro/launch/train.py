"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
        --steps 96 --vr centralvr --num-workers 4 --backend spmd

Default runtime is the epoch-scan loop (``train/loop.py``, DESIGN.md §3
"LM epoch scan"): whole communication epochs as one jitted scan, with
``--backend vmap`` (W stacked workers on one device) or ``--backend spmd``
(one worker per device of a worker mesh; on CPU the devices are simulated,
forced before jax initializes). ``--runtime host`` selects the retained
per-step reference loop (``train/host_loop.py``), which also serves the
production meshes via --mesh.
"""
from __future__ import annotations

import argparse


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU-smoke reduced variant")
    ap.add_argument("--steps", type=int, default=48,
                    help="scan runtime: must be a multiple of M*K")
    ap.add_argument("--epochs", type=int, default=0,
                    help="communication epochs (overrides --steps)")
    ap.add_argument("--runtime", default="scan", choices=["scan", "host"],
                    help="epoch-scan runtime vs per-step reference loop")
    ap.add_argument("--backend", default="vmap", choices=["vmap", "spmd"],
                    help="scan runtime: simulated worker stack vs one "
                         "worker per mesh device")
    ap.add_argument("--num-workers", type=int, default=1,
                    help="CentralVR worker count for the scan runtime")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adam")
    ap.add_argument("--vr", default="centralvr",
                    choices=["none", "centralvr", "svrg", "saga"])
    ap.add_argument("--vr-table-size", type=int, default=8)
    ap.add_argument("--local-epoch", type=int, default=1)
    ap.add_argument("--workers", default="none",
                    choices=["none", "data", "pod"],
                    help="host runtime: which mesh axes carry worker copies")
    ap.add_argument("--dp-replicated", action="store_true")
    ap.add_argument("--mesh", default="test", choices=["test", "production",
                                                       "production-multipod"])
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="scan runtime: epochs; host runtime: steps")
    ap.add_argument("--resume", action="store_true",
                    help="scan runtime: continue from --checkpoint")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs", default="", metavar="PATH",
                    help="record structured run telemetry (epoch spans + "
                         "structured epoch rows) to this JSONL file")
    ap.add_argument("--profile", default="", metavar="DIR",
                    help="capture a jax.profiler trace of the run into "
                         "this directory")
    from repro.launch.compile_cache import add_compile_cache_arg
    add_compile_cache_arg(ap)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    if args.runtime == "scan" and args.backend == "spmd":
        # must run before the first jax operation (core/spmd.py)
        from repro.core import spmd
        spmd.force_host_devices(args.num_workers)
    from repro.launch.compile_cache import enable_compile_cache
    enable_compile_cache(args.compile_cache)
    from repro import obs
    from repro.config import TrainConfig, get_arch
    from repro.launch import mesh as meshlib

    if args.obs:
        obs.enable(args.obs)
    if args.profile:
        import jax
        jax.profiler.start_trace(args.profile)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        microbatch=args.microbatch, learning_rate=args.lr,
        optimizer=args.optimizer, vr=args.vr,
        vr_table_size=args.vr_table_size, local_epoch=args.local_epoch,
        dp_replicated=args.dp_replicated, seed=args.seed)

    if args.runtime == "host":
        if args.backend != "vmap":
            raise SystemExit("--runtime host is vmap-only; the spmd "
                             "backend lives in the epoch-scan runtime")
        if args.resume:
            raise SystemExit("--resume is an epoch-scan-runtime feature "
                             "(the host reference loop restarts from step "
                             "0 and would overwrite the checkpoint)")
        from repro.train import host_loop
        if args.mesh == "production":
            mesh = meshlib.make_production_mesh()
        elif args.mesh == "production-multipod":
            mesh = meshlib.make_production_mesh(multi_pod=True)
        else:
            mesh = meshlib.make_test_mesh()
        res = host_loop.run_training(
            cfg, tcfg, steps=args.steps, mesh=mesh,
            vr_workers=args.workers,
            workers=args.num_workers if args.num_workers > 1 else None,
            checkpoint_path=args.checkpoint or None,
            checkpoint_every=args.checkpoint_every)
    else:
        if args.mesh != "test" or args.workers != "none":
            raise SystemExit(
                "--mesh production*/--workers data|pod drive the mesh-"
                "derived worker layout of the per-step reference loop; "
                "pass --runtime host for them (the scan runtime takes "
                "--num-workers and --backend instead)")
        from repro.train import loop
        mesh = (meshlib.make_worker_mesh(args.num_workers)
                if args.backend == "spmd" else None)
        res = loop.run_training(
            cfg, tcfg, epochs=args.epochs or None,
            steps=None if args.epochs else args.steps,
            workers=args.num_workers, backend=args.backend, mesh=mesh,
            checkpoint_path=args.checkpoint or None,
            checkpoint_every=args.checkpoint_every, resume=args.resume)
    if args.profile:
        import jax
        jax.profiler.stop_trace()
        print(f"wrote profiler trace to {args.profile}")
    if args.obs:
        obs.disable()
        print(f"wrote telemetry to {args.obs}")
    print(f"done: {res.steps} steps in {res.wall_time:.1f}s; "
          f"final train loss {res.losses[-1]:.4f}; "
          f"eval loss {res.final_eval_loss:.4f}")


if __name__ == "__main__":
    main()
