"""repro: a multi-pod JAX training/inference framework implementing
"Efficient Distributed SGD with Variance Reduction" (De & Goldstein, 2015)
as a first-class distributed-optimizer feature."""
__version__ = "1.0.0"
