"""InternVL2-26B [arXiv:2404.16821] — VLM: InternViT-6B frontend (STUB) +
InternLM2-20B language decoder (48L, d=6144, 48Q/8KV GQA, d_ff=16384).

Per the assignment carve-out, the vision encoder is a stub:
``input_specs()``/the data pipeline provide pre-computed patch embeddings of
shape (batch, frontend_tokens, d_model); the decoder we implement consumes
them interleaved before the text tokens.
"""
from repro.config import ModelConfig, register

INTERNVL2_26B = register(ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    qkv_bias=False,
    rope_theta=1_000_000.0,
    norm_type="rmsnorm",
    mlp_type="swiglu",
    frontend="vision",
    frontend_tokens=256,   # 256 visual tokens per image tile (InternVL2 pixel-shuffle)
))
